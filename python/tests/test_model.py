"""L2 correctness: window estimator graph vs oracle and vs direct numpy.

Verifies the stratified estimate τ̂ and variance V̂ar(τ̂) (paper Eqs
3.2–3.4 inputs) both against ref.py and against an independent, de-novo
numpy implementation of the stratified estimator formulas.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import window_estimate_ref

jax.config.update("jax_enable_x64", True)


def make_window(rng, chunks=8, chunk=32, strata=3, dtype=np.float64):
    """Random packed window: each chunk belongs to one stratum."""
    values = rng.normal(loc=5.0, scale=2.0, size=(chunks, chunk)).astype(dtype)
    mask = np.zeros((chunks, chunk), dtype)
    onehot = np.zeros((chunks, strata), dtype)
    for c in range(chunks):
        n = rng.integers(1, chunk + 1)
        mask[c, :n] = 1.0
        onehot[c, rng.integers(0, strata)] = 1.0
    b = onehot.T @ mask.sum(axis=1)  # sampled per stratum
    population = (b * rng.uniform(1.0, 4.0, size=strata)).astype(dtype)
    return tuple(jnp.asarray(x) for x in (values, mask, onehot, population))


def numpy_stratified_estimate(values, mask, onehot, population):
    """Independent numpy implementation of the Eq 3.4 estimator."""
    values, mask, onehot, population = map(np.asarray, (values, mask, onehot, population))
    strata = onehot.shape[1]
    tau, var = 0.0, 0.0
    stats = np.zeros((strata, 3))
    for i in range(strata):
        rows = onehot[:, i] > 0
        v = values[rows][mask[rows] > 0]
        b = len(v)
        stats[i] = (b, v.sum(), (v**2).sum())
        if b == 0:
            continue
        B = population[i]
        tau += B / b * v.sum()
        if b > 1:
            var += B * (B - b) * v.var(ddof=1) / b
    return tau, var, stats


class TestWindowEstimate:
    def test_matches_ref(self):
        rng = np.random.default_rng(7)
        args = make_window(rng)
        tau, var, stats = model.window_estimate_graph(*args)
        rtau, rvar, rstats = window_estimate_ref(*args)
        assert_allclose(float(tau), float(rtau), rtol=1e-10)
        assert_allclose(float(var), float(rvar), rtol=1e-10)
        assert_allclose(np.asarray(stats), np.asarray(rstats), rtol=1e-10)

    def test_matches_independent_numpy(self):
        rng = np.random.default_rng(8)
        args = make_window(rng, chunks=16, chunk=64, strata=4)
        tau, var, stats = model.window_estimate_graph(*args)
        ntau, nvar, nstats = numpy_stratified_estimate(*args)
        assert_allclose(float(tau), ntau, rtol=1e-8)
        assert_allclose(float(var), nvar, rtol=1e-8)
        assert_allclose(np.asarray(stats), nstats, rtol=1e-8)

    def test_census_stratum_has_zero_variance(self):
        """b_i == B_i (full census of a stratum) → FPC kills its variance."""
        rng = np.random.default_rng(9)
        values, mask, onehot, _ = make_window(rng, strata=1)
        b = float(np.asarray(mask).sum())
        population = jnp.asarray([b])
        tau, var, _ = model.window_estimate_graph(values, mask, onehot, population)
        v = np.asarray(values)[np.asarray(mask) > 0]
        assert_allclose(float(tau), v.sum(), rtol=1e-9)
        assert_allclose(float(var), 0.0, atol=1e-6)

    def test_empty_stratum_contributes_nothing(self):
        values = jnp.ones((2, 8), jnp.float64)
        mask = jnp.ones((2, 8), jnp.float64)
        onehot = jnp.asarray([[1.0, 0.0], [1.0, 0.0]])  # stratum 1 unobserved
        population = jnp.asarray([16.0, 1000.0])
        tau, var, stats = model.window_estimate_graph(values, mask, onehot, population)
        assert_allclose(float(tau), 16.0, rtol=1e-9)
        assert_allclose(float(var), 0.0, atol=1e-9)
        assert_allclose(np.asarray(stats)[1], 0.0)

    def test_scaling_estimate_unbiasedness(self):
        """Monte-Carlo: E[τ̂] ≈ true total under random subsampling."""
        rng = np.random.default_rng(10)
        pop = rng.normal(10.0, 3.0, size=512)
        true_total = pop.sum()
        est = []
        for _ in range(200):
            idx = rng.choice(512, size=128, replace=False)
            values = np.zeros((1, 128))
            values[0] = pop[idx]
            mask = np.ones((1, 128))
            onehot = np.ones((1, 1))
            tau, _, _ = model.window_estimate_graph(
                jnp.asarray(values), jnp.asarray(mask), jnp.asarray(onehot),
                jnp.asarray([512.0]))
            est.append(float(tau))
        assert abs(np.mean(est) - true_total) < 0.05 * abs(true_total)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    chunks=st.integers(1, 12),
    chunk=st.sampled_from([8, 32, 128]),
    strata=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_window_estimate_property(chunks, chunk, strata, seed):
    """Graph == ref == independent numpy across random configurations."""
    rng = np.random.default_rng(seed)
    args = make_window(rng, chunks, chunk, strata)
    tau, var, stats = model.window_estimate_graph(*args)
    ntau, nvar, nstats = numpy_stratified_estimate(*args)
    assert_allclose(float(tau), ntau, rtol=1e-7, atol=1e-7)
    assert_allclose(float(var), nvar, rtol=1e-7, atol=1e-4)
    assert_allclose(np.asarray(stats), nstats, rtol=1e-7, atol=1e-7)
    assert float(var) >= -1e-6  # variance estimate is non-negative
