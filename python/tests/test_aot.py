"""AOT path: lowering produces loadable HLO text + a consistent manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model


class TestLowering:
    def test_chunk_moments_hlo_text(self):
        text = aot.lower_chunk_moments(4, 128)
        assert "HloModule" in text
        assert "ENTRY" in text
        # return_tuple=True → root is a tuple
        assert "tuple(" in text or "(f32[" in text

    def test_window_estimate_hlo_text(self):
        text = aot.lower_window_estimate(4, 128, 8)
        assert "HloModule" in text
        assert "f32[4,128]" in text

    def test_build_all_manifest(self, tmp_path):
        rows = aot.build_all(str(tmp_path))
        manifest = os.path.join(str(tmp_path), "manifest.tsv")
        assert os.path.exists(manifest)
        with open(manifest) as f:
            lines = [l for l in f.read().splitlines() if l and not l.startswith("#")]
        assert len(lines) == len(rows)
        for line in lines:
            cols = line.split("\t")
            assert len(cols) == 9
            assert os.path.exists(os.path.join(str(tmp_path), cols[2]))
            assert int(cols[3]) > 0 and int(cols[4]) % 128 == 0
            assert int(cols[8]) >= 0

    def test_variant_count_matches_spec(self, tmp_path):
        rows = aot.build_all(str(tmp_path))
        kinds = [r[0] for r in rows]
        assert kinds.count("chunk_moments") == len(aot.CHUNK_MOMENTS_VARIANTS)
        assert kinds.count("window_estimate") == len(aot.WINDOW_ESTIMATE_VARIANTS)


class TestRoundTrip:
    """Execute the lowered module via jax's own CPU client and compare
    against direct graph evaluation — catches lowering-induced numeric
    drift before the rust side ever sees the artifact."""

    def test_chunk_moments_roundtrip(self):
        from jax._src.lib import xla_client as xc

        chunks, chunk = 4, 128
        spec = jax.ShapeDtypeStruct((chunks, chunk), jnp.float32)
        lowered = jax.jit(model.chunk_moments_graph).lower(spec, spec)
        compiled = lowered.compile()
        rng = np.random.default_rng(3)
        v = rng.normal(size=(chunks, chunk)).astype(np.float32)
        m = (rng.uniform(size=(chunks, chunk)) < 0.6).astype(np.float32)
        (got,) = compiled(jnp.asarray(v), jnp.asarray(m))
        (want,) = model.chunk_moments_graph(jnp.asarray(v), jnp.asarray(m))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
