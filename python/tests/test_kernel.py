"""L1 correctness: Pallas chunk_moments vs the pure-jnp oracle.

This is the core correctness signal for the kernel layer: hypothesis
sweeps shapes and dtypes, numpy assert_allclose compares against ref.py.
"""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels.ref import chunk_moments_ref
from compile.kernels.stratified_agg import MOMENTS, chunk_moments

jax.config.update("jax_enable_x64", True)

TOL = {np.float32: dict(rtol=1e-5, atol=1e-5), np.float64: dict(rtol=1e-12, atol=1e-12)}


def random_inputs(rng, chunks, chunk, dtype, mask_p=0.7):
    values = rng.normal(size=(chunks, chunk)).astype(dtype)
    mask = (rng.uniform(size=(chunks, chunk)) < mask_p).astype(dtype)
    return jnp.asarray(values), jnp.asarray(mask)


class TestChunkMomentsBasic:
    def test_matches_ref_small(self):
        rng = np.random.default_rng(0)
        v, m = random_inputs(rng, 4, 16, np.float32)
        got = chunk_moments(v, m)
        want = chunk_moments_ref(v, m)
        assert_allclose(np.asarray(got), np.asarray(want), **TOL[np.float32])

    def test_output_shape_and_order(self):
        rng = np.random.default_rng(1)
        v, m = random_inputs(rng, 8, 128, np.float32)
        out = np.asarray(chunk_moments(v, m))
        assert out.shape == (8, len(MOMENTS))
        # count column is integral
        assert_allclose(out[:, 0], np.asarray(m).sum(axis=-1), rtol=0, atol=0)

    def test_all_masked_chunk(self):
        """A fully padded chunk: count 0, sums 0, min=+big, max=-big."""
        v = jnp.ones((2, 32), jnp.float32)
        m = jnp.zeros((2, 32), jnp.float32)
        out = np.asarray(chunk_moments(v, m))
        assert_allclose(out[:, :3], 0.0)
        assert (out[:, 3] > 1e30).all()
        assert (out[:, 4] < -1e30).all()

    def test_full_mask_equals_plain_reduction(self):
        rng = np.random.default_rng(2)
        v = rng.normal(size=(3, 64)).astype(np.float32)
        m = np.ones_like(v)
        out = np.asarray(chunk_moments(jnp.asarray(v), jnp.asarray(m)))
        assert_allclose(out[:, 1], v.sum(axis=-1), rtol=1e-5, atol=1e-5)
        assert_allclose(out[:, 2], (v * v).sum(axis=-1), rtol=1e-5, atol=1e-5)
        assert_allclose(out[:, 3], v.min(axis=-1), rtol=1e-6)
        assert_allclose(out[:, 4], v.max(axis=-1), rtol=1e-6)

    def test_single_item_chunk(self):
        v = jnp.zeros((1, 8), jnp.float32).at[0, 3].set(7.5)
        m = jnp.zeros((1, 8), jnp.float32).at[0, 3].set(1.0)
        out = np.asarray(chunk_moments(v, m))[0]
        assert_allclose(out, [1.0, 7.5, 56.25, 7.5, 7.5], rtol=1e-6)

    def test_rank_mismatch_raises(self):
        with pytest.raises(ValueError):
            chunk_moments(jnp.zeros((4,)), jnp.zeros((4,)))
        with pytest.raises(ValueError):
            chunk_moments(jnp.zeros((2, 4)), jnp.zeros((2, 8)))


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    chunks=st.integers(1, 16),
    chunk_log2=st.integers(1, 8),
    dtype=st.sampled_from([np.float32, np.float64]),
    mask_p=st.floats(0.0, 1.0),
    rounds=st.sampled_from([0, 3, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_moments_matches_ref_property(chunks, chunk_log2, dtype, mask_p, rounds, seed):
    """Property sweep: shapes/dtypes/mask densities/map rounds vs oracle."""
    rng = np.random.default_rng(seed)
    v, m = random_inputs(rng, chunks, 2**chunk_log2, dtype, mask_p)
    got = np.asarray(chunk_moments(v, m, rounds=rounds))
    want = np.asarray(chunk_moments_ref(v, m, rounds=rounds))
    tol = TOL[dtype] if rounds == 0 else dict(rtol=1e-4, atol=1e-4)
    assert_allclose(got, want, **tol)


def test_map_transform_rounds_zero_is_identity():
    v = jnp.asarray(np.linspace(-5, 5, 64, dtype=np.float32)).reshape(1, 64)
    m = jnp.ones_like(v)
    out0 = np.asarray(chunk_moments(v, m, rounds=0))
    outr = np.asarray(chunk_moments(v, m, rounds=8))
    ref0 = np.asarray(chunk_moments_ref(v, m, rounds=0))
    assert_allclose(out0, ref0, rtol=1e-6)
    assert not np.allclose(out0, outr), "rounds must change the output"


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    arr=hnp.arrays(
        np.float32,
        hnp.array_shapes(min_dims=2, max_dims=2, min_side=1, max_side=64),
        elements=st.floats(-1e4, 1e4, width=32),
    ),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_moments_arbitrary_values(arr, seed):
    """Extreme/adversarial values (hypothesis-generated) still match ref."""
    rng = np.random.default_rng(seed)
    m = (rng.uniform(size=arr.shape) < 0.5).astype(np.float32)
    got = np.asarray(chunk_moments(jnp.asarray(arr), jnp.asarray(m)))
    want = np.asarray(chunk_moments_ref(jnp.asarray(arr), jnp.asarray(m)))
    assert_allclose(got, want, rtol=1e-4, atol=1e-2)
