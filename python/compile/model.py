"""Layer-2 JAX compute graphs for IncApprox (build-time only).

Two graphs are AOT-lowered to HLO text (see ``aot.py``) and executed by the
rust coordinator through PJRT:

* :func:`chunk_moments_graph` — the incremental hot path. The coordinator
  packs only the *fresh* (non-memoized) chunks of the window's biased
  sample and gets back the per-chunk moments it will memoize.
* :func:`window_estimate_graph` — the full-window estimator used by the
  approx-only / native baselines and for end-to-end verification: chunk
  moments → per-stratum totals → stratified total estimate τ̂ and its
  estimated variance V̂ar(τ̂) (paper Eq 3.4). The t-score multiplication of
  Eq 3.2 happens in rust (`stats::tdist`), since the degrees of freedom
  depend on runtime stratum occupancy.

Everything here funnels through the L1 Pallas kernel so the whole model
lowers into one HLO module per shape variant.
"""

import jax.numpy as jnp

from .kernels.stratified_agg import chunk_moments


def chunk_moments_graph(values, mask, *, rounds=0):
    """[CHUNKS, CHUNK] x2 → [CHUNKS, 5] per-chunk map+moments (L1)."""
    return (chunk_moments(values, mask, rounds=rounds),)


def stratum_stats(moments, stratum_onehot):
    """Combine per-chunk moments into per-stratum (b_i, Σv, Σv²).

    ``stratum_onehot`` is ``[CHUNKS, S]`` with exactly one 1.0 per valid
    chunk row (all-zero rows denote padding chunks and drop out of the
    matmul). The contraction is a single [S, CHUNKS] @ [CHUNKS, 3] matmul —
    on TPU this is MXU work; on the CPU PJRT client it fuses into the same
    executable as the kernel.
    """
    return stratum_onehot.T @ moments[:, :3]


def window_estimate_graph(values, mask, stratum_onehot, population):
    """Full-window stratified estimate.

    Args:
      values/mask: ``[CHUNKS, CHUNK]`` packed biased sample.
      stratum_onehot: ``[CHUNKS, S]`` chunk→stratum membership.
      population: ``[S]`` per-stratum window population B_i.

    Returns:
      ``(tau_hat, var_hat, stats)`` — scalar total estimate, scalar
      estimated variance (Eq 3.4), and ``[S, 3]`` per-stratum
      (b_i, Σv, Σv²) for the rust-side error bound (Eqs 3.2–3.3).
    """
    moments = chunk_moments(values, mask)
    stats = stratum_stats(moments, stratum_onehot)
    b = stats[:, 0]
    s = stats[:, 1]
    ss = stats[:, 2]
    b_safe = jnp.maximum(b, 1.0)
    seen = b > 0
    s2 = jnp.where(b > 1, (ss - s * s / b_safe) / jnp.maximum(b - 1.0, 1.0), 0.0)
    tau = jnp.sum(jnp.where(seen, population / b_safe * s, 0.0))
    var = jnp.sum(
        jnp.where(seen, population * (population - b) * s2 / b_safe, 0.0)
    )
    return tau, var, stats
