"""Chunked masked moment reduction — the IncApprox compute hot-spot (L1).

The unit of incrementality in our reproduction is a *chunk*: a fixed-size
row of sampled values belonging to a single stratum (the "map task" of the
paper's Figure 3.1). For every window, the rust coordinator packs the
fresh (non-memoized) chunks of the biased sample into a ``[CHUNKS, CHUNK]``
matrix plus a 0/1 validity mask, and executes this kernel once through the
AOT-compiled PJRT executable. The per-chunk moments it returns are the
memoizable sub-computation results that change propagation combines with
the reused ones.

Kernel shape
------------
    values : f32[CHUNKS, CHUNK]   sampled item values, padded with zeros
    mask   : f32[CHUNKS, CHUNK]   1.0 where the slot holds a real item
    out    : f32[CHUNKS, 5]       per chunk: count, sum, sum-of-squares,
                                  min (+inf if empty), max (-inf if empty)

TPU structure (see DESIGN.md §Hardware-Adaptation): the grid iterates over
chunk rows; each step streams one ``[1, CHUNK]`` tile of values and mask
HBM→VMEM (``CHUNK`` is a multiple of the 128-lane VPU width) and reduces
all five moments in a single fused pass, so every element is touched
exactly once — the kernel is bandwidth-bound and already at its roofline
structure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Order of the per-chunk statistics in the output's last axis.
MOMENTS = ("count", "sum", "sumsq", "min", "max")


def map_transform(v, rounds: int):
    """The user-defined map stage: `rounds` iterations of v += 0.25·sin v.

    Streaming queries rarely aggregate raw bytes — they parse, featurize,
    or score each record first. This iterated nonlinear map is that
    per-item work knob: rounds=0 is a pass-through (pure aggregation),
    larger values emulate an expensive map task. Implemented identically
    in rust (`job::map_fn`) so native and PJRT backends agree.
    """
    if rounds == 0:
        return v
    return jax.lax.fori_loop(0, rounds, lambda _, x: x + 0.25 * jnp.sin(x), v)


def _moments_kernel(values_ref, mask_ref, out_ref, *, rounds: int):
    """One grid step: map + reduce a single [1, CHUNK] chunk tile."""
    v = map_transform(values_ref[...], rounds)
    m = mask_ref[...]
    vm = v * m
    cnt = jnp.sum(m, axis=-1)
    s = jnp.sum(vm, axis=-1)
    # (v*m)*v rather than v*v*m: reuses the vm product already in registers.
    ss = jnp.sum(vm * v, axis=-1)
    big = jnp.asarray(jnp.finfo(v.dtype).max, v.dtype)
    mn = jnp.min(jnp.where(m > 0, v, big), axis=-1)
    mx = jnp.max(jnp.where(m > 0, v, -big), axis=-1)
    out_ref[...] = jnp.stack([cnt, s, ss, mn, mx], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret", "rounds"))
def chunk_moments(values, mask, *, interpret=True, rounds=0):
    """Per-chunk masked map+moments via a Pallas row-tile reduction.

    Args:
      values: ``[CHUNKS, CHUNK]`` float array of sampled values.
      mask: same shape; 1.0 marks valid slots, 0.0 padding.
      interpret: must stay True for CPU-PJRT execution (default).
      rounds: per-item :func:`map_transform` iterations before reducing.

    Returns:
      ``[CHUNKS, 5]`` array ordered per :data:`MOMENTS`.
    """
    if values.ndim != 2:
        raise ValueError(f"values must be rank 2, got {values.shape}")
    if values.shape != mask.shape:
        raise ValueError(f"shape mismatch {values.shape} vs {mask.shape}")
    chunks, chunk = values.shape
    return pl.pallas_call(
        functools.partial(_moments_kernel, rounds=rounds),
        grid=(chunks,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, len(MOMENTS)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((chunks, len(MOMENTS)), values.dtype),
        interpret=interpret,
    )(values, mask)
