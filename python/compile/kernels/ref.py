"""Pure-jnp oracles for the L1 kernels and the L2 estimator graph.

These are the correctness ground truth: no Pallas, no tiling — just the
textbook formulas. pytest (with hypothesis shape/dtype sweeps) asserts the
kernels and the lowered model match these within float tolerance.
"""

import jax.numpy as jnp


def map_transform_ref(values, rounds):
    """Reference for the iterated per-item map (plain python loop)."""
    for _ in range(rounds):
        values = values + 0.25 * jnp.sin(values)
    return values


def chunk_moments_ref(values, mask, rounds=0):
    """Reference for kernels.stratified_agg.chunk_moments."""
    values = map_transform_ref(values, rounds)
    vm = values * mask
    cnt = jnp.sum(mask, axis=-1)
    s = jnp.sum(vm, axis=-1)
    ss = jnp.sum(vm * values, axis=-1)
    big = jnp.asarray(jnp.finfo(values.dtype).max, values.dtype)
    mn = jnp.min(jnp.where(mask > 0, values, big), axis=-1)
    mx = jnp.max(jnp.where(mask > 0, values, -big), axis=-1)
    return jnp.stack([cnt, s, ss, mn, mx], axis=-1)


def stratum_stats_ref(moments, stratum_onehot):
    """Reference per-stratum (b, sum, sumsq) from per-chunk moments.

    Args:
      moments: ``[CHUNKS, 5]`` per-chunk moments.
      stratum_onehot: ``[CHUNKS, S]`` one-hot stratum membership per chunk.

    Returns:
      ``[S, 3]``: per stratum sample count b_i, Σv, Σv².
    """
    return stratum_onehot.T @ moments[:, :3]


def window_estimate_ref(values, mask, stratum_onehot, population):
    """Reference for the L2 window estimator (paper Eqs 3.2–3.4 inputs).

    Returns ``(tau_hat, var_hat, stats)`` where ``stats`` is ``[S, 3]``
    (b_i, Σv, Σv²), ``tau_hat`` is the stratified total estimate and
    ``var_hat`` the estimated variance of Eq 3.4. Strata with b_i = 0
    contribute nothing (their population is unobserved this window).
    """
    stats = stratum_stats_ref(chunk_moments_ref(values, mask), stratum_onehot)
    b = stats[:, 0]
    s = stats[:, 1]
    ss = stats[:, 2]
    b_safe = jnp.maximum(b, 1.0)
    seen = b > 0
    # Unbiased per-stratum sample variance s_i².
    s2 = jnp.where(b > 1, (ss - s * s / b_safe) / jnp.maximum(b - 1.0, 1.0), 0.0)
    tau = jnp.sum(jnp.where(seen, population / b_safe * s, 0.0))
    var = jnp.sum(
        jnp.where(seen, population * (population - b) * s2 / b_safe, 0.0)
    )
    return tau, var, stats
