"""Layer-1 Pallas kernels for IncApprox.

Every kernel here is lowered with ``interpret=True``: the rust request path
executes them through the CPU PJRT client, which cannot run Mosaic
custom-calls. The kernels are still *structured* for TPU execution (row
tiles sized in multiples of 128 lanes, single-pass fused moment
accumulation) — see DESIGN.md §7.
"""

from .stratified_agg import MOMENTS, chunk_moments  # noqa: F401
