"""AOT lowering: JAX (L2+L1) → HLO *text* artifacts for the rust runtime.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --outdir ../artifacts

Emits one ``<name>.hlo.txt`` per shape variant plus ``manifest.tsv``, which
the rust ``runtime::ArtifactRegistry`` reads to compile and cache PJRT
executables. Python is never invoked again after this step.

Interchange format is HLO **text**, not ``lowered.compile().serialize()``:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md). We lower stablehlo → XlaComputation with
``return_tuple=True`` and the rust side unwraps the tuple.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: (chunks, chunk, rounds) variants for the incremental chunk-moments
#: executable. chunk is a multiple of 128 (VPU lane width); variants trade
#: padding waste against per-call batch capacity; rounds is the per-item
#: map weight (0 = pure aggregation, 16 = heavy map stage).
CHUNK_MOMENTS_VARIANTS = [
    (64, 128, 0),
    (256, 128, 0),
    (64, 256, 0),
    (64, 128, 16),
    (256, 128, 16),
    (64, 256, 16),
]

#: (chunks, chunk, strata) variants for the full-window estimator.
WINDOW_ESTIMATE_VARIANTS = [(64, 128, 8), (256, 128, 8)]

DTYPE = jnp.float32


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_chunk_moments(chunks: int, chunk: int, rounds: int = 0) -> str:
    spec = jax.ShapeDtypeStruct((chunks, chunk), DTYPE)
    fn = functools.partial(model.chunk_moments_graph, rounds=rounds)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_window_estimate(chunks: int, chunk: int, strata: int) -> str:
    vspec = jax.ShapeDtypeStruct((chunks, chunk), DTYPE)
    ospec = jax.ShapeDtypeStruct((chunks, strata), DTYPE)
    pspec = jax.ShapeDtypeStruct((strata,), DTYPE)
    return to_hlo_text(
        jax.jit(model.window_estimate_graph).lower(vspec, vspec, ospec, pspec)
    )


def build_all(outdir: str) -> list[tuple]:
    """Lower every variant into ``outdir``; return manifest rows."""
    os.makedirs(outdir, exist_ok=True)
    rows = []
    for chunks, chunk, rounds in CHUNK_MOMENTS_VARIANTS:
        name = f"chunk_moments_{chunks}x{chunk}_r{rounds}"
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(lower_chunk_moments(chunks, chunk, rounds))
        rows.append(
            ("chunk_moments", name, f"{name}.hlo.txt", chunks, chunk, 0, "f32", 1, rounds)
        )
    for chunks, chunk, strata in WINDOW_ESTIMATE_VARIANTS:
        name = f"window_estimate_{chunks}x{chunk}x{strata}"
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(lower_window_estimate(chunks, chunk, strata))
        rows.append(
            ("window_estimate", name, f"{name}.hlo.txt", chunks, chunk, strata, "f32", 3, 0)
        )
    manifest = os.path.join(outdir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# kind\tname\tfile\tchunks\tchunk\tstrata\tdtype\tn_outputs\trounds\n")
        for row in rows:
            f.write("\t".join(str(c) for c in row) + "\n")
    return rows


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--outdir", default="../artifacts")
    args = parser.parse_args()
    rows = build_all(args.outdir)
    for row in rows:
        print(f"lowered {row[1]}")
    print(f"wrote {len(rows)} artifacts + manifest.tsv to {args.outdir}")


if __name__ == "__main__":
    main()
