//! Chunk execution back-ends and the worker pool.
//!
//! The coordinator executes each window's *fresh* chunks through a
//! [`ChunkBackend`]: [`NativeBackend`] computes moments in rust (used by
//! the exact baseline and as the PJRT cross-check); the PJRT backend in
//! `runtime/` batches all fresh chunks into one AOT-executable call.
//! [`WorkerPool`] parallelizes the native path across threads — the
//! "distributed data-parallel job" of §2.3.1, scaled to one process.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::job::chunk::Chunk;
use crate::job::moments::Moments;

/// Computes moments for a batch of chunks.
pub trait ChunkBackend {
    /// One result per chunk, same order.
    fn compute(&self, chunks: &[&Chunk]) -> Result<Vec<Moments>>;

    /// Human-readable backend name (reports/benches).
    fn name(&self) -> &'static str;
}

/// Scalar in-process backend.
#[derive(Debug, Default)]
pub struct NativeBackend {
    /// Per-item map rounds applied before reducing.
    pub rounds: u32,
}

impl NativeBackend {
    /// Backend with the given map weight.
    pub fn new(rounds: u32) -> Self {
        NativeBackend { rounds }
    }
}

impl ChunkBackend for NativeBackend {
    fn compute(&self, chunks: &[&Chunk]) -> Result<Vec<Moments>> {
        Ok(chunks
            .iter()
            .map(|c| Moments::fold_values_mapped(c.values(), self.rounds))
            .collect())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

enum Job {
    /// A contiguous batch of chunks starting at `base` in the caller's
    /// order. Batching (vs one job per chunk) keeps channel and mutex
    /// traffic at O(workers), not O(chunks) — see EXPERIMENTS.md §Perf.
    /// Chunks carry their items behind `Arc`, so building a batch bumps
    /// refcounts instead of copying records.
    Run { base: usize, chunks: Vec<Chunk> },
    Shutdown,
}

/// Fixed-size worker pool computing chunk moments in parallel.
pub struct WorkerPool {
    workers: Vec<JoinHandle<()>>,
    tx: mpsc::Sender<Job>,
    rx_results: mpsc::Receiver<(usize, Vec<Moments>)>,
    tx_results: mpsc::Sender<(usize, Vec<Moments>)>,
}

impl WorkerPool {
    /// Spawn `n` workers with no map stage.
    pub fn new(n: usize) -> Self {
        Self::with_rounds(n, 0)
    }

    /// Spawn `n` workers applying `rounds` map iterations per item.
    pub fn with_rounds(n: usize, rounds: u32) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let (tx_results, rx_results) = mpsc::channel();
        let workers = (0..n)
            .map(|_| {
                let rx = rx.clone();
                let tx_results = tx_results.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        // A worker that panicked holding the guard poisons
                        // the receiver lock; the channel itself is still
                        // intact, so the surviving workers keep draining it.
                        let guard = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                        guard.recv()
                    };
                    match job {
                        Ok(Job::Run { base, chunks }) => {
                            let ms: Vec<Moments> = chunks
                                .iter()
                                .map(|c| Moments::fold_values_mapped(c.values(), rounds))
                                .collect();
                            if tx_results.send((base, ms)).is_err() {
                                break;
                            }
                        }
                        Ok(Job::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        WorkerPool { workers, tx, rx_results, tx_results }
    }

    /// Compute moments for all chunks in parallel; results in input order.
    pub fn compute(&self, chunks: &[&Chunk]) -> Result<Vec<Moments>> {
        let n = chunks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // One contiguous batch per worker (ceil split).
        let workers = self.workers.len();
        let batch_size = n.div_ceil(workers);
        let mut sent = 0usize;
        let mut base = 0usize;
        while base < n {
            let end = (base + batch_size).min(n);
            let batch: Vec<Chunk> =
                chunks[base..end].iter().map(|c| (*c).clone()).collect();
            self.tx
                .send(Job::Run { base, chunks: batch })
                .map_err(|_| Error::Job("worker pool shut down".into()))?;
            sent += 1;
            base = end;
        }
        let mut out = vec![Moments::EMPTY; n];
        for _ in 0..sent {
            let (base, ms) = self
                .rx_results
                .recv()
                .map_err(|_| Error::Job("worker died mid-job".into()))?;
            out[base..base + ms.len()].copy_from_slice(&ms);
        }
        Ok(out)
    }

    /// Number of worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

/// Run `tasks` concurrently on scoped threads and return their outputs in
/// input order.
///
/// Scoped threads (rather than the long-lived pool workers) let tasks
/// borrow per-window state — the biased sample, the previous-window item
/// lists, and the memo shards — without cloning it into `'static`
/// closures; the long-lived pool stays dedicated to chunk-moments
/// batches. A panic in any task is resumed on the caller. Zero or one
/// task runs inline with no thread spawned.
pub fn run_sharded<T: Send, F: FnOnce() -> T + Send>(tasks: Vec<F>) -> Vec<T> {
    if tasks.len() <= 1 {
        return tasks.into_iter().map(|f| f()).collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = tasks.into_iter().map(|f| scope.spawn(f)).collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .collect()
    })
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Keep tx_results alive until here so workers can flush.
        let _ = &self.tx_results;
    }
}

impl ChunkBackend for WorkerPool {
    fn compute(&self, chunks: &[&Chunk]) -> Result<Vec<Moments>> {
        WorkerPool::compute(self, chunks)
    }

    fn name(&self) -> &'static str {
        "worker-pool"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::chunk::chunk_stratum;
    use crate::workload::record::Record;

    fn chunks(n: u64) -> Vec<Chunk> {
        let items: Vec<Record> =
            (0..n).map(|i| Record::new(i, 0, 0, 0, (i % 13) as f64)).collect();
        chunk_stratum(0, &items, 32).unwrap()
    }

    #[test]
    fn native_backend_matches_direct() {
        let cs = chunks(500);
        let refs: Vec<&Chunk> = cs.iter().collect();
        let out = NativeBackend::default().compute(&refs).unwrap();
        for (c, m) in cs.iter().zip(&out) {
            assert_eq!(*m, Moments::from_records(c.items()));
        }
    }

    #[test]
    fn pool_matches_native_and_keeps_order() {
        let cs = chunks(2000);
        let refs: Vec<&Chunk> = cs.iter().collect();
        let pool = WorkerPool::new(4);
        let a = pool.compute(&refs).unwrap();
        let b = NativeBackend::default().compute(&refs).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn pool_handles_empty_batch() {
        let pool = WorkerPool::new(2);
        assert!(pool.compute(&[]).unwrap().is_empty());
    }

    #[test]
    fn run_sharded_preserves_order_and_runs_all() {
        let inputs: Vec<usize> = (0..13).collect();
        let tasks: Vec<_> =
            inputs.iter().map(|&i| move || i * i).collect();
        assert_eq!(
            run_sharded(tasks),
            inputs.iter().map(|&i| i * i).collect::<Vec<_>>()
        );
        // Degenerate sizes run inline.
        assert_eq!(run_sharded::<usize, fn() -> usize>(vec![]), Vec::<usize>::new());
        assert_eq!(run_sharded(vec![|| 7usize]), vec![7]);
    }

    #[test]
    fn run_sharded_tasks_can_borrow_caller_state() {
        let data: Vec<u64> = (0..1000).collect();
        let slices: Vec<&[u64]> = data.chunks(250).collect();
        let tasks: Vec<_> = slices
            .iter()
            .map(|s| move || s.iter().sum::<u64>())
            .collect();
        let partials = run_sharded(tasks);
        assert_eq!(partials.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn pool_reusable_across_batches() {
        let pool = WorkerPool::new(3);
        for round in 1..5u64 {
            let cs = chunks(round * 100);
            let refs: Vec<&Chunk> = cs.iter().collect();
            let out = pool.compute(&refs).unwrap();
            assert_eq!(out.len(), cs.len());
        }
        assert_eq!(pool.worker_count(), 3);
    }
}
