//! Content-defined chunking — stable partitioning of the biased sample
//! into memoizable map-task inputs.
//!
//! Position-based chunking (`items.chunks(64)`) would shift every boundary
//! when one item enters or leaves the window, invalidating every memo key
//! downstream. Instead, following Incoop's *stable partitioning*, chunk
//! boundaries are determined by item **content**: within a stratum, items
//! are ordered by id and a boundary is placed after item `i` when
//! `mix64(id_i) % target == 0` (expected chunk length = `target`), with a
//! hard cap at `4 × target` to bound the PJRT row width. Overlapping
//! windows therefore produce byte-identical chunks — identical memo keys —
//! for all unchanged runs of items.
//!
//! Chunks carry their items as a struct-of-arrays [`ColumnarBatch`]
//! behind `Arc` column buffers, so cloning a chunk — the executor's
//! per-worker batches, the coordinator's per-stratum chunk cache — never
//! copies records, and the hot kernels (moment fold, chunk hash, sketch
//! feed) iterate dense column slices. The content hash is computed by
//! [`chunk_hash_columns`], which issues the exact same `StableHasher`
//! write sequence as the retained row-path reference
//! [`chunk_hash_records`] — byte-output-identical, pinned by the
//! `stable_hasher_golden_vectors` test and the kernel equivalence gate.
//! [`chunk_stratum_cached`] goes further: given the previous window's
//! chunk sequence, runs whose records are unchanged reuse the previous
//! `Chunk` outright (no re-hash, no allocation), making full-path
//! re-chunking O(changed runs) instead of O(sample).

use crate::columnar::ColumnarBatch;
use crate::error::{Error, Result};
use crate::util::hash::{mix64, FastMap, StableHasher};
use crate::workload::record::{Record, StratumId};

/// One map-task input: a stable run of sampled items from one stratum.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Stratum all items belong to.
    pub stratum: StratumId,
    /// Items in the caller's (bias/window) order, stored columnar —
    /// shared `Arc` columns, so cloning a chunk is O(1).
    columns: ColumnarBatch,
    /// Stable content hash (ids + value bits) — the memo key.
    pub hash: u64,
}

/// Columnar chunk-hash kernel: digests `stratum`, then per element
/// `id_i` and `value_i` from two dense slices — the same `StableHasher`
/// write sequence as [`chunk_hash_records`], so the output is
/// byte-identical to the row path (golden-pinned).
#[inline]
pub fn chunk_hash_columns(stratum: StratumId, ids: &[u64], values: &[f64]) -> u64 {
    let mut h = StableHasher::new();
    h.write_u64(stratum as u64);
    for (&id, &v) in ids.iter().zip(values) {
        h.write_u64(id);
        h.write_f64(v);
    }
    h.finish()
}

/// Retained row-path reference for the chunk hash: walks `&[Record]`
/// issuing per-record field writes. The kernel equivalence gate
/// (`tests/columnar_kernels.rs`) pins [`chunk_hash_columns`] bit-equal
/// to this on randomized batches.
#[inline]
pub fn chunk_hash_records(stratum: StratumId, items: &[Record]) -> u64 {
    let mut h = StableHasher::new();
    h.write_u64(stratum as u64);
    for r in items {
        h.write_u64(r.id);
        h.write_f64(r.value);
    }
    h.finish()
}

impl Chunk {
    fn from_run(stratum: StratumId, items: &[Record]) -> Self {
        let columns = ColumnarBatch::from_records(items);
        let hash = chunk_hash_columns(stratum, columns.ids(), columns.values());
        Chunk { stratum, columns, hash }
    }

    fn from_columns(stratum: StratumId, columns: ColumnarBatch) -> Self {
        let hash = chunk_hash_columns(stratum, columns.ids(), columns.values());
        Chunk { stratum, columns, hash }
    }

    /// The chunk's columnar interior.
    #[inline]
    pub fn columns(&self) -> &ColumnarBatch {
        &self.columns
    }

    /// Legacy row view (lazily transposed once, then cached).
    #[inline]
    pub fn items(&self) -> &[Record] {
        self.columns.rows()
    }

    /// Dense `id` column.
    #[inline]
    pub fn ids(&self) -> &[u64] {
        self.columns.ids()
    }

    /// Dense `value` column — the moments-fold input.
    #[inline]
    pub fn values(&self) -> &[f64] {
        self.columns.values()
    }

    /// Dense `timestamp` column.
    #[inline]
    pub fn timestamps(&self) -> &[u64] {
        self.columns.timestamps()
    }

    /// Item count.
    pub fn len(&self) -> usize {
        self.columns.len()
    }

    /// True when the chunk holds no items.
    pub fn is_empty(&self) -> bool {
        self.columns.is_empty()
    }
}

/// Is this item a chunk boundary for the given target size?
#[inline]
fn is_boundary(id: u64, target: usize) -> bool {
    mix64(id) % target as u64 == 0
}

/// Content-defined run bounds over an id sequence: half-open
/// `(start, end)` index pairs with expected length `target`, hard cap
/// `4 × target`. A `target` of 0 is a configuration error (`% 0` has no
/// meaning), reported as [`Error::Config`] rather than a panic so
/// callers surface it through the normal error channel.
fn run_bounds(ids: impl ExactSizeIterator<Item = u64>, target: usize) -> Result<Vec<(usize, usize)>> {
    if target == 0 {
        return Err(Error::Config("chunk target must be positive (got 0)".into()));
    }
    let cap = 4 * target;
    let len = ids.len();
    let mut bounds = Vec::new();
    let mut start = 0usize;
    for (i, id) in ids.enumerate() {
        if is_boundary(id, target) || i + 1 - start >= cap {
            bounds.push((start, i + 1));
            start = i + 1;
        }
    }
    if start < len {
        bounds.push((start, len));
    }
    Ok(bounds)
}

/// Split one stratum's sampled items into stable chunks with expected
/// length `target` (hard cap `4 × target`).
///
/// **Order-sensitive by design.** The caller passes items in *bias order*
/// (the previous window's memoized items in their stored order, fresh
/// items appended — see `sampling::biased`), or in window order for the
/// exact modes. Across adjacent windows that sequence only loses a prefix
/// (evicted old items) and gains a suffix (fresh items), which is exactly
/// the edit pattern content-defined boundaries absorb: all interior
/// chunks — and their memo keys — stay identical. Sorting here (e.g. by
/// id) would interleave fresh items between memoized ones and invalidate
/// every chunk.
///
/// Errors with [`Error::Config`] when `target == 0`.
pub fn chunk_stratum(stratum: StratumId, items: &[Record], target: usize) -> Result<Vec<Chunk>> {
    Ok(run_bounds(items.iter().map(|r| r.id), target)?
        .into_iter()
        .map(|(a, b)| Chunk::from_run(stratum, &items[a..b]))
        .collect())
}

/// [`chunk_stratum`] over an already-columnar run: bounds come from the
/// dense `id` column and each chunk's interior is a dense column
/// `memcpy` ([`ColumnarBatch::slice`]) — no row transpose anywhere.
/// Output is byte-identical to the row path.
pub fn chunk_stratum_columns(
    stratum: StratumId,
    cols: &ColumnarBatch,
    target: usize,
) -> Result<Vec<Chunk>> {
    Ok(run_bounds(cols.ids().iter().copied(), target)?
        .into_iter()
        .map(|(a, b)| Chunk::from_columns(stratum, cols.slice(a, b)))
        .collect())
}

/// [`chunk_stratum`] with reuse from `prev`, the previous window's chunk
/// sequence for this stratum: any run whose records are byte-equal to a
/// previous chunk reuses that `Chunk` — no re-hash, no record copy, just
/// an `Arc` clone. Output is **identical** to `chunk_stratum` (reuse is
/// verified by full record equality before a chunk is taken), so the
/// incremental and from-scratch plans stay byte-identical.
///
/// Returns the chunks plus the number of items that had to be re-hashed
/// (the O(delta) work metric; `prev = &[]` degrades to re-hashing
/// everything). Errors with [`Error::Config`] when `target == 0`.
pub fn chunk_stratum_cached(
    stratum: StratumId,
    items: &[Record],
    target: usize,
    prev: &[Chunk],
) -> Result<(Vec<Chunk>, usize)> {
    let bounds = run_bounds(items.iter().map(|r| r.id), target)?;
    if prev.is_empty() {
        let chunks = bounds
            .into_iter()
            .map(|(a, b)| Chunk::from_run(stratum, &items[a..b]))
            .collect();
        return Ok((chunks, items.len()));
    }
    let by_first = index_by_first_id(prev);
    let mut chunks = Vec::with_capacity(bounds.len());
    let mut rehashed_items = 0usize;
    for (a, b) in bounds {
        let run = &items[a..b];
        if let Some(&cached) = by_first.get(&run[0].id) {
            if cached.stratum == stratum && cached.columns.bit_eq_records(run) {
                chunks.push(cached.clone());
                continue;
            }
        }
        rehashed_items += run.len();
        chunks.push(Chunk::from_run(stratum, run));
    }
    Ok((chunks, rehashed_items))
}

/// [`chunk_stratum_cached`] over an already-columnar run. The reuse gate
/// runs as five dense column compares ([`ColumnarBatch::range_bit_eq`])
/// instead of a row walk; output is byte-identical to every other
/// chunking path.
pub fn chunk_stratum_cached_columns(
    stratum: StratumId,
    cols: &ColumnarBatch,
    target: usize,
    prev: &[Chunk],
) -> Result<(Vec<Chunk>, usize)> {
    let bounds = run_bounds(cols.ids().iter().copied(), target)?;
    if prev.is_empty() {
        let chunks = bounds
            .into_iter()
            .map(|(a, b)| Chunk::from_columns(stratum, cols.slice(a, b)))
            .collect();
        return Ok((chunks, cols.len()));
    }
    let by_first = index_by_first_id(prev);
    let mut chunks = Vec::with_capacity(bounds.len());
    let mut rehashed_items = 0usize;
    for (a, b) in bounds {
        if let Some(&cached) = by_first.get(&cols.ids()[a]) {
            if cached.stratum == stratum && cols.range_bit_eq(a, b, &cached.columns) {
                chunks.push(cached.clone());
                continue;
            }
        }
        rehashed_items += b - a;
        chunks.push(Chunk::from_columns(stratum, cols.slice(a, b)));
    }
    Ok((chunks, rehashed_items))
}

/// Index a previous chunk sequence by first item id (ids are unique
/// within a stratum's sample run, so first ids are unique across its
/// chunks).
fn index_by_first_id(prev: &[Chunk]) -> FastMap<u64, &Chunk> {
    let mut by_first: FastMap<u64, &Chunk> = FastMap::default();
    for c in prev {
        if let Some(&first) = c.ids().first() {
            by_first.insert(first, c);
        }
    }
    by_first
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn recs(ids: impl IntoIterator<Item = u64>) -> Vec<Record> {
        ids.into_iter().map(|i| Record::new(i, 0, 0, 0, i as f64 * 0.5)).collect()
    }

    #[test]
    fn all_items_kept_once() {
        let items = recs(0..1000);
        let chunks = chunk_stratum(0, &items, 64).unwrap();
        let total: usize = chunks.iter().map(Chunk::len).sum();
        assert_eq!(total, 1000);
        let mut ids: Vec<u64> = chunks.iter().flat_map(|c| c.ids().to_vec()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn expected_chunk_size_near_target() {
        let items = recs(0..100_000);
        let chunks = chunk_stratum(0, &items, 64).unwrap();
        let mean = 100_000.0 / chunks.len() as f64;
        assert!((mean - 64.0).abs() < 8.0, "mean chunk size {mean}");
    }

    #[test]
    fn size_cap_enforced() {
        let items = recs(0..50_000);
        let chunks = chunk_stratum(0, &items, 32).unwrap();
        assert!(chunks.iter().all(|c| c.len() <= 128));
    }

    #[test]
    fn stability_under_prefix_removal_and_suffix_insertion() {
        // The defining property: sliding the window (drop oldest, add
        // newest) must keep interior chunks identical.
        let w1 = recs(0..10_000);
        let w2 = recs(400..10_400); // slide by 400
        let c1 = chunk_stratum(0, &w1, 64).unwrap();
        let c2 = chunk_stratum(0, &w2, 64).unwrap();
        let h1: std::collections::HashSet<u64> = c1.iter().map(|c| c.hash).collect();
        let h2: std::collections::HashSet<u64> = c2.iter().map(|c| c.hash).collect();
        let shared = h1.intersection(&h2).count();
        // Only chunks at the trimmed head / extended tail may differ.
        assert!(
            shared as f64 >= 0.9 * c1.len().min(c2.len()) as f64,
            "only {shared}/{} chunks survived the slide",
            c1.len()
        );
    }

    #[test]
    fn hash_depends_on_values() {
        let a = chunk_stratum(0, &recs(0..10), 100).unwrap();
        let mut items = recs(0..10);
        items[3].value += 1.0;
        let b = chunk_stratum(0, &items, 100).unwrap();
        assert_eq!(a.len(), b.len());
        // The chunk containing item 3 must change hash; others must not.
        let ha: Vec<u64> = a.iter().map(|c| c.hash).collect();
        let hb: Vec<u64> = b.iter().map(|c| c.hash).collect();
        assert_ne!(ha, hb);
        let differing = ha.iter().zip(&hb).filter(|(x, y)| x != y).count();
        assert_eq!(differing, 1, "exactly one chunk should change");
    }

    #[test]
    fn hash_depends_on_stratum() {
        let a = chunk_stratum(0, &recs(0..10), 100).unwrap();
        let b = chunk_stratum(1, &recs(0..10), 100).unwrap();
        assert_ne!(a[0].hash, b[0].hash);
    }

    #[test]
    fn columnar_chunking_matches_row_path() {
        // chunk_stratum_columns is the same partition, hash for hash and
        // record for record, as the row path.
        let items = recs(0..3_000);
        let cols = ColumnarBatch::from_records(&items);
        let by_rows = chunk_stratum(0, &items, 64).unwrap();
        let by_cols = chunk_stratum_columns(0, &cols, 64).unwrap();
        assert_eq!(by_rows.len(), by_cols.len());
        for (r, c) in by_rows.iter().zip(&by_cols) {
            assert_eq!(r.hash, c.hash);
            assert_eq!(r.items(), c.items());
        }
    }

    #[test]
    fn order_sensitive_by_design() {
        // Chunking must respect the caller's (bias) order: a reordered
        // input is a different chunk sequence. This is what keeps the
        // memoized prefix stable across windows.
        let mut shuffled = recs(0..500);
        Rng::new(1).shuffle(&mut shuffled);
        let a = chunk_stratum(0, &recs(0..500), 64).unwrap();
        let b = chunk_stratum(0, &shuffled, 64).unwrap();
        let ha: std::collections::HashSet<u64> = a.iter().map(|c| c.hash).collect();
        let hb: std::collections::HashSet<u64> = b.iter().map(|c| c.hash).collect();
        assert_ne!(ha, hb);
        // Same total items either way.
        let na: usize = a.iter().map(Chunk::len).sum();
        let nb: usize = b.iter().map(Chunk::len).sum();
        assert_eq!(na, nb);
    }

    #[test]
    fn memoized_prefix_plus_fresh_suffix_is_stable() {
        // The coordinator's actual edit pattern: drop a prefix (evicted),
        // keep the middle untouched, append fresh items at the end.
        let w1: Vec<Record> = recs(0..5_000);
        let mut w2: Vec<Record> = w1[600..].to_vec();
        w2.extend(recs(5_000..5_600));
        let c1 = chunk_stratum(0, &w1, 64).unwrap();
        let c2 = chunk_stratum(0, &w2, 64).unwrap();
        let h1: std::collections::HashSet<u64> = c1.iter().map(|c| c.hash).collect();
        let shared = c2.iter().filter(|c| h1.contains(&c.hash)).count();
        assert!(
            shared as f64 > 0.75 * c2.len() as f64,
            "only {shared}/{} chunks stable",
            c2.len()
        );
    }

    #[test]
    fn empty_input_no_chunks() {
        assert!(chunk_stratum(0, &[], 64).unwrap().is_empty());
        let (chunks, rehashed) = chunk_stratum_cached(0, &[], 64, &[]).unwrap();
        assert!(chunks.is_empty());
        assert_eq!(rehashed, 0);
    }

    #[test]
    fn zero_target_is_config_error() {
        // Every chunking entry point reports target = 0 as a typed
        // config error instead of panicking.
        let items = recs(0..4);
        let cols = ColumnarBatch::from_records(&items);
        for err in [
            chunk_stratum(0, &items, 0).unwrap_err(),
            chunk_stratum_columns(0, &cols, 0).unwrap_err(),
            chunk_stratum_cached(0, &items, 0, &[]).unwrap_err(),
            chunk_stratum_cached_columns(0, &cols, 0, &[]).unwrap_err(),
        ] {
            assert!(matches!(err, Error::Config(ref m) if m.contains("positive")), "{err}");
        }
    }

    #[test]
    fn cached_identical_input_reuses_everything() {
        let items = recs(0..2_000);
        let prev = chunk_stratum(0, &items, 64).unwrap();
        let (chunks, rehashed) = chunk_stratum_cached(0, &items, 64, &prev).unwrap();
        assert_eq!(rehashed, 0, "identical input must not re-hash");
        assert_eq!(chunks.len(), prev.len());
        for (c, p) in chunks.iter().zip(&prev) {
            assert_eq!(c.hash, p.hash);
            assert!(c.columns().ptr_eq(p.columns()), "reuse must be zero-copy");
        }
    }

    #[test]
    fn cached_columns_identical_to_row_cached_across_slides() {
        // The equivalence contract: cached chunking is an optimization,
        // never a semantic change — hashes and items match the
        // from-scratch sequence for arbitrary prefix-drop/suffix-append
        // edits (with some mid-run removals thrown in), on both the row
        // and the columnar cached paths.
        let mut window: Vec<Record> = recs(0..4_000);
        let mut prev = chunk_stratum(0, &window, 32).unwrap();
        let mut next_id = 4_000u64;
        let mut rng = Rng::new(7);
        for _ in 0..6 {
            // Drop a prefix, remove a few interior items, append a suffix.
            window.drain(..300);
            for _ in 0..10 {
                let victim = rng.below(window.len());
                window.remove(victim);
            }
            window.extend(recs(next_id..next_id + 310));
            next_id += 310;
            let (cached, rehashed) = chunk_stratum_cached(0, &window, 32, &prev).unwrap();
            let scratch = chunk_stratum(0, &window, 32).unwrap();
            let cols = ColumnarBatch::from_records(&window);
            let (cached_cols, rehashed_cols) =
                chunk_stratum_cached_columns(0, &cols, 32, &prev).unwrap();
            assert_eq!(cached.len(), scratch.len());
            assert_eq!(cached_cols.len(), scratch.len());
            assert_eq!(rehashed, rehashed_cols);
            for ((c, s), cc) in cached.iter().zip(&scratch).zip(&cached_cols) {
                assert_eq!(c.hash, s.hash);
                assert_eq!(c.items(), s.items());
                assert_eq!(cc.hash, s.hash);
            }
            assert!(
                rehashed < window.len() / 2,
                "rehashed {rehashed}/{} — cache not reusing",
                window.len()
            );
            prev = cached;
        }
    }

    #[test]
    fn cached_detects_value_mutation() {
        // Same ids, one mutated value: the affected run must re-hash (the
        // equality check, not just the first-id probe, gates reuse).
        let items = recs(0..200);
        let prev = chunk_stratum(0, &items, 32).unwrap();
        let mut mutated = items.clone();
        mutated[100].value += 1.0;
        let (cached, rehashed) = chunk_stratum_cached(0, &mutated, 32, &prev).unwrap();
        let scratch = chunk_stratum(0, &mutated, 32).unwrap();
        assert!(rehashed > 0);
        for (c, s) in cached.iter().zip(&scratch) {
            assert_eq!(c.hash, s.hash);
        }
    }

    #[test]
    fn cached_distinguishes_signed_zero_values() {
        // +0.0 == -0.0 under f64 `==`, but their bit patterns — and thus
        // their chunk hashes — differ. The reuse gate must compare bits,
        // or a cached +0.0 chunk would masquerade as the -0.0 run and
        // split the incremental path's memo keys from the from-scratch
        // path's.
        let mut items = recs(0..64);
        items[10].value = 0.0;
        let prev = chunk_stratum(0, &items, 16).unwrap();
        items[10].value = -0.0;
        let (cached, rehashed) = chunk_stratum_cached(0, &items, 16, &prev).unwrap();
        let scratch = chunk_stratum(0, &items, 16).unwrap();
        assert!(rehashed > 0, "signed-zero flip must re-hash its run");
        for (c, s) in cached.iter().zip(&scratch) {
            assert_eq!(c.hash, s.hash);
        }
        // Bit-identical input still reuses everything — on both cached
        // paths.
        let (again, rehashed) = chunk_stratum_cached(0, &items, 16, &cached).unwrap();
        assert_eq!(rehashed, 0);
        for (a, c) in again.iter().zip(&cached) {
            assert!(a.columns().ptr_eq(c.columns()));
        }
        let cols = ColumnarBatch::from_records(&items);
        let (again_cols, rehashed) = chunk_stratum_cached_columns(0, &cols, 16, &cached).unwrap();
        assert_eq!(rehashed, 0);
        for (a, c) in again_cols.iter().zip(&cached) {
            assert!(a.columns().ptr_eq(c.columns()));
        }
    }

    #[test]
    fn cached_ignores_stale_other_stratum_cache() {
        let items = recs(0..300);
        let prev = chunk_stratum(1, &items, 32).unwrap();
        // A stratum-0 chunking must not adopt stratum-1 cached chunks.
        let (cached, rehashed) = chunk_stratum_cached(0, &items, 32, &prev).unwrap();
        assert_eq!(rehashed, 300);
        let scratch = chunk_stratum(0, &items, 32).unwrap();
        for (c, s) in cached.iter().zip(&scratch) {
            assert_eq!(c.hash, s.hash);
            assert_eq!(c.stratum, 0);
        }
    }
}
