//! Content-defined chunking — stable partitioning of the biased sample
//! into memoizable map-task inputs.
//!
//! Position-based chunking (`items.chunks(64)`) would shift every boundary
//! when one item enters or leaves the window, invalidating every memo key
//! downstream. Instead, following Incoop's *stable partitioning*, chunk
//! boundaries are determined by item **content**: within a stratum, items
//! are ordered by id and a boundary is placed after item `i` when
//! `mix64(id_i) % target == 0` (expected chunk length = `target`), with a
//! hard cap at `4 × target` to bound the PJRT row width. Overlapping
//! windows therefore produce byte-identical chunks — identical memo keys —
//! for all unchanged runs of items.

use crate::util::hash::{mix64, StableHasher};
use crate::workload::record::{Record, StratumId};

/// One map-task input: a stable run of sampled items from one stratum.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Stratum all items belong to.
    pub stratum: StratumId,
    /// Items, in the caller's (bias/window) order.
    pub items: Vec<Record>,
    /// Stable content hash (ids + value bits) — the memo key.
    pub hash: u64,
}

impl Chunk {
    fn build(stratum: StratumId, items: Vec<Record>) -> Self {
        let mut h = StableHasher::new();
        h.write_u64(stratum as u64);
        for r in &items {
            h.write_u64(r.id);
            h.write_f64(r.value);
        }
        Chunk { stratum, items, hash: h.finish() }
    }

    /// Values of the chunk's items.
    pub fn values(&self) -> Vec<f64> {
        self.items.iter().map(|r| r.value).collect()
    }

    /// Item count.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when the chunk holds no items.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Is this item a chunk boundary for the given target size?
#[inline]
fn is_boundary(id: u64, target: usize) -> bool {
    mix64(id) % target as u64 == 0
}

/// Split one stratum's sampled items into stable chunks with expected
/// length `target` (hard cap `4 × target`).
///
/// **Order-sensitive by design.** The caller passes items in *bias order*
/// (the previous window's memoized items in their stored order, fresh
/// items appended — see `sampling::biased`), or in window order for the
/// exact modes. Across adjacent windows that sequence only loses a prefix
/// (evicted old items) and gains a suffix (fresh items), which is exactly
/// the edit pattern content-defined boundaries absorb: all interior
/// chunks — and their memo keys — stay identical. Sorting here (e.g. by
/// id) would interleave fresh items between memoized ones and invalidate
/// every chunk.
pub fn chunk_stratum(stratum: StratumId, items: Vec<Record>, target: usize) -> Vec<Chunk> {
    assert!(target > 0, "chunk target must be positive");
    let cap = 4 * target;
    let mut chunks = Vec::new();
    let mut current: Vec<Record> = Vec::with_capacity(target);
    for r in items {
        current.push(r);
        if is_boundary(r.id, target) || current.len() >= cap {
            chunks.push(Chunk::build(stratum, std::mem::take(&mut current)));
        }
    }
    if !current.is_empty() {
        chunks.push(Chunk::build(stratum, current));
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn recs(ids: impl IntoIterator<Item = u64>) -> Vec<Record> {
        ids.into_iter().map(|i| Record::new(i, 0, 0, 0, i as f64 * 0.5)).collect()
    }

    #[test]
    fn all_items_kept_once() {
        let items = recs(0..1000);
        let chunks = chunk_stratum(0, items.clone(), 64);
        let total: usize = chunks.iter().map(Chunk::len).sum();
        assert_eq!(total, 1000);
        let mut ids: Vec<u64> = chunks.iter().flat_map(|c| c.items.iter().map(|r| r.id)).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn expected_chunk_size_near_target() {
        let items = recs(0..100_000);
        let chunks = chunk_stratum(0, items, 64);
        let mean = 100_000.0 / chunks.len() as f64;
        assert!((mean - 64.0).abs() < 8.0, "mean chunk size {mean}");
    }

    #[test]
    fn size_cap_enforced() {
        let items = recs(0..50_000);
        let chunks = chunk_stratum(0, items, 32);
        assert!(chunks.iter().all(|c| c.len() <= 128));
    }

    #[test]
    fn stability_under_prefix_removal_and_suffix_insertion() {
        // The defining property: sliding the window (drop oldest, add
        // newest) must keep interior chunks identical.
        let w1 = recs(0..10_000);
        let w2 = recs(400..10_400); // slide by 400
        let c1 = chunk_stratum(0, w1, 64);
        let c2 = chunk_stratum(0, w2, 64);
        let h1: std::collections::HashSet<u64> = c1.iter().map(|c| c.hash).collect();
        let h2: std::collections::HashSet<u64> = c2.iter().map(|c| c.hash).collect();
        let shared = h1.intersection(&h2).count();
        // Only chunks at the trimmed head / extended tail may differ.
        assert!(
            shared as f64 >= 0.9 * c1.len().min(c2.len()) as f64,
            "only {shared}/{} chunks survived the slide",
            c1.len()
        );
    }

    #[test]
    fn hash_depends_on_values() {
        let a = chunk_stratum(0, recs(0..10), 100);
        let mut items = recs(0..10);
        items[3].value += 1.0;
        let b = chunk_stratum(0, items, 100);
        assert_eq!(a.len(), b.len());
        // The chunk containing item 3 must change hash; others must not.
        let ha: Vec<u64> = a.iter().map(|c| c.hash).collect();
        let hb: Vec<u64> = b.iter().map(|c| c.hash).collect();
        assert_ne!(ha, hb);
        let differing = ha.iter().zip(&hb).filter(|(x, y)| x != y).count();
        assert_eq!(differing, 1, "exactly one chunk should change");
    }

    #[test]
    fn hash_depends_on_stratum() {
        let a = chunk_stratum(0, recs(0..10), 100);
        let b = chunk_stratum(1, recs(0..10), 100);
        assert_ne!(a[0].hash, b[0].hash);
    }

    #[test]
    fn order_sensitive_by_design() {
        // Chunking must respect the caller's (bias) order: a reordered
        // input is a different chunk sequence. This is what keeps the
        // memoized prefix stable across windows.
        let mut shuffled = recs(0..500);
        Rng::new(1).shuffle(&mut shuffled);
        let a = chunk_stratum(0, recs(0..500), 64);
        let b = chunk_stratum(0, shuffled, 64);
        let ha: std::collections::HashSet<u64> = a.iter().map(|c| c.hash).collect();
        let hb: std::collections::HashSet<u64> = b.iter().map(|c| c.hash).collect();
        assert_ne!(ha, hb);
        // Same total items either way.
        let na: usize = a.iter().map(Chunk::len).sum();
        let nb: usize = b.iter().map(Chunk::len).sum();
        assert_eq!(na, nb);
    }

    #[test]
    fn memoized_prefix_plus_fresh_suffix_is_stable() {
        // The coordinator's actual edit pattern: drop a prefix (evicted),
        // keep the middle untouched, append fresh items at the end.
        let w1: Vec<Record> = recs(0..5_000);
        let mut w2: Vec<Record> = w1[600..].to_vec();
        w2.extend(recs(5_000..5_600));
        let c1 = chunk_stratum(0, w1, 64);
        let c2 = chunk_stratum(0, w2, 64);
        let h1: std::collections::HashSet<u64> = c1.iter().map(|c| c.hash).collect();
        let shared = c2.iter().filter(|c| h1.contains(&c.hash)).count();
        assert!(
            shared as f64 > 0.75 * c2.len() as f64,
            "only {shared}/{} chunks stable",
            c2.len()
        );
    }

    #[test]
    fn empty_input_no_chunks() {
        assert!(chunk_stratum(0, vec![], 64).is_empty());
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_target_panics() {
        chunk_stratum(0, recs(0..4), 0);
    }
}
