//! Job planning: biased sample → chunks → memo classification → DDG.
//!
//! The plan of one window's job: every stratum's biased sample is chunked
//! (content-defined, `chunk.rs`), each chunk is classified as a **memo
//! hit** (result reused, no execution) or **fresh** (must execute), and a
//! dependence graph is built with one map node per chunk, one reduce node
//! per stratum, and an output node — the concrete instantiation of
//! Figure 3.1 for this pipeline.
//!
//! Planning borrows the sample runs' columnar views
//! ([`crate::sampling::SampleRun::columns`]) — it never clones the
//! sample — and [`JobPlan::plan_stratum_cached`] additionally reuses the
//! previous window's chunks for unchanged runs, so per-window planning
//! work is O(changed items), not O(sample). Chunking errors (a zero
//! chunk target) surface as typed [`crate::error::Error::Config`]
//! results instead of panics.

use std::collections::BTreeMap;

use crate::columnar::ColumnarBatch;
use crate::error::Result;
use crate::job::chunk::{chunk_stratum_cached_columns, chunk_stratum_columns, Chunk};
use crate::job::moments::Moments;
use crate::sac::ddg::{Ddg, NodeKind};
use crate::sac::memo::{MemoShard, MemoStore};
use crate::sampling::biased::BiasOutcome;
use crate::workload::record::StratumId;

/// A chunk with its memo classification.
#[derive(Debug, Clone)]
pub struct PlannedChunk {
    /// The chunk itself.
    pub chunk: Chunk,
    /// Memoized result, if the store already has this chunk.
    pub memoized: Option<Moments>,
}

impl PlannedChunk {
    /// True when no execution is needed.
    pub fn is_hit(&self) -> bool {
        self.memoized.is_some()
    }
}

/// The executable plan of one window.
#[derive(Debug)]
pub struct JobPlan {
    /// All chunks, grouped per stratum (deterministic order).
    pub per_stratum: BTreeMap<StratumId, Vec<PlannedChunk>>,
    /// The window job's dependence graph.
    pub ddg: Ddg,
}

impl JobPlan {
    /// Build the plan from the biased sample and the memo store.
    ///
    /// Counts one memo hit/miss per chunk in the store's statistics.
    pub fn build(biased: &BiasOutcome, memo: &mut MemoStore, chunk_target: usize) -> Result<JobPlan> {
        let mut per_stratum = BTreeMap::new();
        let mut ddg = Ddg::new();
        let output = ddg.add_node(NodeKind::Output);
        for (&stratum, run) in &biased.per_stratum {
            let chunks = chunk_stratum_columns(stratum, run.columns(), chunk_target)?;
            let reduce = ddg.add_node(NodeKind::Reduce { group: stratum as u64 });
            ddg.add_edge(reduce, output);
            let planned: Vec<PlannedChunk> = chunks
                .into_iter()
                .map(|chunk| {
                    let map_node = ddg.add_node(NodeKind::Map { chunk_hash: chunk.hash });
                    ddg.add_edge(map_node, reduce);
                    let memoized = memo.get_chunk(chunk.hash);
                    PlannedChunk { chunk, memoized }
                })
                .collect();
            per_stratum.insert(stratum, planned);
        }
        Ok(JobPlan { per_stratum, ddg })
    }

    /// Chunk + classify a single stratum against its memo shard — the
    /// per-stratum unit of the sharded window pipeline.
    ///
    /// Read-only with respect to the memo (`MemoShard` lookups are
    /// lock-free), so any number of strata can be planned concurrently.
    /// Pass `memo: None` for the non-memoizing baselines: every chunk is
    /// classified fresh and no hit/miss counters are touched.
    pub fn plan_stratum(
        stratum: StratumId,
        cols: &ColumnarBatch,
        memo: Option<&MemoShard>,
        chunk_target: usize,
    ) -> Result<Vec<PlannedChunk>> {
        Ok(Self::plan_stratum_cached(stratum, cols, memo, chunk_target, &[])?.0)
    }

    /// [`JobPlan::plan_stratum`] with chunk reuse from `prev_chunks`, the
    /// previous window's chunk sequence for this stratum (see
    /// [`chunk_stratum_cached`]): unchanged runs are neither copied nor
    /// re-hashed, so planning cost tracks the change, not the sample.
    /// Returns the planned chunks plus the number of re-hashed items.
    pub fn plan_stratum_cached(
        stratum: StratumId,
        cols: &ColumnarBatch,
        memo: Option<&MemoShard>,
        chunk_target: usize,
        prev_chunks: &[Chunk],
    ) -> Result<(Vec<PlannedChunk>, usize)> {
        let (chunks, rehashed_items) =
            chunk_stratum_cached_columns(stratum, cols, chunk_target, prev_chunks)?;
        let planned = chunks
            .into_iter()
            .map(|chunk| {
                let memoized = memo.and_then(|m| m.get_chunk(chunk.hash));
                PlannedChunk { chunk, memoized }
            })
            .collect();
        Ok((planned, rehashed_items))
    }

    /// All fresh (to-execute) chunks in deterministic order.
    pub fn fresh_chunks(&self) -> Vec<&Chunk> {
        self.per_stratum
            .values()
            .flatten()
            .filter(|p| !p.is_hit())
            .map(|p| &p.chunk)
            .collect()
    }

    /// Total chunk count.
    pub fn chunk_count(&self) -> usize {
        self.per_stratum.values().map(Vec::len).sum()
    }

    /// Memo-hit chunk count.
    pub fn hit_count(&self) -> usize {
        self.per_stratum.values().flatten().filter(|p| p.is_hit()).count()
    }

    /// Fraction of chunks whose results are reused.
    pub fn reuse_fraction(&self) -> f64 {
        let n = self.chunk_count();
        if n == 0 {
            0.0
        } else {
            self.hit_count() as f64 / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::SampleRun;
    use crate::workload::record::Record;

    fn biased(strata: &[(StratumId, std::ops::Range<u64>)]) -> BiasOutcome {
        let mut out = BiasOutcome::default();
        for (s, ids) in strata {
            out.per_stratum.insert(
                *s,
                SampleRun::from_vec(
                    ids.clone().map(|i| Record::new(i, *s, i, 0, i as f64)).collect(),
                ),
            );
        }
        out
    }

    #[test]
    fn cold_plan_is_all_fresh() {
        let mut memo = MemoStore::new();
        let b = biased(&[(0, 0..500), (1, 500..900)]);
        let plan = JobPlan::build(&b, &mut memo, 64).unwrap();
        assert_eq!(plan.hit_count(), 0);
        assert_eq!(plan.fresh_chunks().len(), plan.chunk_count());
        assert!(plan.chunk_count() > 2);
    }

    #[test]
    fn warm_plan_reuses_identical_chunks() {
        let mut memo = MemoStore::new();
        let b = biased(&[(0, 0..500)]);
        let plan = JobPlan::build(&b, &mut memo, 64).unwrap();
        // Execute + memoize everything.
        for p in plan.per_stratum[&0].iter() {
            memo.put_chunk(p.chunk.hash, Moments::from_records(p.chunk.items()), 0, 0);
        }
        // Same sample again → all hits.
        let plan2 = JobPlan::build(&b, &mut memo, 64).unwrap();
        assert_eq!(plan2.hit_count(), plan2.chunk_count());
        assert_eq!(plan2.reuse_fraction(), 1.0);
    }

    #[test]
    fn partial_overlap_partial_reuse() {
        let mut memo = MemoStore::new();
        let w1 = biased(&[(0, 0..1000)]);
        let plan1 = JobPlan::build(&w1, &mut memo, 32).unwrap();
        for p in plan1.per_stratum[&0].iter() {
            memo.put_chunk(p.chunk.hash, Moments::from_records(p.chunk.items()), 0, 0);
        }
        // Slide: drop first 100 ids, add 100 new.
        let w2 = biased(&[(0, 100..1100)]);
        let plan2 = JobPlan::build(&w2, &mut memo, 32).unwrap();
        assert!(plan2.hit_count() > 0, "no reuse after slide");
        assert!(plan2.hit_count() < plan2.chunk_count(), "new items must be fresh");
        assert!(plan2.reuse_fraction() > 0.6, "reuse {}", plan2.reuse_fraction());
    }

    #[test]
    fn plan_stratum_matches_legacy_build() {
        let mut memo = MemoStore::new();
        let b = biased(&[(0, 0..600)]);
        let warm = JobPlan::build(&b, &mut memo, 32).unwrap();
        // Memoize every second chunk.
        for p in warm.per_stratum[&0].iter().step_by(2) {
            memo.put_chunk(p.chunk.hash, Moments::from_records(p.chunk.items()), 0, 0);
        }
        let via_build = JobPlan::build(&b, &mut memo, 32).unwrap();
        let via_shard =
            JobPlan::plan_stratum(0, b.per_stratum[&0].columns(), Some(memo.shard(0)), 32).unwrap();
        assert_eq!(via_build.per_stratum[&0].len(), via_shard.len());
        for (a, c) in via_build.per_stratum[&0].iter().zip(&via_shard) {
            assert_eq!(a.chunk.hash, c.chunk.hash);
            assert_eq!(a.is_hit(), c.is_hit());
        }
        assert!(via_shard.iter().any(|p| p.is_hit()));
        assert!(via_shard.iter().any(|p| !p.is_hit()));
        // Without a shard (non-memoizing modes): all fresh, counters
        // untouched.
        let before = memo.stats();
        let cold = JobPlan::plan_stratum(0, b.per_stratum[&0].columns(), None, 32).unwrap();
        assert!(cold.iter().all(|p| !p.is_hit()));
        assert_eq!(memo.stats(), before);
    }

    #[test]
    fn plan_stratum_cached_reuses_chunks_and_matches_uncached() {
        let mut memo = MemoStore::new();
        let b = biased(&[(0, 0..600)]);
        let (cold, rehashed) =
            JobPlan::plan_stratum_cached(0, b.per_stratum[&0].columns(), None, 32, &[]).unwrap();
        assert_eq!(rehashed, 600, "no cache → everything hashed");
        let prev: Vec<Chunk> = cold.iter().map(|p| p.chunk.clone()).collect();
        for p in &cold {
            memo.put_chunk(p.chunk.hash, Moments::from_records(p.chunk.items()), 0, 0);
        }
        let (warm, rehashed) = JobPlan::plan_stratum_cached(
            0,
            b.per_stratum[&0].columns(),
            Some(memo.shard(0)),
            32,
            &prev,
        )
        .unwrap();
        assert_eq!(rehashed, 0, "identical sample must reuse every chunk");
        assert_eq!(warm.len(), cold.len());
        for (w, c) in warm.iter().zip(&cold) {
            assert_eq!(w.chunk.hash, c.chunk.hash);
            assert!(w.is_hit());
        }
    }

    #[test]
    fn ddg_shape_matches_plan() {
        let mut memo = MemoStore::new();
        let b = biased(&[(0, 0..200), (1, 200..400)]);
        let plan = JobPlan::build(&b, &mut memo, 64).unwrap();
        // nodes = 1 output + strata + chunks
        assert_eq!(plan.ddg.len(), 1 + 2 + plan.chunk_count());
    }

    #[test]
    fn empty_sample_empty_plan() {
        let mut memo = MemoStore::new();
        let plan = JobPlan::build(&BiasOutcome::default(), &mut memo, 64).unwrap();
        assert_eq!(plan.chunk_count(), 0);
        assert_eq!(plan.reuse_fraction(), 0.0);
    }
}
