//! The memoizable sub-computation result: masked moments of one chunk.
//!
//! Identical, field for field, to one output row of the L1 Pallas kernel
//! (`python/compile/kernels/stratified_agg.py`), so results computed
//! natively and through PJRT are interchangeable — the integration tests
//! assert they agree.

use crate::util::ksum::NeumaierSum;
use crate::workload::record::Record;

/// Independent accumulator lanes in the columnar fold. Element `i` of a
/// run always lands in lane `i % LANES`, whether the fold walks a dense
/// `&[f64]` column, a `&[Record]` row slice, or the retained scalar
/// reference — that fixed assignment (plus the fixed lane-combine order
/// in [`LaneFold::finish`]) is what makes every fold path bit-equal.
pub const LANES: usize = 8;

/// One Neumaier step (twin of [`NeumaierSum::add`], kept branch-shaped
/// so LLVM can if-convert it inside the lane loop).
#[inline(always)]
fn neumaier_step(sum: &mut f64, comp: &mut f64, v: f64) {
    let t = *sum + v;
    if sum.abs() >= v.abs() {
        *comp += (*sum - t) + v;
    } else {
        *comp += (v - t) + *sum;
    }
    *sum = t;
}

/// Lane-wise compensated moment accumulator: `LANES` independent
/// Neumaier chains for Σv and Σv² plus per-lane min/max, merged in a
/// fixed order at the end. Independent lanes break the serial
/// dependency of a single compensated chain, so the inner loop
/// auto-vectorizes (and pipelines) over dense value columns.
#[derive(Debug)]
struct LaneFold {
    sum: [f64; LANES],
    sum_c: [f64; LANES],
    sumsq: [f64; LANES],
    sumsq_c: [f64; LANES],
    min: [f64; LANES],
    max: [f64; LANES],
}

impl LaneFold {
    #[inline]
    fn new() -> Self {
        LaneFold {
            sum: [0.0; LANES],
            sum_c: [0.0; LANES],
            sumsq: [0.0; LANES],
            sumsq_c: [0.0; LANES],
            min: [f64::INFINITY; LANES],
            max: [f64::NEG_INFINITY; LANES],
        }
    }

    /// Fold one value into lane `j`.
    #[inline(always)]
    fn step(&mut self, j: usize, v: f64) {
        neumaier_step(&mut self.sum[j], &mut self.sum_c[j], v);
        neumaier_step(&mut self.sumsq[j], &mut self.sumsq_c[j], v * v);
        self.min[j] = self.min[j].min(v);
        self.max[j] = self.max[j].max(v);
    }

    /// Merge the lanes in index order (0, 1, …, LANES−1): each lane's
    /// compensated total enters one final Neumaier chain. The order is
    /// part of the pinned arithmetic — every fold path shares it.
    fn finish(&self, count: usize) -> Moments {
        let mut sum = NeumaierSum::new();
        let mut sumsq = NeumaierSum::new();
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for j in 0..LANES {
            sum.add(self.sum[j] + self.sum_c[j]);
            sumsq.add(self.sumsq[j] + self.sumsq_c[j]);
            min = min.min(self.min[j]);
            max = max.max(self.max[j]);
        }
        Moments { count: count as f64, sum: sum.total(), sumsq: sumsq.total(), min, max }
    }
}

/// Count, sum, sum of squares, min, max of a set of values.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Moments {
    /// Number of items.
    pub count: f64,
    /// Σv.
    pub sum: f64,
    /// Σv².
    pub sumsq: f64,
    /// Minimum (+∞ when empty, matching the kernel's masked min).
    pub min: f64,
    /// Maximum (−∞ when empty).
    pub max: f64,
}

impl Default for Moments {
    fn default() -> Self {
        Moments::EMPTY
    }
}

impl Moments {
    /// The identity element of [`Moments::combine`].
    pub const EMPTY: Moments =
        Moments { count: 0.0, sum: 0.0, sumsq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY };

    /// Exact (compensated) moments of a dense value column — the
    /// columnar hot-path fold. `LANES`-wide chunked traversal; element
    /// `i` lands in lane `i % LANES` (see [`LANES`] for why).
    pub fn fold_values(values: &[f64]) -> Self {
        let mut acc = LaneFold::new();
        let mut chunks = values.chunks_exact(LANES);
        for c in &mut chunks {
            for j in 0..LANES {
                acc.step(j, c[j]);
            }
        }
        for (j, &v) in chunks.remainder().iter().enumerate() {
            acc.step(j, v);
        }
        acc.finish(values.len())
    }

    /// Columnar fold with `rounds` map iterations applied per value
    /// (see [`crate::job::map_fn::apply_map`]).
    pub fn fold_values_mapped(values: &[f64], rounds: u32) -> Self {
        let mut acc = LaneFold::new();
        let mut chunks = values.chunks_exact(LANES);
        for c in &mut chunks {
            for j in 0..LANES {
                acc.step(j, crate::job::map_fn::apply_map(c[j], rounds));
            }
        }
        for (j, &v) in chunks.remainder().iter().enumerate() {
            acc.step(j, crate::job::map_fn::apply_map(v, rounds));
        }
        acc.finish(values.len())
    }

    /// Retained scalar reference for the columnar fold: one plain
    /// element loop, no chunking, accumulators written out longhand.
    /// Performs the identical arithmetic DAG (same lane assignment,
    /// same Neumaier steps, same lane-combine order), so the kernel
    /// equivalence gate (`tests/columnar_kernels.rs`) pins
    /// `fold_values` bit-equal to it — a remainder- or reordering bug
    /// in the chunked kernel breaks the gate.
    pub fn fold_values_reference(values: &[f64]) -> Self {
        let mut sum = [0.0f64; LANES];
        let mut sum_c = [0.0f64; LANES];
        let mut sumsq = [0.0f64; LANES];
        let mut sumsq_c = [0.0f64; LANES];
        let mut min = [f64::INFINITY; LANES];
        let mut max = [f64::NEG_INFINITY; LANES];
        for (i, &v) in values.iter().enumerate() {
            let j = i % LANES;
            // Neumaier step for Σv, spelled out.
            let t = sum[j] + v;
            if sum[j].abs() >= v.abs() {
                sum_c[j] += (sum[j] - t) + v;
            } else {
                sum_c[j] += (v - t) + sum[j];
            }
            sum[j] = t;
            // Neumaier step for Σv².
            let sq = v * v;
            let t = sumsq[j] + sq;
            if sumsq[j].abs() >= sq.abs() {
                sumsq_c[j] += (sumsq[j] - t) + sq;
            } else {
                sumsq_c[j] += (sq - t) + sumsq[j];
            }
            sumsq[j] = t;
            min[j] = min[j].min(v);
            max[j] = max[j].max(v);
        }
        let mut total = NeumaierSum::new();
        let mut total_sq = NeumaierSum::new();
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for j in 0..LANES {
            total.add(sum[j] + sum_c[j]);
            total_sq.add(sumsq[j] + sumsq_c[j]);
            mn = mn.min(min[j]);
            mx = mx.max(max[j]);
        }
        Moments {
            count: values.len() as f64,
            sum: total.total(),
            sumsq: total_sq.total(),
            min: mn,
            max: mx,
        }
    }

    /// Exact (compensated) moments of a value slice.
    pub fn from_values(values: &[f64]) -> Self {
        Self::fold_values(values)
    }

    /// Moments of a record slice's values.
    pub fn from_records(records: &[Record]) -> Self {
        Self::from_records_mapped(records, 0)
    }

    /// Moments of a record slice after `rounds` map iterations per item
    /// (see [`crate::job::map_fn::apply_map`]).
    ///
    /// Row-path fold: walks the 40-byte record stride but performs the
    /// same lane-wise arithmetic as [`Moments::fold_values_mapped`]
    /// (element `i` → lane `i % LANES`), so row and columnar folds of
    /// the same run are bit-equal — the "columnar ≡ row bytes"
    /// invariant.
    pub fn from_records_mapped(records: &[Record], rounds: u32) -> Self {
        let mut acc = LaneFold::new();
        for (i, r) in records.iter().enumerate() {
            acc.step(i % LANES, crate::job::map_fn::apply_map(r.value, rounds));
        }
        acc.finish(records.len())
    }

    /// Associative, commutative combine — the reduce of Figure 3.1.
    pub fn combine(&self, other: &Moments) -> Moments {
        Moments {
            count: self.count + other.count,
            sum: self.sum + other.sum,
            sumsq: self.sumsq + other.sumsq,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }

    /// Inverse of [`Moments::combine`] for the additive fields — the
    /// "un-reduce" of the paper's §4.2.2 `reduceByKeyAndWindow`
    /// implementation: subtract the moments of removed items.
    ///
    /// `count`, `sum`, `sumsq` are exactly invertible. `min`/`max` are
    /// **not** (removing the extremal item loses information): the result
    /// keeps the conservative bounds `min ≤ true min`, `max ≥ true max`.
    /// This mirrors the paper, which supports error estimation for
    /// aggregate queries only and defers extreme-value queries (§3.5.1);
    /// pipelines needing exact extremes use the full recompute path.
    pub fn inverse_combine(&self, removed: &Moments) -> Moments {
        Moments {
            count: (self.count - removed.count).max(0.0),
            sum: self.sum - removed.sum,
            sumsq: self.sumsq - removed.sumsq,
            min: self.min,
            max: self.max,
        }
    }

    /// Combine many.
    pub fn combine_all<'a>(parts: impl IntoIterator<Item = &'a Moments>) -> Moments {
        let mut acc_sum = NeumaierSum::new();
        let mut acc_sumsq = NeumaierSum::new();
        let mut count = 0.0;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for m in parts {
            count += m.count;
            acc_sum.add(m.sum);
            acc_sumsq.add(m.sumsq);
            min = min.min(m.min);
            max = max.max(m.max);
        }
        Moments { count, sum: acc_sum.total(), sumsq: acc_sumsq.total(), min, max }
    }

    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        self.sum / self.count
    }

    /// Unbiased sample variance s² (0 when count < 2).
    pub fn variance(&self) -> f64 {
        if self.count < 2.0 {
            return 0.0;
        }
        // Numerically: max(0, ·) guards tiny negative round-off.
        ((self.sumsq - self.sum * self.sum / self.count) / (self.count - 1.0)).max(0.0)
    }

    /// Pack into the kernel's 5-wide row layout (f32, PJRT side).
    pub fn to_row_f32(&self) -> [f32; 5] {
        [self.count as f32, self.sum as f32, self.sumsq as f32, self.min as f32, self.max as f32]
    }

    /// Unpack from the kernel's row layout. The kernel encodes empty-chunk
    /// min/max as ±FLT_MAX sentinels; map them back to ±∞.
    pub fn from_row_f32(row: &[f32]) -> Self {
        debug_assert_eq!(row.len(), 5);
        let min = if row[3] >= f32::MAX { f64::INFINITY } else { row[3] as f64 };
        let max = if row[4] <= f32::MIN { f64::NEG_INFINITY } else { row[4] as f64 };
        Moments { count: row[0] as f64, sum: row[1] as f64, sumsq: row[2] as f64, min, max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_values_basic() {
        let m = Moments::from_values(&[1.0, 2.0, 3.0]);
        assert_eq!(m.count, 3.0);
        assert_eq!(m.sum, 6.0);
        assert_eq!(m.sumsq, 14.0);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.max, 3.0);
        assert_eq!(m.mean(), 2.0);
        assert!((m.variance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_identity() {
        let m = Moments::from_values(&[4.0, 5.0]);
        assert_eq!(m.combine(&Moments::EMPTY), m);
        assert_eq!(Moments::EMPTY.combine(&m), m);
        assert_eq!(Moments::EMPTY.variance(), 0.0);
    }

    #[test]
    fn combine_matches_whole() {
        let a = [1.5, -2.0, 3.25, 0.0];
        let b = [10.0, 7.5];
        let whole: Vec<f64> = a.iter().chain(&b).copied().collect();
        let combined = Moments::from_values(&a).combine(&Moments::from_values(&b));
        let direct = Moments::from_values(&whole);
        assert!((combined.sum - direct.sum).abs() < 1e-12);
        assert!((combined.sumsq - direct.sumsq).abs() < 1e-12);
        assert_eq!(combined.count, direct.count);
        assert_eq!(combined.min, direct.min);
        assert_eq!(combined.max, direct.max);
    }

    #[test]
    fn combine_all_associativity() {
        let parts: Vec<Moments> = (0..10)
            .map(|i| Moments::from_values(&[i as f64, (i * i) as f64]))
            .collect();
        let left = parts.iter().fold(Moments::EMPTY, |acc, m| acc.combine(m));
        let all = Moments::combine_all(parts.iter());
        assert!((left.sum - all.sum).abs() < 1e-9);
        assert_eq!(left.count, all.count);
    }

    #[test]
    fn inverse_combine_undoes_combine() {
        let a = Moments::from_values(&[1.0, 2.0, 3.0]);
        let b = Moments::from_values(&[4.0, 5.0]);
        let both = a.combine(&b);
        let back = both.inverse_combine(&b);
        assert!((back.count - a.count).abs() < 1e-12);
        assert!((back.sum - a.sum).abs() < 1e-9);
        assert!((back.sumsq - a.sumsq).abs() < 1e-9);
    }

    #[test]
    fn inverse_combine_chain_stays_accurate() {
        // Simulate many windows of add/remove and compare to direct.
        let mut live: Vec<f64> = (0..100).map(|i| i as f64 * 0.7).collect();
        let mut m = Moments::from_values(&live);
        for round in 0..200 {
            let removed: Vec<f64> = live.drain(..5).collect();
            let added: Vec<f64> = (0..5).map(|i| (round * 5 + i) as f64 * 0.31).collect();
            live.extend(added.iter().copied());
            m = m.combine(&Moments::from_values(&added))
                .inverse_combine(&Moments::from_values(&removed));
        }
        let direct = Moments::from_values(&live);
        assert!((m.sum - direct.sum).abs() < 1e-6 * direct.sum.abs().max(1.0));
        assert!((m.sumsq - direct.sumsq).abs() < 1e-6 * direct.sumsq.abs().max(1.0));
        assert_eq!(m.count, direct.count);
    }

    #[test]
    fn variance_never_negative() {
        // Catastrophic cancellation scenario.
        let vals = vec![1e8 + 1.0, 1e8 + 1.0, 1e8 + 1.0];
        let m = Moments::from_values(&vals);
        assert!(m.variance() >= 0.0);
    }

    #[test]
    fn row_roundtrip() {
        let m = Moments::from_values(&[1.0, 2.0]);
        let row = m.to_row_f32();
        let back = Moments::from_row_f32(&row);
        assert_eq!(back.count, m.count);
        assert!((back.sum - m.sum).abs() < 1e-6);
        // Empty sentinel mapping.
        let empty_row = [0.0f32, 0.0, 0.0, f32::MAX, f32::MIN];
        let back = Moments::from_row_f32(&empty_row);
        assert_eq!(back.min, f64::INFINITY);
        assert_eq!(back.max, f64::NEG_INFINITY);
    }
}
