//! Mergeable, deterministic sketches backing the non-moment aggregates
//! (`Quantile`, `TopK`, `DistinctCount`).
//!
//! §3.5's error-bounded estimation only covers moment-derivable
//! statistics; quantiles, heavy hitters, and cardinalities need
//! mergeable synopses. The three sketches here are hand-rolled for the
//! offline workspace and chosen for one non-negotiable property on top
//! of the usual space/accuracy trade: **byte determinism under any
//! merge order**, because the equivalence gate demands serial, sharded,
//! and incremental execution produce identical bytes.
//!
//! The quantile and top-K sketches share a *level filter*: each element
//! gets `level(x) = trailing_zeros(mix64(x ^ seed))`, a geometric
//! random variable derived only from the element and the seed. A sketch
//! keeps every element with `level >= floor` and raises `floor` when
//! the kept set outgrows its cap (KLL-style compaction by level). The
//! final floor is the *minimal* `F` with
//! `|{x : level(x) >= F}| <= cap` over the full element set: during any
//! insertion/merge order, the kept set at a floor is a subset of the
//! full set's, so intermediate floors never overshoot, and the final
//! compaction lands every replica on the same `(floor, kept set)`
//! regardless of order. Merge is therefore associative, commutative,
//! and bit-identical to rebuild-from-scratch (`tests/sketch_laws.rs`
//! pins all three laws). The cardinality sketch is an HLL-style
//! register file stored as a refcounted `(bucket, rho)` histogram,
//! which is commutative by construction.
//!
//! Inverse-reduce support differs by sketch and is part of the public
//! contract (see the README aggregates matrix):
//!
//! * [`DistinctSketch`] — **exact deletion**: the refcounted cell
//!   histogram is an invertible multiset, so `delete` is the exact
//!   inverse of `insert` (law-tested as delete ≡ rebuild).
//! * [`QuantileSketch`] / [`TopKSketch`] — **merge-only**: once a
//!   compaction raises the floor, sub-floor elements are gone; deleting
//!   the elements that forced the raise could not lower it again, so
//!   deletion would diverge from rebuild. The coordinator instead
//!   re-folds memoized per-chunk sketches each slide (the re-chunk
//!   fallback): unchanged chunks are never re-sketched, and the fold is
//!   O(chunks), never O(items).

use std::collections::BTreeMap;

use crate::columnar::ColumnarBatch;
use crate::error::{Error, Result};
use crate::util::hash::mix64;
use crate::workload::record::Record;

/// Kept-set cap of the quantile sketch. At `floor == 0` (any input up
/// to the cap) the sketch is exact.
pub const QUANTILE_CAP: usize = 256;
/// Kept-key cap of the top-K sketch.
pub const TOPK_CAP: usize = 128;
/// HLL bucket count (`b = 8` index bits); relative standard error is
/// `1.04 / sqrt(256) = 6.5%`.
pub const DISTINCT_BUCKETS: usize = 256;

/// Salt folded into `SystemConfig::seed` by the coordinator so sketch
/// levels are decorrelated from every other seeded subsystem (sampler
/// ranks, fault injector, workload generators).
pub(crate) const SKETCH_SEED_SALT: u64 = 0x5CE7_C41B_3F9D_2A6E;

// Per-sketch salts decorrelate the three level/bucket hashes from each
// other even though they share one bundle seed.
const QUANTILE_SALT: u64 = 0x9E37_79B9_7F4A_7C15;
const TOPK_SALT: u64 = 0xC2B2_AE3D_27D4_EB4F;
const DISTINCT_SALT: u64 = 0x1656_67B1_9E37_79F9;

/// Geometric level of an element: the number of trailing zeros of its
/// salted hash (capped so it fits a `u8` comparison against any floor).
fn level_of(seed: u64, salt: u64, x: u64) -> u8 {
    mix64(x ^ seed ^ salt).trailing_zeros().min(63) as u8
}

/// One retained heavy-hitter entry. Counts of retained keys are exact
/// (`count_lo == count_hi`): the level filter drops whole keys, never
/// partial counts, so what survives is the true frequency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopEntry {
    pub key: u64,
    /// Guaranteed lower bound on the key's true count.
    pub count_lo: u64,
    /// Guaranteed upper bound on the key's true count.
    pub count_hi: u64,
}

// ---------------------------------------------------------------------
// Quantile
// ---------------------------------------------------------------------

/// KLL-style quantile sketch: a level-filtered subsample of
/// `(id, value)` pairs. Exact while `floor == 0`; past the cap it keeps
/// a ~`2^-floor` uniform subsample and reports a DKW rank-error bound.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    seed: u64,
    floor: u8,
    /// `id -> (value bits, level)`, sorted by id for deterministic
    /// iteration and serialization.
    entries: BTreeMap<u64, (u64, u8)>,
}

impl QuantileSketch {
    pub fn new(seed: u64) -> QuantileSketch {
        QuantileSketch { seed, floor: 0, entries: BTreeMap::new() }
    }

    /// Absorb one record's value, keyed by its (window-unique) id.
    pub fn insert(&mut self, id: u64, value: f64) {
        let level = level_of(self.seed, QUANTILE_SALT, id);
        if level >= self.floor {
            self.entries.insert(id, (value.to_bits(), level));
            self.compact();
        }
    }

    /// Absorb a dense `(id, value)` column pair — the columnar feed of
    /// the bundle. Per-element work is identical to [`Self::insert`] in
    /// the same order, so the resulting sketch is bit-equal to a
    /// record-at-a-time feed.
    pub fn insert_column(&mut self, ids: &[u64], values: &[f64]) {
        debug_assert_eq!(ids.len(), values.len());
        for (&id, &value) in ids.iter().zip(values.iter()) {
            self.insert(id, value);
        }
    }

    /// Fold another sketch of the same seed into this one.
    pub fn merge(&mut self, other: &QuantileSketch) {
        debug_assert_eq!(self.seed, other.seed, "cannot merge differently-seeded sketches");
        if other.floor > self.floor {
            self.floor = other.floor;
            let f = self.floor;
            self.entries.retain(|_, v| v.1 >= f);
        }
        for (&id, &(bits, level)) in &other.entries {
            if level >= self.floor {
                self.entries.insert(id, (bits, level));
            }
        }
        self.compact();
    }

    fn compact(&mut self) {
        while self.entries.len() > QUANTILE_CAP {
            self.floor += 1;
            let f = self.floor;
            self.entries.retain(|_, v| v.1 >= f);
        }
    }

    /// Nearest-rank quantile over the kept values; `0.0` when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        let mut values: Vec<f64> =
            self.entries.values().map(|&(bits, _)| f64::from_bits(bits)).collect();
        values.sort_by(|a, b| a.total_cmp(b));
        let q = q.clamp(0.0, 1.0);
        let idx = (q * (values.len() - 1) as f64).round() as usize;
        values[idx.min(values.len() - 1)]
    }

    /// DKW rank-error bound at `confidence`: the reported quantile's
    /// rank is within `epsilon` of the true rank. `0.0` while the
    /// sketch is exact (`floor == 0`), `1.0` when empty (no claim).
    pub fn rank_error(&self, confidence: f64) -> f64 {
        if self.entries.is_empty() {
            return 1.0;
        }
        if self.floor == 0 {
            return 0.0;
        }
        let conf = confidence.clamp(0.5, 1.0 - 1e-12);
        let eps = ((2.0 / (1.0 - conf)).ln() / (2.0 * self.entries.len() as f64)).sqrt();
        eps.min(1.0)
    }

    /// Number of retained `(id, value)` pairs.
    pub fn kept(&self) -> usize {
        self.entries.len()
    }

    /// Current compaction floor (`0` = exact).
    pub fn floor(&self) -> u8 {
        self.floor
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

// ---------------------------------------------------------------------
// Top-K
// ---------------------------------------------------------------------

/// Heavy-hitter sketch with a SpaceSaving-style memory cap enforced by
/// the deterministic level filter over *keys*: retained keys carry
/// exact counts, and `coverage()` reports the retained fraction of
/// key-hash space.
#[derive(Debug, Clone, PartialEq)]
pub struct TopKSketch {
    seed: u64,
    floor: u8,
    /// `key -> (count, level)`, sorted by key.
    keys: BTreeMap<u64, (u64, u8)>,
}

impl TopKSketch {
    pub fn new(seed: u64) -> TopKSketch {
        TopKSketch { seed, floor: 0, keys: BTreeMap::new() }
    }

    /// Count one occurrence of `key`.
    pub fn insert(&mut self, key: u64) {
        let level = level_of(self.seed, TOPK_SALT, key);
        if level >= self.floor {
            self.keys.entry(key).or_insert((0, level)).0 += 1;
            self.compact();
        }
    }

    /// Absorb a dense key column (see [`QuantileSketch::insert_column`]
    /// for the equivalence argument).
    pub fn insert_column(&mut self, keys: &[u64]) {
        for &key in keys {
            self.insert(key);
        }
    }

    /// Fold another sketch of the same seed into this one.
    pub fn merge(&mut self, other: &TopKSketch) {
        debug_assert_eq!(self.seed, other.seed, "cannot merge differently-seeded sketches");
        if other.floor > self.floor {
            self.floor = other.floor;
            let f = self.floor;
            self.keys.retain(|_, v| v.1 >= f);
        }
        for (&key, &(count, level)) in &other.keys {
            if level >= self.floor {
                self.keys.entry(key).or_insert((0, level)).0 += count;
            }
        }
        self.compact();
    }

    fn compact(&mut self) {
        while self.keys.len() > TOPK_CAP {
            self.floor += 1;
            let f = self.floor;
            self.keys.retain(|_, v| v.1 >= f);
        }
    }

    /// The `k` heaviest retained keys (count descending, key ascending
    /// for determinism), with exact count bounds.
    pub fn top_k(&self, k: usize) -> Vec<TopEntry> {
        let mut all: Vec<TopEntry> = self
            .keys
            .iter()
            .map(|(&key, &(count, _))| TopEntry { key, count_lo: count, count_hi: count })
            .collect();
        all.sort_by(|a, b| b.count_lo.cmp(&a.count_lo).then(a.key.cmp(&b.key)));
        all.truncate(k);
        all
    }

    /// Fraction of key-hash space the sketch still observes
    /// (`1.0` = every key retained, counts are the complete truth).
    pub fn coverage(&self) -> f64 {
        1.0 / (1u64 << self.floor.min(63)) as f64
    }

    /// Current compaction floor (`0` = exact).
    pub fn floor(&self) -> u8 {
        self.floor
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

// ---------------------------------------------------------------------
// Distinct count
// ---------------------------------------------------------------------

/// HLL-style cardinality sketch stored as a refcounted
/// `(bucket, rho) -> multiplicity` histogram. The histogram is an
/// invertible multiset, so unlike classic HLL register files this
/// sketch supports **exact deletion** — the property the inverse-reduce
/// path needs.
#[derive(Debug, Clone, PartialEq)]
pub struct DistinctSketch {
    seed: u64,
    cells: BTreeMap<(u8, u8), u64>,
}

impl DistinctSketch {
    pub fn new(seed: u64) -> DistinctSketch {
        DistinctSketch { seed, cells: BTreeMap::new() }
    }

    fn cell(&self, key: u64) -> (u8, u8) {
        let h = mix64(key ^ self.seed ^ DISTINCT_SALT);
        let bucket = (h & 0xFF) as u8;
        let rho = ((h >> 8).trailing_zeros().min(55) + 1) as u8;
        (bucket, rho)
    }

    /// Observe `key` once.
    pub fn insert(&mut self, key: u64) {
        *self.cells.entry(self.cell(key)).or_insert(0) += 1;
    }

    /// Exactly undo one prior `insert(key)`. Deleting a key that was
    /// never inserted is a no-op.
    pub fn delete(&mut self, key: u64) {
        let cell = self.cell(key);
        if let Some(count) = self.cells.get_mut(&cell) {
            *count -= 1;
            if *count == 0 {
                self.cells.remove(&cell);
            }
        }
    }

    /// Absorb a dense key column (see [`QuantileSketch::insert_column`]
    /// for the equivalence argument).
    pub fn insert_column(&mut self, keys: &[u64]) {
        for &key in keys {
            self.insert(key);
        }
    }

    /// Fold another sketch of the same seed into this one.
    pub fn merge(&mut self, other: &DistinctSketch) {
        debug_assert_eq!(self.seed, other.seed, "cannot merge differently-seeded sketches");
        for (&cell, &count) in &other.cells {
            *self.cells.entry(cell).or_insert(0) += count;
        }
    }

    /// HLL cardinality estimate with the standard small-range
    /// (linear-counting) correction.
    pub fn estimate(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        let m = DISTINCT_BUCKETS as f64;
        let mut registers = [0u8; DISTINCT_BUCKETS];
        for (&(bucket, rho), _) in &self.cells {
            let slot = &mut registers[bucket as usize];
            if rho > *slot {
                *slot = rho;
            }
        }
        let alpha = 0.7213 / (1.0 + 1.079 / m);
        let sum: f64 = registers.iter().map(|&r| 2f64.powi(-(r as i32))).sum();
        let raw = alpha * m * m / sum;
        let zeros = registers.iter().filter(|&&r| r == 0).count();
        if raw <= 2.5 * m && zeros > 0 {
            m * (m / zeros as f64).ln()
        } else {
            raw
        }
    }

    /// Relative standard error of the estimator: `1.04 / sqrt(m)`.
    pub fn std_error(&self) -> f64 {
        1.04 / (DISTINCT_BUCKETS as f64).sqrt()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }
}

// ---------------------------------------------------------------------
// Bundle
// ---------------------------------------------------------------------

/// The per-chunk (and, folded, per-stratum) synopsis: one sketch of
/// each kind over the same records, sharing one seed. This is what the
/// memo substrate stores next to `Moments` and what the checkpoint
/// serializes.
#[derive(Debug, Clone, PartialEq)]
pub struct SketchBundle {
    pub quantile: QuantileSketch,
    pub topk: TopKSketch,
    pub distinct: DistinctSketch,
}

impl SketchBundle {
    pub fn new(seed: u64) -> SketchBundle {
        SketchBundle {
            quantile: QuantileSketch::new(seed),
            topk: TopKSketch::new(seed),
            distinct: DistinctSketch::new(seed),
        }
    }

    /// Sketch a chunk's records: values (keyed by record id) feed the
    /// quantile sketch; keys feed the top-K and distinct sketches.
    ///
    /// Retained as the row-path reference for [`Self::from_columns`]
    /// (the kernel equivalence gate pins them bit-equal).
    pub fn from_records(seed: u64, records: &[Record]) -> SketchBundle {
        let mut bundle = SketchBundle::new(seed);
        for r in records {
            bundle.insert(r);
        }
        bundle
    }

    /// Sketch a columnar chunk: three tight column passes, one per
    /// sketch. Bit-equal to [`Self::from_records`] on the same data —
    /// the three sketches are independent and each sees its elements in
    /// the same order either way, so splitting the interleaved
    /// per-record feed into per-sketch passes changes nothing.
    pub fn from_columns(seed: u64, cols: &ColumnarBatch) -> SketchBundle {
        let mut bundle = SketchBundle::new(seed);
        bundle.quantile.insert_column(cols.ids(), cols.values());
        bundle.topk.insert_column(cols.keys());
        bundle.distinct.insert_column(cols.keys());
        bundle
    }

    /// Absorb one record.
    pub fn insert(&mut self, r: &Record) {
        self.quantile.insert(r.id, r.value);
        self.topk.insert(r.key);
        self.distinct.insert(r.key);
    }

    /// Fold another bundle of the same seed into this one.
    pub fn merge(&mut self, other: &SketchBundle) {
        self.quantile.merge(&other.quantile);
        self.topk.merge(&other.topk);
        self.distinct.merge(&other.distinct);
    }

    pub fn is_empty(&self) -> bool {
        self.quantile.is_empty() && self.topk.is_empty() && self.distinct.is_empty()
    }

    /// Canonical wire encoding (little-endian, BTreeMap order — byte
    /// deterministic). Layout:
    ///
    /// ```text
    /// u64 seed
    /// u8 q_floor | u32 q_len | (u64 id, u64 value_bits, u8 level)*
    /// u8 t_floor | u32 t_len | (u64 key, u64 count,      u8 level)*
    ///             u32 d_len  | (u8 bucket, u8 rho,       u64 count)*
    /// ```
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        push_u64(&mut buf, self.quantile.seed);
        buf.push(self.quantile.floor);
        push_u32(&mut buf, self.quantile.entries.len() as u32);
        for (&id, &(bits, level)) in &self.quantile.entries {
            push_u64(&mut buf, id);
            push_u64(&mut buf, bits);
            buf.push(level);
        }
        buf.push(self.topk.floor);
        push_u32(&mut buf, self.topk.keys.len() as u32);
        for (&key, &(count, level)) in &self.topk.keys {
            push_u64(&mut buf, key);
            push_u64(&mut buf, count);
            buf.push(level);
        }
        push_u32(&mut buf, self.distinct.cells.len() as u32);
        for (&(bucket, rho), &count) in &self.distinct.cells {
            buf.push(bucket);
            buf.push(rho);
            push_u64(&mut buf, count);
        }
        buf
    }

    /// Decode a canonical encoding. Truncation, trailing bytes, and any
    /// violated structural invariant (caps, sort order, level/floor
    /// consistency) yield [`Error::Checkpoint`], never a panic.
    pub fn from_bytes(bytes: &[u8]) -> Result<SketchBundle> {
        let mut c = Cursor { buf: bytes, pos: 0 };
        let seed = c.u64()?;

        let q_floor = c.u8()?;
        let q_len = c.u32()? as usize;
        if q_len > QUANTILE_CAP {
            return Err(corrupt(format!("quantile sketch holds {q_len} > cap entries")));
        }
        let mut entries = BTreeMap::new();
        let mut prev_id: Option<u64> = None;
        for _ in 0..q_len {
            let id = c.u64()?;
            let bits = c.u64()?;
            let level = c.u8()?;
            if prev_id.is_some_and(|p| p >= id) {
                return Err(corrupt("quantile sketch ids out of order".into()));
            }
            if level > 63 || level < q_floor {
                return Err(corrupt(format!("quantile level {level} vs floor {q_floor}")));
            }
            prev_id = Some(id);
            entries.insert(id, (bits, level));
        }

        let t_floor = c.u8()?;
        let t_len = c.u32()? as usize;
        if t_len > TOPK_CAP {
            return Err(corrupt(format!("top-k sketch holds {t_len} > cap keys")));
        }
        let mut keys = BTreeMap::new();
        let mut prev_key: Option<u64> = None;
        for _ in 0..t_len {
            let key = c.u64()?;
            let count = c.u64()?;
            let level = c.u8()?;
            if prev_key.is_some_and(|p| p >= key) {
                return Err(corrupt("top-k sketch keys out of order".into()));
            }
            if level > 63 || level < t_floor || count == 0 {
                return Err(corrupt(format!("top-k entry level {level} count {count}")));
            }
            prev_key = Some(key);
            keys.insert(key, (count, level));
        }

        let d_len = c.u32()? as usize;
        if d_len > DISTINCT_BUCKETS * 56 {
            return Err(corrupt(format!("distinct sketch holds {d_len} cells")));
        }
        let mut cells = BTreeMap::new();
        let mut prev_cell: Option<(u8, u8)> = None;
        for _ in 0..d_len {
            let bucket = c.u8()?;
            let rho = c.u8()?;
            let count = c.u64()?;
            if prev_cell.is_some_and(|p| p >= (bucket, rho)) {
                return Err(corrupt("distinct sketch cells out of order".into()));
            }
            if rho == 0 || rho > 56 || count == 0 {
                return Err(corrupt(format!("distinct cell rho {rho} count {count}")));
            }
            prev_cell = Some((bucket, rho));
            cells.insert((bucket, rho), count);
        }

        if c.pos != bytes.len() {
            return Err(corrupt(format!(
                "{} trailing bytes after sketch bundle",
                bytes.len() - c.pos
            )));
        }
        Ok(SketchBundle {
            quantile: QuantileSketch { seed, floor: q_floor, entries },
            topk: TopKSketch { seed, floor: t_floor, keys },
            distinct: DistinctSketch { seed, cells },
        })
    }
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn corrupt(msg: String) -> Error {
    Error::Checkpoint(format!("sketch bundle: {msg}"))
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| corrupt("truncated".into()))?;
        let slice = &self.buf[self.pos..end];
        self.pos = end;
        Ok(slice)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let arr: [u8; 4] =
            self.take(4)?.try_into().map_err(|_| corrupt("short u32 read".into()))?;
        Ok(u32::from_le_bytes(arr))
    }

    fn u64(&mut self) -> Result<u64> {
        let arr: [u8; 8] =
            self.take(8)?.try_into().map_err(|_| corrupt("short u64 read".into()))?;
        Ok(u64::from_le_bytes(arr))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hash::fnv1a;
    use crate::util::rng::Rng;

    fn rec(id: u64, key: u64, value: f64) -> Record {
        Record::new(id, 0, id, key, value)
    }

    fn arb_records(rng: &mut Rng, n: usize) -> Vec<Record> {
        (0..n as u64).map(|i| rec(i, rng.below(40) as u64, rng.normal_with(50.0, 20.0))).collect()
    }

    #[test]
    fn quantile_is_exact_below_the_cap() {
        let mut s = QuantileSketch::new(3);
        for i in 0..100u64 {
            s.insert(i, i as f64);
        }
        assert_eq!(s.floor(), 0);
        assert_eq!(s.kept(), 100);
        assert_eq!(s.rank_error(0.95), 0.0, "exact sketches declare zero rank error");
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 99.0);
        assert_eq!(s.quantile(0.5), 50.0, "nearest rank of q=0.5 over 0..=99");
        // Empty sketch: defined answers, no claim.
        let empty = QuantileSketch::new(3);
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.rank_error(0.95), 1.0);
    }

    #[test]
    fn quantile_compacts_to_cap_and_reports_dkw_error() {
        let mut s = QuantileSketch::new(17);
        let n = 5000u64;
        for i in 0..n {
            s.insert(i, i as f64);
        }
        assert!(s.kept() <= QUANTILE_CAP);
        assert!(s.floor() > 0, "5000 inserts must exceed a 256-entry cap");
        let eps = s.rank_error(0.95);
        assert!(eps > 0.0 && eps < 1.0);
        // The declared 99.99%-confidence rank band must hold for the
        // median (a deterministic check: fixed seed, fixed input).
        let wide = s.rank_error(0.9999);
        let med = s.quantile(0.5);
        let observed = (med / (n - 1) as f64 - 0.5).abs();
        assert!(
            observed <= wide,
            "median rank error {observed:.4} exceeds declared {wide:.4}"
        );
    }

    #[test]
    fn topk_counts_are_exact_below_the_cap() {
        let mut s = TopKSketch::new(5);
        for _ in 0..30 {
            s.insert(7);
        }
        for _ in 0..20 {
            s.insert(3);
        }
        s.insert(11);
        assert_eq!(s.floor(), 0);
        assert_eq!(s.coverage(), 1.0);
        let top = s.top_k(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0], TopEntry { key: 7, count_lo: 30, count_hi: 30 });
        assert_eq!(top[1], TopEntry { key: 3, count_lo: 20, count_hi: 20 });
        // Ties break by key ascending, deterministically.
        let mut t = TopKSketch::new(5);
        t.insert(9);
        t.insert(2);
        let tied = t.top_k(2);
        assert_eq!(tied[0].key, 2);
        assert_eq!(tied[1].key, 9);
    }

    #[test]
    fn topk_compaction_keeps_exact_counts_for_survivors() {
        let mut s = TopKSketch::new(23);
        // 1000 distinct keys, key k inserted (k % 5 + 1) times.
        for k in 0..1000u64 {
            for _ in 0..(k % 5 + 1) {
                s.insert(k);
            }
        }
        assert!(s.floor() > 0, "1000 keys must exceed a 128-key cap");
        assert!(s.coverage() < 1.0);
        for e in s.top_k(TOPK_CAP) {
            assert_eq!(e.count_lo, e.count_hi, "retained counts are exact");
            assert_eq!(e.count_lo, e.key % 5 + 1, "count of key {} is wrong", e.key);
        }
    }

    #[test]
    fn distinct_estimate_tracks_true_cardinality() {
        let mut s = DistinctSketch::new(29);
        let truth = 10_000u64;
        for k in 0..truth {
            s.insert(k);
            // Duplicates must not move the estimate's registers.
            if k % 3 == 0 {
                s.insert(k);
            }
        }
        let est = s.estimate();
        let rel = (est - truth as f64).abs() / truth as f64;
        // 4x the declared standard error — a deterministic check.
        assert!(rel <= 4.0 * s.std_error(), "relative error {rel:.3} too large");
        assert_eq!(s.std_error(), 1.04 / 16.0);
        assert_eq!(DistinctSketch::new(29).estimate(), 0.0);
    }

    #[test]
    fn distinct_delete_is_the_exact_inverse_of_insert() {
        let keep: Vec<u64> = (0..500).collect();
        let churn: Vec<u64> = (500..900).collect();
        let mut s = DistinctSketch::new(31);
        for &k in keep.iter().chain(&churn) {
            s.insert(k);
        }
        for &k in &churn {
            s.delete(k);
        }
        let mut direct = DistinctSketch::new(31);
        for &k in &keep {
            direct.insert(k);
        }
        assert_eq!(s, direct, "delete must equal rebuild-from-scratch");
        // Deleting an absent key is a no-op.
        let before = s.clone();
        s.delete(123_456);
        assert_eq!(s, before);
    }

    #[test]
    fn columnar_feed_matches_record_feed() {
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..10 {
            let records = arb_records(&mut rng, 150 + case * 113);
            let by_rows = SketchBundle::from_records(11, &records);
            let by_cols = SketchBundle::from_columns(11, &ColumnarBatch::from_records(&records));
            assert_eq!(by_cols, by_rows);
            assert_eq!(by_cols.to_bytes(), by_rows.to_bytes(), "byte-identical, case {case}");
        }
    }

    #[test]
    fn merge_is_bit_identical_to_rebuild() {
        let mut rng = Rng::new(0xFACE);
        for case in 0..20 {
            let n = 200 + case * 97;
            let records = arb_records(&mut rng, n);
            let direct = SketchBundle::from_records(42, &records);
            // Split into uneven chunks, sketch each, merge in reverse.
            let cut1 = n / 3;
            let cut2 = 2 * n / 3 + 7;
            let parts = [&records[..cut1], &records[cut1..cut2], &records[cut2..]];
            let mut merged = SketchBundle::new(42);
            for part in parts.iter().rev() {
                merged.merge(&SketchBundle::from_records(42, part));
            }
            assert_eq!(merged, direct);
            assert_eq!(merged.to_bytes(), direct.to_bytes(), "byte-identical, case {case}");
        }
    }

    #[test]
    fn bundle_bytes_roundtrip_and_reject_corruption() {
        let mut rng = Rng::new(0xB0B);
        let records = arb_records(&mut rng, 700);
        let bundle = SketchBundle::from_records(9, &records);
        let bytes = bundle.to_bytes();
        let back = SketchBundle::from_bytes(&bytes).unwrap();
        assert_eq!(back, bundle);
        assert_eq!(back.to_bytes(), bytes, "decode/encode is canonical");

        // Truncation at every prefix length fails loudly.
        for cut in [0, 1, 7, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                matches!(SketchBundle::from_bytes(&bytes[..cut]), Err(Error::Checkpoint(_))),
                "truncation at {cut} must be rejected"
            );
        }
        // Trailing garbage fails.
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(SketchBundle::from_bytes(&long), Err(Error::Checkpoint(_))));
        // An implausible length field fails (offsets 0..8 = seed,
        // 8 = q_floor, 9..13 = q_len LE; 12 is q_len's high byte).
        let mut bad = bytes.clone();
        bad[12] = 0xFF;
        assert!(matches!(SketchBundle::from_bytes(&bad), Err(Error::Checkpoint(_))));
    }

    #[test]
    fn golden_vectors_pin_the_wire_layout() {
        // Tiny bundle: full byte image. Any layout, hash, or ordering
        // drift shows up here at `cargo test` time.
        let records = [rec(1, 10, 1.5), rec(2, 10, -2.25), rec(3, 42, 100.0)];
        let bundle = SketchBundle::from_records(7, &records);
        let hex: String = bundle.to_bytes().iter().map(|b| format!("{b:02x}")).collect();
        assert_eq!(hex, GOLDEN_SMALL_HEX);

        // Larger bundle: pinned digest.
        let records: Vec<Record> =
            (0..64u64).map(|i| rec(i, i % 7, i as f64 * 0.5 - 16.0)).collect();
        let bundle = SketchBundle::from_records(0xDEAD_BEEF, &records);
        assert_eq!(fnv1a(&bundle.to_bytes()), GOLDEN_LARGE_DIGEST);
    }

    const GOLDEN_SMALL_HEX: &str = "070000000000000000030000000100000000000000000000000000f83f000200\
                                    00000000000000000000000002c0010300000000000000000000000000594001\
                                    00020000000a000000000000000200000000000000002a000000000000000100\
                                    00000000000000020000000b02010000000000000026010200000000000000";
    const GOLDEN_LARGE_DIGEST: u64 = 0xEE55_6A44_65A7_2ADE;
}
