//! Data-parallel job execution over the biased sample.
//!
//! * [`moments`] — the sub-computation result type (count, Σv, Σv², min,
//!   max) with an exact combine, mirroring the L1 kernel's output row.
//! * [`aggregate`] — per-query aggregate derivation (sum / mean / count /
//!   variance / stddev / extrema, plus the sketch-backed quantile /
//!   top-K / distinct kinds) from the shared per-stratum moments — the
//!   O(strata) fold that lets one window's memoized state answer N
//!   concurrent queries.
//! * [`sketch`] — mergeable, byte-deterministic synopses (level-filtered
//!   quantile + top-K, refcounted HLL) memoized per chunk next to the
//!   moments; the substrate behind the non-moment aggregate kinds.
//! * [`chunk`] — content-defined chunking of per-stratum item lists into
//!   stable, memoizable map-task inputs (Incoop-style stable partitioning:
//!   boundaries depend on item ids, not positions, so window overlap
//!   yields identical chunks and identical memo keys).
//! * [`plan`] — builds the window's job plan + DDG: which chunks hit the
//!   memo, which must execute.
//! * [`executor`] — the worker-pool backend that computes fresh chunks
//!   (native scalar path; the PJRT path lives in `runtime/`).

pub mod aggregate;
pub mod chunk;
pub mod map_fn;
pub mod executor;
pub mod moments;
pub mod plan;
pub mod sketch;

pub use aggregate::{
    derive_aggregate, derive_aggregate_sketched, AggregateKind, DerivedAggregate, ErrorSurface,
};
pub use sketch::{DistinctSketch, QuantileSketch, SketchBundle, TopEntry, TopKSketch};
pub use chunk::{chunk_stratum, chunk_stratum_cached, Chunk};
pub use map_fn::apply_map;
pub use executor::{ChunkBackend, NativeBackend, WorkerPool};
pub use moments::Moments;
pub use plan::{JobPlan, PlannedChunk};
