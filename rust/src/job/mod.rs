//! Data-parallel job execution over the biased sample.
//!
//! * [`moments`] — the sub-computation result type (count, Σv, Σv², min,
//!   max) with an exact combine, mirroring the L1 kernel's output row.
//! * [`aggregate`] — per-query aggregate derivation (sum / mean / count /
//!   variance / stddev / extrema) from the shared per-stratum moments —
//!   the O(strata) fold that lets one window's memoized state answer N
//!   concurrent queries.
//! * [`chunk`] — content-defined chunking of per-stratum item lists into
//!   stable, memoizable map-task inputs (Incoop-style stable partitioning:
//!   boundaries depend on item ids, not positions, so window overlap
//!   yields identical chunks and identical memo keys).
//! * [`plan`] — builds the window's job plan + DDG: which chunks hit the
//!   memo, which must execute.
//! * [`executor`] — the worker-pool backend that computes fresh chunks
//!   (native scalar path; the PJRT path lives in `runtime/`).

pub mod aggregate;
pub mod chunk;
pub mod map_fn;
pub mod executor;
pub mod moments;
pub mod plan;

pub use aggregate::{derive_aggregate, AggregateKind, DerivedAggregate};
pub use chunk::{chunk_stratum, chunk_stratum_cached, Chunk};
pub use map_fn::apply_map;
pub use executor::{ChunkBackend, NativeBackend, WorkerPool};
pub use moments::Moments;
pub use plan::{JobPlan, PlannedChunk};
