//! Query aggregates derived from the shared per-stratum [`Moments`].
//!
//! The paper's memoized sub-computation (a chunk's masked moments) is the
//! reusable asset of the whole system: once a window's per-stratum
//! `Moments` exist, *every* aggregate a query could ask for — sum, mean,
//! count, variance, standard deviation, extrema — is a pure O(strata)
//! fold over them. That is what lets a
//! [`Session`](crate::coordinator::Session) serve N concurrent queries
//! from **one** sample, one memo store, and one batched backend call per
//! slide: the per-query cost is derivation only, never sampling or chunk
//! execution.
//!
//! ## Error bounds per kind
//!
//! * [`AggregateKind::Sum`] / [`AggregateKind::Mean`] carry the rigorous
//!   stratified confidence interval of §3.5 (Eqs 3.2–3.4) via
//!   [`estimate_sum`] / [`estimate_mean`].
//! * [`AggregateKind::Count`] is **exact** (the per-stratum populations
//!   are exact window counts, not sampled), so its margin is 0.
//! * [`AggregateKind::Variance`] / [`AggregateKind::StdDev`] are point
//!   estimates (margin 0): a rigorous interval would need fourth moments
//!   the chunk kernel does not produce. The estimate expands per-stratum
//!   sums Eq 3.2-style: `σ̂² = τ̂₂/N − (τ̂/N)²`.
//! * [`AggregateKind::Extrema`] reports the sample extrema (margin 0).
//!   On the §4.2.2 inverse-reduce path `min`/`max` are *conservative*
//!   (`min ≤ true min`, `max ≥ true max` — removing an extremal item
//!   loses information), mirroring the paper's deferral of extreme-value
//!   error estimation (§3.5.1).

use std::collections::BTreeMap;

use crate::error::Result;
use crate::job::moments::Moments;
use crate::stats::stratified::{estimate_mean, estimate_sum, Estimate, StratumAgg};
use crate::workload::record::StratumId;

/// The aggregate a query asks for over the (optionally filtered) window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AggregateKind {
    /// Estimated population total τ̂ with a §3.5 confidence interval.
    Sum,
    /// Estimated population mean μ̂ = τ̂ / N with a confidence interval.
    Mean,
    /// Exact item count over the queried strata (populations are exact).
    Count,
    /// Estimated population variance (point estimate, margin 0).
    Variance,
    /// Estimated population standard deviation (point estimate, margin 0).
    StdDev,
    /// Sample extrema; conservative bounds on the inverse-reduce path.
    Extrema,
}

impl AggregateKind {
    /// Display name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Sum => "sum",
            Self::Mean => "mean",
            Self::Count => "count",
            Self::Variance => "variance",
            Self::StdDev => "stddev",
            Self::Extrema => "extrema",
        }
    }

    /// Does this kind carry a rigorous §3.5 confidence interval? The
    /// remaining kinds report margin 0 (exact, or a point estimate).
    pub fn has_error_bounds(&self) -> bool {
        matches!(self, Self::Sum | Self::Mean)
    }

    /// All kinds, in a fixed order (test matrices, benches).
    pub const ALL: [AggregateKind; 6] = [
        AggregateKind::Sum,
        AggregateKind::Mean,
        AggregateKind::Count,
        AggregateKind::Variance,
        AggregateKind::StdDev,
        AggregateKind::Extrema,
    ];
}

/// One derived query answer plus its accounting.
#[derive(Debug, Clone, Copy)]
pub struct DerivedAggregate {
    /// The answer with its (possibly zero) margin.
    pub estimate: Estimate,
    /// Sampled items that backed the answer (Σ bᵢ over queried strata).
    pub sample_size: usize,
    /// Window population over the queried strata (Σ Bᵢ).
    pub population: u64,
    /// `(min, max)` of the queried sample, when observed (`Extrema`).
    pub extrema: Option<(f64, f64)>,
    /// Strata folded over — the per-query derive work, O(strata).
    pub strata_touched: u64,
}

/// Derive one aggregate from the window's shared per-stratum moments and
/// exact populations. `stratum` restricts the query to one stratum
/// (`None` = whole window). Pure and O(strata): this is the *entire*
/// per-query, per-slide cost of a multi-query session.
pub fn derive_aggregate(
    kind: AggregateKind,
    stratum: Option<StratumId>,
    confidence: f64,
    moments: &BTreeMap<StratumId, Moments>,
    populations: &BTreeMap<StratumId, u64>,
) -> Result<DerivedAggregate> {
    let mut aggs: Vec<StratumAgg> = Vec::with_capacity(moments.len());
    let mut sample_size = 0usize;
    let mut population = 0u64;
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    let mut strata_touched = 0u64;
    for (&s, m) in moments {
        if stratum.is_some_and(|want| want != s) {
            continue;
        }
        strata_touched += 1;
        let pop = populations.get(&s).copied().unwrap_or(0);
        aggs.push(StratumAgg::from_moments(m, pop as f64));
        sample_size += m.count as usize;
        population += pop;
        min = min.min(m.min);
        max = max.max(m.max);
    }
    let estimate = match kind {
        AggregateKind::Sum => estimate_sum(&aggs, confidence)?,
        AggregateKind::Mean => estimate_mean(&aggs, confidence)?,
        AggregateKind::Count => exact(population as f64, confidence),
        AggregateKind::Variance => exact(variance_of(&aggs), confidence),
        AggregateKind::StdDev => exact(variance_of(&aggs).sqrt(), confidence),
        AggregateKind::Extrema => {
            exact(if max.is_finite() { max } else { 0.0 }, confidence)
        }
    };
    let extrema = if kind == AggregateKind::Extrema && min.is_finite() && max.is_finite() {
        Some((min, max))
    } else {
        None
    };
    Ok(DerivedAggregate { estimate, sample_size, population, extrema, strata_touched })
}

/// A margin-free estimate (exact answers and point estimates).
fn exact(value: f64, confidence: f64) -> Estimate {
    Estimate { value, margin: 0.0, variance: 0.0, df: 0.0, t: 0.0, confidence }
}

/// Estimated population variance by stratified expansion of the first
/// two moments: `τ̂ = Σ (Bᵢ/bᵢ)·Σv`, `τ̂₂ = Σ (Bᵢ/bᵢ)·Σv²`, then
/// `σ̂² = τ̂₂/N − (τ̂/N)²` (clamped at 0 against round-off).
fn variance_of(aggs: &[StratumAgg]) -> f64 {
    let mut n = 0.0;
    let mut tau = 0.0;
    let mut tau2 = 0.0;
    for a in aggs {
        if a.b <= 0.0 {
            continue;
        }
        n += a.population;
        tau += a.population / a.b * a.sum;
        tau2 += a.population / a.b * a.sumsq;
    }
    if n <= 0.0 {
        return 0.0;
    }
    let mean = tau / n;
    (tau2 / n - mean * mean).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::record::Record;

    /// Shared fixture: two strata fully enumerated (sample == population)
    /// so every estimator collapses to the exact answer.
    fn census() -> (BTreeMap<StratumId, Moments>, BTreeMap<StratumId, u64>) {
        let mut moments = BTreeMap::new();
        let mut pops = BTreeMap::new();
        moments.insert(0, Moments::from_values(&[1.0, 2.0, 3.0]));
        pops.insert(0, 3);
        moments.insert(1, Moments::from_values(&[10.0, 20.0]));
        pops.insert(1, 2);
        (moments, pops)
    }

    #[test]
    fn census_sum_mean_count_are_exact() {
        let (m, p) = census();
        let sum = derive_aggregate(AggregateKind::Sum, None, 0.95, &m, &p).unwrap();
        assert_eq!(sum.estimate.value, 36.0);
        assert_eq!(sum.estimate.margin, 0.0, "census: FPC zeroes the margin");
        assert_eq!(sum.sample_size, 5);
        assert_eq!(sum.population, 5);
        assert_eq!(sum.strata_touched, 2);
        let mean = derive_aggregate(AggregateKind::Mean, None, 0.95, &m, &p).unwrap();
        assert!((mean.estimate.value - 36.0 / 5.0).abs() < 1e-12);
        let count = derive_aggregate(AggregateKind::Count, None, 0.95, &m, &p).unwrap();
        assert_eq!(count.estimate.value, 5.0);
        assert_eq!(count.estimate.margin, 0.0);
    }

    #[test]
    fn census_variance_matches_population_variance() {
        let (m, p) = census();
        let values = [1.0f64, 2.0, 3.0, 10.0, 20.0];
        let mean = values.iter().sum::<f64>() / values.len() as f64;
        let want =
            values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64;
        let var = derive_aggregate(AggregateKind::Variance, None, 0.95, &m, &p).unwrap();
        assert!((var.estimate.value - want).abs() < 1e-9, "{} vs {want}", var.estimate.value);
        let sd = derive_aggregate(AggregateKind::StdDev, None, 0.95, &m, &p).unwrap();
        assert_eq!(sd.estimate.value.to_bits(), var.estimate.value.sqrt().to_bits());
    }

    #[test]
    fn extrema_reports_min_max() {
        let (m, p) = census();
        let e = derive_aggregate(AggregateKind::Extrema, None, 0.95, &m, &p).unwrap();
        assert_eq!(e.estimate.value, 20.0);
        assert_eq!(e.extrema, Some((1.0, 20.0)));
        assert_eq!(e.estimate.margin, 0.0);
    }

    #[test]
    fn stratum_filter_restricts_the_fold() {
        let (m, p) = census();
        let sum = derive_aggregate(AggregateKind::Sum, Some(1), 0.9, &m, &p).unwrap();
        assert_eq!(sum.estimate.value, 30.0);
        assert_eq!(sum.population, 2);
        assert_eq!(sum.strata_touched, 1);
        assert_eq!(sum.estimate.confidence, 0.9);
        // Absent stratum: empty fold, zero answer, zero work beyond the scan.
        let none = derive_aggregate(AggregateKind::Sum, Some(99), 0.9, &m, &p).unwrap();
        assert_eq!(none.estimate.value, 0.0);
        assert_eq!(none.strata_touched, 0);
        assert_eq!(none.population, 0);
    }

    #[test]
    fn sampled_stratum_gets_a_positive_margin() {
        // 3 of 30 sampled → expansion + a real confidence interval.
        let mut m = BTreeMap::new();
        let mut p = BTreeMap::new();
        m.insert(0, Moments::from_values(&[1.0, 2.0, 6.0]));
        p.insert(0, 30);
        let sum = derive_aggregate(AggregateKind::Sum, None, 0.95, &m, &p).unwrap();
        assert!((sum.estimate.value - 90.0).abs() < 1e-12, "10× expansion");
        assert!(sum.estimate.margin > 0.0);
        assert!(AggregateKind::Sum.has_error_bounds());
        assert!(!AggregateKind::Variance.has_error_bounds());
    }

    #[test]
    fn empty_moments_yield_zero_answers() {
        let m = BTreeMap::new();
        let p = BTreeMap::new();
        for kind in AggregateKind::ALL {
            let d = derive_aggregate(kind, None, 0.95, &m, &p).unwrap();
            assert_eq!(d.estimate.value, 0.0, "{}", kind.name());
            assert_eq!(d.extrema, None);
            assert_eq!(d.strata_touched, 0);
        }
    }

    #[test]
    fn derivation_from_combined_chunks_matches_direct_records() {
        // The sharing theorem in miniature: moments built by chunked
        // combine (how the driver produces them) derive the same answers
        // as a direct pass over the records.
        let records: Vec<Record> =
            (0..100u64).map(|i| Record::new(i, (i % 3) as u32, i, 0, (i % 13) as f64 + 0.5)).collect();
        let mut by_stratum: BTreeMap<StratumId, Vec<Record>> = BTreeMap::new();
        for r in &records {
            by_stratum.entry(r.stratum).or_default().push(*r);
        }
        let mut chunked = BTreeMap::new();
        let mut direct = BTreeMap::new();
        let mut pops = BTreeMap::new();
        for (&s, recs) in &by_stratum {
            let chunks = crate::job::chunk::chunk_stratum(s, recs, 8);
            let parts: Vec<Moments> =
                chunks.iter().map(|c| Moments::from_records(&c.items)).collect();
            chunked.insert(s, Moments::combine_all(parts.iter()));
            direct.insert(s, Moments::from_records(recs));
            pops.insert(s, recs.len() as u64);
        }
        for kind in AggregateKind::ALL {
            let a = derive_aggregate(kind, None, 0.95, &chunked, &pops).unwrap();
            let b = derive_aggregate(kind, None, 0.95, &direct, &pops).unwrap();
            let tol = 1e-9 * b.estimate.value.abs().max(1.0);
            assert!(
                (a.estimate.value - b.estimate.value).abs() <= tol,
                "{}: {} vs {}",
                kind.name(),
                a.estimate.value,
                b.estimate.value
            );
        }
    }

    #[test]
    fn kind_names_are_stable() {
        let names: Vec<&str> = AggregateKind::ALL.iter().map(|k| k.name()).collect();
        assert_eq!(names, ["sum", "mean", "count", "variance", "stddev", "extrema"]);
    }
}
