//! Query aggregates derived from the shared per-stratum [`Moments`].
//!
//! The paper's memoized sub-computation (a chunk's masked moments) is the
//! reusable asset of the whole system: once a window's per-stratum
//! `Moments` exist, *every* aggregate a query could ask for — sum, mean,
//! count, variance, standard deviation, extrema — is a pure O(strata)
//! fold over them. That is what lets a
//! [`Session`](crate::coordinator::Session) serve N concurrent queries
//! from **one** sample, one memo store, and one batched backend call per
//! slide: the per-query cost is derivation only, never sampling or chunk
//! execution.
//!
//! ## Error bounds per kind
//!
//! * [`AggregateKind::Sum`] / [`AggregateKind::Mean`] carry the rigorous
//!   stratified confidence interval of §3.5 (Eqs 3.2–3.4) via
//!   [`estimate_sum`] / [`estimate_mean`].
//! * [`AggregateKind::Count`] is **exact** (the per-stratum populations
//!   are exact window counts, not sampled), so its margin is 0.
//! * [`AggregateKind::Variance`] / [`AggregateKind::StdDev`] are point
//!   estimates (margin 0): a rigorous interval would need fourth moments
//!   the chunk kernel does not produce. The estimate expands per-stratum
//!   sums Eq 3.2-style: `σ̂² = τ̂₂/N − (τ̂/N)²`.
//! * [`AggregateKind::Extrema`] reports the sample extrema (margin 0).
//!   On the §4.2.2 inverse-reduce path `min`/`max` are *conservative*
//!   (`min ≤ true min`, `max ≥ true max` — removing an extremal item
//!   loses information), mirroring the paper's deferral of extreme-value
//!   error estimation (§3.5.1).
//! * [`AggregateKind::Quantile`] / [`AggregateKind::TopK`] /
//!   [`AggregateKind::DistinctCount`] are **sketch-backed**
//!   ([`crate::job::sketch`]): the §3.5 moment interval does not apply
//!   to rank, count, or cardinality statistics, so their `Estimate`
//!   margin stays 0 and the honest uncertainty lives in the
//!   kind-appropriate [`ErrorSurface`] instead (DKW rank error,
//!   guaranteed count bounds + coverage, HLL standard error).

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::job::moments::Moments;
use crate::job::sketch::{SketchBundle, TopEntry, DISTINCT_BUCKETS};
use crate::stats::stratified::{estimate_mean, estimate_sum, Estimate, StratumAgg};
use crate::workload::record::StratumId;

/// The aggregate a query asks for over the (optionally filtered) window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AggregateKind {
    /// Estimated population total τ̂ with a §3.5 confidence interval.
    Sum,
    /// Estimated population mean μ̂ = τ̂ / N with a confidence interval.
    Mean,
    /// Exact item count over the queried strata (populations are exact).
    Count,
    /// Estimated population variance (point estimate, margin 0).
    Variance,
    /// Estimated population standard deviation (point estimate, margin 0).
    StdDev,
    /// Sample extrema; conservative bounds on the inverse-reduce path.
    Extrema,
    /// Sketch-backed quantile at `q = permille / 1000` (e.g. `Quantile(990)`
    /// is p99). Reports a DKW rank-error surface.
    Quantile(u16),
    /// Sketch-backed `k` heaviest keys. Reports guaranteed count bounds
    /// plus the retained key-space coverage.
    TopK(u16),
    /// Sketch-backed distinct-key cardinality (HLL). Reports the
    /// estimator's relative standard error.
    DistinctCount,
}

impl AggregateKind {
    /// Display name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Sum => "sum",
            Self::Mean => "mean",
            Self::Count => "count",
            Self::Variance => "variance",
            Self::StdDev => "stddev",
            Self::Extrema => "extrema",
            Self::Quantile(_) => "quantile",
            Self::TopK(_) => "topk",
            Self::DistinctCount => "distinct",
        }
    }

    /// Does this kind carry a rigorous §3.5 confidence interval? The
    /// remaining kinds report margin 0 (exact, a point estimate, or a
    /// sketch answer whose uncertainty lives in its [`ErrorSurface`]).
    pub fn has_error_bounds(&self) -> bool {
        matches!(self, Self::Sum | Self::Mean)
    }

    /// Is this kind answered from the per-stratum sketch bundles rather
    /// than the moments? Sketch kinds carry an [`ErrorSurface`] and opt
    /// out of the §3.5 target-error budget loop.
    pub fn is_sketch(&self) -> bool {
        matches!(self, Self::Quantile(_) | Self::TopK(_) | Self::DistinctCount)
    }

    /// Reject parameterizations that cannot denote a valid answer.
    pub fn validate(&self) -> Result<()> {
        match self {
            Self::Quantile(permille) if !(1..=999).contains(permille) => {
                Err(Error::Config(format!(
                    "quantile permille must be in 1..=999, got {permille}"
                )))
            }
            Self::TopK(0) => Err(Error::Config("top-k needs k >= 1".into())),
            _ => Ok(()),
        }
    }

    /// All kinds, in a fixed order (test matrices, benches). Sketch
    /// kinds sit at the end so positional assertions over the moment
    /// kinds — and the checkpoint kind tags — stay stable.
    pub const ALL: [AggregateKind; 9] = [
        AggregateKind::Sum,
        AggregateKind::Mean,
        AggregateKind::Count,
        AggregateKind::Variance,
        AggregateKind::StdDev,
        AggregateKind::Extrema,
        AggregateKind::Quantile(500),
        AggregateKind::TopK(4),
        AggregateKind::DistinctCount,
    ];
}

/// The kind-appropriate uncertainty of a sketch-backed answer — never
/// the §3.5 moment interval, which would be dishonest for rank, count,
/// or cardinality statistics.
#[derive(Debug, Clone, PartialEq)]
pub enum ErrorSurface {
    /// Quantiles: with the query's confidence, the reported value's
    /// rank is within `epsilon` of the requested rank (DKW over the
    /// sketch's `kept` retained values; `0.0` = exact).
    RankError { epsilon: f64, kept: usize },
    /// Top-K: retained keys carry guaranteed `[count_lo, count_hi]`
    /// bounds (exact for this sketch), over `coverage` of key space
    /// (`1.0` = every key observed).
    CountBounds { entries: Vec<TopEntry>, coverage: f64 },
    /// Distinct count: the HLL estimator's relative standard error over
    /// `registers` registers.
    StdError { relative: f64, registers: usize },
}

/// One derived query answer plus its accounting.
#[derive(Debug, Clone)]
pub struct DerivedAggregate {
    /// The answer with its (possibly zero) margin.
    pub estimate: Estimate,
    /// Sampled items that backed the answer (Σ bᵢ over queried strata).
    pub sample_size: usize,
    /// Window population over the queried strata (Σ Bᵢ).
    pub population: u64,
    /// `(min, max)` of the queried sample, when observed (`Extrema`).
    pub extrema: Option<(f64, f64)>,
    /// Sketch-kind uncertainty; `None` for moment kinds or an empty fold.
    pub surface: Option<ErrorSurface>,
    /// Strata folded over — the per-query derive work, O(strata).
    pub strata_touched: u64,
}

/// Derive one aggregate from the window's shared per-stratum moments and
/// exact populations. `stratum` restricts the query to one stratum
/// (`None` = whole window). Pure and O(strata): this is the *entire*
/// per-query, per-slide cost of a multi-query session. Sketch kinds
/// answer zero here (no bundles supplied) — the coordinator calls
/// [`derive_aggregate_sketched`].
pub fn derive_aggregate(
    kind: AggregateKind,
    stratum: Option<StratumId>,
    confidence: f64,
    moments: &BTreeMap<StratumId, Moments>,
    populations: &BTreeMap<StratumId, u64>,
) -> Result<DerivedAggregate> {
    derive_aggregate_sketched(
        kind,
        stratum,
        confidence,
        moments,
        populations,
        &BTreeMap::new(),
    )
}

/// [`derive_aggregate`] plus the window's per-stratum sketch bundles.
/// The sketch fold rides the same O(strata) loop as the moment fold, so
/// a sketch query costs exactly as much derive work as a moment query —
/// the flat-substrate gate (`tests/session_queries.rs`) pins this at
/// N = 16 concurrent queries.
pub fn derive_aggregate_sketched(
    kind: AggregateKind,
    stratum: Option<StratumId>,
    confidence: f64,
    moments: &BTreeMap<StratumId, Moments>,
    populations: &BTreeMap<StratumId, u64>,
    sketches: &BTreeMap<StratumId, SketchBundle>,
) -> Result<DerivedAggregate> {
    let mut aggs: Vec<StratumAgg> = Vec::with_capacity(moments.len());
    let mut sample_size = 0usize;
    let mut population = 0u64;
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    let mut strata_touched = 0u64;
    let mut folded: Option<SketchBundle> = None;
    for (&s, m) in moments {
        if stratum.is_some_and(|want| want != s) {
            continue;
        }
        strata_touched += 1;
        let pop = populations.get(&s).copied().unwrap_or(0);
        aggs.push(StratumAgg::from_moments(m, pop as f64));
        sample_size += m.count as usize;
        population += pop;
        min = min.min(m.min);
        max = max.max(m.max);
        if kind.is_sketch() {
            if let Some(b) = sketches.get(&s) {
                match &mut folded {
                    Some(acc) => acc.merge(b),
                    None => folded = Some(b.clone()),
                }
            }
        }
    }
    let (estimate, surface) = match kind {
        AggregateKind::Sum => (estimate_sum(&aggs, confidence)?, None),
        AggregateKind::Mean => (estimate_mean(&aggs, confidence)?, None),
        AggregateKind::Count => (exact(population as f64, confidence), None),
        AggregateKind::Variance => (exact(variance_of(&aggs), confidence), None),
        AggregateKind::StdDev => (exact(variance_of(&aggs).sqrt(), confidence), None),
        AggregateKind::Extrema => {
            (exact(if max.is_finite() { max } else { 0.0 }, confidence), None)
        }
        AggregateKind::Quantile(permille) => match &folded {
            Some(b) if !b.quantile.is_empty() => (
                exact(b.quantile.quantile(permille as f64 / 1000.0), confidence),
                Some(ErrorSurface::RankError {
                    epsilon: b.quantile.rank_error(confidence),
                    kept: b.quantile.kept(),
                }),
            ),
            _ => (exact(0.0, confidence), None),
        },
        AggregateKind::TopK(k) => match &folded {
            Some(b) if !b.topk.is_empty() => {
                let entries = b.topk.top_k(k as usize);
                let value = entries.first().map(|e| e.count_hi as f64).unwrap_or(0.0);
                let coverage = b.topk.coverage();
                (
                    exact(value, confidence),
                    Some(ErrorSurface::CountBounds { entries, coverage }),
                )
            }
            _ => (exact(0.0, confidence), None),
        },
        AggregateKind::DistinctCount => match &folded {
            Some(b) if !b.distinct.is_empty() => (
                exact(b.distinct.estimate(), confidence),
                Some(ErrorSurface::StdError {
                    relative: b.distinct.std_error(),
                    registers: DISTINCT_BUCKETS,
                }),
            ),
            _ => (exact(0.0, confidence), None),
        },
    };
    let extrema = if kind == AggregateKind::Extrema && min.is_finite() && max.is_finite() {
        Some((min, max))
    } else {
        None
    };
    Ok(DerivedAggregate { estimate, sample_size, population, extrema, surface, strata_touched })
}

/// A margin-free estimate (exact answers and point estimates).
fn exact(value: f64, confidence: f64) -> Estimate {
    Estimate { value, margin: 0.0, variance: 0.0, df: 0.0, t: 0.0, confidence }
}

/// Estimated population variance by stratified expansion of the first
/// two moments: `τ̂ = Σ (Bᵢ/bᵢ)·Σv`, `τ̂₂ = Σ (Bᵢ/bᵢ)·Σv²`, then
/// `σ̂² = τ̂₂/N − (τ̂/N)²` (clamped at 0 against round-off).
fn variance_of(aggs: &[StratumAgg]) -> f64 {
    let mut n = 0.0;
    let mut tau = 0.0;
    let mut tau2 = 0.0;
    for a in aggs {
        if a.b <= 0.0 {
            continue;
        }
        n += a.population;
        tau += a.population / a.b * a.sum;
        tau2 += a.population / a.b * a.sumsq;
    }
    if n <= 0.0 {
        return 0.0;
    }
    let mean = tau / n;
    (tau2 / n - mean * mean).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::record::Record;

    /// Shared fixture: two strata fully enumerated (sample == population)
    /// so every estimator collapses to the exact answer.
    fn census() -> (BTreeMap<StratumId, Moments>, BTreeMap<StratumId, u64>) {
        let mut moments = BTreeMap::new();
        let mut pops = BTreeMap::new();
        moments.insert(0, Moments::from_values(&[1.0, 2.0, 3.0]));
        pops.insert(0, 3);
        moments.insert(1, Moments::from_values(&[10.0, 20.0]));
        pops.insert(1, 2);
        (moments, pops)
    }

    #[test]
    fn census_sum_mean_count_are_exact() {
        let (m, p) = census();
        let sum = derive_aggregate(AggregateKind::Sum, None, 0.95, &m, &p).unwrap();
        assert_eq!(sum.estimate.value, 36.0);
        assert_eq!(sum.estimate.margin, 0.0, "census: FPC zeroes the margin");
        assert_eq!(sum.sample_size, 5);
        assert_eq!(sum.population, 5);
        assert_eq!(sum.strata_touched, 2);
        let mean = derive_aggregate(AggregateKind::Mean, None, 0.95, &m, &p).unwrap();
        assert!((mean.estimate.value - 36.0 / 5.0).abs() < 1e-12);
        let count = derive_aggregate(AggregateKind::Count, None, 0.95, &m, &p).unwrap();
        assert_eq!(count.estimate.value, 5.0);
        assert_eq!(count.estimate.margin, 0.0);
    }

    #[test]
    fn census_variance_matches_population_variance() {
        let (m, p) = census();
        let values = [1.0f64, 2.0, 3.0, 10.0, 20.0];
        let mean = values.iter().sum::<f64>() / values.len() as f64;
        let want =
            values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64;
        let var = derive_aggregate(AggregateKind::Variance, None, 0.95, &m, &p).unwrap();
        assert!((var.estimate.value - want).abs() < 1e-9, "{} vs {want}", var.estimate.value);
        let sd = derive_aggregate(AggregateKind::StdDev, None, 0.95, &m, &p).unwrap();
        assert_eq!(sd.estimate.value.to_bits(), var.estimate.value.sqrt().to_bits());
    }

    #[test]
    fn extrema_reports_min_max() {
        let (m, p) = census();
        let e = derive_aggregate(AggregateKind::Extrema, None, 0.95, &m, &p).unwrap();
        assert_eq!(e.estimate.value, 20.0);
        assert_eq!(e.extrema, Some((1.0, 20.0)));
        assert_eq!(e.estimate.margin, 0.0);
    }

    #[test]
    fn stratum_filter_restricts_the_fold() {
        let (m, p) = census();
        let sum = derive_aggregate(AggregateKind::Sum, Some(1), 0.9, &m, &p).unwrap();
        assert_eq!(sum.estimate.value, 30.0);
        assert_eq!(sum.population, 2);
        assert_eq!(sum.strata_touched, 1);
        assert_eq!(sum.estimate.confidence, 0.9);
        // Absent stratum: empty fold, zero answer, zero work beyond the scan.
        let none = derive_aggregate(AggregateKind::Sum, Some(99), 0.9, &m, &p).unwrap();
        assert_eq!(none.estimate.value, 0.0);
        assert_eq!(none.strata_touched, 0);
        assert_eq!(none.population, 0);
    }

    #[test]
    fn sampled_stratum_gets_a_positive_margin() {
        // 3 of 30 sampled → expansion + a real confidence interval.
        let mut m = BTreeMap::new();
        let mut p = BTreeMap::new();
        m.insert(0, Moments::from_values(&[1.0, 2.0, 6.0]));
        p.insert(0, 30);
        let sum = derive_aggregate(AggregateKind::Sum, None, 0.95, &m, &p).unwrap();
        assert!((sum.estimate.value - 90.0).abs() < 1e-12, "10× expansion");
        assert!(sum.estimate.margin > 0.0);
        assert!(AggregateKind::Sum.has_error_bounds());
        assert!(!AggregateKind::Variance.has_error_bounds());
    }

    #[test]
    fn empty_moments_yield_zero_answers() {
        let m = BTreeMap::new();
        let p = BTreeMap::new();
        for kind in AggregateKind::ALL {
            let d = derive_aggregate(kind, None, 0.95, &m, &p).unwrap();
            assert_eq!(d.estimate.value, 0.0, "{}", kind.name());
            assert_eq!(d.extrema, None);
            assert_eq!(d.strata_touched, 0);
        }
    }

    #[test]
    fn derivation_from_combined_chunks_matches_direct_records() {
        // The sharing theorem in miniature: moments built by chunked
        // combine (how the driver produces them) derive the same answers
        // as a direct pass over the records.
        let records: Vec<Record> =
            (0..100u64).map(|i| Record::new(i, (i % 3) as u32, i, 0, (i % 13) as f64 + 0.5)).collect();
        let mut by_stratum: BTreeMap<StratumId, Vec<Record>> = BTreeMap::new();
        for r in &records {
            by_stratum.entry(r.stratum).or_default().push(*r);
        }
        let mut chunked = BTreeMap::new();
        let mut direct = BTreeMap::new();
        let mut pops = BTreeMap::new();
        for (&s, recs) in &by_stratum {
            let chunks = crate::job::chunk::chunk_stratum(s, recs, 8).unwrap();
            let parts: Vec<Moments> =
                chunks.iter().map(|c| Moments::from_records(c.items())).collect();
            chunked.insert(s, Moments::combine_all(parts.iter()));
            direct.insert(s, Moments::from_records(recs));
            pops.insert(s, recs.len() as u64);
        }
        for kind in AggregateKind::ALL {
            let a = derive_aggregate(kind, None, 0.95, &chunked, &pops).unwrap();
            let b = derive_aggregate(kind, None, 0.95, &direct, &pops).unwrap();
            let tol = 1e-9 * b.estimate.value.abs().max(1.0);
            assert!(
                (a.estimate.value - b.estimate.value).abs() <= tol,
                "{}: {} vs {}",
                kind.name(),
                a.estimate.value,
                b.estimate.value
            );
        }
    }

    #[test]
    fn kind_names_are_stable() {
        let names: Vec<&str> = AggregateKind::ALL.iter().map(|k| k.name()).collect();
        assert_eq!(
            names,
            ["sum", "mean", "count", "variance", "stddev", "extrema", "quantile", "topk",
             "distinct"]
        );
    }

    #[test]
    fn kind_validation_rejects_degenerate_parameters() {
        assert!(AggregateKind::Quantile(0).validate().is_err());
        assert!(AggregateKind::Quantile(1000).validate().is_err());
        assert!(AggregateKind::TopK(0).validate().is_err());
        for kind in AggregateKind::ALL {
            assert!(kind.validate().is_ok(), "{} in ALL must be valid", kind.name());
        }
    }

    /// Sketch fixture: two strata with known values/keys, plus the
    /// moments/populations the shared loop folds alongside.
    fn sketched() -> (
        BTreeMap<StratumId, Moments>,
        BTreeMap<StratumId, u64>,
        BTreeMap<StratumId, SketchBundle>,
    ) {
        let mut moments = BTreeMap::new();
        let mut pops = BTreeMap::new();
        let mut sketches = BTreeMap::new();
        // Stratum 0: values 0..10, all key 5. Stratum 1: values 100..105,
        // keys 7 (x3) and 9 (x2).
        let s0: Vec<Record> =
            (0..10u64).map(|i| Record::new(i, 0, i, 5, i as f64)).collect();
        let s1: Vec<Record> = (0..5u64)
            .map(|i| Record::new(100 + i, 1, i, if i < 3 { 7 } else { 9 }, 100.0 + i as f64))
            .collect();
        for (s, recs) in [(0u32, &s0), (1u32, &s1)] {
            moments.insert(s, Moments::from_records(recs));
            pops.insert(s, recs.len() as u64);
            sketches.insert(s, SketchBundle::from_records(77, recs));
        }
        (moments, pops, sketches)
    }

    #[test]
    fn sketch_kinds_answer_from_folded_bundles() {
        let (m, p, sk) = sketched();
        let med = derive_aggregate_sketched(
            AggregateKind::Quantile(500), None, 0.95, &m, &p, &sk,
        )
        .unwrap();
        // 15 values, all retained (floor 0): nearest rank of q=0.5 is 7.0.
        assert_eq!(med.estimate.value, 7.0);
        assert_eq!(med.estimate.margin, 0.0, "sketch kinds never claim a §3.5 interval");
        assert_eq!(med.strata_touched, 2);
        assert_eq!(med.sample_size, 15);
        assert_eq!(
            med.surface,
            Some(ErrorSurface::RankError { epsilon: 0.0, kept: 15 }),
            "below the cap the quantile sketch is exact"
        );

        let top = derive_aggregate_sketched(AggregateKind::TopK(2), None, 0.95, &m, &p, &sk)
            .unwrap();
        assert_eq!(top.estimate.value, 10.0, "top-1 count is the scalar answer");
        assert!(
            matches!(top.surface, Some(ErrorSurface::CountBounds { .. })),
            "wrong surface: {:?}",
            top.surface
        );
        if let Some(ErrorSurface::CountBounds { ref entries, coverage }) = top.surface {
            assert_eq!(coverage, 1.0);
            assert_eq!(
                entries,
                &vec![
                    TopEntry { key: 5, count_lo: 10, count_hi: 10 },
                    TopEntry { key: 7, count_lo: 3, count_hi: 3 },
                ]
            );
        }

        let distinct =
            derive_aggregate_sketched(AggregateKind::DistinctCount, None, 0.95, &m, &p, &sk)
                .unwrap();
        // 3 distinct keys; small-range linear counting is near-exact here.
        assert!(
            (distinct.estimate.value - 3.0).abs() < 0.1,
            "distinct estimate {}",
            distinct.estimate.value
        );
        assert!(matches!(
            distinct.surface,
            Some(ErrorSurface::StdError { relative, registers: DISTINCT_BUCKETS })
                if relative == 1.04 / 16.0
        ));
    }

    #[test]
    fn sketch_kinds_respect_the_stratum_filter_and_empty_input() {
        let (m, p, sk) = sketched();
        let med = derive_aggregate_sketched(
            AggregateKind::Quantile(500), Some(1), 0.95, &m, &p, &sk,
        )
        .unwrap();
        assert_eq!(med.estimate.value, 102.0, "median of 100..=104");
        assert_eq!(med.strata_touched, 1);

        // No bundles at all (the plain 5-arg path): defined zeros.
        for kind in [AggregateKind::Quantile(500), AggregateKind::TopK(2),
                     AggregateKind::DistinctCount] {
            let d = derive_aggregate(kind, None, 0.95, &m, &p).unwrap();
            assert_eq!(d.estimate.value, 0.0, "{}", kind.name());
            assert_eq!(d.surface, None);
            assert_eq!(d.strata_touched, 2, "fold accounting is kind-independent");
        }
    }
}
