//! The per-item map stage — rust twin of the kernel's `map_transform`.
//!
//! Streaming queries rarely aggregate raw record values; they parse,
//! featurize, or score each item first (the expensive "map task" of the
//! paper's data-parallel jobs). `rounds` iterations of `v += 0.25·sin v`
//! are that per-item work knob: `rounds = 0` is a pass-through (pure
//! aggregation), larger values emulate heavier user-defined maps. The
//! Pallas kernel (`python/compile/kernels/stratified_agg.py`) implements
//! the identical transform so native and PJRT results agree.

/// Apply `rounds` map iterations to one value.
#[inline]
pub fn apply_map(mut v: f64, rounds: u32) -> f64 {
    for _ in 0..rounds {
        v += 0.25 * v.sin();
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rounds_is_identity() {
        for v in [-3.5, 0.0, 1.0, 42.0] {
            assert_eq!(apply_map(v, 0), v);
        }
    }

    #[test]
    fn converges_toward_sin_zeros() {
        // Fixed points of v + 0.25 sin v are multiples of π; iteration is
        // a contraction near the stable (odd) ones.
        let v = apply_map(3.0, 200);
        assert!((v - std::f64::consts::PI).abs() < 1e-6, "{v}");
    }

    #[test]
    fn monotone_in_rounds_effect() {
        let a = apply_map(2.0, 1);
        let b = apply_map(2.0, 8);
        assert!(a != 2.0 && b != a);
    }

    #[test]
    fn bounded_output() {
        for i in 0..100 {
            let v = (i as f64 - 50.0) * 3.3;
            let out = apply_map(v, 64);
            assert!(out.is_finite());
            assert!((out - v).abs() <= 0.25 * 64.0 + 1.0);
        }
    }
}
