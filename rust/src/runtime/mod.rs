//! PJRT runtime: load AOT artifacts, compile once, execute on the hot path.
//!
//! * [`manifest`] — parses `artifacts/manifest.tsv` written by
//!   `python/compile/aot.py`.
//! * [`client`] — wraps the `xla` crate: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `compile`, caches one executable
//!   per artifact, and exposes typed entry points ([`client::PjrtRuntime::
//!   chunk_moments`]) that pack chunks into the fixed-shape literals the
//!   L2 graph was lowered with. Python never runs here — artifacts are
//!   plain HLO text files.

pub mod client;
pub mod manifest;

pub use client::{PjrtBackend, PjrtRuntime};
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest};
