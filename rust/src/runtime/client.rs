//! The PJRT execution client.
//!
//! Compiles every manifest artifact once on the CPU PJRT client and keeps
//! the loaded executables cached. The hot-path entry point,
//! [`PjrtRuntime::chunk_moments`], packs an arbitrary batch of fresh
//! chunks into the fixed `[CHUNKS, CHUNK]` shapes the artifacts were
//! lowered with: chunks longer than the row width are split across rows
//! (moments combine associatively), batches larger than the row capacity
//! run as multiple executions, and the smallest adequate variant is
//! chosen per batch to minimize padding waste.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::job::chunk::Chunk;
use crate::job::executor::ChunkBackend;
use crate::job::moments::Moments;
use crate::runtime::manifest::{ArtifactKind, ArtifactSpec, Manifest};

/// Compiled-executable cache over one PJRT client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Number of PJRT executions issued (perf accounting).
    executions: std::sync::atomic::AtomicU64,
}

impl PjrtRuntime {
    /// Load the manifest from `artifacts_dir` and eagerly compile every
    /// artifact on the CPU PJRT client.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        let rt = PjrtRuntime {
            client,
            manifest,
            exes: Mutex::new(HashMap::new()),
            executions: std::sync::atomic::AtomicU64::new(0),
        };
        for spec in rt.manifest.specs.clone() {
            rt.compile_spec(&spec)?;
        }
        Ok(rt)
    }

    fn compile_spec(&self, spec: &ArtifactSpec) -> Result<()> {
        let path = spec.path.to_str().ok_or_else(|| {
            Error::Runtime(format!("non-utf8 artifact path {:?}", spec.path))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.lock().unwrap().insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Platform name of the PJRT client (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of executions issued so far.
    pub fn execution_count(&self) -> u64 {
        self.executions.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Pick the chunk-moments variant compiled with the requested map
    /// `rounds`, with the smallest capacity that still fits `rows` rows
    /// of width ≥ `width` — or, if none fits `rows`, the variant with the
    /// largest capacity of adequate width (the batch will run as several
    /// executions).
    fn pick_chunk_variant(
        &self,
        rows: usize,
        width: usize,
        rounds: u32,
    ) -> Result<&ArtifactSpec> {
        let candidates: Vec<&ArtifactSpec> = self
            .manifest
            .specs
            .iter()
            .filter(|s| {
                s.kind == ArtifactKind::ChunkMoments && s.chunk >= width && s.rounds == rounds
            })
            .collect();
        if candidates.is_empty() {
            return Err(Error::Runtime(format!(
                "no chunk_moments artifact with width >= {width} and rounds == {rounds} \
                 (re-run `make artifacts` with this variant added to aot.py)"
            )));
        }
        if let Some(fit) = candidates
            .iter()
            .filter(|s| s.chunks >= rows)
            .min_by_key(|s| (s.chunks, s.chunk))
        {
            return Ok(fit);
        }
        Ok(candidates
            .into_iter()
            .max_by_key(|s| s.chunks)
            .expect("non-empty candidates"))
    }

    fn execute_moments(
        &self,
        spec: &ArtifactSpec,
        values: &[f32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        let exes = self.exes.lock().unwrap();
        let exe = exes
            .get(&spec.name)
            .ok_or_else(|| Error::Runtime(format!("artifact {} not compiled", spec.name)))?;
        let dims = [spec.chunks as i64, spec.chunk as i64];
        let v = xla::Literal::vec1(values).reshape(&dims)?;
        let m = xla::Literal::vec1(mask).reshape(&dims)?;
        let result = exe.execute::<xla::Literal>(&[v, m])?[0][0].to_literal_sync()?;
        self.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Lowered with return_tuple=True → a 1-tuple of [CHUNKS, 5].
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Compute moments for a batch of chunks through the AOT executable
    /// compiled with `rounds` map iterations.
    ///
    /// Returns one [`Moments`] per chunk, input order, numerically equal
    /// (within f32) to [`crate::job::executor::NativeBackend`].
    pub fn chunk_moments(&self, chunks: &[&Chunk], rounds: u32) -> Result<Vec<Moments>> {
        if chunks.is_empty() {
            return Ok(Vec::new());
        }
        // Segment long chunks into row-sized pieces.
        let max_len = chunks.iter().map(|c| c.len()).max().expect("non-empty");
        let widest = self
            .manifest
            .specs
            .iter()
            .filter(|s| s.kind == ArtifactKind::ChunkMoments && s.rounds == rounds)
            .map(|s| s.chunk)
            .max()
            .ok_or_else(|| {
                Error::Runtime(format!("no chunk_moments artifacts with rounds == {rounds}"))
            })?;
        let width = max_len.min(widest).max(1);
        // (chunk_index, start) segments, each ≤ width items.
        let mut segments: Vec<(usize, usize, usize)> = Vec::new(); // (chunk, start, len)
        for (ci, c) in chunks.iter().enumerate() {
            let mut start = 0;
            loop {
                let len = (c.len() - start).min(width);
                segments.push((ci, start, len));
                start += len;
                if start >= c.len() {
                    break;
                }
            }
        }
        let spec = self.pick_chunk_variant(segments.len(), width, rounds)?;
        let (rows_cap, row_w) = (spec.chunks, spec.chunk);
        let mut out = vec![Moments::EMPTY; chunks.len()];
        for batch in segments.chunks(rows_cap) {
            let mut values = vec![0f32; rows_cap * row_w];
            let mut mask = vec![0f32; rows_cap * row_w];
            for (row, &(ci, start, len)) in batch.iter().enumerate() {
                let vals = &chunks[ci].values()[start..start + len];
                for (j, &v) in vals.iter().enumerate() {
                    values[row * row_w + j] = v as f32;
                    mask[row * row_w + j] = 1.0;
                }
            }
            let flat = self.execute_moments(spec, &values, &mask)?;
            for (row, &(ci, _, _)) in batch.iter().enumerate() {
                let m = Moments::from_row_f32(&flat[row * 5..row * 5 + 5]);
                out[ci] = out[ci].combine(&m);
            }
        }
        Ok(out)
    }
}

/// [`ChunkBackend`] adapter so the coordinator can swap PJRT in for the
/// native scalar path.
pub struct PjrtBackend {
    runtime: std::sync::Arc<PjrtRuntime>,
    rounds: u32,
}

impl PjrtBackend {
    /// Wrap a shared runtime with no map stage.
    pub fn new(runtime: std::sync::Arc<PjrtRuntime>) -> Self {
        Self::with_rounds(runtime, 0)
    }

    /// Wrap a shared runtime using the artifacts compiled with `rounds`
    /// map iterations per item.
    pub fn with_rounds(runtime: std::sync::Arc<PjrtRuntime>, rounds: u32) -> Self {
        PjrtBackend { runtime, rounds }
    }
}

impl ChunkBackend for PjrtBackend {
    fn compute(&self, chunks: &[&Chunk]) -> Result<Vec<Moments>> {
        self.runtime.chunk_moments(chunks, self.rounds)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    //! These tests need `artifacts/` built (`make artifacts`); they are
    //! skipped gracefully when it is absent so `cargo test` works in a
    //! fresh checkout, and exercised for real by `make test`.
    use super::*;
    use crate::job::chunk::chunk_stratum;
    use crate::job::executor::NativeBackend;
    use crate::workload::record::Record;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.tsv").exists().then_some(dir)
    }

    fn chunks(n: u64, target: usize) -> Vec<Chunk> {
        let items: Vec<Record> =
            (0..n).map(|i| Record::new(i, 0, 0, 0, (i as f64 * 0.37).sin() * 10.0)).collect();
        chunk_stratum(0, &items, target).unwrap()
    }

    #[test]
    fn pjrt_matches_native_backend() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = PjrtRuntime::load(dir).unwrap();
        let cs = chunks(700, 48);
        let refs: Vec<&Chunk> = cs.iter().collect();
        let got = rt.chunk_moments(&refs, 0).unwrap();
        let want = NativeBackend::default().compute(&refs).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.count, w.count);
            assert!((g.sum - w.sum).abs() < 1e-3 * w.sum.abs().max(1.0), "{g:?} vs {w:?}");
            assert!((g.min - w.min).abs() < 1e-4);
            assert!((g.max - w.max).abs() < 1e-4);
        }
    }

    #[test]
    fn long_chunks_split_across_rows() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = PjrtRuntime::load(dir).unwrap();
        // target 200 → cap 800 ≫ widest row (256): forces splitting.
        let cs = chunks(900, 200);
        assert!(cs.iter().any(|c| c.len() > 256), "need a long chunk");
        let refs: Vec<&Chunk> = cs.iter().collect();
        let got = rt.chunk_moments(&refs, 0).unwrap();
        let want = NativeBackend::default().compute(&refs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.count, w.count);
            assert!((g.sum - w.sum).abs() < 1e-2 * w.sum.abs().max(1.0));
        }
    }

    #[test]
    fn batch_larger_than_capacity_multi_executes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = PjrtRuntime::load(dir).unwrap();
        let cs = chunks(40_000, 64); // ~600 chunks > 256-row capacity
        let refs: Vec<&Chunk> = cs.iter().collect();
        let before = rt.execution_count();
        let got = rt.chunk_moments(&refs, 0).unwrap();
        assert!(rt.execution_count() - before >= 2);
        let want = NativeBackend::default().compute(&refs).unwrap();
        let total_got: f64 = got.iter().map(|m| m.count).sum();
        let total_want: f64 = want.iter().map(|m| m.count).sum();
        assert_eq!(total_got, total_want);
    }

    #[test]
    fn empty_batch_ok() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = PjrtRuntime::load(dir).unwrap();
        assert!(rt.chunk_moments(&[], 0).unwrap().is_empty());
    }
}
