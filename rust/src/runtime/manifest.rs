//! Artifact manifest parsing.
//!
//! `aot.py` writes `manifest.tsv` with one row per lowered HLO module:
//! `kind, name, file, chunks, chunk, strata, dtype, n_outputs`.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Which L2 graph an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `[CHUNKS, CHUNK] ×2 → [CHUNKS, 5]` per-chunk moments.
    ChunkMoments,
    /// Full-window estimator `(values, mask, onehot, population) →
    /// (tau, var, stats)`.
    WindowEstimate,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "chunk_moments" => Ok(Self::ChunkMoments),
            "window_estimate" => Ok(Self::WindowEstimate),
            other => Err(Error::Runtime(format!("unknown artifact kind `{other}`"))),
        }
    }
}

/// One artifact row.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Graph kind.
    pub kind: ArtifactKind,
    /// Unique artifact name (e.g. `chunk_moments_64x128`).
    pub name: String,
    /// HLO text file path (absolute, resolved against the manifest dir).
    pub path: PathBuf,
    /// Row capacity (CHUNKS dimension).
    pub chunks: usize,
    /// Row width (CHUNK dimension).
    pub chunk: usize,
    /// Strata capacity (0 for chunk-moments artifacts).
    pub strata: usize,
    /// Tuple arity of the module output.
    pub n_outputs: usize,
    /// Per-item map rounds compiled into the module.
    pub rounds: u32,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// All artifact rows.
    pub specs: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let mut specs = Vec::new();
        for (idx, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 9 {
                return Err(Error::Runtime(format!(
                    "manifest line {}: expected 9 columns, got {}",
                    idx + 1,
                    cols.len()
                )));
            }
            let parse_usize = |i: usize, what: &str| {
                cols[i].parse::<usize>().map_err(|_| {
                    Error::Runtime(format!("manifest line {}: bad {what}", idx + 1))
                })
            };
            if cols[6] != "f32" {
                return Err(Error::Runtime(format!(
                    "manifest line {}: unsupported dtype {}",
                    idx + 1,
                    cols[6]
                )));
            }
            specs.push(ArtifactSpec {
                kind: ArtifactKind::parse(cols[0])?,
                name: cols[1].to_string(),
                path: dir.join(cols[2]),
                chunks: parse_usize(3, "chunks")?,
                chunk: parse_usize(4, "chunk")?,
                strata: parse_usize(5, "strata")?,
                n_outputs: parse_usize(7, "n_outputs")?,
                rounds: parse_usize(8, "rounds")? as u32,
            });
        }
        if specs.is_empty() {
            return Err(Error::Runtime("manifest is empty".into()));
        }
        Ok(Manifest { specs })
    }

    /// All specs of one kind.
    pub fn of_kind(&self, kind: ArtifactKind) -> Vec<&ArtifactSpec> {
        self.specs.iter().filter(|s| s.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(content: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("incapprox_manifest_{}", crate::util::hash::fnv1a(content.as_bytes())));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), content).unwrap();
        dir
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = write_manifest(
            "# header\nchunk_moments\tcm\tcm.hlo.txt\t64\t128\t0\tf32\t1\t0\n\
             window_estimate\twe\twe.hlo.txt\t64\t128\t8\tf32\t3\t0\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.specs.len(), 2);
        assert_eq!(m.of_kind(ArtifactKind::ChunkMoments).len(), 1);
        let cm = &m.specs[0];
        assert_eq!((cm.chunks, cm.chunk, cm.n_outputs), (64, 128, 1));
        assert!(cm.path.ends_with("cm.hlo.txt"));
    }

    #[test]
    fn rejects_bad_rows() {
        for bad in [
            "chunk_moments\tcm\tf.hlo\t64\t128\t0\tf32\t1\n",           // 8 cols
            "bogus_kind\tcm\tf.hlo\t64\t128\t0\tf32\t1\t0\n",          // kind
            "chunk_moments\tcm\tf.hlo\tx\t128\t0\tf32\t1\t0\n",        // chunks
            "chunk_moments\tcm\tf.hlo\t64\t128\t0\tf64\t1\t0\n",       // dtype
            "chunk_moments\tcm\tf.hlo\t64\t128\t0\tf32\t1\tx\n",       // rounds
            "",                                                          // empty
        ] {
            let dir = write_manifest(bad);
            assert!(Manifest::load(&dir).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn missing_manifest_is_friendly_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
