//! The mergeable per-partition slide state.
//!
//! [`PartitionState`] is everything one coordinator's
//! `slide_finish` produces for one window: per-stratum moments, sketch
//! bundles, exact populations, per-stratum reports, and the slide's work
//! counters. The merge law is **disjoint union plus sums**: partitions
//! own disjoint stratum ranges, so per-stratum maps merge by union (an
//! overlapping stratum is a routing bug and a hard error, never a silent
//! `Moments::combine` — float combination order would break
//! byte-determinism) and window-level scalars merge by addition.
//! That makes `merge` commutative and associative *by construction*:
//! `BTreeMap` union is order-independent, integer sums commute, and no
//! float is ever folded across partitions — floats only travel inside
//! their stratum's slot, computed by exactly one partition.

use std::collections::BTreeMap;

use crate::coordinator::report::StratumReport;
use crate::error::{Error, Result};
use crate::job::moments::Moments;
use crate::job::sketch::SketchBundle;
use crate::metrics::SlideWork;
use crate::util::hash::StableHasher;
use crate::workload::record::StratumId;

/// One partition's complete mergeable output for one window.
///
/// Produced by the driver's `slide_finish`; folded across partitions by
/// the [`MergeTier`](crate::partition::MergeTier). A solo run is the
/// degenerate K = 1 deployment: its "merge" of one state is the state
/// itself, which is why the single-coordinator path and the partitioned
/// path are byte-identical by construction.
#[derive(Debug, Clone, Default)]
pub struct PartitionState {
    /// Monotonic window sequence number (identical across partitions in
    /// lockstep; a mismatch on merge is a hard error).
    pub window_id: u64,
    /// Items in this partition's window slice (sums on merge).
    pub window_len: usize,
    /// Realized biased-sample size (sums on merge).
    pub sample_size: usize,
    /// Full-path chunks planned (sums on merge).
    pub chunks_total: usize,
    /// Full-path chunks served from memo (sums on merge).
    pub chunks_reused: usize,
    /// Items actually recomputed (sums on merge).
    pub fresh_items: usize,
    /// Per-stratum combined moments (disjoint union on merge).
    pub moments: BTreeMap<StratumId, Moments>,
    /// Per-stratum sketch bundles (disjoint union on merge; empty when
    /// no sketch-backed query is registered).
    pub sketches: BTreeMap<StratumId, SketchBundle>,
    /// Per-stratum exact populations (disjoint union on merge).
    pub populations: BTreeMap<StratumId, u64>,
    /// Per-stratum sampling/reuse reports (disjoint union on merge).
    pub strata: BTreeMap<StratumId, StratumReport>,
    /// Strata whose compute budget exhausted this slide, sorted
    /// (concatenated + re-sorted on merge — fault isolation: only the
    /// faulting partition's strata appear).
    pub degraded_strata: Vec<StratumId>,
    /// Whether a memo-loss fault fired in this partition (ORs on merge).
    pub fault_injected: bool,
    /// The slide's work counters (field-wise sums on merge).
    pub work: SlideWork,
}

/// Field-wise sum of two slides' work counters.
fn sum_work(a: SlideWork, b: SlideWork) -> SlideWork {
    SlideWork {
        window_items: a.window_items + b.window_items,
        sampler_items: a.sampler_items + b.sampler_items,
        plan_items: a.plan_items + b.plan_items,
        compute_items: a.compute_items + b.compute_items,
        derive_items: a.derive_items + b.derive_items,
        budget_adjust: a.budget_adjust + b.budget_adjust,
        sketch_items: a.sketch_items + b.sketch_items,
        checkpoint_bytes: a.checkpoint_bytes + b.checkpoint_bytes,
        restore_items: a.restore_items + b.restore_items,
        fault_injections: a.fault_injections + b.fault_injections,
        retries: a.retries + b.retries,
        merge_items: a.merge_items + b.merge_items,
    }
}

impl PartitionState {
    /// The merge identity: no strata, no items, no work. `merge(s,
    /// empty) == merge(empty, s) == s` for every state `s`.
    pub fn empty() -> PartitionState {
        PartitionState::default()
    }

    /// Is this state the merge identity? (A partition that owns no
    /// strata yet produces exactly this, modulo its window id — which
    /// the identity deliberately does not pin, so strata-less partitions
    /// never block a merge.)
    pub fn is_identity(&self) -> bool {
        self.window_len == 0
            && self.sample_size == 0
            && self.chunks_total == 0
            && self.chunks_reused == 0
            && self.fresh_items == 0
            && self.moments.is_empty()
            && self.sketches.is_empty()
            && self.populations.is_empty()
            && self.strata.is_empty()
            && self.degraded_strata.is_empty()
            && !self.fault_injected
            && self.work == SlideWork::default()
    }

    /// Fold another partition's state into this one.
    ///
    /// Commutative and associative (see module docs). Errors when the
    /// two states cover the same stratum (routing bug) or carry
    /// different window ids (lockstep bug) — never silently combines.
    pub fn merge(mut self, other: PartitionState) -> Result<PartitionState> {
        if other.is_identity() {
            return Ok(self);
        }
        if self.is_identity() {
            return Ok(other);
        }
        if self.window_id != other.window_id {
            return Err(Error::Job(format!(
                "partition states out of lockstep: window {} vs {}",
                self.window_id, other.window_id
            )));
        }
        for (s, m) in other.moments {
            if self.moments.insert(s, m).is_some() {
                return Err(overlap(s, "moments"));
            }
        }
        for (s, b) in other.sketches {
            if self.sketches.insert(s, b).is_some() {
                return Err(overlap(s, "sketches"));
            }
        }
        for (s, n) in other.populations {
            if self.populations.insert(s, n).is_some() {
                return Err(overlap(s, "populations"));
            }
        }
        for (s, r) in other.strata {
            if self.strata.insert(s, r).is_some() {
                return Err(overlap(s, "strata reports"));
            }
        }
        self.degraded_strata.extend(other.degraded_strata);
        self.degraded_strata.sort_unstable();
        self.degraded_strata.dedup();
        self.window_len += other.window_len;
        self.sample_size += other.sample_size;
        self.chunks_total += other.chunks_total;
        self.chunks_reused += other.chunks_reused;
        self.fresh_items += other.fresh_items;
        self.fault_injected |= other.fault_injected;
        self.work = sum_work(self.work, other.work);
        Ok(self)
    }

    /// Seed-stable digest of the full state (floats by bit pattern,
    /// sketches by wire encoding) — what the law tests compare to pin
    /// byte-determinism under permuted merge orders.
    pub fn digest(&self) -> u64 {
        let mut h = StableHasher::new();
        h.write_u64(self.window_id);
        h.write_u64(self.window_len as u64);
        h.write_u64(self.sample_size as u64);
        h.write_u64(self.chunks_total as u64);
        h.write_u64(self.chunks_reused as u64);
        h.write_u64(self.fresh_items as u64);
        h.write_u64(self.moments.len() as u64);
        for (s, m) in &self.moments {
            h.write_u64(u64::from(*s));
            h.write_f64(m.count);
            h.write_f64(m.sum);
            h.write_f64(m.sumsq);
            h.write_f64(m.min);
            h.write_f64(m.max);
        }
        h.write_u64(self.sketches.len() as u64);
        for (s, b) in &self.sketches {
            h.write_u64(u64::from(*s));
            h.write_bytes(&b.to_bytes());
        }
        h.write_u64(self.populations.len() as u64);
        for (s, n) in &self.populations {
            h.write_u64(u64::from(*s));
            h.write_u64(*n);
        }
        h.write_u64(self.strata.len() as u64);
        for (s, r) in &self.strata {
            h.write_u64(u64::from(*s));
            h.write_u64(r.sample_size as u64);
            h.write_u64(r.memo_reused as u64);
            h.write_u64(r.memo_available as u64);
            h.write_u64(r.population);
        }
        h.write_u64(self.degraded_strata.len() as u64);
        for s in &self.degraded_strata {
            h.write_u64(u64::from(*s));
        }
        h.write_u64(u64::from(self.fault_injected));
        for w in [
            self.work.window_items,
            self.work.sampler_items,
            self.work.plan_items,
            self.work.compute_items,
            self.work.derive_items,
            self.work.budget_adjust,
            self.work.sketch_items,
            self.work.checkpoint_bytes,
            self.work.restore_items,
            self.work.fault_injections,
            self.work.retries,
            self.work.merge_items,
        ] {
            h.write_u64(w);
        }
        h.finish()
    }
}

fn overlap(s: StratumId, what: &str) -> Error {
    Error::Job(format!(
        "partition merge overlap: stratum {s} appears in two partitions' {what} \
         (strata must be disjoint across partitions)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(window_id: u64, strata: &[(StratumId, f64)]) -> PartitionState {
        let mut st = PartitionState { window_id, ..PartitionState::default() };
        for &(s, v) in strata {
            let m = Moments { count: 1.0, sum: v, sumsq: v * v, min: v, max: v };
            st.moments.insert(s, m);
            st.populations.insert(s, 10 + u64::from(s));
            st.strata.insert(
                s,
                StratumReport {
                    sample_size: 3,
                    memo_reused: 1,
                    memo_available: 2,
                    population: 10 + u64::from(s),
                },
            );
            st.window_len += 10 + s as usize;
            st.sample_size += 3;
        }
        st
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        let a = state(4, &[(0, 1.5)]);
        let b = state(4, &[(1, 2.5)]);
        let c = state(4, &[(2, -3.0)]);
        let abc = a.clone().merge(b.clone()).unwrap().merge(c.clone()).unwrap();
        let cba = c.clone().merge(b.clone()).unwrap().merge(a.clone()).unwrap();
        let a_bc = a.clone().merge(b.clone().merge(c.clone()).unwrap()).unwrap();
        assert_eq!(abc.digest(), cba.digest());
        assert_eq!(abc.digest(), a_bc.digest());
    }

    #[test]
    fn empty_is_identity_on_both_sides() {
        let a = state(9, &[(0, 1.0), (2, 2.0)]);
        let left = PartitionState::empty().merge(a.clone()).unwrap();
        let right = a.clone().merge(PartitionState::empty()).unwrap();
        assert_eq!(left.digest(), a.digest());
        assert_eq!(right.digest(), a.digest());
    }

    #[test]
    fn overlapping_stratum_is_an_error() {
        let a = state(1, &[(0, 1.0)]);
        let b = state(1, &[(0, 2.0)]);
        let err = a.merge(b).unwrap_err();
        assert!(err.to_string().contains("overlap"), "got: {err}");
    }

    #[test]
    fn lockstep_violation_is_an_error() {
        let a = state(1, &[(0, 1.0)]);
        let b = state(2, &[(1, 2.0)]);
        let err = a.merge(b).unwrap_err();
        assert!(err.to_string().contains("lockstep"), "got: {err}");
    }

    #[test]
    fn merge_sums_scalars_and_unions_flags() {
        let mut a = state(3, &[(0, 1.0)]);
        a.degraded_strata = vec![0];
        a.work.compute_items = 7;
        let mut b = state(3, &[(1, 2.0)]);
        b.fault_injected = true;
        b.work.compute_items = 5;
        let m = a.merge(b).unwrap();
        assert_eq!(m.work.compute_items, 12);
        assert!(m.fault_injected);
        assert_eq!(m.degraded_strata, vec![0]);
        assert_eq!(m.moments.len(), 2);
        assert_eq!(m.window_len, 10 + 11);
    }
}
