//! Multi-partition scale-out: K coordinators over disjoint stratum
//! ranges plus a byte-identical merge tier.
//!
//! The single-coordinator slide is split at its allocation seam into a
//! prepare half and a finish half; the finish half returns a mergeable
//! [`PartitionState`] whose merge law is *disjoint union plus sums* —
//! commutative and associative by construction, because no float is
//! ever folded across partitions (each stratum's moments are computed
//! by exactly one partition and travel whole). The [`MergeTier`] routes
//! records by stratum, computes ONE global sample allocation over the
//! merged populations, folds the K states in O(strata · K) (charged to
//! `SlideWork::merge_items`), and derives every query's answer from the
//! merged state through the same registry code path the solo driver
//! uses. A solo run is the degenerate K = 1 deployment, which is why
//! `tests/partition_equivalence.rs` can demand byte-identical reports.
//!
//! State hand-off reuses the checkpoint base + delta segment chain: a
//! partition's chain IS its exported state, and rebalancing a stratum
//! ships that stratum's slice of the chain (window records, memo image,
//! chunk caches) to another partition mid-stream.
//!
//! Adding a new field to [`PartitionState`] obligates three things: a
//! merge rule in `PartitionState::merge` (disjoint-union or sum — never
//! a float fold), a wire op if it must survive restore, and a law-test
//! extension in `tests/partition_laws.rs`.

pub mod coordinator;
pub mod merge;
pub mod state;

pub use coordinator::PartitionCoordinator;
pub use merge::MergeTier;
pub use state::PartitionState;
