//! One partition of a scale-out deployment: a full
//! [`Coordinator`] scoped to a disjoint stratum range.
//!
//! A partition coordinator is not a new execution engine — it is the
//! single-node coordinator with its slide split at the allocation seam
//! (`slide_prepare` / `slide_finish`) so the
//! [`MergeTier`](crate::partition::MergeTier) can compute ONE global
//! sample allocation over the merged populations and hand it back to
//! every partition. Partitions register no queries; answers are derived
//! once, at the tier, from the merged [`PartitionState`].
//!
//! Its checkpoint **is** its exported state: the base + delta segment
//! chain of the inner coordinator doubles as the partition hand-off
//! transport — restoring the artifact on another host resumes the
//! partition byte-identically, and shipping a single stratum
//! (rebalancing) exports that stratum's slice of the same state.

use std::collections::BTreeMap;
use std::io::Write;

use crate::config::system::SystemConfig;
use crate::coordinator::driver::{Coordinator, SlidePrep, SlideTiming, StratumTransfer};
use crate::error::Result;
use crate::partition::state::PartitionState;
use crate::workload::record::{Record, StratumId};

/// A coordinator running as one partition of K (see module docs).
pub struct PartitionCoordinator {
    inner: Coordinator,
}

impl PartitionCoordinator {
    /// Count-windowed partition from a config. The window size is the
    /// GLOBAL size: the tier's router enforces global capacity via
    /// explicit eviction counts, so the partition's own buffer — the
    /// global window restricted to its strata — never trips it.
    pub(crate) fn new(cfg: SystemConfig) -> Self {
        PartitionCoordinator { inner: Coordinator::new(cfg) }
    }

    /// Time-windowed partition; every partition sees the same `now`, so
    /// emission stays in lockstep.
    pub(crate) fn new_time_windowed(cfg: SystemConfig, length: u64, slide: u64) -> Self {
        PartitionCoordinator { inner: Coordinator::new_time_windowed(cfg, length, slide) }
    }

    /// Wrap a coordinator restored from a checkpoint artifact.
    pub(crate) fn from_inner(inner: Coordinator) -> Self {
        PartitionCoordinator { inner }
    }

    /// The partition's configuration.
    pub fn config(&self) -> &SystemConfig {
        self.inner.config()
    }

    /// The stratum range this partition owns (`None` before the tier
    /// has routed it anything).
    pub fn owned_strata(&self) -> Option<&[StratumId]> {
        self.inner.owned_strata()
    }

    pub(crate) fn set_owned_strata(&mut self, strata: Option<Vec<StratumId>>) {
        self.inner.set_owned_strata(strata);
    }

    pub(crate) fn sampler_populations(&self) -> BTreeMap<StratumId, u64> {
        self.inner.sampler_populations()
    }

    pub(crate) fn prepare_count(&mut self, batch: Vec<Record>, evict: usize) -> Result<SlidePrep> {
        self.inner.partition_prepare_count(batch, evict)
    }

    pub(crate) fn prepare_tick(
        &mut self,
        records: Vec<Record>,
        now: u64,
    ) -> Result<Option<SlidePrep>> {
        self.inner.partition_prepare_tick(records, now)
    }

    pub(crate) fn finish(
        &mut self,
        prep: SlidePrep,
        horizon: u64,
        alloc: Option<&BTreeMap<StratumId, usize>>,
        want_sketches: bool,
    ) -> Result<(PartitionState, SlideTiming)> {
        self.inner.slide_finish(prep, horizon, alloc, want_sketches)
    }

    pub(crate) fn export_stratum(&mut self, stratum: StratumId) -> Result<StratumTransfer> {
        self.inner.export_stratum(stratum)
    }

    pub(crate) fn import_stratum(&mut self, transfer: StratumTransfer) -> Result<()> {
        self.inner.import_stratum(transfer)
    }

    pub(crate) fn is_count_windowed(&self) -> bool {
        self.inner.is_count_windowed()
    }

    pub(crate) fn windows_processed(&self) -> u64 {
        self.inner.windows_processed()
    }

    pub(crate) fn window_buffer_records(&self) -> Vec<Record> {
        self.inner.window_buffer_records()
    }

    /// Write this partition's full state as a base + delta checkpoint
    /// segment chain — the same artifact format as a solo coordinator's,
    /// and the partition hand-off transport (see module docs). Returns
    /// the bytes written.
    pub fn checkpoint<W: Write>(&mut self, sink: &mut W) -> Result<u64> {
        self.inner.checkpoint(sink)
    }
}
