//! The merge tier: K partition coordinators folded into one global
//! answer per slide.
//!
//! The tier owns everything *global*: the query registry, the
//! session-level budget, the degradation ladder, the stratum → partition
//! assignment, and — for count windows — the global FIFO router that
//! turns "window of W items" into per-partition eviction counts. Each
//! slide runs the two-phase protocol:
//!
//! 1. **Prepare** — route the slide's records to their owning
//!    partitions; every partition runs the front half of Algorithm 1
//!    (fault draw, memo aging bookkeeping, sampler maintenance), after
//!    which its per-stratum populations are current.
//! 2. **Allocate** — the tier merges the populations and computes ONE
//!    proportional allocation (Eq 3.1) over the union budget, exactly
//!    the allocation a solo coordinator would compute for the global
//!    window. This is the seam that makes K-way scale-out byte-identical
//!    to K = 1: sampling decisions depend only on (seed, allocation),
//!    never on which partition runs them.
//! 3. **Finish + merge** — partitions run the back half (sample, bias,
//!    plan, compute, sketch, memoize) against the GLOBAL eviction
//!    horizon and return mergeable [`PartitionState`]s; the tier folds
//!    them (O(strata · K), charged to `SlideWork::merge_items`) and
//!    derives every query's answer from the merged state via the same
//!    [`QueryRegistry`] code path the solo driver uses.
//!
//! Rebalancing ships one stratum's segment chain — window slice, memo
//! image, chunk caches — to another partition mid-stream
//! ([`MergeTier::rebalance`]); both sides re-base their checkpoint
//! chains and the continuation stays byte-identical because every piece
//! of per-stratum state is location-independent.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write;

use crate::budget::{self, CostFunction, DegradationController};
use crate::config::system::SystemConfig;
use crate::coordinator::driver::{Coordinator, SlidePrep};
use crate::coordinator::registry::QueryRegistry;
use crate::coordinator::report::{SlideOutput, WindowReport};
use crate::coordinator::{QueryId, QuerySpec};
use crate::error::{Error, Result};
use crate::metrics::{Stopwatch, WorkProfile};
use crate::partition::coordinator::PartitionCoordinator;
use crate::partition::state::PartitionState;
use crate::sampling::stratified::allocate_proportional;
use crate::stats::stratified::{estimate_sum, StratumAgg};
use crate::workload::record::{Record, StratumId};

/// Global FIFO window simulator for count-based scale-out: the tier
/// pushes every slide's records and pops the overflow, so eviction
/// counts are decided by GLOBAL capacity — a partition's own buffer
/// length says nothing about the global window. Only strata are
/// buffered (the owner of an eviction is resolved at pop time, which
/// keeps the router correct across rebalances).
///
/// Batch-then-evict here mirrors `CountWindow::slide_external` on the
/// partitions: FIFO eviction means the evicted multiset and order
/// depend only on counts, never on push/evict interleaving.
struct CountRouter {
    size: usize,
    buf: VecDeque<StratumId>,
}

impl CountRouter {
    fn new(size: usize) -> Self {
        CountRouter { size, buf: VecDeque::with_capacity(size + 1) }
    }

    /// Push one slide's records; return the strata of the evicted
    /// records, oldest first.
    fn slide(&mut self, batch: &[Record]) -> Vec<StratumId> {
        for r in batch {
            self.buf.push_back(r.stratum);
        }
        let mut evicted = Vec::new();
        while self.buf.len() > self.size {
            if let Some(s) = self.buf.pop_front() {
                evicted.push(s);
            }
        }
        evicted
    }

    /// Rebuild from restored partition buffers: `records` is the union
    /// of the partitions' windows, re-ordered to global arrival order
    /// by `(timestamp, id)`.
    fn rebuild(size: usize, mut records: Vec<Record>) -> Self {
        records.sort_by_key(|r| (r.timestamp, r.id));
        let mut router = CountRouter::new(size);
        for r in records {
            router.buf.push_back(r.stratum);
        }
        router
    }
}

/// K partition coordinators plus the global merge/derive layer (see
/// module docs). Drop-in for a solo [`Coordinator`]'s
/// `process_batch_queries` / `ingest_tick_queries` surface, producing
/// byte-identical reports.
pub struct MergeTier {
    cfg: SystemConfig,
    queries: QueryRegistry,
    cost: Box<dyn CostFunction>,
    degrade: DegradationController,
    partitions: Vec<PartitionCoordinator>,
    /// Rebalance overrides on top of the default `stratum % K` owner.
    overrides: BTreeMap<StratumId, usize>,
    /// Every stratum the tier has routed so far (drives the
    /// `owned_strata` bookkeeping carried in partition checkpoints).
    seen: BTreeSet<StratumId>,
    /// Global FIFO router — `Some` iff the partitions run count windows.
    router: Option<CountRouter>,
    windows_processed: u64,
    work: WorkProfile,
}

impl MergeTier {
    /// K count-windowed partitions sharing one config.
    pub fn new(cfg: SystemConfig, k: usize) -> Result<MergeTier> {
        Self::with_partition_configs(vec![cfg; k.max(1)])
    }

    /// K count-windowed partitions with per-partition configs — the
    /// chaos harness points fault injection at ONE partition this way.
    /// Every field that feeds the deterministic compute cone (seed,
    /// mode, window geometry, chunking, epochs) must match across
    /// partitions; fault and worker knobs may differ.
    pub fn with_partition_configs(cfgs: Vec<SystemConfig>) -> Result<MergeTier> {
        let cfg = Self::validate_configs(&cfgs)?;
        let partitions = cfgs.into_iter().map(PartitionCoordinator::new).collect();
        Ok(Self::assemble(cfg, partitions, true))
    }

    /// K time-windowed partitions (length and slide in ticks) sharing
    /// one config; feed with [`MergeTier::ingest_tick_queries`].
    pub fn new_time_windowed(
        cfg: SystemConfig,
        k: usize,
        length: u64,
        slide: u64,
    ) -> Result<MergeTier> {
        let cfgs = vec![cfg; k.max(1)];
        let tier_cfg = Self::validate_configs(&cfgs)?;
        let partitions = cfgs
            .into_iter()
            .map(|c| PartitionCoordinator::new_time_windowed(c, length, slide))
            .collect();
        Ok(Self::assemble(tier_cfg, partitions, false))
    }

    /// Rebuild a tier from per-partition checkpoint artifacts — the
    /// segment chains double as the partition state transport. Configs
    /// are per-partition (worker counts may differ from checkpoint
    /// time; the outputs cannot). The tier-global query registry is NOT
    /// in the partition artifacts: re-submit queries after restoring.
    pub fn restore_partitions(
        cfgs: Vec<SystemConfig>,
        artifacts: &[Vec<u8>],
    ) -> Result<MergeTier> {
        if cfgs.len() != artifacts.len() {
            return Err(Error::Config(format!(
                "restore_partitions: {} configs for {} artifacts",
                cfgs.len(),
                artifacts.len()
            )));
        }
        let tier_cfg = Self::validate_configs(&cfgs)?;
        let mut partitions = Vec::with_capacity(cfgs.len());
        for (cfg, bytes) in cfgs.into_iter().zip(artifacts) {
            partitions.push(PartitionCoordinator::from_inner(Coordinator::restore(
                &bytes[..],
                cfg,
            )?));
        }
        let count_windowed = partitions[0].is_count_windowed();
        if partitions.iter().any(|p| p.is_count_windowed() != count_windowed) {
            return Err(Error::Config(
                "restore_partitions: mixed window kinds across artifacts".into(),
            ));
        }
        let mut tier = Self::assemble(tier_cfg, partitions, count_windowed);
        // Rebuild the global bookkeeping the artifacts carry implicitly:
        // the stratum universe, the rebalance overrides (a stratum owned
        // away from its `s % K` home), and — for count windows — the
        // global FIFO router, from the union of the partition buffers.
        let k = tier.partitions.len();
        let mut all_records: Vec<Record> = Vec::new();
        for (i, p) in tier.partitions.iter().enumerate() {
            for s in p.owned_strata().unwrap_or(&[]) {
                tier.seen.insert(*s);
                if (*s as usize) % k != i {
                    tier.overrides.insert(*s, i);
                }
            }
            all_records.extend(p.window_buffer_records());
            tier.windows_processed = tier.windows_processed.max(p.windows_processed());
        }
        for r in &all_records {
            tier.seen.insert(r.stratum);
        }
        if count_windowed {
            tier.router = Some(CountRouter::rebuild(tier.cfg.window_size, all_records));
        }
        Ok(tier)
    }

    /// The compute-cone fields every partition must agree on; returns
    /// the tier config (the first partition's).
    fn validate_configs(cfgs: &[SystemConfig]) -> Result<SystemConfig> {
        let first = cfgs.first().ok_or_else(|| {
            Error::Config("a merge tier needs at least one partition".into())
        })?;
        for c in &cfgs[1..] {
            let same = c.seed == first.seed
                && c.mode.name() == first.mode.name()
                && c.window_size == first.window_size
                && c.slide == first.slide
                && c.chunk_size == first.chunk_size
                && c.map_rounds == first.map_rounds
                && c.recompute_epoch == first.recompute_epoch
                && c.incremental_slide == first.incremental_slide
                && c.confidence == first.confidence;
            if !same {
                return Err(Error::Config(
                    "partition configs diverge on a compute-cone field \
                     (seed / mode / window geometry / chunking / epoch / confidence)"
                        .into(),
                ));
            }
        }
        Ok(first.clone())
    }

    fn assemble(
        cfg: SystemConfig,
        partitions: Vec<PartitionCoordinator>,
        count_windowed: bool,
    ) -> MergeTier {
        let cost = budget::from_spec(&cfg.budget);
        let degrade = DegradationController::new(cfg.degradation_policy());
        let router = count_windowed.then(|| CountRouter::new(cfg.window_size));
        MergeTier {
            cfg,
            queries: QueryRegistry::default(),
            cost,
            degrade,
            partitions,
            overrides: BTreeMap::new(),
            seen: BTreeSet::new(),
            router,
            windows_processed: 0,
            work: WorkProfile::default(),
        }
    }

    /// Number of partitions (K).
    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// The partitions, for inspection (ownership ranges, configs).
    pub fn partitions(&self) -> &[PartitionCoordinator] {
        &self.partitions
    }

    /// The tier configuration (the partitions' shared compute cone plus
    /// the tier-level budget).
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The partition currently owning `stratum`.
    pub fn owner(&self, stratum: StratumId) -> usize {
        self.overrides
            .get(&stratum)
            .copied()
            .unwrap_or((stratum as usize) % self.partitions.len())
    }

    /// Register a query at the tier (partitions carry none; see module
    /// docs).
    pub fn submit_query(&mut self, spec: QuerySpec) -> Result<QueryId> {
        self.queries.submit(&self.cfg, spec)
    }

    /// Deregister a query; returns whether the id was registered.
    pub fn remove_query(&mut self, id: QueryId) -> bool {
        self.queries.remove(id)
    }

    /// Number of registered queries.
    pub fn query_count(&self) -> usize {
        self.queries.len()
    }

    /// Aggregated per-slide work counters (merge work lands in
    /// `SlideWork::merge_items` — O(strata · K), never O(records)).
    pub fn work_profile(&self) -> &WorkProfile {
        &self.work
    }

    /// Consumer-lag feedback for the overload-degradation ladder, as on
    /// a solo coordinator.
    pub fn observe_lag_slides(&mut self, lag_slides: u64) {
        self.degrade.observe_lag_slides(lag_slides, self.cfg.lag_watermark_slides as u64);
    }

    /// Current degradation bound multiplier (1.0 = baseline).
    pub fn bound_scale(&self) -> f64 {
        self.degrade.scale()
    }

    /// Windows emitted so far.
    pub fn windows_processed(&self) -> u64 {
        self.windows_processed
    }

    /// Checkpoint one partition's segment chain into `sink`; returns
    /// bytes written. Checkpointing every partition captures the whole
    /// tier (the registry is rebuilt by re-submitting queries).
    pub fn checkpoint_partition<W: Write>(&mut self, i: usize, sink: &mut W) -> Result<u64> {
        let p = self.partitions.get_mut(i).ok_or_else(|| {
            Error::Config(format!("checkpoint_partition: no partition {i}"))
        })?;
        p.checkpoint(sink)
    }

    /// Ship `stratum`'s complete live state — window slice, memo image,
    /// chunk caches — to partition `to`, mid-stream. Count windows
    /// only. Both partitions re-base their checkpoint chains; the
    /// continuation is byte-identical because per-stratum state is
    /// location-independent.
    pub fn rebalance(&mut self, stratum: StratumId, to: usize) -> Result<()> {
        if to >= self.partitions.len() {
            return Err(Error::Config(format!(
                "rebalance: no partition {to} (K = {})",
                self.partitions.len()
            )));
        }
        let from = self.owner(stratum);
        if from == to {
            return Ok(());
        }
        let transfer = self.partitions[from].export_stratum(stratum)?;
        self.partitions[to].import_stratum(transfer)?;
        self.overrides.insert(stratum, to);
        self.seen.insert(stratum);
        self.refresh_owned(from);
        self.refresh_owned(to);
        Ok(())
    }

    /// Re-derive partition `i`'s `owned_strata` bookkeeping from the
    /// seen-stratum universe and the current assignment.
    fn refresh_owned(&mut self, i: usize) {
        let owned: Vec<StratumId> =
            self.seen.iter().copied().filter(|&s| self.owner(s) == i).collect();
        self.partitions[i].set_owned_strata(Some(owned));
    }

    /// Note newly seen strata and refresh the affected partitions'
    /// ownership bookkeeping.
    fn note_strata(&mut self, batch: &[Record]) {
        let mut dirty: BTreeSet<usize> = BTreeSet::new();
        for r in batch {
            if self.seen.insert(r.stratum) {
                dirty.insert(self.owner(r.stratum));
            }
        }
        for i in dirty {
            self.refresh_owned(i);
        }
    }

    /// Route records to their owning partitions, preserving arrival
    /// order within each partition.
    fn route(&self, batch: &[Record]) -> Vec<Vec<Record>> {
        let mut per: Vec<Vec<Record>> = (0..self.partitions.len()).map(|_| Vec::new()).collect();
        for r in batch {
            per[self.owner(r.stratum)].push(*r);
        }
        per
    }

    /// Count-windowed slide: the window-report half of
    /// [`MergeTier::process_batch_queries`].
    pub fn process_batch(&mut self, batch: Vec<Record>) -> Result<WindowReport> {
        Ok(self.process_batch_queries(batch)?.window)
    }

    /// Count-windowed slide across all K partitions: route, prepare,
    /// allocate globally, finish, merge, derive (see module docs).
    pub fn process_batch_queries(&mut self, batch: Vec<Record>) -> Result<SlideOutput> {
        let sw = Stopwatch::start();
        if self.router.is_none() {
            return Err(Error::Job(
                "process_batch needs count-windowed partitions; use ingest_tick".into(),
            ));
        }
        self.note_strata(&batch);
        let mut per = self.route(&batch);
        let evicted = match &mut self.router {
            Some(router) => router.slide(&batch),
            None => Vec::new(),
        };
        let mut evicts = vec![0usize; self.partitions.len()];
        for s in evicted {
            evicts[self.owner(s)] += 1;
        }
        let mut preps = Vec::with_capacity(self.partitions.len());
        for (i, p) in self.partitions.iter_mut().enumerate() {
            preps.push(p.prepare_count(std::mem::take(&mut per[i]), evicts[i])?);
        }
        self.finish_merged(preps, sw)
    }

    /// Time-windowed tick: the window-report half of
    /// [`MergeTier::ingest_tick_queries`].
    pub fn ingest_tick(
        &mut self,
        records: Vec<Record>,
        now: u64,
    ) -> Result<Option<WindowReport>> {
        Ok(self.ingest_tick_queries(records, now)?.map(|s| s.window))
    }

    /// Time-windowed tick across all K partitions. Every partition sees
    /// every tick (possibly with no records), so emission stays in
    /// lockstep; a partial emission is a hard error, never a partial
    /// answer.
    pub fn ingest_tick_queries(
        &mut self,
        records: Vec<Record>,
        now: u64,
    ) -> Result<Option<SlideOutput>> {
        let sw = Stopwatch::start();
        if self.router.is_some() {
            return Err(Error::Job(
                "ingest_tick needs time-windowed partitions; use process_batch".into(),
            ));
        }
        self.note_strata(&records);
        let mut per = self.route(&records);
        let mut preps: Vec<SlidePrep> = Vec::with_capacity(self.partitions.len());
        let mut emitted = 0usize;
        for (i, p) in self.partitions.iter_mut().enumerate() {
            if let Some(prep) = p.prepare_tick(std::mem::take(&mut per[i]), now)? {
                emitted += 1;
                preps.push(prep);
            }
        }
        if emitted == 0 {
            return Ok(None);
        }
        if emitted != self.partitions.len() {
            return Err(Error::Job(format!(
                "partition time windows fell out of lockstep: {emitted} of {} emitted",
                self.partitions.len()
            )));
        }
        self.finish_merged(preps, sw).map(Some)
    }

    /// Phases 2–3 of the slide protocol: global allocation, per-partition
    /// finish at the GLOBAL horizon, the O(strata · K) merge fold, and
    /// the single derive pass over the merged state.
    fn finish_merged(&mut self, preps: Vec<SlidePrep>, sw: Stopwatch) -> Result<SlideOutput> {
        let window_id = preps.first().map(SlidePrep::window_id).unwrap_or(0);
        if preps.iter().any(|p| p.window_id() != window_id) {
            return Err(Error::Job(
                "partition windows fell out of lockstep (window ids diverge)".into(),
            ));
        }
        let window_len: usize = preps.iter().map(SlidePrep::window_len).sum();
        // The global eviction horizon: the minimum in-window timestamp
        // across non-empty partitions — exactly the solo window's
        // `start_ts`, whose per-partition value is the same minimum
        // restricted to the partition's strata.
        let horizon = preps
            .iter()
            .filter(|p| p.window_len() > 0)
            .map(SlidePrep::start_ts)
            .min()
            .unwrap_or(0);

        // Degradation propagates to the budgets BEFORE they size the
        // slide — same order as the solo driver's `slide_prepare`.
        let bound_scale = self.degrade.scale();
        self.cost.set_bound_scale(bound_scale);
        self.queries.set_bound_scale(bound_scale);

        // One global allocation over the merged exact populations: the
        // partitions' samplers are current after prepare, and their
        // strata are disjoint by construction.
        let alloc = if self.cfg.mode.samples() {
            let mut populations: BTreeMap<StratumId, u64> = BTreeMap::new();
            for p in &self.partitions {
                for (s, n) in p.sampler_populations() {
                    if populations.insert(s, n).is_some() {
                        return Err(Error::Job(format!(
                            "stratum {s} tracked by two partitions' samplers"
                        )));
                    }
                }
            }
            let n = match self.queries.union_sample_size(window_len) {
                Some(n) => n,
                None => self.cost.sample_size(window_len),
            };
            Some(allocate_proportional(n, &populations))
        } else {
            None
        };
        let want_sketches = self.queries.wants_sketches();

        // Finish every partition at the global horizon and fold the
        // mergeable states. The fold touches per-stratum ENTRIES, never
        // records: its cost is O(strata · K) and is charged to
        // `merge_items` so the flat-merge gate can pin it.
        let mut merged = PartitionState::empty();
        let mut merge_items: u64 = 0;
        for (p, prep) in self.partitions.iter_mut().zip(preps) {
            let (state, _timing) = p.finish(prep, horizon, alloc.as_ref(), want_sketches)?;
            merge_items += 1
                + state.moments.len() as u64
                + state.sketches.len() as u64
                + state.populations.len() as u64
                + state.strata.len() as u64;
            merged = merged.merge(state)?;
        }
        let mut slide_work = merged.work;
        slide_work.merge_items += merge_items;

        // --- Derive from the merged state (same code path as solo) ---
        let degraded = !merged.degraded_strata.is_empty();
        let mut aggs: Vec<StratumAgg> = Vec::with_capacity(merged.moments.len());
        for (s, m) in &merged.moments {
            let population = merged.populations.get(s).copied().unwrap_or(0) as f64;
            aggs.push(StratumAgg::from_moments(m, population));
        }
        let estimate = estimate_sum(&aggs, self.cfg.confidence)?;
        // The tier knows which partition each stratum lives in, so
        // stratum-scoped queries get precise (non-blanket) degradation
        // flags: one partition's fault never taints a healthy
        // partition's answers.
        let (query_reports, derive_ms) = self.queries.derive_phase(
            &merged.moments,
            &merged.populations,
            &merged.sketches,
            bound_scale,
            &merged.degraded_strata,
            false,
            &mut slide_work,
        )?;
        if self.cost.wants_bound_feedback() {
            slide_work.budget_adjust += aggs.len() as u64;
            self.cost.observe_bound(&aggs, window_len as f64);
        }
        self.queries.observe_bounds(
            &merged.moments,
            &merged.populations,
            window_len,
            &mut slide_work,
        );

        let latency_ms = sw.elapsed_ms();
        self.work.observe(slide_work);
        self.cost.observe(merged.sample_size, latency_ms);
        let total_derive_ms: f64 = derive_ms.iter().sum();
        let substrate_ms = (latency_ms - total_derive_ms).max(0.0);
        self.queries.attribute_costs(merged.sample_size, substrate_ms, &derive_ms);
        self.windows_processed += 1;

        Ok(SlideOutput {
            window: WindowReport {
                window_id,
                mode: self.cfg.mode.name(),
                estimate,
                window_len,
                sample_size: merged.sample_size,
                chunks_total: merged.chunks_total,
                chunks_reused: merged.chunks_reused,
                fresh_items: merged.fresh_items,
                strata: merged.strata,
                latency_ms,
                fault_injected: merged.fault_injected,
                degraded,
            },
            queries: query_reports,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::{BudgetSpec, ExecModeSpec};
    use crate::workload::gen::MultiStream;

    fn config() -> SystemConfig {
        SystemConfig {
            seed: 11,
            mode: ExecModeSpec::IncApprox,
            window_size: 800,
            slide: 200,
            budget: BudgetSpec::Fraction(0.2),
            chunk_size: 16,
            ..SystemConfig::default()
        }
    }

    fn assert_windows_match(a: &WindowReport, b: &WindowReport, label: &str) {
        assert_eq!(a.window_id, b.window_id, "{label}: window_id");
        assert_eq!(
            a.estimate.value.to_bits(),
            b.estimate.value.to_bits(),
            "{label}: estimate"
        );
        assert_eq!(
            a.estimate.margin.to_bits(),
            b.estimate.margin.to_bits(),
            "{label}: margin"
        );
        assert_eq!(a.window_len, b.window_len, "{label}: window_len");
        assert_eq!(a.sample_size, b.sample_size, "{label}: sample_size");
        assert_eq!(a.strata, b.strata, "{label}: strata");
    }

    #[test]
    fn two_partitions_match_solo_count_windows() {
        let mut solo = Coordinator::new(config());
        let mut tier = MergeTier::new(config(), 2).unwrap();
        let mut gen = MultiStream::paper_section5(5);
        for i in 0..8 {
            let batch = gen.take_records(200);
            let a = solo.process_batch(batch.clone()).unwrap();
            let b = tier.process_batch(batch).unwrap();
            assert_windows_match(&a, &b, &format!("slide {i}"));
        }
        assert!(tier.work_profile().total().merge_items > 0, "merge work uncharged");
    }

    #[test]
    fn window_kind_mismatch_is_an_error() {
        let mut tier = MergeTier::new(config(), 2).unwrap();
        assert!(tier.ingest_tick(Vec::new(), 1).is_err());
        let mut tier = MergeTier::new_time_windowed(config(), 2, 100, 25).unwrap();
        assert!(tier.process_batch(Vec::new()).is_err());
    }

    #[test]
    fn rebalance_requires_count_windows() {
        let mut tier = MergeTier::new_time_windowed(config(), 2, 100, 25).unwrap();
        let mut gen = MultiStream::paper_section5(5);
        let mut now = 0;
        for _ in 0..120 {
            now += 1;
            let recs = gen.tick();
            let _ = tier.ingest_tick(recs, now).unwrap();
        }
        let err = tier.rebalance(0, tier.owner(0) ^ 1).unwrap_err();
        assert!(err.to_string().contains("count"), "got: {err}");
    }
}
