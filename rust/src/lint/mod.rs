//! `pallas-lint` — the repo-native invariant linter.
//!
//! Every headline property of this reproduction (byte-identical
//! serial/sharded/incremental reports, the deterministic `TargetError`
//! trajectory, restore equivalence) rests on source-level disciplines
//! that dynamic gates can only spot-check. This module makes them
//! machine-checked. The workspace is offline, so there is no `syn`:
//! [`lexer`] blanks comments and literal interiors, and the rules are
//! scoped token scans plus brace-matched test-region detection over the
//! masked text.
//!
//! Rules (each documented in its own module):
//!
//! * [`determinism`] — no wall-clock reads, no unordered hash-container
//!   use, in the determinism-critical cone;
//! * [`panic_free`] — library code routes failures through
//!   [`crate::error::Error`], never the panic family;
//! * [`flat_substrate`] — substrate modules must not reference the
//!   query registry (the PR 3 flat-scaling invariant);
//! * [`wire_schema`] — a digest over the checkpoint wire layer pinned
//!   per `checkpoint::VERSION`, so wire edits without a version bump
//!   fail statically.
//!
//! **Pragmas.** A finding can be suppressed — auditedly — with a
//! comment on the offending line or the line above:
//!
//! ```text
//! // lint:allow(panic-freedom) -- Vec<u8> sink is infallible
//! ```
//!
//! The reason after `--` is mandatory; unknown rule names and malformed
//! pragmas are themselves diagnostics (rule `pragma`), and pragmas that
//! suppress nothing are reported as non-failing warnings. Every pragma
//! is listed in the JSON report, so the escape hatch stays reviewable.
//!
//! Entry points: [`check_source`] lints one in-memory file under a
//! virtual path (how the fixture corpus drives the rules) and [`run`]
//! walks a real `src/` tree, adds the wire-schema check, and returns a
//! [`LintReport`] that renders as text or JSON
//! (`target/lint-results/pallas-lint.json` in CI). The gate is
//! `tests/lint_clean.rs`: the tree must produce zero diagnostics.

use std::cell::Cell;
use std::fmt;
use std::path::{Path, PathBuf};

pub mod determinism;
pub mod flat_substrate;
pub mod lexer;
pub mod panic_free;
pub mod wire_schema;

/// Rule name: determinism cone (clocks, unordered containers).
pub const RULE_DETERMINISM: &str = "determinism";
/// Rule name: no panic family in library code.
pub const RULE_PANIC_FREEDOM: &str = "panic-freedom";
/// Rule name: substrate modules must not know queries exist.
pub const RULE_FLAT_SUBSTRATE: &str = "flat-substrate";
/// Rule name: checkpoint wire digest vs the pinned golden.
pub const RULE_WIRE_SCHEMA: &str = "wire-schema";
/// Rule name: malformed / unknown / unused suppression pragmas.
pub const RULE_PRAGMA: &str = "pragma";

/// Rules a pragma may name (the positional, per-line rules; the
/// wire-schema rule has its own escape hatch — re-pinning the golden).
pub const SUPPRESSIBLE_RULES: [&str; 3] =
    [RULE_DETERMINISM, RULE_PANIC_FREEDOM, RULE_FLAT_SUBSTRATE];

// Assembled from pieces so the linter's own sources never contain the
// contiguous marker — the pragma scan reads raw lines (pragmas *are*
// comments), so a literal occurrence in a message string would
// self-flag when the tree lints itself.
const MARKER: &str = concat!("lint", ":allow(");

/// One finding: where, which rule, and what to do about it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Rule that fired (one of the `RULE_*` constants).
    pub rule: &'static str,
    /// Virtual path, relative to `src/`, forward slashes.
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    /// Human-oriented explanation with the remediation.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// One audited suppression pragma, for the report's escape-hatch list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PragmaAudit {
    /// Virtual path of the file holding the pragma.
    pub file: String,
    /// 1-indexed line of the pragma comment.
    pub line: usize,
    /// Rules it names.
    pub rules: Vec<String>,
    /// The mandatory justification after `--`.
    pub reason: String,
    /// Whether it suppressed at least one finding.
    pub used: bool,
}

/// A parsed, well-formed pragma awaiting use.
struct Pragma {
    line: usize,
    rules: Vec<String>,
    reason: String,
    used: Cell<bool>,
}

/// One source file prepared for rule checks: raw text, masked text,
/// test-region spans, and its suppression pragmas.
pub struct SourceFile {
    /// Virtual path relative to `src/`, forward slashes (rules scope on
    /// prefixes of this).
    pub path: String,
    /// Masked source: comments and literal interiors blanked, byte
    /// offsets and newlines preserved (see [`lexer::mask_source`]).
    pub masked: String,
    tests: Vec<lexer::Span>,
    pragmas: Vec<Pragma>,
}

impl SourceFile {
    /// Prepare a file for linting. Returns the prepared file plus any
    /// malformed-pragma diagnostics found while parsing.
    pub fn new(path: &str, source: &str) -> (SourceFile, Vec<Diagnostic>) {
        let masked = lexer::mask_source(source);
        let tests = lexer::test_regions(&masked);
        let (pragmas, diags) = parse_pragmas(path, source, &tests);
        (SourceFile { path: path.to_string(), masked, tests, pragmas }, diags)
    }

    /// Whether the byte offset falls inside a `#[cfg(test)]` /
    /// `#[test]` item.
    pub fn in_test_region(&self, pos: usize) -> bool {
        self.tests.iter().any(|s| s.contains(pos))
    }

    /// Record a finding at byte offset `pos` unless a well-formed
    /// pragma naming `rule` covers its line (the pragma's own line or
    /// the one right below it).
    pub fn push_unless_allowed(
        &self,
        out: &mut Vec<Diagnostic>,
        rule: &'static str,
        pos: usize,
        message: String,
    ) {
        let line = lexer::line_of(&self.masked, pos);
        for p in &self.pragmas {
            if (p.line == line || p.line + 1 == line) && p.rules.iter().any(|r| r == rule) {
                p.used.set(true);
                return;
            }
        }
        out.push(Diagnostic { rule, file: self.path.clone(), line, message });
    }
}

/// Scan raw lines for suppression pragmas. Lines inside test regions
/// are skipped (test code is exempt from every positional rule, so a
/// pragma there could only ever be noise). Assumes LF line endings, as
/// the tree uses throughout.
fn parse_pragmas(
    path: &str,
    source: &str,
    tests: &[lexer::Span],
) -> (Vec<Pragma>, Vec<Diagnostic>) {
    let mut pragmas = Vec::new();
    let mut diags = Vec::new();
    let mut offset = 0usize;
    for (idx, line) in source.lines().enumerate() {
        let lineno = idx + 1;
        let line_start = offset;
        offset += line.len() + 1;
        if tests.iter().any(|s| s.contains(line_start)) {
            continue;
        }
        // Doc comments may *show* the pragma syntax (this module's own
        // docs do); they can never carry a live pragma.
        let trimmed = line.trim_start();
        if trimmed.starts_with("///") || trimmed.starts_with("//!") {
            continue;
        }
        let Some(at) = line.find(MARKER) else {
            continue;
        };
        let mut bad = |msg: String| {
            diags.push(Diagnostic {
                rule: RULE_PRAGMA,
                file: path.to_string(),
                line: lineno,
                message: msg,
            });
        };
        if !line[..at].contains("//") {
            bad(format!("`{MARKER}…)` must sit in a `//` comment"));
            continue;
        }
        let after = &line[at + MARKER.len()..];
        let Some(close) = after.find(')') else {
            bad(format!("unterminated `{MARKER}…)` pragma"));
            continue;
        };
        let rules: Vec<String> = after[..close]
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        if rules.is_empty() {
            bad("pragma names no rules".to_string());
            continue;
        }
        if let Some(unknown) = rules.iter().find(|r| !SUPPRESSIBLE_RULES.contains(&r.as_str())) {
            bad(format!(
                "pragma names unknown rule `{unknown}` (suppressible: {})",
                SUPPRESSIBLE_RULES.join(", ")
            ));
            continue;
        }
        let rest = after[close + 1..].trim_start();
        let Some(reason) = rest.strip_prefix("--") else {
            bad("pragma is missing its mandatory `-- <reason>`".to_string());
            continue;
        };
        let reason = reason.trim();
        if reason.is_empty() {
            bad("pragma has an empty `-- <reason>`".to_string());
            continue;
        }
        pragmas.push(Pragma {
            line: lineno,
            rules,
            reason: reason.to_string(),
            used: Cell::new(false),
        });
    }
    (pragmas, diags)
}

/// The outcome of linting one file.
#[derive(Debug, Clone, Default)]
pub struct FileReport {
    /// Failing findings (including malformed pragmas).
    pub diagnostics: Vec<Diagnostic>,
    /// Non-failing findings (currently: unused pragmas).
    pub warnings: Vec<Diagnostic>,
    /// Every well-formed pragma, used or not, for the audit trail.
    pub pragmas: Vec<PragmaAudit>,
}

/// Lint one in-memory source under a virtual path (e.g.
/// `"sampling/fixture.rs"` to place it inside the determinism cone).
/// This is the whole positional-rule engine; [`run`] adds the
/// tree walk and the wire-schema check on top.
pub fn check_source(path: &str, source: &str) -> FileReport {
    let (file, mut diagnostics) = SourceFile::new(path, source);
    diagnostics.extend(determinism::check(&file));
    diagnostics.extend(panic_free::check(&file));
    diagnostics.extend(flat_substrate::check(&file));
    let mut warnings = Vec::new();
    let mut pragmas = Vec::new();
    for p in &file.pragmas {
        let used = p.used.get();
        if !used {
            warnings.push(Diagnostic {
                rule: RULE_PRAGMA,
                file: file.path.clone(),
                line: p.line,
                message: format!(
                    "unused `{MARKER}{})` pragma — it suppresses nothing; remove it",
                    p.rules.join(", ")
                ),
            });
        }
        pragmas.push(PragmaAudit {
            file: file.path.clone(),
            line: p.line,
            rules: p.rules.clone(),
            reason: p.reason.clone(),
            used,
        });
    }
    FileReport { diagnostics, warnings, pragmas }
}

/// The aggregate outcome of linting a tree.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Number of `.rs` files checked.
    pub files_checked: usize,
    /// Failing findings across all files + the wire-schema check.
    pub diagnostics: Vec<Diagnostic>,
    /// Non-failing findings (unused pragmas).
    pub warnings: Vec<Diagnostic>,
    /// The audited escape hatches.
    pub pragmas: Vec<PragmaAudit>,
    /// Current [`wire_schema::schema_digest`] of the checkpoint layer.
    pub wire_digest: u64,
    /// `checkpoint::VERSION` as parsed from source, if found.
    pub wire_version: Option<u32>,
}

impl LintReport {
    /// Whether the tree passes (warnings do not fail the gate).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-oriented rendering: one line per finding, then the
    /// summary and the pragma audit.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&format!("error: {d}\n"));
        }
        for w in &self.warnings {
            s.push_str(&format!("warning: {w}\n"));
        }
        for p in &self.pragmas {
            if p.used {
                s.push_str(&format!(
                    "allowed: {}:{}: [{}] {}\n",
                    p.file,
                    p.line,
                    p.rules.join(", "),
                    p.reason
                ));
            }
        }
        let version = match self.wire_version {
            Some(v) => v.to_string(),
            None => "?".to_string(),
        };
        s.push_str(&format!(
            "pallas-lint: {} files, {} error(s), {} warning(s), {} pragma(s); \
             wire v{version} digest {:#018x}\n",
            self.files_checked,
            self.diagnostics.len(),
            self.warnings.len(),
            self.pragmas.len(),
            self.wire_digest,
        ));
        s
    }

    /// Hand-rolled JSON rendering (the workspace is offline — no
    /// serde), written to `target/lint-results/pallas-lint.json` by the
    /// binary and uploaded as a CI artifact.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len() + 2);
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        fn diag_json(d: &Diagnostic) -> String {
            format!(
                "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                esc(d.rule),
                esc(&d.file),
                d.line,
                esc(&d.message)
            )
        }
        let diags: Vec<String> = self.diagnostics.iter().map(diag_json).collect();
        let warns: Vec<String> = self.warnings.iter().map(diag_json).collect();
        let pragmas: Vec<String> = self
            .pragmas
            .iter()
            .map(|p| {
                let rules: Vec<String> =
                    p.rules.iter().map(|r| format!("\"{}\"", esc(r))).collect();
                format!(
                    "{{\"file\":\"{}\",\"line\":{},\"rules\":[{}],\"reason\":\"{}\",\"used\":{}}}",
                    esc(&p.file),
                    p.line,
                    rules.join(","),
                    esc(&p.reason),
                    p.used
                )
            })
            .collect();
        let version = match self.wire_version {
            Some(v) => v.to_string(),
            None => "null".to_string(),
        };
        format!(
            "{{\"tool\":\"pallas-lint\",\"files_checked\":{},\"clean\":{},\
             \"wire\":{{\"version\":{version},\"digest\":\"{:#018x}\"}},\
             \"diagnostics\":[{}],\"warnings\":[{}],\"pragmas\":[{}]}}\n",
            self.files_checked,
            self.is_clean(),
            self.wire_digest,
            diags.join(","),
            warns.join(","),
            pragmas.join(",")
        )
    }
}

/// Recursively collect `.rs` files under `root`, sorted by path so the
/// report order is deterministic.
fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            if entry.file_type()?.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// `src/`-relative virtual path with forward slashes, for scoping and
/// display.
fn virtual_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

/// Lint a real `src/` tree: every `.rs` file through the positional
/// rules, plus the wire-schema digest check against the pinned golden.
/// Diagnostics come back sorted by (file, line, rule).
pub fn run(src_root: &Path) -> crate::error::Result<LintReport> {
    let files = collect_rs_files(src_root)?;
    let mut report = LintReport { files_checked: files.len(), ..LintReport::default() };
    for path in &files {
        let source = std::fs::read_to_string(path)?;
        let fr = check_source(&virtual_path(src_root, path), &source);
        report.diagnostics.extend(fr.diagnostics);
        report.warnings.extend(fr.warnings);
        report.pragmas.extend(fr.pragmas);
    }
    let wire = std::fs::read_to_string(src_root.join(wire_schema::WIRE_PATH))?;
    let module = std::fs::read_to_string(src_root.join(wire_schema::MOD_PATH))?;
    report.wire_digest = wire_schema::schema_digest(wire.as_bytes(), module.as_bytes());
    report.wire_version = wire_schema::parse_version(&module);
    match std::fs::read_to_string(src_root.join(wire_schema::GOLDEN_PATH)) {
        Ok(golden) => {
            report.diagnostics.extend(wire_schema::check_sources(&wire, &module, &golden));
        }
        Err(_) => report.diagnostics.push(Diagnostic {
            rule: RULE_WIRE_SCHEMA,
            file: wire_schema::GOLDEN_PATH.to_string(),
            line: 1,
            message: "missing wire-schema golden; pin it with \
                      `cargo run --bin pallas-lint -- --update-wire-golden`"
                .to_string(),
        }),
    }
    report.diagnostics.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pragma markers in these fixture strings are assembled with
    // `concat!` so this file's raw bytes never contain the contiguous
    // marker (the pragma scan reads raw lines).

    #[test]
    fn determinism_fires_in_cone_only() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(check_source("sampling/x.rs", src).diagnostics.len(), 1);
        assert!(check_source("workload/x.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn clock_fires_outside_allowlist_only() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        let d = check_source("budget/x.rs", src).diagnostics;
        assert!(!d.is_empty());
        assert!(d.iter().all(|d| d.rule == RULE_DETERMINISM));
        assert!(check_source("metrics/x.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn pragma_suppresses_line_below_and_is_audited() {
        let src = concat!(
            "// lint",
            ":allow(determinism) -- fixture justification\n",
            "use std::collections::HashMap;\n"
        );
        let fr = check_source("sampling/x.rs", src);
        assert!(fr.diagnostics.is_empty(), "{:?}", fr.diagnostics);
        assert!(fr.warnings.is_empty());
        assert_eq!(fr.pragmas.len(), 1);
        assert!(fr.pragmas[0].used);
        assert_eq!(fr.pragmas[0].reason, "fixture justification");
    }

    #[test]
    fn pragma_suppresses_same_line() {
        let src = concat!(
            "fn f() { x.unwrap(); } // lint",
            ":allow(panic-freedom) -- fixture\n"
        );
        let fr = check_source("classify/x.rs", src);
        assert!(fr.diagnostics.is_empty(), "{:?}", fr.diagnostics);
    }

    #[test]
    fn malformed_pragmas_are_diagnostics() {
        let missing_reason = concat!("// lint", ":allow(determinism)\n");
        let unknown_rule = concat!("// lint", ":allow(speed) -- because\n");
        let empty_rules = concat!("// lint", ":allow() -- because\n");
        for src in [missing_reason, unknown_rule, empty_rules] {
            let fr = check_source("window/x.rs", src);
            assert_eq!(fr.diagnostics.len(), 1, "{src:?}");
            assert_eq!(fr.diagnostics[0].rule, RULE_PRAGMA);
        }
    }

    #[test]
    fn unused_pragma_warns_without_failing() {
        let src = concat!("// lint", ":allow(determinism) -- nothing here\n", "fn ok() {}\n");
        let fr = check_source("window/x.rs", src);
        assert!(fr.diagnostics.is_empty());
        assert_eq!(fr.warnings.len(), 1);
        assert_eq!(fr.warnings[0].rule, RULE_PRAGMA);
        assert!(!fr.pragmas[0].used);
    }

    #[test]
    fn panic_family_exempt_in_test_regions() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { \
                   let v: Vec<u32> = vec![]; v.first().unwrap(); panic!(\"boom\"); }\n}\n";
        let fr = check_source("stats/x.rs", src);
        assert!(fr.diagnostics.is_empty(), "{:?}", fr.diagnostics);
    }

    #[test]
    fn panic_family_fires_in_library_code() {
        let src = "fn lib(v: &[u32]) -> u32 { *v.first().unwrap() }\n";
        let fr = check_source("stats/x.rs", src);
        assert_eq!(fr.diagnostics.len(), 1);
        assert_eq!(fr.diagnostics[0].rule, RULE_PANIC_FREEDOM);
        assert_eq!(fr.diagnostics[0].line, 1);
    }

    #[test]
    fn flat_substrate_bans_registry_symbols() {
        let src = "use crate::coordinator::query::QuerySpec;\n";
        let fr = check_source("window/x.rs", src);
        assert_eq!(fr.diagnostics.len(), 1);
        assert_eq!(fr.diagnostics[0].rule, RULE_FLAT_SUBSTRATE);
        assert!(check_source("coordinator/x.rs", src).diagnostics.is_empty());
    }

    #[test]
    fn json_renders_and_escapes() {
        let mut report = LintReport::default();
        report.diagnostics.push(Diagnostic {
            rule: RULE_PRAGMA,
            file: "a/b.rs".to_string(),
            line: 3,
            message: "quote \" backslash \\ done".to_string(),
        });
        let json = report.to_json();
        assert!(json.contains("\"clean\":false"));
        assert!(json.contains("quote \\\" backslash \\\\ done"));
    }
}
