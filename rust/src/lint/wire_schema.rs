//! Rule `wire-schema` — any edit to the checkpoint wire layer must be
//! acknowledged with a `checkpoint::VERSION` bump (or an explicit
//! golden re-pin).
//!
//! The artifact format is hand-rolled (`checkpoint/wire.rs` primitives,
//! segment tags matched inline in `checkpoint/mod.rs`), so there is no
//! schema file a reviewer can diff. This rule synthesizes one: a
//! [`schema_digest`] over the **raw bytes** of both files, pinned next
//! to the `VERSION` it was taken at in `lint/wire_schema.golden`.
//!
//! * digest differs, `VERSION` unchanged → the wire layer moved without
//!   a version bump: fail.
//! * `VERSION` differs from the golden's → the bump happened but the
//!   golden is stale: fail with a pointer to `--update-wire-golden`.
//!
//! Digesting raw bytes is deliberately conservative: comment-only edits
//! also require a re-pin. That is the point — *every* change to the
//! wire layer gets an explicit acknowledgment in the diff, the same way
//! a golden-vector test pins behavior. Re-pin with
//! `cargo run --bin pallas-lint -- --update-wire-golden`.

use super::lexer;
use super::Diagnostic;

/// Virtual path diagnostics attach to (the golden lives beside the lint
/// module, the digest covers the checkpoint layer).
pub const WIRE_PATH: &str = "checkpoint/wire.rs";
/// Virtual path of the segment/tag half of the digest.
pub const MOD_PATH: &str = "checkpoint/mod.rs";
/// Where the golden is pinned, relative to `src/`.
pub const GOLDEN_PATH: &str = "lint/wire_schema.golden";

/// The pinned schema fingerprint: the `checkpoint::VERSION` it was
/// taken at, and the [`schema_digest`] of the wire layer at that point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Golden {
    /// `checkpoint::VERSION` at pin time.
    pub version: u32,
    /// [`schema_digest`] at pin time.
    pub digest: u64,
}

/// Order-dependent digest of the two wire-layer sources: the fnv1a hash
/// of each file's raw bytes folded through [`StableHasher`]
/// (`crate::util::hash`), so the fingerprint inherits the same pinned,
/// platform-independent behavior as the memo keys.
pub fn schema_digest(wire: &[u8], module: &[u8]) -> u64 {
    use crate::util::hash::{fnv1a, StableHasher};
    let mut h = StableHasher::new();
    h.write_u64(fnv1a(wire));
    h.write_u64(fnv1a(module));
    h.finish()
}

/// Extract `const VERSION: u32 = N;` from `checkpoint/mod.rs` source
/// (comments masked first, so prose mentioning the constant cannot
/// confuse the scan). `None` when the declaration is missing.
pub fn parse_version(mod_src: &str) -> Option<u32> {
    let masked = lexer::mask_source(mod_src);
    let pat = "const VERSION: u32 =";
    let at = masked.find(pat)?;
    let rest = &masked[at + pat.len()..];
    let end = rest.find(';')?;
    rest[..end].trim().parse().ok()
}

/// Parse the golden file: `#` comments and blank lines ignored,
/// `version = <dec>` and `digest = 0x<hex>` required.
pub fn parse_golden(text: &str) -> Result<Golden, String> {
    let mut version: Option<u32> = None;
    let mut digest: Option<u64> = None;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("malformed golden line `{line}`"));
        };
        match key.trim() {
            "version" => {
                version = match value.trim().parse() {
                    Ok(v) => Some(v),
                    Err(_) => return Err(format!("bad version `{}`", value.trim())),
                };
            }
            "digest" => {
                let hex = value.trim().trim_start_matches("0x");
                digest = match u64::from_str_radix(hex, 16) {
                    Ok(d) => Some(d),
                    Err(_) => return Err(format!("bad digest `{}`", value.trim())),
                };
            }
            other => return Err(format!("unknown golden key `{other}`")),
        }
    }
    match (version, digest) {
        (Some(version), Some(digest)) => Ok(Golden { version, digest }),
        _ => Err("golden must pin both `version` and `digest`".to_string()),
    }
}

/// Render the golden file for `--update-wire-golden`.
pub fn render_golden(version: u32, digest: u64) -> String {
    format!(
        "# pallas-lint wire-schema golden: fnv1a/StableHasher digest of the raw\n\
         # bytes of checkpoint/wire.rs + checkpoint/mod.rs, pinned at the\n\
         # checkpoint::VERSION it was taken for. Any edit to either file must\n\
         # either bump VERSION or consciously re-pin:\n\
         #   cargo run --bin pallas-lint -- --update-wire-golden\n\
         version = {version}\n\
         digest = {digest:#018x}\n"
    )
}

/// Run the rule against in-memory sources + golden text. Pure, so
/// fixture tests can feed a mutated `wire.rs` copy and assert the
/// mismatch diagnostic without touching the real tree.
pub fn check_sources(wire_src: &str, mod_src: &str, golden_text: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let rule = super::RULE_WIRE_SCHEMA;
    let golden = match parse_golden(golden_text) {
        Ok(g) => g,
        Err(e) => {
            out.push(Diagnostic {
                rule,
                file: GOLDEN_PATH.to_string(),
                line: 1,
                message: format!("unreadable wire-schema golden: {e}"),
            });
            return out;
        }
    };
    let Some(version) = parse_version(mod_src) else {
        out.push(Diagnostic {
            rule,
            file: MOD_PATH.to_string(),
            line: 1,
            message: "cannot find `const VERSION: u32 = …;` in checkpoint/mod.rs".to_string(),
        });
        return out;
    };
    let digest = schema_digest(wire_src.as_bytes(), mod_src.as_bytes());
    if version != golden.version {
        out.push(Diagnostic {
            rule,
            file: GOLDEN_PATH.to_string(),
            line: 1,
            message: format!(
                "checkpoint::VERSION is {version} but the golden pins {}; \
                 re-pin with `cargo run --bin pallas-lint -- --update-wire-golden`",
                golden.version
            ),
        });
    } else if digest != golden.digest {
        out.push(Diagnostic {
            rule,
            file: WIRE_PATH.to_string(),
            line: 1,
            message: format!(
                "wire layer changed (digest {digest:#018x}, golden {:#018x}) without a \
                 checkpoint::VERSION bump; bump VERSION for format changes, or re-pin \
                 with `cargo run --bin pallas-lint -- --update-wire-golden` for \
                 format-preserving edits",
                golden.digest
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const MOD_SRC: &str = "/// Artifact format revision.\npub(crate) const VERSION: u32 = 4;\n";

    #[test]
    fn digest_is_order_dependent_and_stable() {
        let a = schema_digest(b"wire", b"module");
        let b = schema_digest(b"module", b"wire");
        assert_ne!(a, b);
        assert_eq!(a, schema_digest(b"wire", b"module"));
    }

    #[test]
    fn version_parses_through_comments() {
        let src = "// the const VERSION: u32 = 99; in prose\npub(crate) const VERSION: u32 = 4;";
        assert_eq!(parse_version(src), Some(4));
        assert_eq!(parse_version("no decl here"), None);
    }

    #[test]
    fn golden_round_trips() {
        let g = Golden { version: 4, digest: 0x1234_5678_9abc_def0 };
        let text = render_golden(g.version, g.digest);
        assert_eq!(parse_golden(&text), Ok(g));
        assert!(parse_golden("version = 4").is_err(), "digest required");
        assert!(parse_golden("bogus line").is_err());
    }

    #[test]
    fn matching_sources_pass_and_edits_fail() {
        let wire = "fn u32_le() {}";
        let digest = schema_digest(wire.as_bytes(), MOD_SRC.as_bytes());
        let golden = render_golden(4, digest);
        assert!(check_sources(wire, MOD_SRC, &golden).is_empty());

        // Un-bumped edit → digest mismatch on the wire path.
        let diags = check_sources("fn u32_be() {}", MOD_SRC, &golden);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].file, WIRE_PATH);
        assert!(diags[0].message.contains("without a checkpoint::VERSION bump"));

        // Bumped VERSION with a stale golden → re-pin diagnostic.
        let bumped = MOD_SRC.replace("= 4;", "= 5;");
        let diags = check_sources("fn u32_be() {}", &bumped, &golden);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].message.contains("re-pin"));
    }
}
