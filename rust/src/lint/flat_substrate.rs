//! Rule `flat-substrate` — substrate modules must not know queries
//! exist.
//!
//! The PR 3 invariant (ARCHITECTURE.md, "flat multi-query substrate"):
//! N concurrent queries share one window/sampler/memo, and only the
//! coordinator's `derive_items` / `budget_adjust` layers may scale with
//! N. The dynamic gate (`substrate_work_independent_of_query_count`)
//! catches per-query *work*; this rule catches the upstream design
//! drift — a substrate module merely *naming* a query-registry type is
//! one refactor away from looping over it.
//!
//! Banned inside substrate modules: the query-registry vocabulary
//! (`QuerySpec`, `QueryId`, `QueryReport`, `RegisteredQuery`,
//! `SlideOutput`, `submit_query`, `remove_query`). The coordinator
//! (`coordinator/`), which owns the registry, is naturally out of
//! scope.
//!
//! Test regions are exempt (a substrate unit test asserting against a
//! report type is not a scaling hazard).
//!
//! Escape hatch (audited): `// lint:allow(flat-substrate) -- <reason>`.

use super::lexer;
use super::{Diagnostic, SourceFile};

/// Modules that make up the shared substrate: one instance serves every
/// registered query, so none of them may reference the registry.
pub const SUBSTRATE: [&str; 6] =
    ["window/", "sampling/", "sac/", "job/", "kafka/", "columnar/"];

/// The query-registry vocabulary: types and methods owned by
/// `coordinator/query.rs` / `coordinator/report.rs`.
const TOKENS: [&str; 7] = [
    "QuerySpec",
    "QueryId",
    "QueryReport",
    "RegisteredQuery",
    "SlideOutput",
    "submit_query",
    "remove_query",
];

/// Run the rule over one file.
pub fn check(file: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if !SUBSTRATE.iter().any(|p| file.path.starts_with(p)) {
        return out;
    }
    for token in TOKENS {
        for pos in lexer::find_token(&file.masked, token, true) {
            if file.in_test_region(pos) {
                continue;
            }
            file.push_unless_allowed(
                &mut out,
                super::RULE_FLAT_SUBSTRATE,
                pos,
                format!(
                    "substrate module references query-registry symbol `{token}`; \
                     only coordinator derive/budget layers may scale with query count"
                ),
            );
        }
    }
    out
}
