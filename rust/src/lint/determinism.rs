//! Rule `determinism` — no wall-clock reads, no unordered hash
//! iteration, in the determinism-critical cone.
//!
//! Every headline property of this reproduction (byte-identical
//! serial/sharded/incremental reports, the deterministic `TargetError`
//! trajectory, restore equivalence) requires that the substrate and the
//! error-loop math read only byte-identical quantities. Two classic
//! ways to silently break that:
//!
//! * **wall-clock reads** (`Instant::now`, `SystemTime`, anything under
//!   `std::time`) feeding a value that influences sampling, budgeting,
//!   or the wire format — banned everywhere except the observability
//!   layers (`metrics/`, `logging.rs`, `bench_harness.rs`, `runtime/`),
//!   which measure but never steer;
//! * **unordered iteration** over `std::collections::HashMap` /
//!   `HashSet` (randomized per process) inside the cone — banned in the
//!   cone outright. The sanctioned containers are `BTreeMap`/`BTreeSet`
//!   (ordered) and [`FastMap`](crate::util::hash::FastMap) /
//!   [`FastSet`](crate::util::hash::FastSet), whose fixed-seed hasher
//!   makes iteration a pure function of the operation sequence.
//!
//! Test regions (`#[cfg(test)]` / `#[test]`) are exempt — assertions
//! may use std containers and measure time without affecting the
//! production dataflow.
//!
//! Escape hatch (audited): `// lint:allow(determinism) -- <reason>`.

use super::lexer;
use super::{Diagnostic, SourceFile};

/// Modules whose outputs must be a pure function of (input, seed): the
/// window/sampler/memo substrate, the job layer, the checkpoint wire,
/// the statistics + budget solve paths, the partition merge tier
/// (whose merged reports are pinned byte-identical to a solo run), and
/// the columnar batch layer (whose column views are pinned bit-equal
/// to the row records they transpose).
pub const CONE: [&str; 9] = [
    "window/",
    "sampling/",
    "sac/",
    "job/",
    "checkpoint/",
    "stats/",
    "budget/",
    "partition/",
    "columnar/",
];

/// Observability layers allowed to read the clock: they measure,
/// report, and benchmark, but nothing they produce flows back into
/// sampled, memoized, or serialized state. (`runtime/` is the
/// feature-gated PJRT boundary — host-side timing there never reaches
/// the coordinator's math.)
pub const CLOCK_ALLOWED: [&str; 4] = ["metrics/", "logging.rs", "bench_harness.rs", "runtime/"];

const CLOCK_TOKENS: [&str; 3] = ["std::time", "Instant::now", "SystemTime"];
const UNORDERED_TOKENS: [&str; 3] = ["HashMap", "HashSet", "DefaultHasher"];

/// Run the rule over one file.
pub fn check(file: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let clock_scoped = !CLOCK_ALLOWED.iter().any(|p| file.path.starts_with(p));
    let cone_scoped = CONE.iter().any(|p| file.path.starts_with(p));
    if clock_scoped {
        for token in CLOCK_TOKENS {
            for pos in lexer::find_token(&file.masked, token, true) {
                if file.in_test_region(pos) {
                    continue;
                }
                file.push_unless_allowed(
                    &mut out,
                    super::RULE_DETERMINISM,
                    pos,
                    format!(
                        "wall-clock read `{token}` outside the observability allowlist; \
                         clock values must never influence sampled, memoized, budgeted, \
                         or serialized state"
                    ),
                );
            }
        }
    }
    if cone_scoped {
        for token in UNORDERED_TOKENS {
            for pos in lexer::find_token(&file.masked, token, true) {
                if file.in_test_region(pos) {
                    continue;
                }
                file.push_unless_allowed(
                    &mut out,
                    super::RULE_DETERMINISM,
                    pos,
                    format!(
                        "`{token}` in the determinism-critical cone; use BTreeMap/BTreeSet \
                         or util::hash::FastMap/FastSet (fixed-seed, iteration order is a \
                         pure function of the operation sequence)"
                    ),
                );
            }
        }
    }
    out
}
