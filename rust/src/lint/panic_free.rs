//! Rule `panic-freedom` — library code routes failures through
//! [`crate::error::Error`], never through a panic.
//!
//! The fault-isolation invariant (ARCHITECTURE.md) promises that
//! injected and organic failures surface as typed errors; a stray
//! `unwrap()` on a path the chaos campaigns happen not to exercise
//! turns a recoverable condition into an abort. This rule bans the
//! panic family — `.unwrap()`, `.expect(…)`, `panic!`, `unreachable!`,
//! `todo!`, `unimplemented!` — in library code.
//!
//! Out of scope by construction:
//!
//! * **test regions** (`#[cfg(test)]` / `#[test]` items) — panicking is
//!   how Rust tests fail, and extractor-style `assert!(matches!(…))`
//!   patterns are idiomatic there;
//! * **`bench_harness.rs`** and **`runtime/`** — offline tooling and
//!   the feature-gated PJRT boundary, where aborting on a broken
//!   environment is the right behavior;
//! * `assert!` / `debug_assert!` — stating an invariant is fine; the
//!   rule targets *control flow* that reaches a panic on bad input.
//!
//! Escape hatch (audited): `// lint:allow(panic-freedom) -- <reason>`,
//! e.g. for an infallible `Vec<u8>` sink or a documented panicking
//! accessor with a non-panicking sibling.

use super::lexer;
use super::{Diagnostic, SourceFile};

/// Files where aborting is acceptable: the bench harness is offline
/// tooling, and `runtime/` is the feature-gated PJRT FFI boundary.
pub const PANIC_ALLOWED: [&str; 2] = ["bench_harness.rs", "runtime/"];

/// `(token, word_boundary)` — dotted call tokens carry their own
/// delimiters (the receiver before `.` is an identifier, so a word
/// boundary would reject every real hit); macro tokens use boundaries
/// so `my_unreachable!`-style names cannot false-positive.
const TOKENS: [(&str, bool); 6] = [
    (".unwrap()", false),
    (".expect(", false),
    ("panic!", true),
    ("unreachable!", true),
    ("todo!", true),
    ("unimplemented!", true),
];

/// Run the rule over one file.
pub fn check(file: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if PANIC_ALLOWED.iter().any(|p| file.path.starts_with(p)) {
        return out;
    }
    for (token, boundary) in TOKENS {
        for pos in lexer::find_token(&file.masked, token, boundary) {
            if file.in_test_region(pos) {
                continue;
            }
            file.push_unless_allowed(
                &mut out,
                super::RULE_PANIC_FREEDOM,
                pos,
                format!(
                    "`{token}` in library code; route the failure through \
                     error::Error (or state the invariant with debug_assert!)"
                ),
            );
        }
    }
    out
}
