//! Source masking and region detection for `pallas-lint`.
//!
//! The workspace is offline (no `syn`), so the linter works on a
//! *masked* view of each source file: a byte-for-byte copy in which
//! every comment and every string/char-literal interior is blanked to
//! spaces (newlines preserved). Token scans over the masked text can
//! then use plain substring search without tripping on `panic!` inside
//! a doc comment or `HashMap` inside an error message, and brace
//! matching is reliable because literal braces are blanked too.
//!
//! The masker is a hand-rolled byte state machine covering the literal
//! forms the tree actually uses: line comments, nested block comments,
//! `"…"` strings with escapes, raw strings `r"…"` / `r#"…"#`, byte
//! strings `b"…"` / `br#"…"#`, char and byte-char literals (including
//! `'\''` and `'"'`), and lifetimes (`'a`, `'static`), which are *not*
//! literals and pass through untouched.

/// One half-open byte range `[start, end)` of the masked source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Inclusive start byte.
    pub start: usize,
    /// Exclusive end byte.
    pub end: usize,
}

impl Span {
    /// Whether `pos` falls inside the span.
    pub fn contains(&self, pos: usize) -> bool {
        self.start <= pos && pos < self.end
    }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank comments and literal interiors to spaces, preserving byte
/// offsets and newlines exactly. Multi-byte UTF-8 sequences inside
/// blanked regions become one space per byte, so the result is always
/// valid UTF-8 of the same length as the input.
pub fn mask_source(src: &str) -> String {
    let b = src.as_bytes();
    let n = b.len();
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut i = 0usize;

    // Push `count` blanks, preserving any newline bytes verbatim.
    let blank = |out: &mut Vec<u8>, bytes: &[u8]| {
        for &c in bytes {
            out.push(if c == b'\n' { b'\n' } else { b' ' });
        }
    };

    while i < n {
        let c = b[i];

        // Line comment: `//…` to end of line.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, &b[i..j]);
            i = j;
            continue;
        }

        // Block comment: `/* … */`, nesting allowed.
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, &b[i..j]);
            i = j;
            continue;
        }

        // Possible literal prefix: `r"`, `r#"`, `b"`, `br#"`, `b'` —
        // only when not glued to a preceding identifier (so `for` /
        // `attr"` never start a literal).
        let prev_ident = i > 0 && is_ident_byte(b[i - 1]);
        if (c == b'r' || c == b'b') && !prev_ident {
            let mut j = i;
            let mut raw = false;
            if b[j] == b'b' {
                j += 1;
            }
            if j < n && b[j] == b'r' && j <= i + 1 {
                raw = true;
                j += 1;
            }
            let mut hashes = 0usize;
            if raw {
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
            }
            if raw && j < n && b[j] == b'"' {
                // Raw (byte) string: ends at `"` + `hashes` hashes.
                let body = j + 1;
                let mut k = body;
                'scan: while k < n {
                    if b[k] == b'"' {
                        let mut h = 0usize;
                        while h < hashes && k + 1 + h < n && b[k + 1 + h] == b'#' {
                            h += 1;
                        }
                        if h == hashes {
                            k += 1 + hashes;
                            break 'scan;
                        }
                    }
                    k += 1;
                }
                out.extend_from_slice(&b[i..body]);
                blank(&mut out, &b[body..k]);
                i = k;
                continue;
            }
            if !raw && c == b'b' && j == i + 1 && j < n && (b[j] == b'"' || b[j] == b'\'') {
                // Fall through to the plain string / char handling with
                // the `b` prefix emitted as code.
                out.push(b'b');
                i = j;
                // Handled by the `"` / `'` arms below on the next pass.
                continue;
            }
            // Not a literal prefix after all.
            out.push(c);
            i += 1;
            continue;
        }

        // Plain string literal.
        if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j = (j + 2).min(n);
                    continue;
                }
                if b[j] == b'"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            out.push(b'"');
            blank(&mut out, &b[i + 1..j.saturating_sub(1).max(i + 1)]);
            if j > i + 1 {
                out.push(b'"');
            }
            i = j;
            continue;
        }

        // Char literal vs lifetime.
        if c == b'\'' {
            let j = i + 1;
            if j >= n {
                out.push(c);
                i += 1;
                continue;
            }
            if b[j] == b'\\' {
                // Escaped char literal: scan past the escape intro to
                // the closing quote (covers `'\''`, `'\\'`, `'\x41'`,
                // `'\u{1F600}'`).
                let mut k = j + 2; // skip the backslash and escape head
                while k < n && b[k] != b'\'' {
                    k += 1;
                }
                k = (k + 1).min(n);
                out.push(b'\'');
                blank(&mut out, &b[i + 1..k.saturating_sub(1).max(i + 1)]);
                if k > i + 1 {
                    out.push(b'\'');
                }
                i = k;
                continue;
            }
            // Multi-byte scalar (`'§'`) is always a char literal;
            // ASCII `'x'` is one only when a quote closes it.
            let multibyte = b[j] >= 0x80;
            let closes_ascii = b[j] != b'\'' && j + 1 < n && b[j + 1] == b'\'';
            if multibyte || closes_ascii {
                let mut k = j;
                while k < n && b[k] != b'\'' {
                    k += 1;
                }
                k = (k + 1).min(n);
                out.push(b'\'');
                blank(&mut out, &b[i + 1..k.saturating_sub(1).max(i + 1)]);
                if k > i + 1 {
                    out.push(b'\'');
                }
                i = k;
                continue;
            }
            // Lifetime (or a stray quote): pass through.
            out.push(c);
            i += 1;
            continue;
        }

        out.push(c);
        i += 1;
    }

    // Masked regions are all-ASCII; code regions are copied verbatim,
    // so the byte stream is valid UTF-8 whenever the input was.
    String::from_utf8(out).unwrap_or_default()
}

/// Byte spans of test-only code in the masked source: every item
/// annotated `#[cfg(test)]` or `#[test]`, brace-matched. Overlapping
/// spans (a `#[test]` fn inside a `#[cfg(test)]` mod) are fine — rule
/// checks treat membership in *any* span as "test code".
pub fn test_regions(masked: &str) -> Vec<Span> {
    let mut spans = Vec::new();
    let b = masked.as_bytes();
    for marker in ["#[cfg(test)]", "#[test]"] {
        let mut from = 0usize;
        while let Some(rel) = masked[from..].find(marker) {
            let at = from + rel;
            from = at + marker.len();
            if let Some(span) = item_span_after(b, at, from) {
                spans.push(span);
            }
        }
    }
    spans
}

/// From the end of an attribute, skip whitespace and further
/// attributes, then brace-match the item body. Returns `None` when the
/// item has no body (e.g. the attribute sits on a `use`).
fn item_span_after(b: &[u8], attr_start: usize, attr_end: usize) -> Option<Span> {
    let n = b.len();
    let mut i = attr_end;
    while i < n {
        let c = b[i];
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        if c == b'#' && i + 1 < n && b[i + 1] == b'[' {
            // Skip a following attribute, bracket-matched.
            let mut depth = 0usize;
            let mut j = i + 1;
            while j < n {
                match b[j] {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            i = (j + 1).min(n);
            continue;
        }
        break;
    }
    // Scan the item header to its opening brace; a `;` first means a
    // body-less item.
    while i < n {
        match b[i] {
            b'{' => break,
            b';' => return None,
            _ => i += 1,
        }
    }
    if i >= n {
        return None;
    }
    let mut depth = 0usize;
    let mut j = i;
    while j < n {
        match b[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(Span { start: attr_start, end: j + 1 });
                }
            }
            _ => {}
        }
        j += 1;
    }
    Some(Span { start: attr_start, end: n })
}

/// 1-indexed line number of a byte offset.
pub fn line_of(src: &str, pos: usize) -> usize {
    let upto = pos.min(src.len());
    src.as_bytes()[..upto].iter().filter(|&&c| c == b'\n').count() + 1
}

/// Find every occurrence of `token` in the masked source. When
/// `word_boundary` is set, occurrences glued to identifier characters
/// on either side are skipped (so `HashMap` does not match
/// `MyHashMapExt`).
pub fn find_token(masked: &str, token: &str, word_boundary: bool) -> Vec<usize> {
    let mut hits = Vec::new();
    let b = masked.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = masked[from..].find(token) {
        let at = from + rel;
        from = at + token.len().max(1);
        if word_boundary {
            let before_ok = at == 0 || !is_ident_byte(b[at - 1]);
            let after = at + token.len();
            let after_ok = after >= b.len() || !is_ident_byte(b[after]);
            if !(before_ok && after_ok) {
                continue;
            }
        }
        hits.push(at);
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_comments_and_strings() {
        let src = "let x = 1; // panic!\nlet s = \"unwrap() inside\";\n/* block\npanic! */ call();";
        let m = mask_source(src);
        assert_eq!(m.len(), src.len());
        assert!(!m.contains("panic!"));
        assert!(!m.contains("unwrap"));
        assert!(m.contains("let x = 1;"));
        assert!(m.contains("call();"));
        // Newlines survive so line numbers stay aligned.
        assert_eq!(m.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn masks_raw_and_byte_strings() {
        let src = "let a = r#\"raw panic! {\"#; let b = b\"bytes unwrap()\"; let c = r\"x{\";";
        let m = mask_source(src);
        assert!(!m.contains("panic!"));
        assert!(!m.contains("unwrap"));
        assert!(!m.contains('{'), "literal braces must be blanked: {m}");
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let q = '\"'; let e = '\\''; let z = 'y'; }";
        let m = mask_source(src);
        // The double-quote char literal must not open a string.
        assert!(m.contains("let z ="));
        assert!(!m.contains('"'), "quote char literal interior must be blanked");
        assert!(m.contains("<'a>"), "lifetimes pass through: {m}");
    }

    #[test]
    fn unicode_in_comments_is_blanked_per_byte() {
        let src = "x(); // §3.5 — bound ≤ 1.25× target\ny();";
        let m = mask_source(src);
        assert_eq!(m.len(), src.len());
        assert!(m.contains("x();"));
        assert!(m.contains("y();"));
        assert!(m.is_ascii());
    }

    #[test]
    fn test_region_detection() {
        let src = "fn lib() { a(); }\n#[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { b(); }\n}\nfn lib2() {}";
        let m = mask_source(src);
        let regions = test_regions(&m);
        assert_eq!(regions.len(), 2, "cfg(test) mod + inner #[test] fn");
        let b_pos = m.find("b();").unwrap_or(usize::MAX);
        assert!(regions.iter().any(|r| r.contains(b_pos)));
        let a_pos = m.find("a();").unwrap_or(usize::MAX);
        assert!(!regions.iter().any(|r| r.contains(a_pos)));
    }

    #[test]
    fn token_word_boundaries() {
        let m = "use std::collections::HashMap; struct MyHashMapExt;".to_string();
        assert_eq!(find_token(&m, "HashMap", true).len(), 1);
        assert_eq!(find_token(&m, "HashMap", false).len(), 2);
    }

    #[test]
    fn line_numbers() {
        let src = "a\nb\nc";
        assert_eq!(line_of(src, 0), 1);
        assert_eq!(line_of(src, 2), 2);
        assert_eq!(line_of(src, 4), 3);
    }
}
