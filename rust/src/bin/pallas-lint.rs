//! `pallas-lint` — run the repo-native invariant linter over `src/`.
//!
//! ```text
//! cargo run --release --bin pallas-lint                 # lint the tree
//! cargo run --bin pallas-lint -- --quiet                # findings only via exit code
//! cargo run --bin pallas-lint -- --root other/src       # lint another tree
//! cargo run --bin pallas-lint -- --json out.json        # JSON somewhere else
//! cargo run --bin pallas-lint -- --update-wire-golden   # re-pin the wire digest
//! ```
//!
//! By default the JSON report lands at
//! `target/lint-results/pallas-lint.json` (uploaded as a CI artifact);
//! `--no-json` skips it. Exit status: 0 clean, 1 findings, 2 usage or
//! I/O failure. The rules themselves are documented in
//! [`incapprox::lint`].

use std::path::PathBuf;
use std::process::ExitCode;

use incapprox::cli::Args;
use incapprox::error::{Error, Result};
use incapprox::lint;

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<ExitCode> {
    let args = Args::from_env(&["quiet", "update-wire-golden", "no-json"])?;
    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"),
    };

    if args.flag("update-wire-golden") {
        let wire = std::fs::read_to_string(root.join(lint::wire_schema::WIRE_PATH))?;
        let module = std::fs::read_to_string(root.join(lint::wire_schema::MOD_PATH))?;
        let digest = lint::wire_schema::schema_digest(wire.as_bytes(), module.as_bytes());
        let version = lint::wire_schema::parse_version(&module).ok_or_else(|| {
            Error::Config("cannot find checkpoint::VERSION to pin the golden".to_string())
        })?;
        let golden_path = root.join(lint::wire_schema::GOLDEN_PATH);
        std::fs::write(&golden_path, lint::wire_schema::render_golden(version, digest))?;
        println!(
            "pallas-lint: pinned wire golden v{version} digest {digest:#018x} at {}",
            golden_path.display()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let report = lint::run(&root)?;

    if !args.flag("no-json") {
        let json_path = match args.get("json") {
            Some(p) => PathBuf::from(p),
            None => PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("target/lint-results/pallas-lint.json"),
        };
        if let Some(dir) = json_path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&json_path, report.to_json())?;
    }
    if !args.flag("quiet") {
        print!("{}", report.render_text());
    }
    Ok(if report.is_clean() { ExitCode::SUCCESS } else { ExitCode::FAILURE })
}
