//! The multi-query streaming session: generators → kafka substrate →
//! coordinator → N concurrent queries.
//!
//! [`Session`] is the session-era evolution of the original single-query
//! pipeline: it wires Figure 2.1 together (sub-stream generators publish
//! to a topic on the in-process broker, keyed by stratum; a single
//! consumer pulls the merged stream; the coordinator processes
//! slide-sized batches) and serves every query registered via
//! [`Session::submit`] from that one stream. Each [`Session::step`]
//! yields a [`SlideOutput`]: the window-level stats plus one
//! [`QueryReport`](crate::coordinator::report::QueryReport) per
//! registered query — all derived from the same window, sample, and memo
//! store, so query count multiplies neither per-slide touched items nor
//! memo entries.
//!
//! Backpressure: when consumer lag exceeds
//! `lag_watermark_slides × slide` records (see
//! [`SystemConfig`](crate::config::system::SystemConfig)), a step drains
//! up to `catchup_factor` slides at once so processing catches up instead
//! of falling ever further behind.
//!
//! # Example
//!
//! Three tenants, one stream, one memo store:
//!
//! ```
//! use incapprox::prelude::*;
//!
//! let cfg = SystemConfig {
//!     window_size: 1500,
//!     slide: 150,
//!     seed: 21,
//!     ..SystemConfig::default()
//! };
//! let source = MultiStream::paper_section5(cfg.seed);
//! let mut session = Session::new(Coordinator::new(cfg), source)?;
//!
//! let total = session.submit(QuerySpec::new(AggregateKind::Sum))?;
//! let mean99 = session.submit(
//!     QuerySpec::new(AggregateKind::Mean).with_confidence(0.99),
//! )?;
//! let volume = session.submit(QuerySpec::new(AggregateKind::Count))?;
//!
//! let out = session.warmup()?;
//! assert_eq!(out.queries.len(), 3);
//! assert!(out.query(total).unwrap().estimate.value > 0.0);
//! assert_eq!(out.query(volume).unwrap().estimate.margin, 0.0); // exact
//! assert!(out.query(mean99).unwrap().estimate.confidence == 0.99);
//! # let _ = session.remove(mean99);
//! # Ok::<(), incapprox::Error>(())
//! ```

use std::io::{Read, Write};
use std::sync::Arc;

use crate::checkpoint::{Artifact, SessionSection};
use crate::coordinator::driver::Coordinator;
use crate::coordinator::query::{QueryId, QuerySpec};
use crate::coordinator::report::SlideOutput;
use crate::error::{Error, Result};
use crate::kafka::broker::Broker;
use crate::kafka::consumer::Consumer;
use crate::kafka::producer::{Partitioner, Producer};
use crate::workload::gen::MultiStream;
use crate::workload::record::Record;

/// Default topic the session publishes to.
pub const TOPIC: &str = "incapprox-events";

/// A streaming session serving N concurrent queries over one shared
/// window, sample, and memo store.
pub struct Session {
    broker: Arc<Broker<Record>>,
    producer: Producer<Record>,
    consumer: Consumer<Record>,
    coordinator: Coordinator,
    source: MultiStream,
    /// Slides processed since the last periodic checkpoint (the
    /// `pipeline.checkpoint_every_slides` cadence).
    slides_since_ckpt: usize,
}

impl Session {
    /// Build a session over a generator source. The slide size and the
    /// backpressure knobs (`lag_watermark_slides`, `catchup_factor`) are
    /// read live from the coordinator's [`SystemConfig`] at each step,
    /// so mid-run reconfiguration through
    /// [`Session::coordinator_mut`] is honored.
    ///
    /// [`SystemConfig`]: crate::config::system::SystemConfig
    pub fn new(coordinator: Coordinator, source: MultiStream) -> Result<Self> {
        let broker = Broker::new();
        broker.create_topic(TOPIC, 4)?;
        let producer = Producer::new(&broker, TOPIC, Partitioner::Keyed)?;
        let mut consumer = Consumer::new();
        consumer.subscribe(&broker, TOPIC)?;
        Ok(Session { broker, producer, consumer, coordinator, source, slides_since_ckpt: 0 })
    }

    /// Register a query; every subsequent slide answers it. See
    /// [`Coordinator::submit_query`].
    pub fn submit(&mut self, spec: QuerySpec) -> Result<QueryId> {
        self.coordinator.submit_query(spec)
    }

    /// Deregister a query; returns whether the id was registered.
    pub fn remove(&mut self, id: QueryId) -> bool {
        self.coordinator.remove_query(id)
    }

    /// Number of registered queries.
    pub fn query_count(&self) -> usize {
        self.coordinator.query_count()
    }

    /// Produce from the generators until at least `n` records are queued.
    fn produce_at_least(&mut self, n: usize) -> Result<()> {
        let mut produced = 0;
        while produced < n {
            let records = self.source.tick();
            for r in &records {
                self.producer.send(Some(r.stratum as u64), r.timestamp, *r)?;
            }
            produced += records.len();
        }
        Ok(())
    }

    /// Take a periodic checkpoint when the configured cadence says so
    /// (`pipeline.checkpoint_every_slides`, 0 = off). The chain lives in
    /// memory; [`Session::checkpoint`] flushes it to a writer. A torn
    /// segment write (the `fault.checkpoint_write` channel) surfaces as
    /// a typed [`Error::Checkpoint`](crate::error::Error): the slide
    /// itself already processed — only its durability is late, and the
    /// invalidated chain re-bases at the next cadence.
    fn maybe_periodic_checkpoint(&mut self) -> Result<()> {
        let every = self.coordinator.config().checkpoint_every_slides;
        if every == 0 {
            return Ok(());
        }
        self.slides_since_ckpt += 1;
        if self.slides_since_ckpt >= every {
            self.slides_since_ckpt = 0;
            self.coordinator.refresh_checkpoint_chain()?;
        }
        Ok(())
    }

    /// Warm the window: fill it completely and process the first window.
    pub fn warmup(&mut self) -> Result<SlideOutput> {
        let need = self.coordinator.config().window_size;
        self.produce_at_least(need)?;
        let batch: Vec<Record> =
            self.consumer.poll(need)?.into_iter().map(|m| m.payload).collect();
        let out = self.coordinator.process_batch_queries(batch)?;
        self.maybe_periodic_checkpoint()?;
        Ok(out)
    }

    /// One session step: produce a slide, pull (with catch-up under
    /// backpressure), process the window, answer every query.
    ///
    /// An injected broker fault (the `fault.broker` channel, drawn on
    /// the previous slide) stalls this step's poll: the step returns a
    /// typed [`Error::Kafka`](crate::error::Error) *after* producing, so
    /// the records queue on the broker and lag grows — the next
    /// successful step sees the backlog and the backpressure / catch-up
    /// path drains it, feeding the degradation controller on the way.
    pub fn step(&mut self) -> Result<SlideOutput> {
        let cfg = self.coordinator.config();
        let slide = cfg.slide;
        let lag_high_watermark = (slide * cfg.lag_watermark_slides) as u64;
        let catchup_factor = cfg.catchup_factor;
        self.produce_at_least(slide)?;
        if self.coordinator.take_broker_fault() {
            return Err(Error::Kafka(
                "injected broker fault: consumer poll stalled this step".into(),
            ));
        }
        let lag = self.consumer.lag()?;
        // Overload feedback, in *slides* (an integer division, so every
        // worker count and every restored run computes the same value).
        self.coordinator.observe_lag_slides(lag / slide.max(1) as u64);
        let batch_size = if lag > lag_high_watermark {
            log::warn!("backpressure: lag {lag} > {lag_high_watermark}, catching up");
            slide * catchup_factor
        } else {
            slide
        };
        let batch: Vec<Record> =
            self.consumer.poll(batch_size)?.into_iter().map(|m| m.payload).collect();
        let out = self.coordinator.process_batch_queries(batch)?;
        self.maybe_periodic_checkpoint()?;
        Ok(out)
    }

    /// Serialize the session's full recoverable state — the
    /// coordinator's checkpoint chain (window, memo, sample runs, query
    /// registry) plus the generator state and the broker backlog of
    /// produced-but-unconsumed records — into `sink`. Returns bytes
    /// written. A session rebuilt with [`Session::restore`] continues
    /// the stream **byte-identically**: every subsequent
    /// [`SlideOutput`] matches the uninterrupted run's.
    pub fn checkpoint<W: Write>(&mut self, sink: &mut W) -> Result<u64> {
        let source = self.source.checkpoint_spec()?;
        let backlog: Vec<Record> =
            self.consumer.backlog()?.into_iter().map(|m| m.payload).collect();
        let section = SessionSection {
            source,
            slides_since_ckpt: self.slides_since_ckpt as u64,
            backlog,
        };
        self.coordinator.write_checkpoint(sink, Some(section))
    }

    /// Rebuild a session mid-stream from a checkpoint written by
    /// [`Session::checkpoint`]. `cfg` must match the checkpointed run's
    /// seed, mode, chunk size, map weight, and slide (see
    /// [`Coordinator::restore`]); worker count and shard strategy may
    /// differ. In-flight records captured in the checkpoint are replayed
    /// into the fresh broker in delivery order, so nothing queued is
    /// lost. Corrupted or truncated artifacts yield an
    /// [`Error::Checkpoint`](crate::error::Error), never a panic.
    pub fn restore<R: Read>(source: R, cfg: crate::config::system::SystemConfig) -> Result<Session> {
        let artifact = Artifact::read(source)?;
        let (coordinator, section) = Coordinator::restore_from_artifact(artifact, cfg)?;
        let section = section.ok_or_else(|| {
            Error::Checkpoint(
                "artifact has no session section (a bare Coordinator checkpoint?); \
                 use Coordinator::restore"
                    .into(),
            )
        })?;
        let stream = MultiStream::from_spec(section.source);
        let mut session = Session::new(coordinator, stream)?;
        // Resume the periodic cadence where the live run left it, so the
        // fault-fallback image refreshes on the same schedule.
        session.slides_since_ckpt = section.slides_since_ckpt as usize;
        // Replay in-flight records in delivery order: keyed partitioning
        // re-places each on its stratum's partition, so subsequent polls
        // return exactly what the checkpointed consumer would have seen.
        for r in &section.backlog {
            session.producer.send(Some(r.stratum as u64), r.timestamp, *r)?;
        }
        Ok(session)
    }

    /// Run `n` steps after warmup; returns all outputs (warmup first).
    pub fn run(&mut self, n: usize) -> Result<Vec<SlideOutput>> {
        let mut outputs = vec![self.warmup()?];
        for _ in 0..n {
            outputs.push(self.step()?);
        }
        Ok(outputs)
    }

    /// Current consumer lag (monitoring).
    pub fn lag(&self) -> Result<u64> {
        self.consumer.lag()
    }

    /// Borrow the coordinator (stats inspection).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coordinator
    }

    /// Mutably borrow the coordinator (e.g. window resizing mid-run).
    pub fn coordinator_mut(&mut self) -> &mut Coordinator {
        &mut self.coordinator
    }

    /// The broker (for attaching extra producers/consumers in examples).
    pub fn broker(&self) -> Arc<Broker<Record>> {
        self.broker.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::{BudgetSpec, ExecModeSpec, SystemConfig};
    use crate::job::aggregate::AggregateKind;

    fn session(mode: ExecModeSpec) -> Session {
        let cfg = SystemConfig {
            mode,
            window_size: 1500,
            slide: 150,
            seed: 21,
            ..SystemConfig::default()
        };
        let source = MultiStream::paper_section5(cfg.seed);
        Session::new(Coordinator::new(cfg), source).unwrap()
    }

    #[test]
    fn multi_query_session_end_to_end() {
        let mut s = session(ExecModeSpec::IncApprox);
        let sum = s.submit(QuerySpec::new(AggregateKind::Sum)).unwrap();
        let mean = s
            .submit(
                QuerySpec::new(AggregateKind::Mean)
                    .with_budget(BudgetSpec::Fraction(0.05)),
            )
            .unwrap();
        let hot = s
            .submit(QuerySpec::new(AggregateKind::Extrema).with_stratum(2))
            .unwrap();
        assert_eq!(s.query_count(), 3);
        let outputs = s.run(4).unwrap();
        assert_eq!(outputs.len(), 5);
        for out in &outputs {
            assert_eq!(out.queries.len(), 3);
            assert!(out.query(sum).unwrap().estimate.value > 0.0);
            assert!(out.query(mean).unwrap().estimate.value > 0.0);
            let e = out.query(hot).unwrap();
            assert_eq!(e.kind, AggregateKind::Extrema);
            let (lo, hi) = e.extrema.expect("stratum 2 always populated");
            assert!(lo <= hi);
        }
        // The steady-state window still shows the marriage working.
        let last = &outputs.last().unwrap().window;
        assert_eq!(last.window_len, 1500);
        assert!(last.item_reuse_fraction() > 0.5);
    }

    #[test]
    fn remove_mid_run_drops_only_that_query() {
        let mut s = session(ExecModeSpec::IncApprox);
        let a = s.submit(QuerySpec::new(AggregateKind::Sum)).unwrap();
        let b = s.submit(QuerySpec::new(AggregateKind::Count)).unwrap();
        let out = s.warmup().unwrap();
        assert_eq!(out.queries.len(), 2);
        assert!(s.remove(a));
        let out = s.step().unwrap();
        assert_eq!(out.queries.len(), 1);
        assert!(out.query(a).is_none());
        assert!(out.query(b).is_some());
        assert!(!s.remove(a), "double remove is a no-op");
    }

    #[test]
    fn configured_backpressure_knobs_are_honored() {
        let cfg = SystemConfig {
            window_size: 1500,
            slide: 150,
            seed: 21,
            lag_watermark_slides: 2,
            catchup_factor: 6,
            ..SystemConfig::default()
        };
        let source = MultiStream::paper_section5(cfg.seed);
        let mut s = Session::new(Coordinator::new(cfg.clone()), source).unwrap();
        // The knobs are read live from the coordinator's config.
        assert_eq!(s.coordinator().config().lag_watermark_slides, 2);
        assert_eq!(s.coordinator().config().catchup_factor, 6);
        s.run(6).unwrap();
        // Consumer keeps up: lag bounded by the configured catch-up size.
        assert!(s.lag().unwrap() < (cfg.slide * cfg.catchup_factor * 2) as u64);
    }

    #[test]
    fn all_modes_serve_queries() {
        for mode in [
            ExecModeSpec::Native,
            ExecModeSpec::IncrementalOnly,
            ExecModeSpec::ApproxOnly,
            ExecModeSpec::IncApprox,
        ] {
            let mut s = session(mode);
            for kind in AggregateKind::ALL {
                s.submit(QuerySpec::new(kind)).unwrap();
            }
            let outputs = s.run(2).unwrap();
            assert_eq!(outputs.len(), 3, "{}", mode.name());
            for out in &outputs {
                assert_eq!(out.queries.len(), AggregateKind::ALL.len());
                for q in &out.queries {
                    assert!(q.estimate.value.is_finite(), "{}/{}", mode.name(), q.kind.name());
                    assert!(q.estimate.margin >= 0.0);
                }
                // Exact modes sample the whole window → every bounded
                // aggregate collapses to margin 0 via the FPC.
                if matches!(mode, ExecModeSpec::Native | ExecModeSpec::IncrementalOnly) {
                    for q in &out.queries {
                        assert_eq!(q.estimate.margin, 0.0, "{}", q.kind.name());
                    }
                }
            }
        }
    }
}
