//! The end-to-end pipeline: generators → kafka substrate → coordinator.
//!
//! Wires Figure 2.1 together: sub-stream generators publish to a topic on
//! the in-process broker (keyed by stratum, preserving per-sub-stream
//! ordering), a single consumer pulls the merged stream, and the
//! coordinator processes slide-sized batches. Backpressure: when consumer
//! lag exceeds `lag_high_watermark`, the pipeline drains bigger batches
//! (up to `catchup_factor` slides) per step so processing catches up
//! instead of falling ever further behind.

use std::sync::Arc;

use crate::coordinator::driver::Coordinator;
use crate::coordinator::report::WindowReport;
use crate::error::Result;
use crate::kafka::broker::Broker;
use crate::kafka::consumer::Consumer;
use crate::kafka::producer::{Partitioner, Producer};
use crate::workload::gen::MultiStream;
use crate::workload::record::Record;

/// Default topic the pipeline publishes to.
pub const TOPIC: &str = "incapprox-events";

/// The assembled streaming pipeline.
pub struct Pipeline {
    broker: Arc<Broker<Record>>,
    producer: Producer<Record>,
    consumer: Consumer<Record>,
    coordinator: Coordinator,
    source: MultiStream,
    slide: usize,
    lag_high_watermark: u64,
    catchup_factor: usize,
}

impl Pipeline {
    /// Build a pipeline over a generator source.
    pub fn new(coordinator: Coordinator, source: MultiStream) -> Result<Self> {
        let slide = coordinator.config().slide;
        let broker = Broker::new();
        broker.create_topic(TOPIC, 4)?;
        let producer = Producer::new(&broker, TOPIC, Partitioner::Keyed)?;
        let mut consumer = Consumer::new();
        consumer.subscribe(&broker, TOPIC)?;
        Ok(Pipeline {
            broker,
            producer,
            consumer,
            coordinator,
            source,
            slide,
            lag_high_watermark: (slide * 4) as u64,
            catchup_factor: 4,
        })
    }

    /// Produce from the generators until at least `n` records are queued.
    fn produce_at_least(&mut self, n: usize) -> Result<()> {
        let mut produced = 0;
        while produced < n {
            let records = self.source.tick();
            for r in &records {
                self.producer.send(Some(r.stratum as u64), r.timestamp, *r)?;
            }
            produced += records.len();
        }
        Ok(())
    }

    /// Warm the window: fill it completely and process the first window.
    pub fn warmup(&mut self) -> Result<WindowReport> {
        let need = self.coordinator.config().window_size;
        self.produce_at_least(need)?;
        let batch: Vec<Record> =
            self.consumer.poll(need)?.into_iter().map(|m| m.payload).collect();
        self.coordinator.process_batch(batch)
    }

    /// One pipeline step: produce a slide, pull (with catch-up under
    /// backpressure), process the window.
    pub fn step(&mut self) -> Result<WindowReport> {
        self.produce_at_least(self.slide)?;
        let lag = self.consumer.lag()?;
        let batch_size = if lag > self.lag_high_watermark {
            log::warn!("backpressure: lag {lag} > {}, catching up", self.lag_high_watermark);
            self.slide * self.catchup_factor
        } else {
            self.slide
        };
        let batch: Vec<Record> =
            self.consumer.poll(batch_size)?.into_iter().map(|m| m.payload).collect();
        self.coordinator.process_batch(batch)
    }

    /// Run `n` steps after warmup; returns all reports (warmup first).
    pub fn run(&mut self, n: usize) -> Result<Vec<WindowReport>> {
        let mut reports = vec![self.warmup()?];
        for _ in 0..n {
            reports.push(self.step()?);
        }
        Ok(reports)
    }

    /// Current consumer lag (monitoring).
    pub fn lag(&self) -> Result<u64> {
        self.consumer.lag()
    }

    /// Borrow the coordinator (stats inspection).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coordinator
    }

    /// Mutably borrow the coordinator (e.g. window resizing mid-run).
    pub fn coordinator_mut(&mut self) -> &mut Coordinator {
        &mut self.coordinator
    }

    /// The broker (for attaching extra producers/consumers in examples).
    pub fn broker(&self) -> Arc<Broker<Record>> {
        self.broker.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::{ExecModeSpec, SystemConfig};

    fn pipeline(mode: ExecModeSpec) -> Pipeline {
        let cfg = SystemConfig {
            mode,
            window_size: 1500,
            slide: 150,
            seed: 21,
            ..SystemConfig::default()
        };
        let source = MultiStream::paper_section5(cfg.seed);
        Pipeline::new(Coordinator::new(cfg), source).unwrap()
    }

    #[test]
    fn end_to_end_incapprox_run() {
        let mut p = pipeline(ExecModeSpec::IncApprox);
        let reports = p.run(4).unwrap();
        assert_eq!(reports.len(), 5);
        let last = reports.last().unwrap();
        assert_eq!(last.window_len, 1500);
        assert!(last.item_reuse_fraction() > 0.5);
        assert!(last.estimate.value > 0.0);
    }

    #[test]
    fn all_modes_run_through_pipeline() {
        for mode in [
            ExecModeSpec::Native,
            ExecModeSpec::IncrementalOnly,
            ExecModeSpec::ApproxOnly,
            ExecModeSpec::IncApprox,
        ] {
            let mut p = pipeline(mode);
            let reports = p.run(2).unwrap();
            assert_eq!(reports.len(), 3, "{}", mode.name());
        }
    }

    #[test]
    fn lag_bounded_during_run() {
        let mut p = pipeline(ExecModeSpec::IncApprox);
        p.run(6).unwrap();
        // Consumer keeps up: lag below the catch-up ceiling.
        assert!(p.lag().unwrap() < (p.slide * p.catchup_factor * 2) as u64);
    }
}
