//! The legacy single-query pipeline — a thin wrapper over [`Session`].
//!
//! [`Pipeline`] is the pre-session public API: one stream, one implicit
//! window-level query, [`WindowReport`]s out. It now delegates every
//! step to a zero-query [`Session`] and drops the (empty) per-query
//! answers, which is exactly the equivalence gate the session redesign
//! is held to: `Pipeline::run` reports are byte-identical to the
//! pre-session implementation. New code should use [`Session`] directly
//! and register explicit [`QuerySpec`](crate::coordinator::QuerySpec)s.

use std::sync::Arc;

use crate::coordinator::driver::Coordinator;
use crate::coordinator::report::WindowReport;
use crate::coordinator::session::Session;
use crate::error::Result;
use crate::kafka::broker::Broker;
use crate::workload::gen::MultiStream;
use crate::workload::record::Record;

pub use crate::coordinator::session::TOPIC;

/// The assembled single-query streaming pipeline (legacy API).
pub struct Pipeline {
    inner: Session,
}

impl Pipeline {
    /// Build a pipeline over a generator source.
    pub fn new(coordinator: Coordinator, source: MultiStream) -> Result<Self> {
        Ok(Pipeline { inner: Session::new(coordinator, source)? })
    }

    /// Warm the window: fill it completely and process the first window.
    pub fn warmup(&mut self) -> Result<WindowReport> {
        Ok(self.inner.warmup()?.window)
    }

    /// One pipeline step: produce a slide, pull (with catch-up under
    /// backpressure), process the window.
    pub fn step(&mut self) -> Result<WindowReport> {
        Ok(self.inner.step()?.window)
    }

    /// Run `n` steps after warmup; returns all reports (warmup first).
    pub fn run(&mut self, n: usize) -> Result<Vec<WindowReport>> {
        Ok(self.inner.run(n)?.into_iter().map(|s| s.window).collect())
    }

    /// Current consumer lag (monitoring).
    pub fn lag(&self) -> Result<u64> {
        self.inner.lag()
    }

    /// Borrow the coordinator (stats inspection).
    pub fn coordinator(&self) -> &Coordinator {
        self.inner.coordinator()
    }

    /// Mutably borrow the coordinator (e.g. window resizing mid-run).
    pub fn coordinator_mut(&mut self) -> &mut Coordinator {
        self.inner.coordinator_mut()
    }

    /// The broker (for attaching extra producers/consumers in examples).
    pub fn broker(&self) -> Arc<Broker<Record>> {
        self.inner.broker()
    }

    /// Upgrade into the session-era API, keeping stream position, window
    /// state, and memo store.
    pub fn into_session(self) -> Session {
        self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::{ExecModeSpec, SystemConfig};

    fn pipeline(mode: ExecModeSpec) -> Pipeline {
        let cfg = SystemConfig {
            mode,
            window_size: 1500,
            slide: 150,
            seed: 21,
            ..SystemConfig::default()
        };
        let source = MultiStream::paper_section5(cfg.seed);
        Pipeline::new(Coordinator::new(cfg), source).unwrap()
    }

    #[test]
    fn end_to_end_incapprox_run() {
        let mut p = pipeline(ExecModeSpec::IncApprox);
        let reports = p.run(4).unwrap();
        assert_eq!(reports.len(), 5);
        let last = reports.last().unwrap();
        assert_eq!(last.window_len, 1500);
        assert!(last.item_reuse_fraction() > 0.5);
        assert!(last.estimate.value > 0.0);
    }

    #[test]
    fn all_modes_run_through_pipeline() {
        for mode in [
            ExecModeSpec::Native,
            ExecModeSpec::IncrementalOnly,
            ExecModeSpec::ApproxOnly,
            ExecModeSpec::IncApprox,
        ] {
            let mut p = pipeline(mode);
            let reports = p.run(2).unwrap();
            assert_eq!(reports.len(), 3, "{}", mode.name());
        }
    }

    #[test]
    fn lag_bounded_during_run() {
        let mut p = pipeline(ExecModeSpec::IncApprox);
        p.run(6).unwrap();
        // Consumer keeps up: lag below the *configured* catch-up ceiling
        // (the knobs live in SystemConfig since the session redesign).
        let cfg = p.coordinator().config();
        assert!(p.lag().unwrap() < (cfg.slide * cfg.catchup_factor * 2) as u64);
    }

    #[test]
    fn pipeline_upgrades_into_session() {
        use crate::coordinator::query::QuerySpec;
        use crate::job::aggregate::AggregateKind;
        let mut p = pipeline(ExecModeSpec::IncApprox);
        p.warmup().unwrap();
        let mut s = p.into_session();
        let id = s.submit(QuerySpec::new(AggregateKind::Mean)).unwrap();
        let out = s.step().unwrap();
        // Memo state survived the upgrade: still reusing, now answering.
        assert!(out.window.item_reuse_fraction() > 0.5);
        assert!(out.query(id).is_some());
    }
}
