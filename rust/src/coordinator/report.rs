//! Per-window output reports: window-level stats ([`WindowReport`]),
//! per-query answers ([`QueryReport`]), and the per-slide envelope a
//! session delivers ([`SlideOutput`]).

use std::collections::BTreeMap;

use crate::coordinator::query::QueryId;
use crate::job::aggregate::{AggregateKind, ErrorSurface};
use crate::stats::stratified::Estimate;
use crate::workload::record::StratumId;

/// Per-stratum reuse accounting for one window (the quantities Fig 5.1
//  plots).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StratumReport {
    /// Items sampled from the stratum this window.
    pub sample_size: usize,
    /// Items in the biased sample carrying memoized results.
    pub memo_reused: usize,
    /// Memoized items that were available before biasing.
    pub memo_available: usize,
    /// Items seen in the stratum over the whole window (population Bᵢ).
    pub population: u64,
}

/// The result of processing one window.
#[derive(Debug, Clone)]
pub struct WindowReport {
    /// Window sequence number.
    pub window_id: u64,
    /// Execution mode name.
    pub mode: &'static str,
    /// The approximate (or exact) output with confidence interval.
    pub estimate: Estimate,
    /// Items in the window.
    pub window_len: usize,
    /// Total sample size used.
    pub sample_size: usize,
    /// Chunks planned in total.
    pub chunks_total: usize,
    /// Chunks whose results were reused from the memo.
    pub chunks_reused: usize,
    /// Items actually computed this window (fresh chunk items on the full
    /// path, |added| + |removed| on the inverse-reduce path) — the
    /// per-window work, and the quantity the headline speedup divides.
    pub fresh_items: usize,
    /// Per-stratum accounting.
    pub strata: BTreeMap<StratumId, StratumReport>,
    /// Wall-clock processing time of the window.
    pub latency_ms: f64,
    /// True if a fault was injected before this window.
    pub fault_injected: bool,
    /// True when the slide was answered from surviving strata only: the
    /// batched compute call exhausted its retry budget, so strata that
    /// needed fresh computation dropped out of this window's estimate
    /// (they rejoin on the next slide via a full recompute). The answer
    /// is still a valid estimate over the strata it covers — this flag is
    /// how the error contract stays honest about the missing ones.
    pub degraded: bool,
}

impl WindowReport {
    /// Fraction of sampled items whose sub-computations were reused.
    pub fn item_reuse_fraction(&self) -> f64 {
        let total: usize = self.strata.values().map(|s| s.sample_size).sum();
        let reused: usize = self.strata.values().map(|s| s.memo_reused).sum();
        if total == 0 {
            0.0
        } else {
            reused as f64 / total as f64
        }
    }

    /// Fraction of chunks reused.
    pub fn chunk_reuse_fraction(&self) -> f64 {
        if self.chunks_total == 0 {
            0.0
        } else {
            self.chunks_reused as f64 / self.chunks_total as f64
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "w{:>4} [{}] out={:.2} ±{:.2} ({}%) sample={}/{} computed={} reuse: items {:.1}% lat={:.2}ms{}",
            self.window_id,
            self.mode,
            self.estimate.value,
            self.estimate.margin,
            (self.estimate.confidence * 100.0) as u32,
            self.sample_size,
            self.window_len,
            self.fresh_items,
            self.item_reuse_fraction() * 100.0,
            self.latency_ms,
            match (self.fault_injected, self.degraded) {
                (true, true) => " [FAULT] [DEGRADED]",
                (true, false) => " [FAULT]",
                (false, true) => " [DEGRADED]",
                (false, false) => "",
            }
        )
    }
}

/// One registered query's answer for one window, derived from the shared
/// per-stratum moments (see [`crate::job::aggregate`]).
#[derive(Debug, Clone)]
pub struct QueryReport {
    /// The query this answers.
    pub id: QueryId,
    /// The aggregate kind that was derived.
    pub kind: AggregateKind,
    /// `value ± margin` (margin 0 for exact answers / point estimates).
    pub estimate: Estimate,
    /// Sampled items that backed the answer (Σ bᵢ over queried strata).
    pub sample_size: usize,
    /// Window population over the queried strata (Σ Bᵢ — exact).
    pub population: u64,
    /// `(min, max)` of the queried sample (`Extrema` queries only;
    /// conservative bounds on the inverse-reduce path).
    pub extrema: Option<(f64, f64)>,
    /// Sketch-kind uncertainty (rank error / count bounds / standard
    /// error). `Some` exactly when a sketch kind had data; moment kinds
    /// carry their uncertainty in `estimate.margin` instead.
    pub surface: Option<ErrorSurface>,
    /// The relative error bound the query's `BudgetSpec::TargetError`
    /// budget promises (`None` for open-loop budgets). Compare against
    /// [`QueryReport::achieved_rel_bound`] to see the closed loop at
    /// work: after convergence the achieved bound tracks this target
    /// instead of whatever a fixed resource budget happens to buy.
    /// Under overload degradation this is the *effective* (widened)
    /// target — baseline × [`QueryReport::bound_scale`].
    pub target_rel_bound: Option<f64>,
    /// The degradation-ladder multiplier applied to this query's error
    /// target this slide: 1.0 at baseline (and always 1.0 for open-loop
    /// and sketch queries, which have no target to widen); > 1 while the
    /// `DegradationController` is shedding load.
    pub bound_scale: f64,
    /// True when this answer was derived from a degraded slide (some
    /// strata dropped out after retry exhaustion) — see
    /// [`WindowReport::degraded`].
    pub degraded: bool,
}

impl QueryReport {
    /// The relative error bound this slide actually delivered
    /// (margin / |value|; 0 for exact answers).
    pub fn achieved_rel_bound(&self) -> f64 {
        self.estimate.relative_error()
    }

    /// Did this slide's achieved bound meet the query's error target?
    /// `None` when the query runs an open-loop budget (no target to
    /// meet).
    pub fn meets_target(&self) -> Option<bool> {
        self.target_rel_bound.map(|t| self.achieved_rel_bound() <= t)
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        let target = match self.target_rel_bound {
            Some(t) => format!(
                " bound={:.2}%/≤{:.2}%{}",
                self.achieved_rel_bound() * 100.0,
                t * 100.0,
                if self.meets_target() == Some(true) { "" } else { " [MISS]" }
            ),
            None => String::new(),
        };
        let surface = match &self.surface {
            Some(ErrorSurface::RankError { epsilon, kept }) => {
                format!(" rank±{epsilon:.3} (kept={kept})")
            }
            Some(ErrorSurface::CountBounds { entries, coverage }) => {
                format!(" top{} coverage={:.3}", entries.len(), coverage)
            }
            Some(ErrorSurface::StdError { relative, registers }) => {
                format!(" rse={:.1}% (m={registers})", relative * 100.0)
            }
            None => String::new(),
        };
        let widened = if self.bound_scale > 1.0 {
            format!(" widened=×{:.2}", self.bound_scale)
        } else {
            String::new()
        };
        format!(
            "q{} {} = {:.3} ± {:.3} ({}%) sample={} pop={}{}{}{}{}",
            self.id.as_u64(),
            self.kind.name(),
            self.estimate.value,
            self.estimate.margin,
            (self.estimate.confidence * 100.0) as u32,
            self.sample_size,
            self.population,
            target,
            widened,
            surface,
            if self.degraded { " [DEGRADED]" } else { "" }
        )
    }
}

/// Everything one slide produced: the window-level stats every mode
/// already reported, plus one [`QueryReport`] per registered query, in
/// submission order.
#[derive(Debug, Clone)]
pub struct SlideOutput {
    /// Window-level stats (reuse accounting, window estimate, latency).
    pub window: WindowReport,
    /// Per-query answers, in query submission order.
    pub queries: Vec<QueryReport>,
}

impl SlideOutput {
    /// The answer for one query id, if it is registered.
    pub fn query(&self, id: QueryId) -> Option<&QueryReport> {
        self.queries.iter().find(|q| q.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn estimate() -> Estimate {
        Estimate { value: 100.0, margin: 5.0, variance: 6.5, df: 9.0, t: 2.26, confidence: 0.95 }
    }

    #[test]
    fn reuse_fractions() {
        let mut strata = BTreeMap::new();
        strata.insert(0, StratumReport { sample_size: 60, memo_reused: 30, memo_available: 40, population: 600 });
        strata.insert(1, StratumReport { sample_size: 40, memo_reused: 40, memo_available: 50, population: 400 });
        let r = WindowReport {
            window_id: 1,
            mode: "incapprox",
            estimate: estimate(),
            window_len: 1000,
            sample_size: 100,
            chunks_total: 10,
            chunks_reused: 4,
            fresh_items: 50,
            strata,
            latency_ms: 1.5,
            fault_injected: false,
            degraded: false,
        };
        assert!((r.item_reuse_fraction() - 0.7).abs() < 1e-12);
        assert!((r.chunk_reuse_fraction() - 0.4).abs() < 1e-12);
        let s = r.summary();
        assert!(s.contains("incapprox") && s.contains("±5.00"));
    }

    #[test]
    fn empty_report_zero_fractions() {
        let r = WindowReport {
            window_id: 0,
            mode: "native",
            estimate: estimate(),
            window_len: 0,
            sample_size: 0,
            chunks_total: 0,
            chunks_reused: 0,
            fresh_items: 0,
            strata: BTreeMap::new(),
            latency_ms: 0.0,
            fault_injected: false,
            degraded: false,
        };
        assert_eq!(r.item_reuse_fraction(), 0.0);
        assert_eq!(r.chunk_reuse_fraction(), 0.0);
    }

    #[test]
    fn slide_output_lookup_and_query_summary() {
        let window = WindowReport {
            window_id: 0,
            mode: "incapprox",
            estimate: estimate(),
            window_len: 10,
            sample_size: 5,
            chunks_total: 1,
            chunks_reused: 0,
            fresh_items: 5,
            strata: BTreeMap::new(),
            latency_ms: 0.1,
            fault_injected: false,
            degraded: false,
        };
        let q = QueryReport {
            id: QueryId::new(3),
            kind: AggregateKind::Mean,
            estimate: estimate(),
            sample_size: 5,
            population: 10,
            extrema: None,
            surface: None,
            target_rel_bound: None,
            bound_scale: 1.0,
            degraded: false,
        };
        let out = SlideOutput { window, queries: vec![q] };
        assert!(out.query(QueryId::new(3)).is_some());
        assert!(out.query(QueryId::new(4)).is_none());
        let s = out.queries[0].summary();
        assert!(s.contains("q3 mean"), "{s}");
        assert!(s.contains("95%"), "{s}");
        // Open-loop queries have no target to report against.
        assert_eq!(out.queries[0].meets_target(), None);
        assert!(!s.contains("bound="), "{s}");
    }

    #[test]
    fn target_bound_surfaced_and_compared() {
        // estimate(): 100 ± 5 → achieved relative bound 5%.
        let mut q = QueryReport {
            id: QueryId::new(1),
            kind: AggregateKind::Sum,
            estimate: estimate(),
            sample_size: 5,
            population: 10,
            extrema: None,
            surface: None,
            target_rel_bound: Some(0.10),
            bound_scale: 1.0,
            degraded: false,
        };
        assert!((q.achieved_rel_bound() - 0.05).abs() < 1e-12);
        assert_eq!(q.meets_target(), Some(true));
        let s = q.summary();
        assert!(s.contains("bound=5.00%/≤10.00%"), "{s}");
        assert!(!s.contains("[MISS]"), "{s}");
        // A missed target is called out.
        q.target_rel_bound = Some(0.01);
        assert_eq!(q.meets_target(), Some(false));
        assert!(q.summary().contains("[MISS]"), "{}", q.summary());
    }

    #[test]
    fn degraded_and_widened_markers_surface_in_summaries() {
        let mut w = WindowReport {
            window_id: 9,
            mode: "incapprox",
            estimate: estimate(),
            window_len: 10,
            sample_size: 5,
            chunks_total: 1,
            chunks_reused: 0,
            fresh_items: 5,
            strata: BTreeMap::new(),
            latency_ms: 0.1,
            fault_injected: true,
            degraded: true,
        };
        assert!(w.summary().contains("[FAULT] [DEGRADED]"), "{}", w.summary());
        w.fault_injected = false;
        assert!(w.summary().contains("[DEGRADED]"), "{}", w.summary());
        let mut q = QueryReport {
            id: QueryId::new(1),
            kind: AggregateKind::Sum,
            estimate: estimate(),
            sample_size: 5,
            population: 10,
            extrema: None,
            surface: None,
            target_rel_bound: Some(0.10),
            bound_scale: 1.5,
            degraded: true,
        };
        let s = q.summary();
        assert!(s.contains("widened=×1.50"), "{s}");
        assert!(s.contains("[DEGRADED]"), "{s}");
        q.bound_scale = 1.0;
        q.degraded = false;
        let s = q.summary();
        assert!(!s.contains("widened"), "{s}");
        assert!(!s.contains("DEGRADED"), "{s}");
    }

    #[test]
    fn sketch_surfaces_show_in_query_summaries() {
        let mut q = QueryReport {
            id: QueryId::new(2),
            kind: AggregateKind::Quantile(990),
            estimate: estimate(),
            sample_size: 5,
            population: 10,
            extrema: None,
            surface: Some(ErrorSurface::RankError { epsilon: 0.081, kept: 153 }),
            target_rel_bound: None,
            bound_scale: 1.0,
            degraded: false,
        };
        let s = q.summary();
        assert!(s.contains("q2 quantile"), "{s}");
        assert!(s.contains("rank±0.081"), "{s}");
        assert!(s.contains("kept=153"), "{s}");

        q.kind = AggregateKind::TopK(2);
        q.surface = Some(ErrorSurface::CountBounds {
            entries: vec![crate::job::sketch::TopEntry { key: 7, count_lo: 30, count_hi: 30 }],
            coverage: 0.5,
        });
        let s = q.summary();
        assert!(s.contains("top1 coverage=0.500"), "{s}");

        q.kind = AggregateKind::DistinctCount;
        q.surface = Some(ErrorSurface::StdError { relative: 0.065, registers: 256 });
        let s = q.summary();
        assert!(s.contains("rse=6.5% (m=256)"), "{s}");
    }
}
