//! First-class queries over a shared streaming session.
//!
//! A [`QuerySpec`] describes *what* one tenant wants from the stream —
//! which aggregate ([`AggregateKind`]), over which stratum, at which
//! confidence, within which [`BudgetSpec`] — and is registered on a
//! [`Session`](crate::coordinator::Session) (or directly on a
//! [`Coordinator`](crate::coordinator::Coordinator)) via `submit`, which
//! hands back a [`QueryId`]. Every registered query is answered **every
//! slide** from the same shared substrate: one window, one persistent
//! sampler (sized to the union — the max — of the per-query budget
//! allocations), one memo store, one batched backend call. Adding a
//! query adds an O(strata) derivation fold
//! ([`derive_aggregate`](crate::job::aggregate::derive_aggregate)) and
//! nothing else — per-slide touched items and memo entries are
//! independent of query count (`metrics::SlideWork::derive_items` is the
//! only counter that scales with N).

use crate::budget;
use crate::config::system::{BudgetSpec, SystemConfig};
use crate::error::{Error, Result};
use crate::job::aggregate::AggregateKind;
use crate::workload::record::StratumId;

/// Handle to a registered query (unique within its coordinator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QueryId(u64);

impl QueryId {
    /// Build from a raw sequence number (coordinator-internal).
    pub(crate) fn new(raw: u64) -> Self {
        QueryId(raw)
    }

    /// The raw id, for logging and report labels.
    pub fn as_u64(self) -> u64 {
        self.0
    }
}

/// One user query: aggregate kind, optional stratum restriction,
/// per-query confidence level and budget, and (optionally pinned) map
/// weight.
///
/// Built with [`QuerySpec::new`] plus `with_*` chainers:
///
/// ```
/// use incapprox::prelude::*;
///
/// let spec = QuerySpec::new(AggregateKind::Mean)
///     .with_stratum(2)
///     .with_confidence(0.99)
///     .with_budget(BudgetSpec::Fraction(0.05));
/// assert_eq!(spec.kind, AggregateKind::Mean);
/// assert_eq!(spec.stratum, Some(2));
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct QuerySpec {
    /// The aggregate to derive each slide.
    pub kind: AggregateKind,
    /// Restrict the query to one stratum (`None` = whole window).
    pub stratum: Option<StratumId>,
    /// Confidence level of the query's error bound (default 0.95).
    pub confidence: f64,
    /// The query's resource budget. The session samples at the **max**
    /// of all registered budgets, so a query never gets *less* accuracy
    /// than its own budget affords — sharing can only add headroom.
    pub budget: BudgetSpec,
    /// Per-item map iterations this query expects (`None` = inherit the
    /// session's). Must match the session's `map_rounds`: memoized chunk
    /// moments are computed under one shared map stage, and a divergent
    /// weight would fork the memo per query (see
    /// [`QuerySpec::validate_for`]).
    pub map_rounds: Option<u32>,
}

impl QuerySpec {
    /// A whole-window query for `kind` with the paper's defaults
    /// (95% confidence, 10% sampling-fraction budget).
    pub fn new(kind: AggregateKind) -> Self {
        QuerySpec {
            kind,
            stratum: None,
            confidence: 0.95,
            budget: BudgetSpec::default(),
            map_rounds: None,
        }
    }

    /// Restrict the query to one stratum.
    pub fn with_stratum(mut self, stratum: StratumId) -> Self {
        self.stratum = Some(stratum);
        self
    }

    /// Set the confidence level (must be in (0, 1)).
    pub fn with_confidence(mut self, confidence: f64) -> Self {
        self.confidence = confidence;
        self
    }

    /// Set the query budget.
    pub fn with_budget(mut self, budget: BudgetSpec) -> Self {
        self.budget = budget;
        self
    }

    /// Pin the expected map weight (validated against the session's).
    pub fn with_map_rounds(mut self, rounds: u32) -> Self {
        self.map_rounds = Some(rounds);
        self
    }

    /// Check the spec against a session configuration. Rejects
    /// out-of-range confidence, degenerate kind parameters and budgets,
    /// a sketch kind under a `TargetError` budget (the §3.5 backsolve
    /// has no meaning for rank/count/cardinality surfaces — see
    /// [`budget::validate_kind_budget`]), and a `map_rounds` that
    /// differs from the session's: chunk moments are memoized under
    /// **one** map stage — a query needing a different map weight needs
    /// its own session, not a forked memo store.
    pub fn validate_for(&self, cfg: &SystemConfig) -> Result<()> {
        if !(0.0 < self.confidence && self.confidence < 1.0) {
            return Err(Error::Config(format!(
                "query confidence must be in (0, 1), got {}",
                self.confidence
            )));
        }
        self.kind.validate()?;
        budget::validate_spec(&self.budget)?;
        budget::validate_kind_budget(self.kind, &self.budget)?;
        if let Some(rounds) = self.map_rounds {
            if rounds != cfg.map_rounds {
                return Err(Error::Config(format!(
                    "query map_rounds {rounds} != session map_rounds {}: memoized chunk \
                     moments are computed under one shared map stage; use a separate \
                     session for a different map weight",
                    cfg.map_rounds
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_chainers() {
        let spec = QuerySpec::new(AggregateKind::Sum);
        assert_eq!(spec.kind, AggregateKind::Sum);
        assert_eq!(spec.stratum, None);
        assert_eq!(spec.confidence, 0.95);
        assert_eq!(spec.budget, BudgetSpec::Fraction(0.1));
        assert_eq!(spec.map_rounds, None);
        let spec = spec
            .with_stratum(3)
            .with_confidence(0.9)
            .with_budget(BudgetSpec::LatencyMs(5.0))
            .with_map_rounds(0);
        assert_eq!(spec.stratum, Some(3));
        assert_eq!(spec.confidence, 0.9);
        assert_eq!(spec.budget, BudgetSpec::LatencyMs(5.0));
        assert_eq!(spec.map_rounds, Some(0));
    }

    #[test]
    fn validation_gates() {
        let cfg = SystemConfig::default();
        assert!(QuerySpec::new(AggregateKind::Mean).validate_for(&cfg).is_ok());
        assert!(QuerySpec::new(AggregateKind::Mean)
            .with_confidence(1.0)
            .validate_for(&cfg)
            .is_err());
        assert!(QuerySpec::new(AggregateKind::Mean)
            .with_budget(BudgetSpec::Fraction(0.0))
            .validate_for(&cfg)
            .is_err());
        // Matching map weight passes; a divergent one is rejected.
        assert!(QuerySpec::new(AggregateKind::Mean)
            .with_map_rounds(cfg.map_rounds)
            .validate_for(&cfg)
            .is_ok());
        assert!(QuerySpec::new(AggregateKind::Mean)
            .with_map_rounds(cfg.map_rounds + 1)
            .validate_for(&cfg)
            .is_err());
        // Degenerate sketch parameters are rejected at submit time.
        assert!(QuerySpec::new(AggregateKind::Quantile(0)).validate_for(&cfg).is_err());
        assert!(QuerySpec::new(AggregateKind::Quantile(1000)).validate_for(&cfg).is_err());
        assert!(QuerySpec::new(AggregateKind::TopK(0)).validate_for(&cfg).is_err());
        // Sketch kinds run fine under open-loop budgets…
        assert!(QuerySpec::new(AggregateKind::Quantile(990)).validate_for(&cfg).is_ok());
        assert!(QuerySpec::new(AggregateKind::TopK(8))
            .with_budget(BudgetSpec::LatencyMs(5.0))
            .validate_for(&cfg)
            .is_ok());
        // …but a TargetError budget is meaningless for a sketch surface.
        let closed = BudgetSpec::TargetError { relative_bound: 0.05, confidence: 0.95 };
        for kind in [AggregateKind::Quantile(500), AggregateKind::TopK(4),
                     AggregateKind::DistinctCount] {
            assert!(
                QuerySpec::new(kind).with_budget(closed.clone()).validate_for(&cfg).is_err(),
                "{} must reject a target-error budget",
                kind.name()
            );
        }
        assert!(QuerySpec::new(AggregateKind::Mean)
            .with_budget(closed)
            .validate_for(&cfg)
            .is_ok());
    }

    #[test]
    fn query_ids_are_ordered_values() {
        let a = QueryId::new(1);
        let b = QueryId::new(2);
        assert!(a < b);
        assert_eq!(a.as_u64(), 1);
        assert_eq!(a, QueryId::new(1));
    }
}
