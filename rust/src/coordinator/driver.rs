//! Algorithm 1 — the per-window driver, as a sharded parallel pipeline
//! with an O(delta) slide path.
//!
//! Two incremental mechanisms cooperate, mirroring the paper:
//!
//! * **Chunk memoization + change propagation** (§3.4, Figure 3.1): the
//!   biased sample is chunked in bias order (stable prefixes), planned
//!   against the memo store via the DDG, and only fresh chunks execute.
//!   This is the general self-adjusting path; it is also how a window is
//!   (re)computed from scratch.
//! * **Reduce / inverse-reduce** (§4.2.2, `reduceByKeyAndWindow`): for
//!   aggregate queries the per-stratum moments of the previous sample are
//!   *updated* with the item delta — combine the added items' moments,
//!   un-combine the removed items' — so per-window work is proportional
//!   to the change, not the sample. The delta moments themselves are
//!   computed by the chunk backend (PJRT on the hot path). Every
//!   `recompute_epoch` windows a full recompute bounds float drift.
//!
//! ## The O(delta) slide path
//!
//! With `incremental_slide` on (the default) nothing per-slide costs
//! O(window) anymore:
//!
//! * the window layer hands over a **delta-only snapshot** (no full item
//!   copy; `len`/`start_ts` are maintained incrementally);
//! * the **persistent sampler** (`sampling::incremental`) is updated with
//!   the delta — evicted items removed, arrived items inserted — instead
//!   of re-offering every window item;
//! * planning diffs the biased sample against the previous window via the
//!   id sets that ride along on every [`SampleRun`] (no per-window set
//!   rebuilds, no sample clones), and full-path re-chunking reuses the
//!   previous window's chunks for unchanged runs (no re-hashing);
//! * memo item lists are `Arc`-shared `SampleRun`s — memoize/read-back is
//!   O(strata) refcount traffic.
//!
//! With `incremental_slide` off the same sampler is **rebuilt** from the
//! materialized window every slide — the O(window) reference baseline.
//! Both paths produce byte-identical [`WindowReport`]s (the sample is a
//! pure function of window contents and seed; chunk reuse is verified by
//! record equality), which the driver equivalence tests assert three
//! ways: serial, sharded, and incremental. Per-slide items touched per
//! stage are recorded in [`Coordinator::work_profile`].
//!
//! ## The sharded pipeline
//!
//! With `num_workers > 1` (the default config) the per-window hot path
//! runs in three phases:
//!
//! 1. **Plan (parallel)** — strata are partitioned into shards (by the
//!    configured [`ShardStrategy`](crate::config::system::ShardStrategy))
//!    and each shard's strata are diffed/chunked/classified concurrently
//!    on scoped worker threads. Memo lookups go through the stratum's
//!    lock-free [`MemoShard`](crate::sac::memo::MemoShard) handle.
//! 2. **Compute (batched)** — every fresh chunk from every stratum —
//!    inverse-reduce deltas and full-path misses alike — lands in a
//!    single [`ChunkBackend::compute`] call, so the PJRT backend pays one
//!    dispatch per window and the worker pool splits one large batch.
//! 3. **Finalize (serial)** — results are routed back per stratum in
//!    deterministic order, moments combined, memo updated, bounds
//!    estimated.
//!
//! Per-stratum work is bit-identical to the serial reference path
//! (`num_workers = 1`): same chunks, same combine order — so the two
//! configurations produce identical [`WindowReport`]s, which
//! `sharded_pipeline_matches_serial_exactly` asserts.
//!
//! ## Multi-query serving
//!
//! N concurrent queries ([`Coordinator::submit_query`]) share one slide
//! loop: the sampler is sized to the **union** (max) of the per-query
//! budget allocations, planning/compute/memoization run exactly once,
//! and each query's answer is an O(strata) derivation fold over the
//! shared per-stratum moments ([`crate::job::aggregate`]). Per-slide
//! touched items and memo entries are therefore independent of query
//! count — only [`SlideWork::derive_items`] and
//! [`SlideWork::budget_adjust`] scale with N. With no queries registered
//! the coordinator behaves exactly like the pre-session single-query API
//! (the equivalence the session tests pin).
//!
//! ## The closed error-bound loop
//!
//! Budgets of kind [`BudgetSpec::TargetError`] run **closed-loop**: after
//! every slide the driver hands each adaptive budget the per-stratum
//! aggregates its query covers
//! ([`CostFunction::observe_bound`](crate::budget::CostFunction)), and
//! the controller solves Eq 3.2 backwards for the sample size the next
//! slide needs (see [`crate::budget::TargetErrorCost`]). Everything the
//! controller reads is byte-identical across the serial, sharded, and
//! incremental paths, so the adaptive trajectory is deterministic and
//! checkpointable: controller states ride in the base segment and as
//! `BudgetAdjust` journal ops, and a restored run continues the exact
//! trajectory. Per-query *cost* feedback is attributed too: each query's
//! `observe` receives its own allocation and its own cost share
//! ([`crate::budget::attribute_query_cost`]), never the union sample +
//! whole-slide latency.

use std::collections::BTreeMap;
use std::io::{Read, Write};

use crate::budget::{self, CostFunction, DegradationController};
use crate::checkpoint::{
    self, Artifact, BaseState, ChunkEntry, CkptTracker, Compat, DeltaState, JournalOp,
    Misc, Segment, SessionSection, SketchChunkEntry, WindowCkpt,
    SESSION_BUDGET_SLOT,
};
use crate::config::system::{ExecModeSpec, SystemConfig};
use crate::coordinator::query::{QueryId, QuerySpec};
use crate::coordinator::registry::QueryRegistry;
use crate::coordinator::report::{SlideOutput, StratumReport, WindowReport};
use crate::error::Result;
use crate::fault::{FaultInjector, MemoReplica, RecoveryPolicy, SlideFaults};
use crate::job::chunk::{chunk_stratum, chunk_stratum_cached_columns, Chunk};
use crate::job::executor::{run_sharded, ChunkBackend, NativeBackend, WorkerPool};
use crate::job::moments::Moments;
use crate::job::plan::{JobPlan, PlannedChunk};
use crate::job::sketch::{SketchBundle, SKETCH_SEED_SALT};
use crate::metrics::{PhaseProfile, SlideWork, Stopwatch, WorkProfile};
use crate::partition::PartitionState;
use crate::sac::memo::{MemoStore, StratumExport};
use crate::sampling::biased::{bias_sample, BiasOutcome};
use crate::sampling::incremental::IncrementalSampler;
use crate::sampling::stratified::{allocate_proportional, StratifiedSample};
use crate::sampling::SampleRun;
use crate::stats::stratified::{estimate_sum, StratumAgg};
use crate::window::{CountWindow, TimeWindow, WindowSnapshot};
use crate::workload::record::{Record, StratumId};

/// Execution pipeline variants: the paper's system and its baselines.
pub type ExecMode = ExecModeSpec;

impl ExecModeSpec {
    /// Does this mode sample (vs. process the whole window)?
    pub(crate) fn samples(&self) -> bool {
        matches!(self, ExecModeSpec::ApproxOnly | ExecModeSpec::IncApprox)
    }

    /// Does this mode memoize and reuse sub-computations?
    pub(crate) fn memoizes(&self) -> bool {
        matches!(self, ExecModeSpec::IncrementalOnly | ExecModeSpec::IncApprox)
    }

    /// Does this mode bias the sample toward memoized items?
    pub(crate) fn biases(&self) -> bool {
        matches!(self, ExecModeSpec::IncApprox)
    }
}

/// The window manager variant in use: count-based (what §5's figures
/// parameterize) or time-based (the paper's general model, §2.3.3 —
/// per-window item counts vary with arrival rate).
enum WindowState {
    /// Fixed item count, item-count slide.
    Count(CountWindow),
    /// Tick length + tick slide.
    Time(TimeWindow),
}

/// One stratum's planned work for the window, produced by the (possibly
/// parallel) planning phase.
enum StratumPlan {
    /// §4.2.2 inverse-reduce: update the previous moments with the item
    /// delta's chunk moments.
    Delta {
        /// Previous window's combined moments for the stratum.
        base: Moments,
        /// Chunks of items that entered the sample.
        added: Vec<Chunk>,
        /// Chunks of items that left the sample.
        removed: Vec<Chunk>,
        /// |added items| + |removed items| — the work this window.
        delta_items: usize,
    },
    /// Figure 3.1 chunked full path with per-chunk memo classification.
    Full {
        /// Chunks in bias order with their memo hits.
        planned: Vec<PlannedChunk>,
        /// Items hashed into freshly built chunks (cache misses); the
        /// O(delta) planning work metric.
        rehashed_items: usize,
    },
}

/// Plan one stratum: decide delta vs. full path and do the chunking and
/// memo classification. Pure and read-only (lock-free shard lookups), so
/// the coordinator runs it concurrently across strata.
///
/// `cur`/`prev` are the biased sample runs of this and the previous
/// window; their id sets drive the diff, so no per-window set is built.
/// `prev_chunks` is the previous full-path chunk sequence (incremental
/// chunk reuse; `None` on the from-scratch baseline).
#[allow(clippy::too_many_arguments)]
fn plan_one_stratum(
    stratum: StratumId,
    cur: &SampleRun,
    prev: Option<&SampleRun>,
    prev_chunks: Option<&[Chunk]>,
    memo: &MemoStore,
    memoizes: bool,
    epoch_recompute: bool,
    chunk_size: usize,
) -> Result<StratumPlan> {
    let shard = memo.shard(stratum);
    let prev_m = shard.stratum_moments(stratum);
    let cache = prev_chunks.unwrap_or(&[]);
    let (prev, base) = match (prev, prev_m) {
        (Some(p), Some(m)) if memoizes && !epoch_recompute => (p, m),
        _ => {
            let (planned, rehashed_items) = JobPlan::plan_stratum_cached(
                stratum,
                cur.columns(),
                if memoizes { Some(shard) } else { None },
                chunk_size,
                cache,
            )?;
            return Ok(StratumPlan::Full { planned, rehashed_items });
        }
    };
    // Diff via the runs' resident id sets — O(|cur| + |prev|) lookups,
    // zero allocations beyond the outputs.
    let added: Vec<Record> =
        cur.records().iter().filter(|r| !prev.contains(r.id)).copied().collect();
    let removed: Vec<Record> =
        prev.records().iter().filter(|r| !cur.contains(r.id)).copied().collect();
    if added.len() + removed.len() >= cur.len() {
        // Delta as big as the sample: recompute instead.
        let (planned, rehashed_items) = JobPlan::plan_stratum_cached(
            stratum,
            cur.columns(),
            Some(shard),
            chunk_size,
            cache,
        )?;
        return Ok(StratumPlan::Full { planned, rehashed_items });
    }
    let delta_items = added.len() + removed.len();
    Ok(StratumPlan::Delta {
        base,
        added: chunk_stratum(stratum, &added, chunk_size)?,
        removed: chunk_stratum(stratum, &removed, chunk_size)?,
        delta_items,
    })
}

/// The front half of a slide, produced by [`Coordinator::slide_prepare`]
/// and consumed by [`Coordinator::slide_finish`]. Between the two the
/// caller decides the slide's per-stratum sample allocation: the solo
/// driver allocates over its own sampler's populations; the partition
/// merge tier allocates ONE global budget over the *merged* populations
/// of every partition — the step that makes K disjoint samplers
/// reproduce exactly the sample a single sampler over the union would
/// have drawn.
pub(crate) struct SlidePrep {
    snap: WindowSnapshot,
    sw: Stopwatch,
    slide_work: SlideWork,
    faults: SlideFaults,
    prev_items: BTreeMap<StratumId, SampleRun>,
    /// Sampler-maintenance kernel wall-clock (measured in
    /// [`Coordinator::slide_prepare`], reported through [`SlideTiming`]).
    sampler_ms: f64,
}

impl SlidePrep {
    /// Items in the prepared window (post-slide).
    pub(crate) fn window_len(&self) -> usize {
        self.snap.len
    }

    /// The window's id (lockstep-checked across partitions).
    pub(crate) fn window_id(&self) -> u64 {
        self.snap.window_id
    }

    /// The window's start timestamp — this coordinator's local memo
    /// eviction horizon; the merge tier folds the global horizon from
    /// these.
    pub(crate) fn start_ts(&self) -> u64 {
        self.snap.start_ts
    }
}

/// Wall-clock handles carried out of [`Coordinator::slide_finish`] so
/// the caller can close the latency accounting at the same points the
/// fused slide path did.
pub(crate) struct SlideTiming {
    /// Running since the top of `slide_prepare`.
    pub(crate) sw: Stopwatch,
    /// Planning phase wall-clock.
    pub(crate) plan_ms: f64,
    /// Compute phase wall-clock.
    pub(crate) compute_ms: f64,
    /// Running since the top of the finalize phase.
    pub(crate) sw_finalize: Stopwatch,
    /// Sampler-maintenance kernel wall-clock (batched delta ranks on the
    /// incremental path, full rebuild on the baseline).
    pub(crate) sampler_ms: f64,
    /// Sketch feed-pass wall-clock (~0 when no sketch query is live).
    pub(crate) sketch_ms: f64,
}

/// One stratum's complete live state in flight between two partition
/// coordinators (see [`Coordinator::export_stratum`]): the "segment
/// chain as transport" rule's in-memory leg — the same state a
/// checkpoint would carry for the stratum, addressed by stratum instead
/// of by segment.
pub(crate) struct StratumTransfer {
    stratum: StratumId,
    records: Vec<Record>,
    memo: StratumExport,
    chunk_cache: Option<Vec<Chunk>>,
    sketch_chunks: Option<Vec<Chunk>>,
}

/// The streaming coordinator: owns the window, the persistent sampler,
/// the memo store, the cost function, the chunk execution backend, and
/// the registered queries (see [`Coordinator::submit_query`]).
///
/// # Example
///
/// One warm-up window plus one slide of the paper's §5 stream:
///
/// ```
/// use incapprox::prelude::*;
///
/// let cfg = SystemConfig {
///     mode: ExecModeSpec::IncApprox,
///     window_size: 2000,
///     slide: 200,
///     seed: 11,
///     ..SystemConfig::default()
/// };
/// let mut gen = MultiStream::paper_section5(cfg.seed);
/// let mut coord = Coordinator::new(cfg.clone());
///
/// let warm = coord.process_batch(gen.take_records(cfg.window_size)).unwrap();
/// assert_eq!(warm.window_len, 2000);
///
/// let report = coord.process_batch(gen.take_records(cfg.slide)).unwrap();
/// // 10% sampling budget with a confidence interval around the estimate.
/// assert!(report.sample_size <= report.window_len / 5);
/// assert!(report.estimate.margin > 0.0);
/// // The O(delta) slide touched far fewer items than the window holds.
/// assert!(coord.work_profile().last().total() < 2000);
/// ```
pub struct Coordinator {
    cfg: SystemConfig,
    window: WindowState,
    memo: MemoStore,
    cost: Box<dyn CostFunction>,
    backend: Box<dyn ChunkBackend>,
    /// Persistent rank-based sampler; maintained with window deltas on
    /// the incremental path, rebuilt per window on the from-scratch path.
    sampler: IncrementalSampler,
    /// Previous full-path chunk sequences per stratum (incremental chunk
    /// reuse; correctness-neutral — reuse is equality-verified).
    chunk_cache: BTreeMap<StratumId, Vec<Chunk>>,
    /// Previous sketch-pass chunk sequences per stratum (same equality-
    /// verified reuse, kept separate because the sketch pass chunks the
    /// biased sample even on slides where the moment path takes the
    /// inverse-reduce route and never re-chunks).
    sketch_chunks: BTreeMap<StratumId, Vec<Chunk>>,
    /// Registered queries, in submission order. Empty = legacy
    /// single-query behavior (the window budget sizes the sample).
    queries: QueryRegistry,
    /// The stratum range this coordinator owns when it runs as one
    /// partition of a scale-out deployment (`None` = the whole stream —
    /// every single-coordinator run). Carried in checkpoint [`Misc`] so
    /// a restored partition knows its range.
    owned_strata: Option<Vec<StratumId>>,
    injector: FaultInjector,
    recovery: RecoveryPolicy,
    replica: Option<MemoReplica>,
    /// Overload-degradation ladder: widens error-target bounds while
    /// consumer lag stays above the watermark, walks back to baseline as
    /// it drains. Fed only byte-identical quantities (lag in slides), so
    /// the trajectory is deterministic across worker counts and survives
    /// checkpoint/restore.
    degrade: DegradationController,
    /// In-memory incremental checkpoint chain. `None` until armed by the
    /// first [`Coordinator::checkpoint`] call or the periodic
    /// `pipeline.checkpoint_every_slides` knob; once armed, substrate
    /// mutations are journaled so later checkpoints cost O(state delta).
    ckpt: Option<CkptTracker>,
    windows_processed: u64,
    profile: PhaseProfile,
    work: WorkProfile,
}

impl Coordinator {
    /// Coordinator from a config, with a count-based window (use
    /// [`Coordinator::new_time_windowed`] for the time-based model). With
    /// `num_workers > 1` the sharded pipeline is on: strata are planned
    /// in parallel and fresh chunks execute on a worker pool; with `1`
    /// the serial scalar path runs (identical outputs).
    pub fn new(cfg: SystemConfig) -> Self {
        let window = WindowState::Count(CountWindow::new(cfg.window_size));
        Self::with_window(cfg, window)
    }

    /// Coordinator over a **time-based** sliding window of `length` ticks
    /// sliding by `slide` ticks; feed it with [`Coordinator::ingest_tick`].
    pub fn new_time_windowed(cfg: SystemConfig, length: u64, slide: u64) -> Self {
        Self::with_window(cfg, WindowState::Time(TimeWindow::new(length, slide)))
    }

    fn with_window(cfg: SystemConfig, window: WindowState) -> Self {
        let cost = budget::from_spec(&cfg.budget);
        // Multi-channel fault plan off one derived seed. The memo channel
        // keeps the exact pre-existing stream (`FaultSpec::memo_only`
        // compatibility); the other channels fold in per-channel salts.
        let injector = FaultInjector::with_spec(cfg.fault_spec(), cfg.seed ^ 0xFA17);
        // `use_pjrt` callers install their backend via `with_backend`
        // right after construction — don't spawn a worker pool they
        // would immediately discard.
        let backend: Box<dyn ChunkBackend> = if cfg.num_workers > 1 && !cfg.use_pjrt {
            Box::new(WorkerPool::with_rounds(cfg.num_workers, cfg.map_rounds))
        } else {
            Box::new(NativeBackend::new(cfg.map_rounds))
        };
        Coordinator {
            window,
            memo: MemoStore::sharded(cfg.num_workers, cfg.shard_strategy),
            cost,
            backend,
            // Keyed off the master seed so every slide path — serial,
            // sharded, incremental, from-scratch — ranks items identically.
            sampler: IncrementalSampler::new(cfg.seed ^ 0x0DE1_7A51_D35A_3D01),
            chunk_cache: BTreeMap::new(),
            sketch_chunks: BTreeMap::new(),
            queries: QueryRegistry::default(),
            owned_strata: None,
            injector,
            recovery: RecoveryPolicy::LineageRecompute,
            replica: None,
            degrade: DegradationController::new(cfg.degradation_policy()),
            ckpt: None,
            windows_processed: 0,
            profile: PhaseProfile::default(),
            work: WorkProfile::default(),
            cfg,
        }
    }

    /// Swap the chunk execution backend (worker pool or PJRT).
    pub fn with_backend(mut self, backend: Box<dyn ChunkBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Set the §6.3 recovery policy for injected memo loss.
    pub fn with_recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery = policy;
        self
    }

    /// The active configuration.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Register a query. Every subsequent slide answers it (one
    /// [`QueryReport`] inside the [`SlideOutput`]) from the shared
    /// window / sampler / memo substrate; the only added per-slide work
    /// is an O(strata) derivation fold. Fails if the spec is invalid for
    /// this session (see [`QuerySpec::validate_for`]).
    pub fn submit_query(&mut self, spec: QuerySpec) -> Result<QueryId> {
        self.queries.submit(&self.cfg, spec)
    }

    /// Test seam: register a query with a caller-supplied cost function
    /// (the driver tests use a recording stub to pin what `observe`
    /// actually receives). Production budgets always come from
    /// [`budget::from_spec`] via [`Coordinator::submit_query`].
    #[cfg(test)]
    pub(crate) fn submit_query_with_cost(
        &mut self,
        spec: QuerySpec,
        cost: Box<dyn CostFunction>,
    ) -> Result<QueryId> {
        self.queries.submit_with_cost(&self.cfg, spec, cost)
    }

    /// Deregister a query; later slides stop answering it. Returns
    /// whether the id was registered. The shared substrate (sample,
    /// memo) is untouched — remaining queries keep their amortization.
    pub fn remove_query(&mut self, id: QueryId) -> bool {
        self.queries.remove(id)
    }

    /// Number of registered queries.
    pub fn query_count(&self) -> usize {
        self.queries.len()
    }

    /// The specs of the registered queries, in submission order.
    pub fn query_specs(&self) -> impl Iterator<Item = (QueryId, &QuerySpec)> {
        self.queries.specs()
    }

    /// The slide's sample budget: the union (max) of the registered
    /// queries' per-budget allocations, so every query gets at least the
    /// accuracy its own budget affords; with no queries registered, the
    /// session-level budget (legacy single-query behavior).
    fn union_sample_size(&mut self, window_len: usize) -> usize {
        match self.queries.union_sample_size(window_len) {
            Some(n) => n,
            None => self.cost.sample_size(window_len),
        }
    }

    /// Memoization statistics so far.
    pub fn memo_stats(&self) -> crate::sac::memo::MemoStats {
        self.memo.stats()
    }

    /// Cumulative plan/compute/finalize wall-clock breakdown of every
    /// window processed so far.
    pub fn phase_profile(&self) -> &PhaseProfile {
        &self.profile
    }

    /// Per-slide items-touched accounting (window / sampler / plan /
    /// compute stages) of every window processed so far — the O(delta)
    /// invariant made measurable.
    pub fn work_profile(&self) -> &WorkProfile {
        &self.work
    }

    /// Backend name (reports).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Memo-loss faults injected so far (the original single-channel
    /// counter; see [`Coordinator::faults_by_channel`]).
    pub fn faults_injected(&self) -> u64 {
        self.injector.injected()
    }

    /// Faults injected per channel so far: `[memo, compute, broker,
    /// checkpoint_write]`.
    pub fn faults_by_channel(&self) -> [u64; 4] {
        self.injector.injected_by_channel()
    }

    /// Consume a pending injected broker fault (drawn by the fault plan
    /// on the last slide). The `Session` polls this before each consumer
    /// poll and surfaces a typed [`Error::Kafka`](crate::error::Error)
    /// for that step; unconsumed verdicts stay latched (and survive
    /// checkpoints), so coordinator-only runs are unaffected.
    pub fn take_broker_fault(&mut self) -> bool {
        self.injector.take_broker_fault()
    }

    /// Feed one slide's consumer lag, measured in slides
    /// (`lag_items / slide_len` — an integer division, so every worker
    /// count computes the same value), to the degradation controller.
    /// The watermark is `pipeline.lag_watermark_slides`. Called by the
    /// `Session` before each poll; standalone coordinators may call it
    /// directly to model external overload.
    pub fn observe_lag_slides(&mut self, lag_slides: u64) {
        self.degrade.observe_lag_slides(lag_slides, self.cfg.lag_watermark_slides as u64);
    }

    /// Current degradation ladder level (0 = configured baseline).
    pub fn degradation_level(&self) -> u32 {
        self.degrade.level()
    }

    /// Current error-bound multiplier (1.0 at baseline).
    pub fn bound_scale(&self) -> f64 {
        self.degrade.scale()
    }

    /// Resize the sliding window (Fig 5.1(c): Δ between adjacent windows).
    /// Count-based windows only; a no-op for time-based windows (their
    /// size is the time length). Evicted items surface in the next
    /// slide's delta, keeping the incremental sampler consistent.
    pub fn resize_window(&mut self, new_size: usize) {
        let resized = if let WindowState::Count(w) = &mut self.window {
            w.resize(new_size);
            true
        } else {
            false
        };
        if resized {
            self.cfg.window_size = new_size;
            self.ckpt_push(JournalOp::Resize { new_size: new_size as u64 });
        }
    }

    /// Does this configuration need the full window view per slide?
    /// Sampling modes on the incremental path run delta-only.
    fn wants_full_view(&self) -> bool {
        !(self.cfg.mode.samples() && self.cfg.incremental_slide)
    }

    /// Group a full window per stratum — the "sample" of the exact modes.
    fn full_window_sample(items: &[Record]) -> StratifiedSample {
        let mut out = StratifiedSample::default();
        for r in items {
            out.per_stratum.entry(r.stratum).or_default().push(*r);
            *out.population.entry(r.stratum).or_default() += 1;
        }
        out
    }

    /// Build a no-bias outcome that still *reports* the overlap with the
    /// memoized items (so baselines expose comparable reuse accounting).
    /// Membership tests ride on the memo runs' id sets — nothing is
    /// rebuilt here.
    fn no_bias_outcome(
        sample: &StratifiedSample,
        memo_items: &BTreeMap<StratumId, SampleRun>,
    ) -> BiasOutcome {
        let mut out = BiasOutcome::default();
        for (&s, items) in &sample.per_stratum {
            let memo_run = memo_items.get(&s);
            let reused = match memo_run {
                Some(run) => items.iter().filter(|r| run.contains(r.id)).count(),
                None => 0,
            };
            out.memo_available.insert(s, memo_run.map_or(0, SampleRun::len));
            out.memo_reused.insert(s, reused);
            out.per_stratum.insert(s, SampleRun::from_slice(items));
        }
        out
    }

    /// Phase 1: plan every stratum — in parallel shard groups when
    /// `num_workers > 1`, serially otherwise. Outputs are keyed by
    /// stratum, so the merge order (and everything downstream) is
    /// identical either way.
    fn plan_strata(
        &self,
        biased: &BiasOutcome,
        prev_items: &BTreeMap<StratumId, SampleRun>,
        epoch_recompute: bool,
    ) -> Result<BTreeMap<StratumId, StratumPlan>> {
        let memoizes = self.cfg.mode.memoizes();
        let chunk_size = self.cfg.chunk_size;
        let memo = &self.memo;
        let chunk_cache = &self.chunk_cache;
        let use_cache = self.cfg.incremental_slide;
        fn cached_chunks(
            cache: &BTreeMap<StratumId, Vec<Chunk>>,
            use_cache: bool,
            s: StratumId,
        ) -> Option<&[Chunk]> {
            if use_cache {
                cache.get(&s).map(Vec::as_slice)
            } else {
                None
            }
        }
        if self.cfg.num_workers > 1 && biased.per_stratum.len() > 1 {
            // Group strata by their memo shard; one scoped task per group.
            let mut groups: BTreeMap<usize, Vec<StratumId>> = BTreeMap::new();
            for &s in biased.per_stratum.keys() {
                groups.entry(memo.shard_for(s)).or_default().push(s);
            }
            let tasks: Vec<_> = groups
                .into_values()
                .map(|strata| {
                    move || {
                        strata
                            .into_iter()
                            .map(|s| {
                                let cur = &biased.per_stratum[&s];
                                let plan = plan_one_stratum(
                                    s,
                                    cur,
                                    prev_items.get(&s),
                                    cached_chunks(chunk_cache, use_cache, s),
                                    memo,
                                    memoizes,
                                    epoch_recompute,
                                    chunk_size,
                                )?;
                                Ok((s, plan))
                            })
                            .collect::<Result<Vec<_>>>()
                    }
                })
                .collect();
            let mut out = BTreeMap::new();
            for group in run_sharded(tasks) {
                out.extend(group?);
            }
            Ok(out)
        } else {
            biased
                .per_stratum
                .iter()
                .map(|(&s, cur)| {
                    let plan = plan_one_stratum(
                        s,
                        cur,
                        prev_items.get(&s),
                        cached_chunks(chunk_cache, use_cache, s),
                        memo,
                        memoizes,
                        epoch_recompute,
                        chunk_size,
                    )?;
                    Ok((s, plan))
                })
                .collect()
        }
    }

    /// Process one slide's worth of new records (count-based windows):
    /// runs the full Algorithm 1 body for the resulting window and
    /// returns its window-level report. Legacy single-query entry point —
    /// a thin wrapper over [`Coordinator::process_batch_queries`] that
    /// drops the per-query answers; its reports are byte-identical to the
    /// pre-session API.
    pub fn process_batch(&mut self, batch: Vec<Record>) -> Result<WindowReport> {
        Ok(self.process_batch_queries(batch)?.window)
    }

    /// Process one slide's worth of new records (count-based windows) and
    /// return the full [`SlideOutput`]: window-level stats plus one
    /// [`QueryReport`] per registered query.
    pub fn process_batch_queries(&mut self, batch: Vec<Record>) -> Result<SlideOutput> {
        if !matches!(self.window, WindowState::Count(_)) {
            return Err(crate::error::Error::Job(
                "process_batch needs a count window; use ingest_tick".into(),
            ));
        }
        if self.ckpt_wants_ops() {
            self.ckpt_push(JournalOp::Slide { inserted: batch.clone() });
        }
        let want_full = self.wants_full_view();
        let snap = match &mut self.window {
            WindowState::Count(w) => w.slide_with(batch, want_full),
            WindowState::Time(_) => {
                return Err(crate::error::Error::Job(
                    "process_batch needs a count window; use ingest_tick".into(),
                ));
            }
        };
        self.process_snapshot(snap)
    }

    /// Feed one tick's records to a **time-based** window (records must
    /// carry timestamps ≤ `now`). Emits a report whenever a window
    /// boundary is crossed; between boundaries returns `Ok(None)`.
    /// Legacy wrapper over [`Coordinator::ingest_tick_queries`].
    pub fn ingest_tick(
        &mut self,
        records: Vec<Record>,
        now: u64,
    ) -> Result<Option<WindowReport>> {
        Ok(self.ingest_tick_queries(records, now)?.map(|s| s.window))
    }

    /// Time-based-window twin of [`Coordinator::process_batch_queries`]:
    /// emits a [`SlideOutput`] whenever a window boundary is crossed.
    pub fn ingest_tick_queries(
        &mut self,
        records: Vec<Record>,
        now: u64,
    ) -> Result<Option<SlideOutput>> {
        if !matches!(self.window, WindowState::Time(_)) {
            return Err(crate::error::Error::Job(
                "ingest_tick needs a time window; use process_batch".into(),
            ));
        }
        if self.ckpt_wants_ops() {
            self.ckpt_push(JournalOp::Tick { records: records.clone(), now });
        }
        let want_full = self.wants_full_view();
        let snap = match &mut self.window {
            WindowState::Time(w) => {
                w.ingest(records);
                w.try_emit_with(now, want_full)
            }
            WindowState::Count(_) => {
                return Err(crate::error::Error::Job(
                    "ingest_tick needs a time window; use process_batch".into(),
                ));
            }
        };
        snap.map(|s| self.process_snapshot(s)).transpose()
    }

    // --- Partition driver seams (see `crate::partition`) ----------------

    /// The stratum range this coordinator owns as a partition (`None`
    /// for solo runs — the whole stream).
    pub(crate) fn owned_strata(&self) -> Option<&[StratumId]> {
        self.owned_strata.as_deref()
    }

    /// Record the stratum range this coordinator owns as a partition;
    /// carried into every checkpoint's [`Misc`] section.
    pub(crate) fn set_owned_strata(&mut self, strata: Option<Vec<StratumId>>) {
        self.owned_strata = strata;
    }

    /// The sampler's exact per-stratum populations — current after
    /// [`Coordinator::slide_prepare`]; the merge tier folds these into
    /// the global populations its Eq 3.1 allocation runs over.
    pub(crate) fn sampler_populations(&self) -> BTreeMap<StratumId, u64> {
        self.sampler.populations()
    }

    /// Is this coordinator driving a count-based window? (The merge tier
    /// restores partitions from artifacts and must rebuild its router
    /// for count windows only.)
    pub(crate) fn is_count_windowed(&self) -> bool {
        matches!(self.window, WindowState::Count(_))
    }

    /// Windows processed so far (tier bookkeeping after restore).
    pub(crate) fn windows_processed(&self) -> u64 {
        self.windows_processed
    }

    /// The currently buffered window records (count windows; a restored
    /// merge tier rebuilds its global FIFO router from the union of its
    /// partitions' buffers, re-ordered by `(timestamp, id)` — arrival
    /// order, by the workload generator's id monotonicity).
    pub(crate) fn window_buffer_records(&self) -> Vec<Record> {
        match &self.window {
            WindowState::Count(w) => w.checkpoint_parts().0,
            WindowState::Time(w) => w.window_records(),
        }
    }

    /// Partition twin of [`Coordinator::process_batch_queries`]'s front
    /// half: apply a router-driven count-window slide — `batch` inserts
    /// plus an **explicit** eviction count (the router decides evictions
    /// globally; a partition's own buffer length says nothing about the
    /// global window) — and run slide preparation. Journals a
    /// `PartitionSlide` op so checkpoints replay the same external
    /// eviction schedule.
    pub(crate) fn partition_prepare_count(
        &mut self,
        batch: Vec<Record>,
        evict: usize,
    ) -> Result<SlidePrep> {
        if !matches!(self.window, WindowState::Count(_)) {
            return Err(crate::error::Error::Job(
                "partition_prepare_count needs a count window".into(),
            ));
        }
        if self.ckpt_wants_ops() {
            self.ckpt_push(JournalOp::PartitionSlide {
                inserted: batch.clone(),
                evict: evict as u64,
            });
        }
        let want_full = self.wants_full_view();
        let snap = match &mut self.window {
            WindowState::Count(w) => w.slide_external(batch, evict, want_full),
            WindowState::Time(_) => {
                return Err(crate::error::Error::Job(
                    "partition_prepare_count needs a count window".into(),
                ));
            }
        };
        Ok(self.slide_prepare(snap))
    }

    /// Partition twin of [`Coordinator::ingest_tick_queries`]'s front
    /// half: every partition's time window sees the same `now`, so
    /// emission stays in lockstep across partitions (the merge tier
    /// asserts it).
    pub(crate) fn partition_prepare_tick(
        &mut self,
        records: Vec<Record>,
        now: u64,
    ) -> Result<Option<SlidePrep>> {
        if !matches!(self.window, WindowState::Time(_)) {
            return Err(crate::error::Error::Job(
                "partition_prepare_tick needs a time window".into(),
            ));
        }
        if self.ckpt_wants_ops() {
            self.ckpt_push(JournalOp::Tick { records: records.clone(), now });
        }
        let want_full = self.wants_full_view();
        let snap = match &mut self.window {
            WindowState::Time(w) => {
                w.ingest(records);
                w.try_emit_with(now, want_full)
            }
            WindowState::Count(_) => {
                return Err(crate::error::Error::Job(
                    "partition_prepare_tick needs a time window".into(),
                ));
            }
        };
        Ok(snap.map(|s| self.slide_prepare(s)))
    }

    /// Extract one stratum's full live state — window records in arrival
    /// order, memo image, chunk caches — for shipment to another
    /// partition (rebalancing). The remaining state is re-anchored: the
    /// checkpoint chain re-bases (the journal cannot express an
    /// out-of-band departure) and the sampler rebuilds from the
    /// remaining window (it is a pure function of contents + seed, so
    /// the rebuild lands exactly where incremental maintenance would
    /// have). Count windows only — a time window's buffer order is not
    /// reconstructible from `(timestamp, id)` alone.
    pub(crate) fn export_stratum(&mut self, stratum: StratumId) -> Result<StratumTransfer> {
        let records = match &mut self.window {
            WindowState::Count(w) => w.extract_stratum(stratum),
            WindowState::Time(_) => {
                return Err(crate::error::Error::Job(
                    "stratum rebalancing requires count-based windows".into(),
                ));
            }
        };
        let memo = self.memo.extract_stratum(stratum);
        let chunk_cache = self.chunk_cache.remove(&stratum);
        let sketch_chunks = self.sketch_chunks.remove(&stratum);
        if let Some(t) = &mut self.ckpt {
            t.invalidate();
        }
        let remaining = match &self.window {
            WindowState::Count(w) => {
                let (mut buf, pending) = w.checkpoint_parts();
                buf.extend(pending);
                buf
            }
            WindowState::Time(w) => w.window_records(),
        };
        self.sampler.rebuild(&remaining);
        Ok(StratumTransfer { stratum, records, memo, chunk_cache, sketch_chunks })
    }

    /// Splice a shipped stratum into this coordinator: the inverse of
    /// [`Coordinator::export_stratum`], with the same re-anchoring
    /// (chain re-base, sampler rebuild).
    pub(crate) fn import_stratum(&mut self, transfer: StratumTransfer) -> Result<()> {
        let StratumTransfer { stratum, records, memo, chunk_cache, sketch_chunks } = transfer;
        match &mut self.window {
            WindowState::Count(w) => w.splice_records(records),
            WindowState::Time(_) => {
                return Err(crate::error::Error::Job(
                    "stratum rebalancing requires count-based windows".into(),
                ));
            }
        }
        self.memo.absorb_stratum(stratum, memo);
        if let Some(chunks) = chunk_cache {
            self.chunk_cache.insert(stratum, chunks);
        }
        if let Some(chunks) = sketch_chunks {
            self.sketch_chunks.insert(stratum, chunks);
        }
        if let Some(t) = &mut self.ckpt {
            t.invalidate();
        }
        let full = match &self.window {
            WindowState::Count(w) => {
                let (mut buf, pending) = w.checkpoint_parts();
                buf.extend(pending);
                buf
            }
            WindowState::Time(w) => w.window_records(),
        };
        self.sampler.rebuild(&full);
        Ok(())
    }

    /// The Algorithm 1 body, shared by both window kinds: prepare, one
    /// proportional allocation over this coordinator's own sampler
    /// populations (a solo run owns the whole stream), finish, then
    /// derive every answer from the finished state. The partition merge
    /// tier runs the same prepare/finish pair per partition but computes
    /// ONE global allocation over the merged populations and derives
    /// from the merged state — the same code paths, which is what makes
    /// the two deployments byte-identical by construction.
    fn process_snapshot(&mut self, snap: WindowSnapshot) -> Result<SlideOutput> {
        let horizon = snap.start_ts;
        let window_len = snap.len;
        let prep = self.slide_prepare(snap);
        // Cost function gives the sample size based on the budget; Eq 3.1
        // splits it proportionally over the exact per-stratum populations
        // (this is `IncrementalSampler::sample` with the allocation step
        // lifted to the caller).
        let alloc = if self.cfg.mode.samples() {
            let n = self.union_sample_size(window_len);
            Some(allocate_proportional(n, &self.sampler.populations()))
        } else {
            None
        };
        let want_sketches = self.queries.wants_sketches();
        let (state, timing) = self.slide_finish(prep, horizon, alloc.as_ref(), want_sketches)?;
        let PartitionState {
            window_id,
            window_len,
            sample_size,
            chunks_total,
            chunks_reused,
            fresh_items,
            moments,
            sketches,
            populations,
            strata,
            degraded_strata,
            fault_injected,
            work: mut slide_work,
        } = state;
        let degraded = !degraded_strata.is_empty();
        let bound_scale = self.degrade.scale();

        // --- Reduce to the estimate (§3.5) ------------------------------
        let mut aggs: Vec<StratumAgg> = Vec::with_capacity(moments.len());
        for (s, m) in &moments {
            let population = populations.get(s).copied().unwrap_or(0) as f64;
            aggs.push(StratumAgg::from_moments(m, population));
        }
        let estimate = estimate_sum(&aggs, self.cfg.confidence)?;

        // Answer every registered query from the *shared* per-stratum
        // moments and exact populations — O(strata) per query (see
        // `QueryRegistry::derive_phase`). A solo coordinator cannot tell
        // which stratum a degraded slide actually hurt, so the degraded
        // flag is blanket.
        let (query_reports, derive_ms) = self.queries.derive_phase(
            &moments,
            &populations,
            &sketches,
            bound_scale,
            &degraded_strata,
            true,
            &mut slide_work,
        )?;

        // Close the error-bound loop (§3.5 margin → Eq 3.2 backwards):
        // every adaptive error-target budget reads the achieved
        // per-stratum aggregates its own query covers and re-solves for
        // the sample size the *next* slide needs. O(strata) per adaptive
        // budget, charged to `budget_adjust` — with `derive_items` the
        // only work allowed to scale with query count.
        if self.cost.wants_bound_feedback() {
            slide_work.budget_adjust += aggs.len() as u64;
            self.cost.observe_bound(&aggs, window_len as f64);
        }
        self.queries.observe_bounds(&moments, &populations, window_len, &mut slide_work);

        let latency_ms = timing.sw.elapsed_ms();
        self.profile.observe(
            timing.plan_ms,
            timing.compute_ms,
            timing.sw_finalize.elapsed_ms(),
            timing.sampler_ms,
            timing.sketch_ms,
        );
        self.work.observe(slide_work);
        // The session-level budget owns the whole window: it observes the
        // realized union sample and the full slide latency. Per-query
        // budgets observe their OWN cost share (see
        // `QueryRegistry::attribute_costs`).
        self.cost.observe(sample_size, latency_ms);
        let total_derive_ms: f64 = derive_ms.iter().sum();
        let substrate_ms = (latency_ms - total_derive_ms).max(0.0);
        self.queries.attribute_costs(sample_size, substrate_ms, &derive_ms);
        // Journal the post-slide controller states so a restored run
        // continues on the same budget trajectory (absolute values;
        // replay is last-wins).
        if self.ckpt_wants_ops() {
            for (slot, policy, state) in self.budget_state_slots() {
                self.ckpt_push(JournalOp::BudgetAdjust {
                    slot,
                    policy: policy.to_string(),
                    state,
                });
            }
        }

        Ok(SlideOutput {
            window: WindowReport {
                window_id,
                mode: self.cfg.mode.name(),
                estimate,
                window_len,
                sample_size,
                chunks_total,
                chunks_reused,
                fresh_items,
                strata,
                latency_ms,
                fault_injected,
                degraded,
            },
            queries: query_reports,
        })
    }

    /// Everything Algorithm 1 does *before* the slide's sample
    /// allocation can be known: the fault draw + memo-loss recovery, the
    /// degradation-scale propagation, the previous-sample capture, and
    /// the persistent-sampler maintenance from the window delta. After
    /// this returns the sampler's per-stratum populations are current —
    /// exactly what the caller needs to compute the allocation that
    /// [`Coordinator::slide_finish`] consumes.
    pub(crate) fn slide_prepare(&mut self, snap: WindowSnapshot) -> SlidePrep {
        let sw = Stopwatch::start();
        let mut slide_work = SlideWork::default();
        slide_work.window_items =
            snap.full_view().map_or(snap.delta.len(), <[Record]>::len) as u64;

        // Draw this slide's faults from the seeded multi-channel plan.
        // Memo loss applies before eviction (a crash loses the store;
        // recovery may restore the previous window's replica, or — under
        // `RecoveryPolicy::Checkpoint` — the memo image of the last
        // checkpoint segment). Broker / checkpoint-write verdicts latch
        // in the injector until the session or checkpoint path consumes
        // them; the compute verdict drives the retry loop in
        // `slide_finish`.
        let faults = self.injector.begin_slide();
        let fault_injected = faults.memo_loss;
        if fault_injected {
            let fallback = match self.recovery {
                RecoveryPolicy::Replicated => self.replica.as_ref(),
                RecoveryPolicy::Checkpoint => {
                    self.ckpt.as_ref().and_then(|t| t.memo_image.as_ref())
                }
                _ => None,
            };
            FaultInjector::apply_memo_loss(&mut self.memo, self.recovery, fallback);
            // The journal can no longer reproduce the live memo (it was
            // cleared, or reset to an older image): drop it and re-base
            // at the next checkpoint.
            if let Some(t) = &mut self.ckpt {
                t.invalidate();
            }
        }
        slide_work.fault_injections = u64::from(fault_injected);

        // Overload degradation: the controller's current ladder level
        // widens every error-target budget's relative bound *before* it
        // sizes this slide's sample, so demand sheds through the same
        // Eq 3.2 backsolve that normally tightens it. Open-loop budgets
        // (fraction / tokens / latency) ignore the scale by contract.
        let bound_scale = self.degrade.scale();
        self.cost.set_bound_scale(bound_scale);
        self.queries.set_bound_scale(bound_scale);

        // Previous sample (pre-eviction) — the inverse-reduce base state.
        // Zero-copy: Arc handles onto the memoized runs.
        let prev_items = self.memo.items_all();

        // Persistent sampler maintenance: on the incremental path it is
        // updated with the delta (O(delta)); the from-scratch baseline
        // rebuilds it (O(window)). Identical state either way — the
        // sampler is a pure function of window contents and seed.
        let sw_sampler = Stopwatch::start();
        if self.cfg.mode.samples() {
            let touched = if self.cfg.incremental_slide {
                self.sampler.apply_delta(&snap.delta)
            } else {
                match snap.columns() {
                    Some(cols) => self.sampler.rebuild_columns(cols),
                    None => self.sampler.rebuild(snap.items()),
                }
            };
            slide_work.sampler_items = touched as u64;
        }
        let sampler_ms = sw_sampler.elapsed_ms();

        SlidePrep { snap, sw, slide_work, faults, prev_items, sampler_ms }
    }

    /// The back half of the slide: memo eviction at `horizon`, sample
    /// emission under the caller's `alloc`, biasing, the plan / compute /
    /// finalize pipeline, the sketch pass (when `want_sketches`), and
    /// memoization. Returns the slide's mergeable [`PartitionState`] —
    /// derivation to reports happens on the *merged* state (trivially so
    /// for a solo run, whose merge of one partition is the state itself).
    ///
    /// `horizon` is this coordinator's own window start in solo runs and
    /// the GLOBAL minimum across partitions in scale-out runs: every
    /// partition must age its memo against the same horizon or the
    /// merged outputs drift from the single-coordinator reference.
    pub(crate) fn slide_finish(
        &mut self,
        prep: SlidePrep,
        horizon: u64,
        alloc: Option<&BTreeMap<StratumId, usize>>,
        want_sketches: bool,
    ) -> Result<(PartitionState, SlideTiming)> {
        let SlidePrep { snap, sw, mut slide_work, faults, prev_items, sampler_ms } = prep;
        let window_id = snap.window_id;
        let window_len = snap.len;

        // Algorithm 1: remove all old items (and dependent results) from memo.
        self.memo.evict_older_than(horizon);
        self.ckpt_push(JournalOp::Evict { horizon });

        // The persistent sampler emits the window's stratified sample
        // under the caller's per-stratum allocation (sampling modes);
        // exact modes group the full window per stratum instead.
        let sample = match alloc {
            Some(caps) => self.sampler.sample_allocated(caps),
            None => Self::full_window_sample(snap.items()),
        };

        // Bias the stratified sample to include memoized items (§3.3).
        let memo_items = self.memo.items_for_bias(horizon);
        let biased = if self.cfg.mode.biases() {
            bias_sample(&sample, &memo_items)
        } else {
            Self::no_bias_outcome(&sample, &memo_items)
        };
        let sample_size = biased.total_len();

        // --- Phase 1: plan (parallel across memo shards) ---------------
        // Inverse-reduce when the mode memoizes, prior state exists, the
        // delta is small, and we are not on a recompute-epoch boundary;
        // chunked full path otherwise.
        let epoch_recompute = self.cfg.mode.memoizes()
            && self.windows_processed % self.cfg.recompute_epoch as u64
                == self.cfg.recompute_epoch as u64 - 1;
        let sw_plan = Stopwatch::start();
        let plans = self.plan_strata(&biased, &prev_items, epoch_recompute)?;
        let plan_ms = sw_plan.elapsed_ms();
        for plan in plans.values() {
            let touched = match plan {
                StratumPlan::Delta { delta_items, .. } => *delta_items,
                StratumPlan::Full { rehashed_items, .. } => *rehashed_items,
            };
            slide_work.plan_items += touched as u64;
        }

        // --- Phase 2: one batched backend call for EVERY fresh chunk ---
        // Delta chunks and full-path misses from all strata share a
        // single dispatch; order is deterministic (stratum order, added
        // before removed before full-path misses).
        let sw_compute = Stopwatch::start();
        let mut fresh_refs: Vec<&Chunk> = Vec::new();
        for plan in plans.values() {
            match plan {
                StratumPlan::Delta { added, removed, .. } => {
                    fresh_refs.extend(added.iter());
                    fresh_refs.extend(removed.iter());
                }
                StratumPlan::Full { planned, .. } => {
                    fresh_refs
                        .extend(planned.iter().filter(|p| !p.is_hit()).map(|p| &p.chunk));
                }
            }
        }
        // The batched call runs under the configured retry policy. An
        // injected compute fault fails the first
        // `1 + ⌊severity · max_attempts⌋` attempts, so severity spans
        // recovers-on-retry through exhausts-the-budget. Backoff is
        // deterministic bounded exponential in retry *slots* (never
        // wall-clock — the schedule must be byte-identical across serial,
        // sharded, and restored runs). Exhaustion degrades the slide
        // instead of aborting it: `None` takes the surviving-strata
        // route below.
        let retry = self.cfg.retry_policy();
        let mut injected_failures: u32 = if faults.compute {
            1 + (faults.compute_severity * f64::from(retry.max_attempts)) as u32
        } else {
            0
        };
        let mut retries: u32 = 0;
        let fresh_results: Option<Vec<Moments>> = loop {
            let attempt = if injected_failures > 0 {
                injected_failures -= 1;
                Err(crate::error::Error::Fault(
                    "injected transient compute failure".into(),
                ))
            } else {
                self.backend.compute(&fresh_refs)
            };
            match attempt {
                Ok(results) => break Some(results),
                Err(err) if retries + 1 < retry.max_attempts => {
                    retries += 1;
                    log::debug!(
                        "compute attempt {retries} failed ({err}); retrying after {} slots",
                        retry.backoff_slots(retries)
                    );
                }
                Err(err) => {
                    log::warn!(
                        "compute failed after {} attempts ({} backoff slots): {err}; \
                         degrading slide to surviving strata",
                        retry.max_attempts,
                        retry.total_backoff_slots(retries),
                    );
                    break None;
                }
            }
        };
        slide_work.retries = u64::from(retries);
        if let Some(results) = &fresh_results {
            debug_assert_eq!(results.len(), fresh_refs.len());
        }
        drop(fresh_refs);
        let compute_ms = sw_compute.elapsed_ms();

        // --- Phase 3: route results back, combine, memoize -------------
        let sw_finalize = Stopwatch::start();
        let memoizes = self.cfg.mode.memoizes();
        let mut stratum_moments: BTreeMap<StratumId, Moments> = BTreeMap::new();
        let mut chunks_total = 0usize;
        let mut chunks_reused = 0usize;
        let mut fresh_items = 0usize;
        let mut degraded_strata: Vec<StratumId> = Vec::new();
        if let Some(fresh_results) = &fresh_results {
            let mut cursor = 0usize;
            for (&stratum, plan) in &plans {
                match plan {
                    StratumPlan::Delta { base, added, removed, delta_items } => {
                        let mut m = *base;
                        for _ in added {
                            m = m.combine(&fresh_results[cursor]);
                            cursor += 1;
                        }
                        for _ in removed {
                            m = m.inverse_combine(&fresh_results[cursor]);
                            cursor += 1;
                        }
                        fresh_items += delta_items;
                        stratum_moments.insert(stratum, m);
                    }
                    StratumPlan::Full { planned, .. } => {
                        chunks_total += planned.len();
                        let mut parts: Vec<Moments> = Vec::with_capacity(planned.len());
                        for p in planned {
                            if let Some(hit) = p.memoized {
                                chunks_reused += 1;
                                parts.push(hit);
                            } else {
                                let m = fresh_results[cursor];
                                cursor += 1;
                                fresh_items += p.chunk.len();
                                if memoizes {
                                    let min_ts = p
                                        .chunk
                                        .timestamps()
                                        .iter()
                                        .copied()
                                        .min()
                                        .unwrap_or(0);
                                    self.memo.put_chunk_for(
                                        stratum,
                                        p.chunk.hash,
                                        m,
                                        min_ts,
                                        window_id,
                                    );
                                    self.ckpt_push(JournalOp::PutChunk {
                                        stratum,
                                        hash: p.chunk.hash,
                                        moments: m,
                                        min_ts,
                                        window_id,
                                    });
                                }
                                parts.push(m);
                            }
                        }
                        stratum_moments
                            .insert(stratum, Moments::combine_all(parts.iter()));
                    }
                }
            }
            debug_assert_eq!(cursor, fresh_results.len(), "unrouted chunk results");
        } else {
            // Degraded slide: the compute budget is exhausted, so no
            // fresh chunk results exist. Strata that need none — an empty
            // inverse-reduce delta, or a full path served entirely by
            // memo hits — still finalize normally; the rest drop out of
            // this window's answer (queries answer from the survivors,
            // flagged `degraded` below).
            for (&stratum, plan) in &plans {
                match plan {
                    StratumPlan::Delta { base, added, removed, .. }
                        if added.is_empty() && removed.is_empty() =>
                    {
                        stratum_moments.insert(stratum, *base);
                    }
                    StratumPlan::Full { planned, .. }
                        if planned.iter().all(PlannedChunk::is_hit) =>
                    {
                        chunks_total += planned.len();
                        chunks_reused += planned.len();
                        stratum_moments.insert(
                            stratum,
                            Moments::combine_all(
                                planned.iter().filter_map(|p| p.memoized.as_ref()),
                            ),
                        );
                    }
                    _ => degraded_strata.push(stratum),
                }
            }
        }
        slide_work.compute_items = fresh_items as u64;

        // Remember full-path chunk sequences so the next full re-chunking
        // (epoch recompute, post-fault rebuild, exact modes) reuses
        // unchanged runs instead of re-hashing the sample.
        if self.cfg.incremental_slide {
            for (&stratum, plan) in &plans {
                if let StratumPlan::Full { planned, .. } = plan {
                    self.chunk_cache.insert(
                        stratum,
                        planned.iter().map(|p| p.chunk.clone()).collect(),
                    );
                }
            }
            // Strata that left the stream must not pin their cached runs
            // forever (delta-path strata keep their last Full sequence).
            self.chunk_cache.retain(|s, _| plans.contains_key(s));
        }

        // --- Sketch pass: per-chunk synopses for the sketch-backed
        // queries (Quantile / TopK / DistinctCount). Runs only when such
        // a query is registered (`want_sketches` — the caller's registry
        // knows), over the same biased sample the moment path consumed,
        // with the same content-defined chunking — so the memoized
        // bundles share the chunks' content hashes and age out with
        // them. Bundles are pure functions of (seed, chunk items) and
        // merging is order-independent, so every mode, worker count, and
        // partition layout folds to byte-identical per-stratum sketches.
        // One pass serves all registered sketch queries; its work is
        // charged to `sketch_items`, never to the moment substrate's
        // counters.
        let mut stratum_sketches: BTreeMap<StratumId, SketchBundle> = BTreeMap::new();
        let sw_sketch = Stopwatch::start();
        if want_sketches {
            let sketch_seed = self.cfg.seed ^ SKETCH_SEED_SALT;
            for (&stratum, run) in &biased.per_stratum {
                let (chunks, rehashed) = {
                    let prev: &[Chunk] = if self.cfg.incremental_slide {
                        self.sketch_chunks.get(&stratum).map_or(&[], Vec::as_slice)
                    } else {
                        &[]
                    };
                    chunk_stratum_cached_columns(stratum, run.columns(), self.cfg.chunk_size, prev)?
                };
                slide_work.sketch_items += rehashed as u64;
                let mut bundle = SketchBundle::new(sketch_seed);
                for c in &chunks {
                    let memoized = if memoizes {
                        self.memo.shard(stratum).get_chunk_sketch(c.hash)
                    } else {
                        None
                    };
                    let part = match memoized {
                        Some(b) => b,
                        None => {
                            slide_work.sketch_items += c.len() as u64;
                            let b = SketchBundle::from_columns(sketch_seed, c.columns());
                            if memoizes {
                                let min_ts =
                                    c.timestamps().iter().copied().min().unwrap_or(0);
                                self.memo.put_chunk_sketch_for(
                                    stratum,
                                    c.hash,
                                    b.clone(),
                                    min_ts,
                                    window_id,
                                );
                                self.ckpt_push(JournalOp::PutChunkSketch {
                                    stratum,
                                    hash: c.hash,
                                    bundle: b.clone(),
                                    min_ts,
                                    window_id,
                                });
                            }
                            b
                        }
                    };
                    bundle.merge(&part);
                }
                stratum_sketches.insert(stratum, bundle);
                if self.cfg.incremental_slide {
                    self.sketch_chunks.insert(stratum, chunks);
                }
            }
            if self.cfg.incremental_slide {
                self.sketch_chunks.retain(|s, _| biased.per_stratum.contains_key(s));
            }
        }
        let sketch_ms = sw_sketch.elapsed_ms();

        // --- Per-stratum reports (merged as-is by the partition tier) ---
        let mut strata_reports: BTreeMap<StratumId, StratumReport> = BTreeMap::new();
        for &stratum in stratum_moments.keys() {
            let population = sample.population.get(&stratum).copied().unwrap_or(0);
            strata_reports.insert(
                stratum,
                StratumReport {
                    sample_size: biased.stratum(stratum).len(),
                    memo_reused: biased.memo_reused.get(&stratum).copied().unwrap_or(0),
                    memo_available: biased.memo_available.get(&stratum).copied().unwrap_or(0),
                    population,
                },
            );
        }

        // Memoize the biased sample's runs + per-stratum state for the
        // next window (Algorithm 1's `memo ← memoize(biasedSample)`) —
        // Arc clones, no record copies.
        if self.cfg.mode.memoizes() || self.cfg.mode.biases() {
            if degraded_strata.is_empty() {
                self.memo.memoize_items(&biased.per_stratum);
            } else {
                // Degraded strata drop from the memo entirely (Arc
                // handles, no copies): with no memoized run, the next
                // slide's planner takes the Full path for them
                // (`prev.is_none()`) and recomputes from in-window
                // inputs — their stale stratum moments are unreachable
                // without the run, and their chunk results stay
                // content-addressed for reuse.
                let surviving: BTreeMap<StratumId, SampleRun> = biased
                    .per_stratum
                    .iter()
                    .filter(|(s, _)| stratum_moments.contains_key(s))
                    .map(|(&s, run)| (s, run.clone()))
                    .collect();
                self.memo.memoize_items(&surviving);
            }
            for (&s, m) in &stratum_moments {
                self.memo.put_stratum_moments(s, *m);
            }
        }
        if self.recovery == RecoveryPolicy::Replicated {
            self.replica = Some(self.memo.snapshot());
        }
        self.windows_processed += 1;

        Ok((
            PartitionState {
                window_id,
                window_len,
                sample_size,
                chunks_total,
                chunks_reused,
                fresh_items,
                moments: stratum_moments,
                sketches: stratum_sketches,
                populations: sample.population,
                strata: strata_reports,
                degraded_strata,
                fault_injected: faults.memo_loss,
                work: slide_work,
            },
            SlideTiming { sw, plan_ms, compute_ms, sw_finalize, sampler_ms, sketch_ms },
        ))
    }

    // --- Checkpoint / restore (see `crate::checkpoint` for the format) --

    /// Is the journal live? (Armed, and not already waiting to re-base.)
    fn ckpt_wants_ops(&self) -> bool {
        self.ckpt.as_ref().map_or(false, |t| !t.force_base)
    }

    /// Journal one substrate mutation (no-op until checkpointing is
    /// armed; `CkptTracker::push` enforces the journal size cap).
    fn ckpt_push(&mut self, op: JournalOp) {
        if let Some(t) = &mut self.ckpt {
            t.push(op);
        }
    }

    /// Every adaptive budget's durable state, as `(slot, policy, state)`
    /// — the session cost under [`SESSION_BUDGET_SLOT`], then each query
    /// under its raw id. The single source of truth for *which* states
    /// are durable: both the per-slide `BudgetAdjust` journaling and the
    /// base-segment `budget_states` field walk this, so the journal and
    /// the base can never disagree.
    fn budget_state_slots(&self) -> Vec<(u64, &'static str, f64)> {
        let mut slots: Vec<(u64, &'static str, f64)> = Vec::new();
        if let Some(state) = self.cost.export_state() {
            slots.push((SESSION_BUDGET_SLOT, self.cost.name(), state));
        }
        slots.extend(self.queries.budget_state_slots());
        slots
    }

    /// Export the window's durable state.
    fn ckpt_window_state(&self) -> WindowCkpt {
        match &self.window {
            WindowState::Count(w) => {
                let (buf, pending) = w.checkpoint_parts();
                WindowCkpt::Count {
                    size: w.size() as u64,
                    next_window_id: w.next_window_id(),
                    buf,
                    pending,
                }
            }
            WindowState::Time(w) => {
                let (buf, next_end, in_window) = w.checkpoint_parts();
                let (length, slide) = w.params();
                WindowCkpt::Time {
                    length,
                    slide,
                    next_end,
                    in_window: in_window as u64,
                    next_window_id: w.next_window_id(),
                    buf,
                }
            }
        }
    }

    /// Export the small always-current state every segment carries.
    fn ckpt_misc(&self) -> Misc {
        let (degrade_level, degrade_calm) = self.degrade.state();
        Misc {
            windows_processed: self.windows_processed,
            next_query_id: self.queries.next_id(),
            queries: self.queries.entries(),
            recovery: self.recovery,
            fault: self.injector.state(),
            degrade_level,
            degrade_calm,
            owned_strata: self.owned_strata.clone(),
        }
    }

    /// Export the full substrate (a base segment's payload). Chunk
    /// entries are sorted by hash so identical state always encodes to
    /// identical bytes.
    fn ckpt_base_state(&self) -> BaseState {
        let mut chunks: Vec<ChunkEntry> = self
            .memo
            .chunk_entries()
            .map(|(hash, e)| ChunkEntry {
                stratum: e.stratum,
                hash,
                moments: e.moments,
                min_ts: e.min_timestamp,
                window_id: e.window_id,
            })
            .collect();
        chunks.sort_by_key(|c| c.hash);
        // Per-chunk sketch bundles ride along under the same hash keys.
        // The folded per-stratum sketches are NOT exported: they are pure
        // functions of (window, seed) and the restored run refolds them.
        let mut sketches: Vec<SketchChunkEntry> = self
            .memo
            .sketch_entries()
            .map(|(hash, e)| SketchChunkEntry {
                stratum: e.stratum,
                hash,
                bundle: e.bundle.clone(),
                min_ts: e.min_timestamp,
                window_id: e.window_id,
            })
            .collect();
        sketches.sort_by_key(|s| s.hash);
        let items = self
            .memo
            .items_all()
            .into_iter()
            .map(|(s, run)| (s, run.records().to_vec()))
            .collect();
        // Adaptive-budget controller state (error-target demand, token
        // carry-over, latency EWMA) — one slot per stateful cost
        // function, tagged with its policy name, so restored runs
        // continue the same trajectory (and never import a state onto a
        // different policy).
        let budget_states: Vec<(u64, String, f64)> = self
            .budget_state_slots()
            .into_iter()
            .map(|(slot, policy, state)| (slot, policy.to_string(), state))
            .collect();
        BaseState {
            window: self.ckpt_window_state(),
            chunks,
            items,
            moments: self.memo.stratum_moments_all(),
            misc: self.ckpt_misc(),
            budget_states,
            sketches,
        }
    }

    /// The armed checkpoint tracker, or a typed error when journaling
    /// was never armed (a logic error surfaced as
    /// [`Error::Checkpoint`](crate::error::Error) rather than a panic).
    fn ckpt_tracker_mut(&mut self) -> Result<&mut CkptTracker> {
        self.ckpt
            .as_mut()
            .ok_or_else(|| crate::error::Error::Checkpoint("checkpoint tracker not armed".into()))
    }

    /// Bring the in-memory checkpoint chain up to the current slide:
    /// encode a base segment (first checkpoint, post-fault, or when the
    /// deltas have outgrown the base) or a delta segment (the journal
    /// since the last segment plus run diffs — O(state delta)). Arms
    /// journaling on first use. The appended bytes are recorded in
    /// [`SlideWork::checkpoint_bytes`].
    ///
    /// An injected checkpoint-write fault (the `fault.checkpoint_write`
    /// channel) tears the segment *before* it lands: the chain is
    /// invalidated — a torn suffix must never be read back — and a typed
    /// [`Error::Checkpoint`](crate::error::Error) surfaces to the
    /// caller. The next refresh re-bases on current state, exactly like
    /// the post-memo-loss path.
    pub(crate) fn refresh_checkpoint_chain(&mut self) -> Result<()> {
        if self.injector.take_checkpoint_write_fault() {
            if let Some(t) = &mut self.ckpt {
                t.invalidate();
            }
            return Err(crate::error::Error::Checkpoint(
                "injected torn checkpoint write; chain invalidated, re-basing at next cadence"
                    .into(),
            ));
        }
        if self.ckpt.is_none() {
            self.ckpt = Some(CkptTracker::default());
        }
        let wants_base = self.ckpt.as_ref().map_or(true, CkptTracker::wants_base);
        let appended = if wants_base {
            let seg = checkpoint::encode_segment(&Segment::Base(self.ckpt_base_state()));
            self.ckpt_tracker_mut()?.install_base(seg)
        } else {
            let cur_items = self.memo.items_all();
            let moments = self.memo.stratum_moments_all();
            let misc = self.ckpt_misc();
            let tracker = self.ckpt_tracker_mut()?;
            let items: Vec<(StratumId, u64, Vec<checkpoint::RunOp>)> = cur_items
                .iter()
                .map(|(&s, run)| {
                    let prev = tracker.prev_items.get(&s).cloned().unwrap_or_default();
                    (s, run.len() as u64, checkpoint::diff_run(&prev, run))
                })
                .collect();
            let ops = std::mem::take(&mut tracker.journal);
            let seg = checkpoint::encode_segment(&Segment::Delta(DeltaState {
                ops,
                items,
                moments,
                misc,
            }));
            tracker.install_delta(seg)
        };
        // Anchor the next delta's diffs and the fault-recovery image on
        // this segment (both are O(strata) Arc traffic, not copies).
        let prev_items = self.memo.items_all();
        let image = self.memo.snapshot();
        let tracker = self.ckpt_tracker_mut()?;
        tracker.prev_items = prev_items;
        tracker.memo_image = Some(image);
        self.work.note_checkpoint_bytes(appended);
        Ok(())
    }

    /// Flush the checkpoint chain as one artifact, with an optional
    /// session section (the `Session` wrapper adds source + backlog).
    pub(crate) fn write_checkpoint<W: Write>(
        &mut self,
        sink: &mut W,
        session: Option<SessionSection>,
    ) -> Result<u64> {
        self.refresh_checkpoint_chain()?;
        let tracker = self.ckpt.as_ref().ok_or_else(|| {
            crate::error::Error::Checkpoint("checkpoint tracker not armed after refresh".into())
        })?;
        let artifact = Artifact {
            compat: Compat::of(&self.cfg),
            segments: tracker.segments.clone(),
            session,
        };
        artifact.write(sink)
    }

    /// Serialize the full incremental substrate — window buffer, sharded
    /// memo contents, memoized sample runs, per-stratum moments, query
    /// registry, fault-injector RNG — into the versioned checkpoint
    /// format (see [`crate::checkpoint`]). The first call writes a full
    /// base; once armed, later calls append O(state delta) segments.
    /// Returns bytes written. [`Coordinator::restore`] rebuilds a
    /// coordinator that continues **byte-identically** from the next
    /// slide onward.
    pub fn checkpoint<W: Write>(&mut self, sink: &mut W) -> Result<u64> {
        self.write_checkpoint(sink, None)
    }

    /// Rebuild a coordinator from a checkpoint artifact. `cfg` must
    /// match the checkpointed run's seed, mode, chunk size, map weight,
    /// and slide (anything else silently changes outputs — a loud
    /// [`Error::Checkpoint`](crate::error::Error) instead); worker
    /// count, shard strategy, and budgets may differ freely. The
    /// persistent sampler is rebuilt from the restored window (the
    /// sample is a pure function of window contents and seed); the
    /// one-time replay cost lands in
    /// [`SlideWork::restore_items`](crate::metrics::SlideWork).
    /// Corrupted or truncated artifacts error out — they never panic or
    /// restore partial state.
    pub fn restore<R: Read>(source: R, cfg: SystemConfig) -> Result<Coordinator> {
        let artifact = Artifact::read(source)?;
        Self::restore_from_artifact(artifact, cfg).map(|(coord, _)| coord)
    }

    /// [`Coordinator::restore`], also yielding the artifact's session
    /// section for the `Session` wrapper.
    pub(crate) fn restore_from_artifact(
        artifact: Artifact,
        mut cfg: SystemConfig,
    ) -> Result<(Coordinator, Option<SessionSection>)> {
        use crate::error::Error;
        artifact.compat.check(&cfg)?;
        let mut restore_items = 0u64;

        // --- Base segment: materialize window, memo, runs ---------------
        let mut segments = artifact.segments.iter();
        let Some(first) = segments.next() else {
            return Err(Error::Checkpoint("artifact has no segments".into()));
        };
        let base = match checkpoint::decode_segment(first)? {
            Segment::Base(b) => b,
            Segment::Delta(_) => {
                return Err(Error::Checkpoint("first segment is not a base".into()))
            }
        };
        let mut memo = MemoStore::sharded(cfg.num_workers.max(1), cfg.shard_strategy);
        restore_items += base.chunks.len() as u64;
        for c in &base.chunks {
            memo.put_chunk_for(c.stratum, c.hash, c.moments, c.min_ts, c.window_id);
        }
        restore_items += base.sketches.len() as u64;
        for s in base.sketches {
            memo.put_chunk_sketch_for(s.stratum, s.hash, s.bundle, s.min_ts, s.window_id);
        }
        let mut items: BTreeMap<StratumId, SampleRun> = base
            .items
            .into_iter()
            .map(|(s, recs)| (s, SampleRun::from_vec(recs)))
            .collect();
        restore_items += items.values().map(SampleRun::len).sum::<usize>() as u64;
        let mut moments = base.moments;
        let mut misc = base.misc;
        // Adaptive-budget controller trajectory: seeded by the base
        // snapshot, updated by every journaled adjustment (last-wins),
        // applied once the cost functions exist below.
        let mut budget_states: BTreeMap<u64, (String, f64)> = base
            .budget_states
            .into_iter()
            .map(|(slot, policy, state)| (slot, (policy, state)))
            .collect();
        let mut window = match base.window {
            WindowCkpt::Count { size, next_window_id, buf, pending } => {
                restore_items += (buf.len() + pending.len()) as u64;
                WindowState::Count(CountWindow::restore_parts(
                    size as usize,
                    buf,
                    pending,
                    next_window_id,
                ))
            }
            WindowCkpt::Time { length, slide, next_end, in_window, next_window_id, buf } => {
                restore_items += buf.len() as u64;
                WindowState::Time(TimeWindow::restore_parts(
                    length,
                    slide,
                    buf,
                    next_end,
                    in_window as usize,
                    next_window_id,
                ))
            }
        };

        // --- Delta segments: replay the journal through the real window
        // and memo implementations, then patch the sample runs ----------
        for seg_bytes in segments {
            let delta = match checkpoint::decode_segment(seg_bytes)? {
                Segment::Delta(d) => d,
                Segment::Base(_) => {
                    return Err(Error::Checkpoint("unexpected base segment mid-chain".into()))
                }
            };
            for op in delta.ops {
                match op {
                    JournalOp::Slide { inserted } => match &mut window {
                        WindowState::Count(w) => {
                            restore_items += inserted.len() as u64;
                            let _ = w.slide_with(inserted, false);
                        }
                        WindowState::Time(_) => {
                            return Err(Error::Checkpoint(
                                "slide op journaled against a time window".into(),
                            ))
                        }
                    },
                    JournalOp::PartitionSlide { inserted, evict } => match &mut window {
                        WindowState::Count(w) => {
                            restore_items += inserted.len() as u64;
                            let _ = w.slide_external(inserted, evict as usize, false);
                        }
                        WindowState::Time(_) => {
                            return Err(Error::Checkpoint(
                                "partition-slide op journaled against a time window".into(),
                            ))
                        }
                    },
                    JournalOp::Tick { records, now } => match &mut window {
                        WindowState::Time(w) => {
                            restore_items += records.len() as u64;
                            w.ingest(records);
                            let _ = w.try_emit_with(now, false);
                        }
                        WindowState::Count(_) => {
                            return Err(Error::Checkpoint(
                                "tick op journaled against a count window".into(),
                            ))
                        }
                    },
                    JournalOp::Resize { new_size } => match &mut window {
                        WindowState::Count(w) => {
                            let _ = w.resize((new_size as usize).max(1));
                        }
                        WindowState::Time(_) => {
                            return Err(Error::Checkpoint(
                                "resize op journaled against a time window".into(),
                            ))
                        }
                    },
                    JournalOp::Evict { horizon } => memo.evict_older_than(horizon),
                    JournalOp::PutChunk { stratum, hash, moments: m, min_ts, window_id } => {
                        restore_items += 1;
                        memo.put_chunk_for(stratum, hash, m, min_ts, window_id);
                    }
                    JournalOp::PutChunkSketch { stratum, hash, bundle, min_ts, window_id } => {
                        restore_items += 1;
                        memo.put_chunk_sketch_for(stratum, hash, bundle, min_ts, window_id);
                    }
                    JournalOp::BudgetAdjust { slot, policy, state } => {
                        budget_states.insert(slot, (policy, state));
                    }
                }
            }
            let mut next_items = BTreeMap::new();
            for (s, final_len, ops) in delta.items {
                let prev = items.get(&s).cloned().unwrap_or_default();
                let recs = checkpoint::apply_run_ops(&prev, &ops, final_len as usize)?;
                restore_items += recs.len() as u64;
                next_items.insert(s, SampleRun::from_vec(recs));
            }
            items = next_items;
            moments = delta.moments;
            misc = delta.misc;
        }

        // --- Assemble the coordinator -----------------------------------
        // The checkpointed window geometry is authoritative (it absorbed
        // any replayed resizes); keep cfg consistent with it.
        if let WindowState::Count(w) = &window {
            cfg.window_size = w.size();
        }
        let sampler_source: Vec<Record> = match &window {
            WindowState::Count(w) => {
                // The sampler tracks the window population *plus* pending
                // resize evictions (it only learns of them via the next
                // slide's delta, exactly like the live run).
                let (mut buf, pending) = w.checkpoint_parts();
                buf.extend(pending);
                buf
            }
            WindowState::Time(w) => w.window_records(),
        };
        let mut coord = Coordinator::with_window(cfg, window);
        coord.memo = memo;
        coord.memo.memoize_items(&items);
        for (&s, m) in &moments {
            coord.memo.put_stratum_moments(s, *m);
        }
        restore_items += coord.sampler.rebuild(&sampler_source) as u64;
        coord.windows_processed = misc.windows_processed;
        coord.queries.restore(&coord.cfg, misc.next_query_id, misc.queries)?;
        coord.owned_strata = misc.owned_strata;
        // Resume the adaptive-budget trajectories. A state only lands on
        // a cost function of the SAME policy: `Compat` deliberately lets
        // budgets differ between checkpoint and restore configs, and a
        // banked-token count imported as, say, a latency EWMA would
        // poison the model. Mismatched or orphaned slots (removed
        // queries, a swapped session budget) are simply ignored.
        if let Some((policy, state)) = budget_states.get(&SESSION_BUDGET_SLOT) {
            if policy == coord.cost.name() {
                coord.cost.import_state(*state);
            }
        }
        coord.queries.import_budget_states(&budget_states);
        coord.injector.restore_state(misc.fault);
        coord.degrade.restore_state(misc.degrade_level, misc.degrade_calm);
        // The recovery policy survives too: the injector RNGs replay the
        // exact multi-channel fault schedule (including any latched but
        // unconsumed broker / checkpoint-write verdicts), so the restored
        // run must also *handle* each fault the same way the live run
        // would have.
        coord.recovery = misc.recovery;
        // Keep `Replicated` recovery seamless across the restore boundary
        // (the live run would have held last window's snapshot here).
        coord.replica = Some(coord.memo.snapshot());
        // Arm the checkpoint chain with the restored memo as its fault
        // fallback image, so `RecoveryPolicy::Checkpoint` handles a fault
        // on the very first post-restore slide exactly like the live run
        // (whose chain held the same image). `force_base` keeps the
        // journal off until the first refresh re-bases on current state.
        let mut tracker = CkptTracker::default();
        tracker.prev_items = coord.memo.items_all();
        tracker.memo_image = Some(coord.memo.snapshot());
        tracker.force_base = true;
        coord.ckpt = Some(tracker);
        coord.work.note_restore_items(restore_items);
        Ok((coord, artifact.session))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::{BudgetSpec, ShardStrategy};
    use crate::job::aggregate::AggregateKind;
    use crate::workload::gen::MultiStream;

    fn config(mode: ExecModeSpec) -> SystemConfig {
        SystemConfig {
            mode,
            window_size: 2000,
            slide: 200,
            seed: 11,
            // Small windows → small samples: keep several chunks per
            // stratum so chunk-level reuse has granularity to show.
            chunk_size: 16,
            ..SystemConfig::default()
        }
    }

    fn run(mode: ExecModeSpec, windows: usize) -> Vec<WindowReport> {
        let cfg = config(mode);
        run_with(cfg, windows)
    }

    fn run_with(cfg: SystemConfig, windows: usize) -> Vec<WindowReport> {
        run_with_coord(cfg, windows).0
    }

    fn run_with_coord(cfg: SystemConfig, windows: usize) -> (Vec<WindowReport>, Coordinator) {
        let mut gen = MultiStream::paper_section5(cfg.seed);
        let mut coord = Coordinator::new(cfg.clone());
        // Warm the window first.
        let warm = gen.take_records(cfg.window_size);
        let mut reports = vec![coord.process_batch(warm).unwrap()];
        for _ in 0..windows {
            let batch = gen.take_records(cfg.slide);
            reports.push(coord.process_batch(batch).unwrap());
        }
        (reports, coord)
    }

    fn assert_reports_identical(a: &[WindowReport], b: &[WindowReport], label: &str) {
        assert_eq!(a.len(), b.len(), "{label}: report counts differ");
        for (ra, rb) in a.iter().zip(b) {
            assert_eq!(ra.window_id, rb.window_id, "{label}");
            assert_eq!(
                ra.estimate.value.to_bits(),
                rb.estimate.value.to_bits(),
                "{label} w{}: estimate {} vs {}",
                ra.window_id,
                ra.estimate.value,
                rb.estimate.value
            );
            assert_eq!(
                ra.estimate.margin.to_bits(),
                rb.estimate.margin.to_bits(),
                "{label} w{}: margin",
                ra.window_id
            );
            assert_eq!(ra.window_len, rb.window_len, "{label}");
            assert_eq!(ra.sample_size, rb.sample_size, "{label}");
            assert_eq!(ra.chunks_total, rb.chunks_total, "{label}");
            assert_eq!(ra.chunks_reused, rb.chunks_reused, "{label}");
            assert_eq!(ra.fresh_items, rb.fresh_items, "{label}");
            assert_eq!(ra.strata, rb.strata, "{label}");
            assert_eq!(ra.degraded, rb.degraded, "{label}");
        }
    }

    #[test]
    fn sharded_pipeline_matches_serial_exactly() {
        // The acceptance bar, extended to a three-way assertion: the
        // serial reference path, the sharded parallel pipeline, and the
        // O(delta) incremental slide path must all produce byte-identical
        // reports, in every mode. (The first two run from-scratch slides;
        // the third maintains window, sampler, and chunk state across
        // slides — identical outputs, fraction of the work.)
        for mode in [
            ExecModeSpec::Native,
            ExecModeSpec::IncrementalOnly,
            ExecModeSpec::ApproxOnly,
            ExecModeSpec::IncApprox,
        ] {
            let mut serial = config(mode);
            serial.num_workers = 1;
            serial.incremental_slide = false;
            let mut sharded = config(mode);
            sharded.num_workers = 4;
            sharded.incremental_slide = false;
            let mut incremental = config(mode);
            incremental.num_workers = 4;
            assert!(incremental.incremental_slide, "O(delta) path is the default");
            let mut serial_incremental = config(mode);
            serial_incremental.num_workers = 1;
            let a = run_with(serial, 5);
            let b = run_with(sharded, 5);
            let c = run_with(incremental, 5);
            let d = run_with(serial_incremental, 5);
            assert_reports_identical(&a, &b, &format!("{}: serial vs sharded", mode.name()));
            assert_reports_identical(
                &a,
                &c,
                &format!("{}: from-scratch vs incremental", mode.name()),
            );
            assert_reports_identical(
                &a,
                &d,
                &format!("{}: from-scratch vs serial-incremental", mode.name()),
            );
        }
    }

    #[test]
    fn shard_strategy_does_not_change_outputs() {
        let mut hash = config(ExecModeSpec::IncApprox);
        hash.num_workers = 3;
        hash.shard_strategy = ShardStrategy::Hash;
        let mut modulo = config(ExecModeSpec::IncApprox);
        modulo.num_workers = 3;
        modulo.shard_strategy = ShardStrategy::Modulo;
        assert_reports_identical(&run_with(hash, 4), &run_with(modulo, 4), "strategy");
    }

    #[test]
    fn time_windowed_incremental_matches_from_scratch_exactly() {
        // The three-way equivalence on the time-based window manager —
        // this also pins the positional delta rewrite in
        // `TimeWindow::try_emit_with`.
        let mk = |workers: usize, incremental: bool| {
            let mut cfg = config(ExecModeSpec::IncApprox);
            cfg.num_workers = workers;
            cfg.incremental_slide = incremental;
            Coordinator::new_time_windowed(cfg, 400, 40)
        };
        let mut coords = [mk(1, false), mk(4, false), mk(4, true)];
        let mut gens = [
            MultiStream::paper_section5(23),
            MultiStream::paper_section5(23),
            MultiStream::paper_section5(23),
        ];
        let mut reports: [Vec<WindowReport>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for now in 1..=1000u64 {
            for (i, (coord, gen)) in coords.iter_mut().zip(gens.iter_mut()).enumerate() {
                if let Some(r) = coord.ingest_tick(gen.tick(), now).unwrap() {
                    reports[i].push(r);
                }
            }
        }
        assert!(reports[0].len() > 10, "no windows emitted");
        assert_reports_identical(&reports[0], &reports[1], "time: serial vs sharded");
        assert_reports_identical(&reports[0], &reports[2], "time: scratch vs incremental");
    }

    #[test]
    fn window_resize_matches_from_scratch() {
        // Mid-stream resizes evict items outside any slide; the
        // incremental path must observe them through the next delta and
        // stay byte-identical to the rebuild path.
        let mut scratch_cfg = config(ExecModeSpec::IncApprox);
        scratch_cfg.incremental_slide = false;
        let inc_cfg = config(ExecModeSpec::IncApprox);
        let mut gen_a = MultiStream::paper_section5(41);
        let mut gen_b = MultiStream::paper_section5(41);
        let mut a = Coordinator::new(scratch_cfg);
        let mut b = Coordinator::new(inc_cfg);
        let mut ra = Vec::new();
        let mut rb = Vec::new();
        let (wa, wb) = (gen_a.take_records(2000), gen_b.take_records(2000));
        ra.push(a.process_batch(wa).unwrap());
        rb.push(b.process_batch(wb).unwrap());
        for new_size in [1500usize, 2200, 900] {
            a.resize_window(new_size);
            b.resize_window(new_size);
            for _ in 0..2 {
                let (ba, bb) = (gen_a.take_records(200), gen_b.take_records(200));
                ra.push(a.process_batch(ba).unwrap());
                rb.push(b.process_batch(bb).unwrap());
            }
        }
        assert_reports_identical(&ra, &rb, "resize: scratch vs incremental");
    }

    #[test]
    fn incremental_slide_work_scales_with_delta() {
        // The O(delta) invariant, measured: a steady-state incremental
        // slide touches far fewer items than the window holds, while the
        // from-scratch baseline pays O(window) every slide.
        let mut scratch_cfg = config(ExecModeSpec::IncApprox);
        scratch_cfg.incremental_slide = false;
        let (_, scratch) = run_with_coord(scratch_cfg, 5);
        let (_, incremental) = run_with_coord(config(ExecModeSpec::IncApprox), 5);
        assert_eq!(incremental.work_profile().windows(), 6);
        let w_inc = incremental.work_profile().last();
        let w_scr = scratch.work_profile().last();
        // Incremental: window + sampler stages are delta-bound — about
        // 2 × slide items (inserted + evicted; `take_records` rounds a
        // batch up to whole generator ticks, so not exactly 400).
        let delta = w_inc.window_items;
        assert!((400..800).contains(&(delta as usize)), "delta-only snapshot, got {delta}");
        assert_eq!(w_inc.sampler_items, delta, "sampler maintained by the same delta");
        assert!(
            w_inc.total() < 2000,
            "incremental slide touched {} items for a 2000-item window",
            w_inc.total()
        );
        // From-scratch: the window is materialized and re-offered whole
        // (the window itself is capped at exactly 2000 items).
        assert_eq!(w_scr.window_items, 2000);
        assert_eq!(w_scr.sampler_items, 2000);
        assert!(w_scr.total() > 2 * w_inc.total());
        // Both paths computed the same fresh moments.
        assert_eq!(w_inc.compute_items, w_scr.compute_items);
    }

    #[test]
    fn sharded_pipeline_is_default_and_profiled() {
        let cfg = config(ExecModeSpec::IncApprox);
        assert!(cfg.num_workers > 1, "sharded pipeline must be on by default");
        let mut gen = MultiStream::paper_section5(cfg.seed);
        let mut coord = Coordinator::new(cfg.clone());
        assert_eq!(coord.backend_name(), "worker-pool");
        coord.process_batch(gen.take_records(cfg.window_size)).unwrap();
        coord.process_batch(gen.take_records(cfg.slide)).unwrap();
        let profile = coord.phase_profile();
        assert_eq!(profile.windows(), 2);
        assert!(profile.plan_mean_ms() >= 0.0);
        assert!(profile.compute_mean_ms() >= 0.0);
        assert_eq!(coord.work_profile().windows(), 2);
        assert!(coord.work_profile().mean_total_per_slide() > 0.0);
    }

    #[test]
    fn native_mode_is_exact() {
        let reports = run(ExecModeSpec::Native, 3);
        for r in &reports {
            assert_eq!(r.sample_size, r.window_len);
            assert_eq!(r.estimate.margin, 0.0, "exact mode must have zero margin");
            assert_eq!(r.chunks_reused, 0, "native never reuses");
            assert_eq!(r.fresh_items, r.window_len, "native computes everything");
        }
    }

    #[test]
    fn incremental_mode_reuses_after_warmup() {
        let reports = run(ExecModeSpec::IncrementalOnly, 4);
        for r in &reports[2..] {
            assert_eq!(r.estimate.margin, 0.0, "incremental is exact");
            assert!(
                r.fresh_items < r.window_len / 2,
                "incremental should compute ≪ window, got {}/{}",
                r.fresh_items,
                r.window_len
            );
        }
    }

    #[test]
    fn approx_mode_bounds_and_samples() {
        let reports = run(ExecModeSpec::ApproxOnly, 3);
        for r in &reports {
            assert!(r.sample_size <= r.window_len / 5, "10% budget");
            assert!(r.estimate.margin > 0.0);
            assert_eq!(r.chunks_reused, 0, "approx-only never reuses");
            assert_eq!(r.fresh_items, r.sample_size, "approx computes the whole sample");
        }
    }

    #[test]
    fn incapprox_samples_and_reuses() {
        let reports = run(ExecModeSpec::IncApprox, 5);
        for r in &reports[2..] {
            assert!(r.sample_size <= r.window_len / 5);
            assert!(r.estimate.margin > 0.0);
            assert!(
                r.item_reuse_fraction() > 0.7,
                "expected high item reuse, got {}",
                r.item_reuse_fraction()
            );
            assert!(
                r.fresh_items < r.sample_size / 2,
                "incremental update should compute ≪ sample: {}/{}",
                r.fresh_items,
                r.sample_size
            );
        }
    }

    #[test]
    fn incapprox_cheaper_than_both_baselines() {
        // The marriage: fewer computed items than approx-only (sampling
        // alone) and than incremental-only (memoization alone).
        let inc = run(ExecModeSpec::IncrementalOnly, 5);
        let approx = run(ExecModeSpec::ApproxOnly, 5);
        let both = run(ExecModeSpec::IncApprox, 5);
        let cost = |rs: &[WindowReport]| -> usize {
            rs.iter().skip(2).map(|r| r.fresh_items).sum()
        };
        assert!(
            cost(&both) < cost(&approx),
            "incapprox {} !< approx {}",
            cost(&both),
            cost(&approx)
        );
        assert!(
            cost(&both) < cost(&inc),
            "incapprox {} !< incremental {}",
            cost(&both),
            cost(&inc)
        );
    }

    #[test]
    fn estimates_track_true_total() {
        // IncApprox estimate should be within a few margins of the exact
        // native output on the same stream.
        let cfg_a = config(ExecModeSpec::IncApprox);
        let cfg_b = config(ExecModeSpec::Native);
        let mut gen_a = MultiStream::paper_section5(5);
        let mut gen_b = MultiStream::paper_section5(5);
        let mut a = Coordinator::new(cfg_a.clone());
        let mut b = Coordinator::new(cfg_b.clone());
        let (wa, wb) =
            (gen_a.take_records(cfg_a.window_size), gen_b.take_records(cfg_b.window_size));
        let mut last = (a.process_batch(wa).unwrap(), b.process_batch(wb).unwrap());
        for _ in 0..4 {
            let (ba, bb) = (gen_a.take_records(200), gen_b.take_records(200));
            last = (a.process_batch(ba).unwrap(), b.process_batch(bb).unwrap());
        }
        let (ra, rb) = last;
        assert_eq!(ra.window_len, rb.window_len);
        let err = (ra.estimate.value - rb.estimate.value).abs();
        assert!(
            err <= 4.0 * ra.estimate.margin.max(1.0),
            "estimate {} vs exact {} margin {}",
            ra.estimate.value,
            rb.estimate.value,
            ra.estimate.margin
        );
    }

    #[test]
    fn incremental_path_matches_full_recompute() {
        // Force epoch recompute every window in one coordinator and never
        // in another; outputs must agree (same stream, same seeds).
        let mut cfg_a = config(ExecModeSpec::IncApprox);
        cfg_a.recompute_epoch = 1; // always full recompute
        let mut cfg_b = config(ExecModeSpec::IncApprox);
        cfg_b.recompute_epoch = 1_000_000; // never
        let mut gen_a = MultiStream::paper_section5(7);
        let mut gen_b = MultiStream::paper_section5(7);
        let mut a = Coordinator::new(cfg_a.clone());
        let mut b = Coordinator::new(cfg_b);
        let (wa, wb) = (gen_a.take_records(2000), gen_b.take_records(2000));
        a.process_batch(wa).unwrap();
        b.process_batch(wb).unwrap();
        for _ in 0..5 {
            let (ba, bb) = (gen_a.take_records(200), gen_b.take_records(200));
            let ra = a.process_batch(ba).unwrap();
            let rb = b.process_batch(bb).unwrap();
            let rel = (ra.estimate.value - rb.estimate.value).abs()
                / ra.estimate.value.abs().max(1.0);
            assert!(rel < 1e-9, "paths diverge: {} vs {}", ra.estimate.value, rb.estimate.value);
        }
    }

    #[test]
    fn window_ids_sequential() {
        let reports = run(ExecModeSpec::IncApprox, 3);
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.window_id, i as u64);
        }
    }

    #[test]
    fn fault_injection_with_lineage_recovers_correctness() {
        let mut cfg = config(ExecModeSpec::IncApprox);
        cfg.fault_memo_loss = 1.0; // lose memo every window
        let mut gen = MultiStream::paper_section5(13);
        let mut coord =
            Coordinator::new(cfg.clone()).with_recovery(RecoveryPolicy::LineageRecompute);
        let warm = gen.take_records(cfg.window_size);
        coord.process_batch(warm).unwrap();
        let r = coord.process_batch(gen.take_records(cfg.slide)).unwrap();
        assert!(r.fault_injected);
        // Everything recomputed, but output still valid.
        assert_eq!(r.fresh_items, r.sample_size);
        assert!(r.estimate.value > 0.0);
        assert!(coord.faults_injected() >= 1);
    }

    #[test]
    fn fault_injection_with_replication_preserves_reuse() {
        let mut cfg = config(ExecModeSpec::IncApprox);
        cfg.fault_memo_loss = 1.0;
        let mut gen = MultiStream::paper_section5(13);
        let mut coord =
            Coordinator::new(cfg.clone()).with_recovery(RecoveryPolicy::Replicated);
        coord.process_batch(gen.take_records(cfg.window_size)).unwrap();
        coord.process_batch(gen.take_records(cfg.slide)).unwrap();
        let r = coord.process_batch(gen.take_records(cfg.slide)).unwrap();
        assert!(r.fault_injected);
        assert!(
            r.fresh_items < r.sample_size,
            "replica should preserve incremental state across the fault"
        );
    }

    #[test]
    fn time_windowed_coordinator_emits_at_boundaries() {
        // Paper §2.3.3: time-based windows, item counts vary with rate.
        let cfg = config(ExecModeSpec::IncApprox);
        let mut coord = Coordinator::new_time_windowed(cfg, 400, 40);
        let mut gen = MultiStream::paper_section5(23);
        let mut reports = Vec::new();
        for now in 1..=1200u64 {
            let records = gen.tick(); // records stamped with tick now-1
            if let Some(r) = coord.ingest_tick(records, now).unwrap() {
                reports.push(r);
            }
        }
        // Boundaries at 400, 440, ..., 1200 → 21 windows.
        assert_eq!(reports.len(), 21);
        for w in reports.windows(2) {
            assert_eq!(w[1].window_id, w[0].window_id + 1);
        }
        // Rates 3+4+5=12/tick → ~4800 items per 400-tick window, varying.
        let lens: Vec<usize> = reports.iter().map(|r| r.window_len).collect();
        assert!(lens.iter().all(|&l| (4000..6000).contains(&l)), "{lens:?}");
        assert!(lens.windows(2).any(|w| w[0] != w[1]), "counts should vary");
        // Steady state behaves like the count path: reuse + bounds.
        let last = reports.last().unwrap();
        assert!(last.item_reuse_fraction() > 0.7);
        assert!(last.estimate.margin > 0.0);
    }

    #[test]
    fn time_windowed_incremental_is_exact() {
        let mut gens = (MultiStream::paper_section5(29), MultiStream::paper_section5(29));
        let mut native =
            Coordinator::new_time_windowed(config(ExecModeSpec::Native), 300, 30);
        let mut inc = Coordinator::new_time_windowed(
            config(ExecModeSpec::IncrementalOnly),
            300,
            30,
        );
        for now in 1..=900u64 {
            let (ra, rb) = (gens.0.tick(), gens.1.tick());
            let a = native.ingest_tick(ra, now).unwrap();
            let b = inc.ingest_tick(rb, now).unwrap();
            assert_eq!(a.is_some(), b.is_some());
            if let (Some(a), Some(b)) = (a, b) {
                let rel = (a.estimate.value - b.estimate.value).abs()
                    / a.estimate.value.abs();
                assert!(rel < 1e-9, "{} vs {}", a.estimate.value, b.estimate.value);
                assert!(b.fresh_items <= a.fresh_items);
            }
        }
    }

    #[test]
    fn window_kind_mismatch_is_an_error() {
        let cfg = config(ExecModeSpec::IncApprox);
        let mut count = Coordinator::new(cfg.clone());
        assert!(count.ingest_tick(vec![], 1).is_err());
        let mut time = Coordinator::new_time_windowed(cfg, 100, 10);
        assert!(time.process_batch(vec![]).is_err());
    }

    #[test]
    fn window_resize_applies() {
        let cfg = config(ExecModeSpec::IncApprox);
        let mut gen = MultiStream::paper_section5(17);
        let mut coord = Coordinator::new(cfg.clone());
        coord.process_batch(gen.take_records(2000)).unwrap();
        coord.resize_window(1500);
        let r = coord.process_batch(gen.take_records(100)).unwrap();
        assert!(r.window_len <= 1500);
    }

    #[test]
    fn empty_window_produces_empty_report() {
        // Degenerate edge: a coordinator fed an empty batch before any
        // data has a zero-length window and must not panic or error.
        let mut coord = Coordinator::new(config(ExecModeSpec::IncApprox));
        let r = coord.process_batch(vec![]).unwrap();
        assert_eq!(r.window_len, 0);
        assert_eq!(r.sample_size, 0);
        assert_eq!(r.fresh_items, 0);
        assert_eq!(r.estimate.value, 0.0);
        assert!(r.strata.is_empty());
    }

    #[test]
    fn submitted_queries_are_answered_each_slide() {
        let cfg = config(ExecModeSpec::IncApprox);
        let mut gen = MultiStream::paper_section5(cfg.seed);
        let mut coord = Coordinator::new(cfg.clone());
        let sum = coord.submit_query(QuerySpec::new(AggregateKind::Sum)).unwrap();
        let mean = coord
            .submit_query(QuerySpec::new(AggregateKind::Mean).with_confidence(0.99))
            .unwrap();
        let count = coord.submit_query(QuerySpec::new(AggregateKind::Count)).unwrap();
        assert_eq!(coord.query_count(), 3);
        assert_eq!(coord.query_specs().count(), 3);
        let out = coord.process_batch_queries(gen.take_records(cfg.window_size)).unwrap();
        assert_eq!(out.queries.len(), 3);
        // A whole-window Sum at the session confidence IS the window
        // estimate — same strata, same populations, same fold.
        let qs = out.query(sum).unwrap();
        assert_eq!(qs.estimate.value.to_bits(), out.window.estimate.value.to_bits());
        assert_eq!(qs.estimate.margin.to_bits(), out.window.estimate.margin.to_bits());
        // Count is exact (populations are exact window counts).
        let qc = out.query(count).unwrap();
        assert_eq!(qc.estimate.value, out.window.window_len as f64);
        assert_eq!(qc.estimate.margin, 0.0);
        // Mean is the sum scaled by the observed population.
        let qm = out.query(mean).unwrap();
        let want = qs.estimate.value / out.window.window_len as f64;
        assert!((qm.estimate.value - want).abs() <= 1e-9 * want.abs().max(1.0));
        assert_eq!(qm.estimate.confidence, 0.99);
        // Removal stops answering; the others keep flowing.
        assert!(coord.remove_query(mean));
        assert!(!coord.remove_query(mean), "second removal is a no-op");
        let out = coord.process_batch_queries(gen.take_records(cfg.slide)).unwrap();
        assert_eq!(out.queries.len(), 2);
        assert!(out.query(mean).is_none());
        assert!(out.query(sum).is_some());
    }

    #[test]
    fn union_budget_sizes_the_shared_sample() {
        let cfg = config(ExecModeSpec::IncApprox);
        let mut gen = MultiStream::paper_section5(cfg.seed);
        let mut coord = Coordinator::new(cfg.clone());
        coord
            .submit_query(
                QuerySpec::new(AggregateKind::Sum).with_budget(BudgetSpec::Fraction(0.02)),
            )
            .unwrap();
        coord
            .submit_query(
                QuerySpec::new(AggregateKind::Mean).with_budget(BudgetSpec::Fraction(0.2)),
            )
            .unwrap();
        let out = coord.process_batch_queries(gen.take_records(cfg.window_size)).unwrap();
        // max(2%, 20%) of the 2000-item window: the shared sample serves
        // the hungriest budget, so no query loses accuracy to sharing.
        assert_eq!(out.window.sample_size, 400);
        // Both queries were answered from that one sample.
        assert!(out.queries.iter().all(|q| q.sample_size == 400));
    }

    /// Fixed-allocation cost stub that records every `observe` call —
    /// the seam that pins what the driver actually feeds per-query cost
    /// models.
    struct RecordingCost {
        alloc: usize,
        observed: std::sync::Arc<std::sync::Mutex<Vec<(usize, f64)>>>,
    }

    impl CostFunction for RecordingCost {
        fn sample_size(&mut self, window_len: usize) -> usize {
            self.alloc.clamp(1, window_len.max(1))
        }

        fn observe(&mut self, items: usize, elapsed_ms: f64) {
            self.observed.lock().unwrap().push((items, elapsed_ms));
        }

        fn name(&self) -> &'static str {
            "recording"
        }
    }

    #[test]
    fn per_query_cost_feedback_is_own_allocation_not_union() {
        // The cross-contamination regression: two queries on wildly
        // different budgets (20× apart). Before the fix every query's
        // cost function observed the UNION sample size and the
        // whole-slide latency, so the small query's model was fed the big
        // query's load. Now each observes its own allocation and its own
        // cost share.
        use std::sync::{Arc, Mutex};
        let cfg = config(ExecModeSpec::IncApprox);
        let mut gen = MultiStream::paper_section5(cfg.seed);
        let mut coord = Coordinator::new(cfg.clone());
        let big_log = Arc::new(Mutex::new(Vec::new()));
        let small_log = Arc::new(Mutex::new(Vec::new()));
        coord
            .submit_query_with_cost(
                QuerySpec::new(AggregateKind::Sum),
                Box::new(RecordingCost { alloc: 400, observed: big_log.clone() }),
            )
            .unwrap();
        coord
            .submit_query_with_cost(
                QuerySpec::new(AggregateKind::Mean),
                Box::new(RecordingCost { alloc: 20, observed: small_log.clone() }),
            )
            .unwrap();
        coord.process_batch(gen.take_records(cfg.window_size)).unwrap();
        for _ in 0..3 {
            coord.process_batch(gen.take_records(cfg.slide)).unwrap();
        }
        let big = big_log.lock().unwrap();
        let small = small_log.lock().unwrap();
        assert_eq!(big.len(), 4);
        assert_eq!(small.len(), 4);
        for ((items_b, ms_b), (items_s, ms_s)) in big.iter().zip(small.iter()) {
            // Each budget sees its OWN ask — the small query must never
            // observe the ~400-item union its neighbor forced.
            assert_eq!(*items_b, 400);
            assert_eq!(*items_s, 20);
            // And its attributed cost share is no larger than the big
            // query's (1/20th of the substrate plus its own derive).
            assert!(
                ms_s <= ms_b,
                "small query charged more than the big one: {ms_s} vs {ms_b}"
            );
        }
    }

    #[test]
    fn submit_rejects_invalid_specs() {
        let mut coord = Coordinator::new(config(ExecModeSpec::IncApprox));
        assert!(coord
            .submit_query(QuerySpec::new(AggregateKind::Sum).with_confidence(2.0))
            .is_err());
        assert!(coord
            .submit_query(QuerySpec::new(AggregateKind::Sum).with_map_rounds(7))
            .is_err());
        assert_eq!(coord.query_count(), 0, "rejected specs must not register");
    }

    /// Warm-up batch plus `n` slide batches off one deterministic stream.
    fn batches(cfg: &SystemConfig, n: usize) -> Vec<Vec<Record>> {
        let mut gen = MultiStream::paper_section5(cfg.seed);
        let mut out = vec![gen.take_records(cfg.window_size)];
        for _ in 0..n {
            out.push(gen.take_records(cfg.slide));
        }
        out
    }

    fn assert_outputs_identical(a: &SlideOutput, b: &SlideOutput, label: &str) {
        assert_reports_identical(
            std::slice::from_ref(&a.window),
            std::slice::from_ref(&b.window),
            label,
        );
        assert_eq!(a.queries.len(), b.queries.len(), "{label}");
        for (qa, qb) in a.queries.iter().zip(&b.queries) {
            assert_eq!(qa.id, qb.id, "{label}");
            assert_eq!(qa.kind, qb.kind, "{label}");
            assert_eq!(qa.estimate.value.to_bits(), qb.estimate.value.to_bits(), "{label}");
            assert_eq!(qa.estimate.margin.to_bits(), qb.estimate.margin.to_bits(), "{label}");
            assert_eq!(qa.sample_size, qb.sample_size, "{label}");
            assert_eq!(qa.population, qb.population, "{label}");
            assert_eq!(qa.bound_scale.to_bits(), qb.bound_scale.to_bits(), "{label}");
            assert_eq!(qa.degraded, qb.degraded, "{label}");
            assert_eq!(
                qa.extrema.map(|(lo, hi)| (lo.to_bits(), hi.to_bits())),
                qb.extrema.map(|(lo, hi)| (lo.to_bits(), hi.to_bits())),
                "{label}"
            );
        }
    }

    #[test]
    fn checkpoint_restore_continues_byte_identically() {
        let cfg = config(ExecModeSpec::IncApprox);
        let data = batches(&cfg, 8);
        let mut live = Coordinator::new(cfg.clone());
        let mut victim = Coordinator::new(cfg.clone());
        for coord in [&mut live, &mut victim] {
            coord.submit_query(QuerySpec::new(AggregateKind::Mean)).unwrap();
            coord.submit_query(QuerySpec::new(AggregateKind::Extrema)).unwrap();
        }
        for b in &data[..4] {
            live.process_batch_queries(b.clone()).unwrap();
            victim.process_batch_queries(b.clone()).unwrap();
        }
        let mut artifact = Vec::new();
        victim.checkpoint(&mut artifact).unwrap();
        drop(victim); // the crash
        let mut restored = Coordinator::restore(&artifact[..], cfg).unwrap();
        assert!(restored.work_profile().last().restore_items > 0);
        assert_eq!(restored.query_count(), 2);
        for (i, b) in data[4..].iter().enumerate() {
            let a = live.process_batch_queries(b.clone()).unwrap();
            let r = restored.process_batch_queries(b.clone()).unwrap();
            assert_outputs_identical(&a, &r, &format!("slide {i} after restore"));
        }
    }

    #[test]
    fn checkpoint_survives_mid_stream_resize() {
        // A resize between checkpoints flows through the journal; a
        // resize *after* the last checkpoint still reaches the artifact
        // because `checkpoint` refreshes the chain before flushing.
        let cfg = config(ExecModeSpec::IncApprox);
        let data = batches(&cfg, 8);
        let mut live = Coordinator::new(cfg.clone());
        let mut victim = Coordinator::new(cfg.clone());
        for b in &data[..3] {
            live.process_batch(b.clone()).unwrap();
            victim.process_batch(b.clone()).unwrap();
        }
        let mut early = Vec::new();
        victim.checkpoint(&mut early).unwrap(); // arm journaling
        live.resize_window(1500);
        victim.resize_window(1500);
        live.process_batch(data[3].clone()).unwrap();
        victim.process_batch(data[3].clone()).unwrap();
        live.resize_window(2300);
        victim.resize_window(2300);
        let mut artifact = Vec::new();
        victim.checkpoint(&mut artifact).unwrap();
        let mut restored = Coordinator::restore(&artifact[..], cfg).unwrap();
        assert_eq!(restored.config().window_size, 2300, "resize must survive restore");
        for (i, b) in data[4..].iter().enumerate() {
            let a = live.process_batch(b.clone()).unwrap();
            let r = restored.process_batch(b.clone()).unwrap();
            assert_reports_identical(
                std::slice::from_ref(&a),
                std::slice::from_ref(&r),
                &format!("post-resize slide {i}"),
            );
        }
    }

    #[test]
    fn delta_checkpoints_are_bounded_by_slide_delta() {
        let cfg = config(ExecModeSpec::IncApprox);
        let data = batches(&cfg, 7);
        let mut coord = Coordinator::new(cfg.clone());
        for b in &data[..3] {
            coord.process_batch(b.clone()).unwrap();
        }
        let mut sink = Vec::new();
        coord.checkpoint(&mut sink).unwrap(); // first = full base
        let base_bytes = coord.work_profile().total().checkpoint_bytes;
        assert!(base_bytes > 0, "base segment must be accounted");
        let mut deltas = Vec::new();
        for b in &data[3..7] {
            coord.process_batch(b.clone()).unwrap();
            let before = coord.work_profile().total().checkpoint_bytes;
            let mut sink = Vec::new();
            coord.checkpoint(&mut sink).unwrap();
            deltas.push(coord.work_profile().total().checkpoint_bytes - before);
        }
        // Steady state: a per-slide delta segment is far smaller than the
        // base — durability costs O(state delta), not O(window).
        for (i, &d) in deltas.iter().enumerate() {
            assert!(d > 0, "delta {i} must be accounted");
            assert!(d * 3 < base_bytes, "delta {i}: {d} bytes vs base {base_bytes}");
        }
    }

    #[test]
    fn checkpoint_recovery_restores_memo_after_injected_loss() {
        let mut cfg = config(ExecModeSpec::IncApprox);
        cfg.fault_memo_loss = 1.0; // lose memo every window
        let mut gen = MultiStream::paper_section5(13);
        let mut coord =
            Coordinator::new(cfg.clone()).with_recovery(RecoveryPolicy::Checkpoint);
        coord.process_batch(gen.take_records(cfg.window_size)).unwrap();
        coord.process_batch(gen.take_records(cfg.slide)).unwrap();
        coord.refresh_checkpoint_chain().unwrap(); // what the periodic knob does
        let r = coord.process_batch(gen.take_records(cfg.slide)).unwrap();
        assert!(r.fault_injected);
        assert!(
            r.fresh_items < r.sample_size,
            "checkpoint image should preserve incremental state across the fault"
        );
        // The injections surface through the work profile (satellite fix).
        assert_eq!(coord.work_profile().total().fault_injections, coord.faults_injected());
        assert!(coord.faults_injected() >= 3);
    }

    #[test]
    fn single_stratum_stream_works_in_all_modes() {
        // Degenerate stratification: every record in one stratum — the
        // sharded pipeline runs with exactly one (serial) shard group.
        for mode in [ExecModeSpec::Native, ExecModeSpec::IncApprox] {
            let cfg = config(mode);
            let mut coord = Coordinator::new(cfg.clone());
            let records: Vec<Record> = (0..2400u64)
                .map(|i| Record::new(i, 0, i / 12, 0, (i % 17) as f64 + 1.0))
                .collect();
            coord.process_batch(records[..2000].to_vec()).unwrap();
            let r = coord.process_batch(records[2000..2200].to_vec()).unwrap();
            assert_eq!(r.strata.len(), 1, "{}", mode.name());
            assert!(r.estimate.value > 0.0);
        }
    }
}
