//! The coordinator — the paper's Algorithm 1 as a streaming orchestrator.
//!
//! [`driver::Coordinator`] executes one window per slide batch: evict old
//! memo state → stratified-sample the window within the **union** of the
//! registered query budgets → bias toward memoized items → plan the job
//! against the memo (change propagation via the DDG) → execute only
//! fresh chunks (native or PJRT) → combine → derive every registered
//! query's answer from the shared moments → estimate error bounds →
//! memoize. [`session::Session`] wires a kafka consumer to the
//! coordinator with lag-based backpressure and serves N concurrent
//! [`query::QuerySpec`]s per slide; [`pipeline::Pipeline`] is the legacy
//! single-query wrapper over it.

pub mod driver;
pub mod pipeline;
pub mod query;
pub(crate) mod registry;
pub mod report;
pub mod session;

pub use driver::{Coordinator, ExecMode};
pub use pipeline::Pipeline;
pub use query::{QueryId, QuerySpec};
pub use report::{QueryReport, SlideOutput, StratumReport, WindowReport};
pub use session::Session;
