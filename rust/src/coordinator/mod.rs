//! The coordinator — the paper's Algorithm 1 as a streaming orchestrator.
//!
//! [`driver::Coordinator`] executes one window per slide batch: evict old
//! memo state → stratified-sample the window within the query budget →
//! bias toward memoized items → plan the job against the memo (change
//! propagation via the DDG) → execute only fresh chunks (native or PJRT)
//! → combine → estimate error bounds → memoize. [`pipeline::Pipeline`]
//! wires a kafka consumer to the coordinator with lag-based backpressure.

pub mod driver;
pub mod pipeline;
pub mod report;

pub use driver::{Coordinator, ExecMode};
pub use pipeline::Pipeline;
pub use report::{StratumReport, WindowReport};
