//! The query registry — N concurrent query specs plus their live cost
//! functions, factored out of the driver so the single-coordinator slide
//! loop and the partition merge tier run the *same* derive / feedback /
//! cost-attribution code paths. Byte-identity between the two is by
//! construction: there is exactly one implementation of "answer every
//! registered query from per-stratum moments" in the crate, and both
//! callers go through it.

use std::collections::BTreeMap;

use crate::budget::{self, CostFunction};
use crate::checkpoint::QueryEntry;
use crate::config::system::{BudgetSpec, SystemConfig};
use crate::coordinator::query::{QueryId, QuerySpec};
use crate::coordinator::report::QueryReport;
use crate::error::Result;
use crate::job::aggregate::derive_aggregate_sketched;
use crate::job::moments::Moments;
use crate::job::sketch::SketchBundle;
use crate::metrics::{SlideWork, Stopwatch};
use crate::stats::stratified::StratumAgg;
use crate::workload::record::StratumId;

/// One registered query: its spec plus its live cost function (the
/// adaptive budgets carry per-query state, e.g. the latency EWMA or the
/// error-target controller's smoothed demand).
pub(crate) struct RegisteredQuery {
    pub(crate) id: QueryId,
    pub(crate) spec: QuerySpec,
    pub(crate) cost: Box<dyn CostFunction>,
    /// The sample size this query's own budget asked for on the current
    /// slide (set by `union_sample_size`). Cost feedback is attributed
    /// against this, never against the union the shared sampler ran at —
    /// feeding every query the union the shared sampler ran at would let
    /// one query's load contaminate every other query's cost model.
    pub(crate) last_alloc: usize,
}

/// The registered queries of a session, in submission order, plus the
/// monotone id counter. Owned by a [`Coordinator`](super::Coordinator)
/// in single-node runs and by the partition
/// [`MergeTier`](crate::partition::MergeTier) in scale-out runs (where
/// the per-partition coordinators carry *no* queries — answers are
/// derived once, from the merged state).
#[derive(Default)]
pub(crate) struct QueryRegistry {
    queries: Vec<RegisteredQuery>,
    next_query_id: u64,
}

impl QueryRegistry {
    /// Validate and register a query spec, minting its id.
    pub(crate) fn submit(&mut self, cfg: &SystemConfig, spec: QuerySpec) -> Result<QueryId> {
        spec.validate_for(cfg)?;
        let id = QueryId::new(self.next_query_id);
        self.next_query_id += 1;
        let cost = budget::from_spec(&spec.budget);
        self.queries.push(RegisteredQuery { id, spec, cost, last_alloc: 0 });
        Ok(id)
    }

    /// Test seam: register with a caller-supplied cost function.
    #[cfg(test)]
    pub(crate) fn submit_with_cost(
        &mut self,
        cfg: &SystemConfig,
        spec: QuerySpec,
        cost: Box<dyn CostFunction>,
    ) -> Result<QueryId> {
        spec.validate_for(cfg)?;
        let id = QueryId::new(self.next_query_id);
        self.next_query_id += 1;
        self.queries.push(RegisteredQuery { id, spec, cost, last_alloc: 0 });
        Ok(id)
    }

    /// Deregister; returns whether the id was present.
    pub(crate) fn remove(&mut self, id: QueryId) -> bool {
        let before = self.queries.len();
        self.queries.retain(|q| q.id != id);
        self.queries.len() != before
    }

    /// Number of registered queries.
    pub(crate) fn len(&self) -> usize {
        self.queries.len()
    }

    /// No queries registered (legacy single-query behavior)?
    pub(crate) fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    /// The specs, in submission order.
    pub(crate) fn specs(&self) -> impl Iterator<Item = (QueryId, &QuerySpec)> {
        self.queries.iter().map(|q| (q.id, &q.spec))
    }

    /// Does any registered query need the per-chunk sketch pass?
    pub(crate) fn wants_sketches(&self) -> bool {
        self.queries.iter().any(|q| q.spec.kind.is_sketch())
    }

    /// Propagate the degradation ladder's bound multiplier to every
    /// query budget (open-loop budgets ignore it by contract).
    pub(crate) fn set_bound_scale(&mut self, scale: f64) {
        for q in &mut self.queries {
            q.cost.set_bound_scale(scale);
        }
    }

    /// The union (max) of the per-query budget allocations for this
    /// slide, remembering each query's own ask for post-slide cost
    /// attribution. `None` with no queries registered — the caller falls
    /// back to its session-level budget.
    pub(crate) fn union_sample_size(&mut self, window_len: usize) -> Option<usize> {
        if self.queries.is_empty() {
            return None;
        }
        Some(
            self.queries
                .iter_mut()
                .map(|q| {
                    // Remember each query's own ask: post-slide cost
                    // feedback is attributed against it, not the union.
                    q.last_alloc = q.cost.sample_size(window_len);
                    q.last_alloc
                })
                .max()
                .unwrap_or(1),
        )
    }

    /// Answer every registered query from the shared per-stratum moments,
    /// exact populations, and sketch bundles — O(strata) per query, timed
    /// individually so cost feedback can charge a query for its own
    /// derivation and not its neighbors'.
    ///
    /// `blanket_degraded` selects the degradation-flag rule: `true` (the
    /// single-coordinator path) flags every query when *any* stratum
    /// degraded this slide; `false` (the merge tier, which knows which
    /// partition each stratum lives in) flags a stratum-scoped query only
    /// when its own stratum is in `degraded_strata`, so one partition's
    /// fault never taints a healthy partition's stratum-scoped answers.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn derive_phase(
        &self,
        moments: &BTreeMap<StratumId, Moments>,
        populations: &BTreeMap<StratumId, u64>,
        sketches: &BTreeMap<StratumId, SketchBundle>,
        bound_scale: f64,
        degraded_strata: &[StratumId],
        blanket_degraded: bool,
        work: &mut SlideWork,
    ) -> Result<(Vec<QueryReport>, Vec<f64>)> {
        let any_degraded = !degraded_strata.is_empty();
        let mut reports: Vec<QueryReport> = Vec::with_capacity(self.queries.len());
        let mut derive_ms: Vec<f64> = Vec::with_capacity(self.queries.len());
        for q in &self.queries {
            let sw_derive = Stopwatch::start();
            let d = derive_aggregate_sketched(
                q.spec.kind,
                q.spec.stratum,
                q.spec.confidence,
                moments,
                populations,
                sketches,
            )?;
            derive_ms.push(sw_derive.elapsed_ms());
            work.derive_items += d.strata_touched;
            let degraded = match q.spec.stratum {
                Some(s) if !blanket_degraded => degraded_strata.contains(&s),
                _ => any_degraded,
            };
            reports.push(QueryReport {
                id: q.id,
                kind: q.spec.kind,
                estimate: d.estimate,
                sample_size: d.sample_size,
                population: d.population,
                extrema: d.extrema,
                surface: d.surface,
                target_rel_bound: match q.spec.budget {
                    // The *effective* target: the configured baseline
                    // widened by the degradation ladder's current level.
                    BudgetSpec::TargetError { relative_bound, .. } => {
                        Some(relative_bound * bound_scale)
                    }
                    _ => None,
                },
                bound_scale: match q.spec.budget {
                    BudgetSpec::TargetError { .. } => bound_scale,
                    _ => 1.0,
                },
                degraded,
            });
        }
        Ok((reports, derive_ms))
    }

    /// Close the per-query error-bound loop: every adaptive error-target
    /// budget reads the achieved per-stratum aggregates its own query
    /// covers and re-solves for the sample size the *next* slide needs.
    /// O(strata) per adaptive budget, charged to `budget_adjust`.
    pub(crate) fn observe_bounds(
        &mut self,
        moments: &BTreeMap<StratumId, Moments>,
        populations: &BTreeMap<StratumId, u64>,
        window_len: usize,
        work: &mut SlideWork,
    ) {
        for q in &mut self.queries {
            if !q.cost.wants_bound_feedback() {
                continue;
            }
            let feedback: Vec<StratumAgg> = moments
                .iter()
                .filter(|entry| q.spec.stratum.map_or(true, |want| want == *entry.0))
                .map(|(s, m)| {
                    StratumAgg::from_moments(
                        m,
                        populations.get(s).copied().unwrap_or(0) as f64,
                    )
                })
                .collect();
            work.budget_adjust += feedback.len() as u64;
            q.cost.observe_bound(&feedback, window_len as f64);
        }
    }

    /// Per-query cost attribution: each budget observes its OWN share —
    /// its proportional slice of the shared substrate plus its own
    /// derivation time — never the union sample + whole-slide latency.
    pub(crate) fn attribute_costs(
        &mut self,
        union_realized: usize,
        substrate_ms: f64,
        derive_ms: &[f64],
    ) {
        for (q, &d_ms) in self.queries.iter_mut().zip(derive_ms) {
            let (items, elapsed) =
                budget::attribute_query_cost(q.last_alloc, union_realized, substrate_ms, d_ms);
            q.cost.observe(items, elapsed);
        }
    }

    /// The per-query half of the durable budget-state slots, as
    /// `(raw id, policy, state)` — the caller prepends its session slot.
    pub(crate) fn budget_state_slots(&self) -> Vec<(u64, &'static str, f64)> {
        let mut slots = Vec::new();
        for q in &self.queries {
            if let Some(state) = q.cost.export_state() {
                slots.push((q.id.as_u64(), q.cost.name(), state));
            }
        }
        slots
    }

    /// The checkpointable registry image: raw ids + specs.
    pub(crate) fn entries(&self) -> Vec<QueryEntry> {
        self.queries
            .iter()
            .map(|q| QueryEntry { raw_id: q.id.as_u64(), spec: q.spec.clone() })
            .collect()
    }

    /// The id the next [`QueryRegistry::submit`] will mint.
    pub(crate) fn next_id(&self) -> u64 {
        self.next_query_id
    }

    /// Restore-path twin of [`QueryRegistry::submit`]: rebuild the
    /// registry from checkpointed entries (ids are preserved, cost
    /// functions are re-derived from the specs) and resume the id
    /// counter.
    pub(crate) fn restore(
        &mut self,
        cfg: &SystemConfig,
        next_query_id: u64,
        entries: Vec<QueryEntry>,
    ) -> Result<()> {
        self.next_query_id = next_query_id;
        for q in entries {
            q.spec.validate_for(cfg)?;
            let cost = budget::from_spec(&q.spec.budget);
            self.queries.push(RegisteredQuery {
                id: QueryId::new(q.raw_id),
                spec: q.spec,
                cost,
                last_alloc: 0,
            });
        }
        Ok(())
    }

    /// Resume the adaptive-budget trajectories from checkpointed slots.
    /// A state only lands on a cost function of the SAME policy (a
    /// banked-token count imported as a latency EWMA would poison the
    /// model); mismatched or orphaned slots are ignored.
    pub(crate) fn import_budget_states(&mut self, states: &BTreeMap<u64, (String, f64)>) {
        for q in &mut self.queries {
            if let Some((policy, state)) = states.get(&q.id.as_u64()) {
                if policy == q.cost.name() {
                    q.cost.import_state(*state);
                }
            }
        }
    }
}
