//! Configuration system: a mini-TOML parser plus the typed system config.
//!
//! The offline crate set has no `serde`/`toml`, so `parser` implements the
//! subset of TOML the launcher needs — `[section]` headers, string / int /
//! float / bool scalars, flat arrays, comments — and `system` maps parsed
//! values onto [`SystemConfig`] with defaults and validation.

pub mod parser;
pub mod system;

pub use parser::{parse_toml, TomlValue};
pub use system::{BudgetSpec, ExecModeSpec, SystemConfig};
