//! Mini-TOML parser (sections, scalars, flat arrays, comments).
//!
//! Supported grammar — the subset our config files use:
//!
//! ```toml
//! # comment
//! top_level = 1
//! [section]
//! name = "string"        # basic strings with \" \\ \n \t escapes
//! count = 42             # i64
//! ratio = 0.25           # f64 (also 1e-3 forms)
//! enabled = true
//! rates = [3.0, 4.0, 5.0]
//! ```
//!
//! Keys are flattened to `section.key`. Duplicate keys: last one wins
//! (documented divergence from strict TOML, convenient for overrides).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML scalar or flat array.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Floating point literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Flat array of scalars.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As i64 (ints only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// As f64 (accepts ints too).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// As &str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(v) => Some(v),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_string(s: &str, line_no: usize) -> Result<(String, &str)> {
    let mut out = String::new();
    let mut chars = s.char_indices();
    let mut escaped = false;
    // Caller guarantees s starts with '"'.
    chars.next();
    for (i, c) in chars {
        if escaped {
            out.push(match c {
                'n' => '\n',
                't' => '\t',
                'r' => '\r',
                '"' => '"',
                '\\' => '\\',
                other => {
                    return Err(Error::Config(format!(
                        "line {line_no}: unknown escape \\{other}"
                    )))
                }
            });
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '"' {
            return Ok((out, &s[i + 1..]));
        } else {
            out.push(c);
        }
    }
    Err(Error::Config(format!("line {line_no}: unterminated string")))
}

fn parse_scalar(raw: &str, line_no: usize) -> Result<TomlValue> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(Error::Config(format!("line {line_no}: empty value")));
    }
    if raw.starts_with('"') {
        let (s, rest) = parse_string(raw, line_no)?;
        if !rest.trim().is_empty() {
            return Err(Error::Config(format!(
                "line {line_no}: trailing characters after string"
            )));
        }
        return Ok(TomlValue::Str(s));
    }
    match raw {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = raw.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(Error::Config(format!("line {line_no}: cannot parse value `{raw}`")))
}

fn parse_value(raw: &str, line_no: usize) -> Result<TomlValue> {
    let raw = raw.trim();
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| Error::Config(format!("line {line_no}: unterminated array")))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = split_array_items(inner, line_no)?
            .into_iter()
            .map(|item| parse_scalar(item, line_no))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    parse_scalar(raw, line_no)
}

fn split_array_items(inner: &str, line_no: usize) -> Result<Vec<&str>> {
    let mut items = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in inner.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err(Error::Config(format!("line {line_no}: unterminated string in array")));
    }
    let tail = &inner[start..];
    if !tail.trim().is_empty() {
        items.push(tail);
    }
    Ok(items)
}

/// Parse mini-TOML text into a flat `section.key → value` map.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {line_no}: bad section header")))?
                .trim();
            if name.is_empty() {
                return Err(Error::Config(format!("line {line_no}: empty section name")));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| Error::Config(format!("line {line_no}: expected `key = value`")))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(Error::Config(format!("line {line_no}: empty key")));
        }
        let value = parse_value(&line[eq + 1..], line_no)?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        map.insert(full_key, value);
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let map = parse_toml(
            r#"
            top = 1
            [stream]
            name = "flows"
            rate = 3.5
            on = true
            "#,
        )
        .unwrap();
        assert_eq!(map["top"], TomlValue::Int(1));
        assert_eq!(map["stream.name"], TomlValue::Str("flows".into()));
        assert_eq!(map["stream.rate"], TomlValue::Float(3.5));
        assert_eq!(map["stream.on"], TomlValue::Bool(true));
    }

    #[test]
    fn parses_arrays() {
        let map = parse_toml("rates = [3, 4.0, 5]").unwrap();
        let arr = map["rates"].as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_float(), Some(3.0));
        assert_eq!(arr[1].as_float(), Some(4.0));
    }

    #[test]
    fn string_arrays_with_commas_inside() {
        let map = parse_toml(r#"names = ["a,b", "c"]"#).unwrap();
        let arr = map["names"].as_array().unwrap();
        assert_eq!(arr[0].as_str(), Some("a,b"));
        assert_eq!(arr[1].as_str(), Some("c"));
    }

    #[test]
    fn comments_stripped_even_after_values() {
        let map = parse_toml("x = 2 # two\ns = \"a#b\" # hash inside string kept").unwrap();
        assert_eq!(map["x"], TomlValue::Int(2));
        assert_eq!(map["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn escapes_in_strings() {
        let map = parse_toml(r#"s = "line\nbreak \"quoted\" \\ done""#).unwrap();
        assert_eq!(map["s"].as_str(), Some("line\nbreak \"quoted\" \\ done"));
    }

    #[test]
    fn underscored_numbers() {
        let map = parse_toml("n = 10_000\nf = 1_000.5").unwrap();
        assert_eq!(map["n"], TomlValue::Int(10_000));
        assert_eq!(map["f"], TomlValue::Float(1000.5));
    }

    #[test]
    fn last_duplicate_wins() {
        let map = parse_toml("x = 1\nx = 2").unwrap();
        assert_eq!(map["x"], TomlValue::Int(2));
    }

    #[test]
    fn errors_are_reported_with_line_numbers() {
        for (src, needle) in [
            ("x 1", "line 1"),
            ("[oops", "line 1"),
            ("x = ", "line 1"),
            ("y = [1, 2", "unterminated array"),
            ("s = \"abc", "unterminated string"),
            ("z = what", "cannot parse"),
        ] {
            let err = parse_toml(src).unwrap_err().to_string();
            assert!(err.contains(needle), "src={src:?} err={err}");
        }
    }

    #[test]
    fn empty_input_ok() {
        assert!(parse_toml("").unwrap().is_empty());
        assert!(parse_toml("\n# only comments\n").unwrap().is_empty());
    }
}
