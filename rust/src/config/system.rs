//! Typed system configuration for the IncApprox coordinator.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::parser::{parse_toml, TomlValue};
use crate::error::{Error, Result};

/// Which execution pipeline the coordinator runs (the paper's system plus
/// the three baselines its headline speedups are measured against).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecModeSpec {
    /// Exact recomputation of the full window (native Spark Streaming).
    Native,
    /// Memoization/change-propagation only, no sampling.
    IncrementalOnly,
    /// Stratified sampling only, no memoization.
    ApproxOnly,
    /// The paper's system: biased sampling + incremental computation.
    IncApprox,
}

impl ExecModeSpec {
    /// Parse a mode name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Self::Native),
            "incremental" | "incremental_only" | "inc" => Ok(Self::IncrementalOnly),
            "approx" | "approx_only" => Ok(Self::ApproxOnly),
            "incapprox" => Ok(Self::IncApprox),
            other => Err(Error::Config(format!("unknown mode `{other}`"))),
        }
    }

    /// Display name used in reports and benches.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Native => "native",
            Self::IncrementalOnly => "incremental",
            Self::ApproxOnly => "approx",
            Self::IncApprox => "incapprox",
        }
    }
}

/// How strata are assigned to memo shards / worker partitions in the
/// sharded window pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardStrategy {
    /// Mix the stratum id through a 64-bit avalanche before taking the
    /// shard modulus — robust to clustered stratum ids (default).
    #[default]
    Hash,
    /// Plain `stratum % shards` — deterministic round-robin over dense,
    /// consecutively numbered strata.
    Modulo,
}

impl ShardStrategy {
    /// Parse a strategy name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Ok(Self::Hash),
            "modulo" | "round_robin" | "mod" => Ok(Self::Modulo),
            other => Err(Error::Config(format!("unknown shard strategy `{other}`"))),
        }
    }

    /// Display name used in reports and benches.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Hash => "hash",
            Self::Modulo => "modulo",
        }
    }
}

/// The user's query budget (§2.2 / §6.2). The virtual cost function in
/// `budget/` turns this into a per-window sample size.
#[derive(Debug, Clone, PartialEq)]
pub enum BudgetSpec {
    /// Direct sampling fraction of the window (used by the paper's §5
    /// micro-benchmarks: "sample size 10% of window").
    Fraction(f64),
    /// Pulsar-style resource budget: tokens available per window; each
    /// item costs `cost_per_item` tokens.
    Tokens {
        /// Tokens refilled each window.
        per_window: f64,
        /// Token cost of processing one item.
        cost_per_item: f64,
    },
    /// Latency SLA per window in milliseconds; the EWMA predictor converts
    /// it to an item count.
    LatencyMs(f64),
    /// Error-target budget (the OLA-style contract: "≤ 2% relative error
    /// at 95% confidence"). Closed-loop: after each slide the adaptive
    /// controller in `budget/` reads the achieved §3.5 margin and solves
    /// Eq 3.2 backwards for the sample size the *next* slide needs —
    /// finite-population-corrected, smoothed, clamped to the window.
    TargetError {
        /// Target relative half-width ε/|value| of the confidence
        /// interval (must be > 0; e.g. `0.02` for ±2%).
        relative_bound: f64,
        /// Confidence level the bound is promised at, in (0, 1).
        confidence: f64,
    },
}

impl Default for BudgetSpec {
    fn default() -> Self {
        BudgetSpec::Fraction(0.1)
    }
}

/// Full system configuration with defaults mirroring the paper's §5 setup.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    /// Master seed for all deterministic randomness.
    pub seed: u64,
    /// Execution pipeline.
    pub mode: ExecModeSpec,
    /// Window size in items (paper: 10 000).
    pub window_size: usize,
    /// Slide in items (paper: 1–16% of window).
    pub slide: usize,
    /// Query budget.
    pub budget: BudgetSpec,
    /// Reservoir re-allocation interval `T` of Algorithm 2, in items
    /// seen. Governs the legacy one-shot
    /// `StratifiedSampler::sample_window` API (benches, library users);
    /// the coordinator's persistent sampler recomputes exact proportional
    /// allocation every slide in O(strata), so no interval applies there.
    pub realloc_interval: usize,
    /// Target items per memoizable chunk (content-defined chunking mean).
    pub chunk_size: usize,
    /// Full-recompute epoch for the inverse-reduce path: every N windows
    /// the per-stratum moments are rebuilt from scratch to bound
    /// floating-point drift from repeated add/subtract.
    pub recompute_epoch: usize,
    /// Per-item map iterations (the user-defined map stage's weight;
    /// see `job::map_fn`). Artifacts must be compiled with a matching
    /// rounds variant for the PJRT backend.
    pub map_rounds: u32,
    /// Confidence level for error bounds (paper example: 0.95).
    pub confidence: f64,
    /// Execute chunk moments through the PJRT runtime (true) or the
    /// native scalar backend (false).
    pub use_pjrt: bool,
    /// Directory holding `manifest.tsv` + HLO artifacts.
    pub artifacts_dir: String,
    /// Worker threads for the sharded window pipeline and the
    /// data-parallel chunk executor. With `num_workers > 1` the
    /// coordinator partitions strata across workers and computes fresh
    /// chunks on a worker pool; `1` runs the serial reference path
    /// (bit-identical outputs either way).
    pub num_workers: usize,
    /// How strata map to memo shards / worker partitions.
    pub shard_strategy: ShardStrategy,
    /// Backpressure high watermark of the streaming pipeline, in slides:
    /// when consumer lag exceeds `lag_watermark_slides × slide` records,
    /// [`Session`](crate::coordinator::Session) steps drain catch-up
    /// batches instead of single slides.
    pub lag_watermark_slides: usize,
    /// Catch-up batch size, in slides, drained per pipeline step while
    /// the consumer is over the lag watermark.
    pub catchup_factor: usize,
    /// Refresh the coordinator's in-memory checkpoint chain every N
    /// slides (0 = checkpointing off, the default). The first refresh
    /// encodes a full base segment; each later one appends a delta
    /// segment whose size is O(state change since the last checkpoint) —
    /// see [`crate::checkpoint`]. `Session::checkpoint` /
    /// `Coordinator::checkpoint` flush the chain to a writer at any time,
    /// and [`RecoveryPolicy::Checkpoint`](crate::fault::RecoveryPolicy)
    /// falls back to the chain's memo image on injected memo loss.
    pub checkpoint_every_slides: usize,
    /// O(delta) slide path (default). When true the coordinator maintains
    /// the sampler, the window view, and the chunk plans incrementally
    /// across slides — per-slide heavy work is proportional to the input
    /// change, not the window. When false every window is rebuilt from
    /// scratch (the O(window) reference baseline). Both settings produce
    /// byte-identical `WindowReport`s; `benches/incremental_scaling.rs`
    /// measures the gap.
    pub incremental_slide: bool,
    /// Per-window probability of injected memo loss (fault testing).
    pub fault_memo_loss: f64,
    /// Per-slide probability of an injected transient failure of the
    /// batched `ChunkBackend::compute` call (fault testing). The driver's
    /// retry policy absorbs it; exhaustion degrades the slide.
    pub fault_compute: f64,
    /// Per-slide probability of an injected broker stall: the session's
    /// next poll fails with a typed `Error::Kafka`, nothing is consumed,
    /// and lag builds until the next step drains it.
    pub fault_broker: f64,
    /// Per-slide probability of an injected torn checkpoint write: the
    /// next segment append fails with a typed `Error::Checkpoint` and the
    /// chain re-bases at the next cadence.
    pub fault_checkpoint_write: f64,
    /// Total attempts (first try + retries) the driver gives the batched
    /// compute call per slide before degrading the slide; ≥ 1.
    pub retry_max_attempts: usize,
    /// Backoff after the first compute failure, in abstract retry slots
    /// (deterministic — never wall-clock); ≥ 1.
    pub retry_backoff_base_slots: usize,
    /// Backoff ceiling in retry slots; ≥ `retry_backoff_base_slots`.
    pub retry_backoff_cap_slots: usize,
    /// Multiplicative widening per degradation-ladder step (> 1). Applied
    /// to `TargetError` relative bounds while consumer lag is above
    /// `pipeline.lag_watermark_slides`.
    pub degradation_step_factor: f64,
    /// Highest degradation-ladder level; 0 (default) disables
    /// overload-adaptive error widening.
    pub degradation_max_steps: usize,
    /// Consecutive calm slides (lag at or below the watermark) before the
    /// ladder steps one level back toward the baseline; ≥ 1.
    pub degradation_recover_slides: usize,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            seed: 42,
            mode: ExecModeSpec::IncApprox,
            window_size: 10_000,
            slide: 400, // 4% of window, Fig 5.1(a) setting
            budget: BudgetSpec::Fraction(0.1),
            realloc_interval: 500,
            chunk_size: 64,
            recompute_epoch: 64,
            map_rounds: 0,
            confidence: 0.95,
            use_pjrt: false,
            artifacts_dir: "artifacts".to_string(),
            num_workers: 4,
            shard_strategy: ShardStrategy::Hash,
            lag_watermark_slides: 4,
            catchup_factor: 4,
            checkpoint_every_slides: 0,
            incremental_slide: true,
            fault_memo_loss: 0.0,
            fault_compute: 0.0,
            fault_broker: 0.0,
            fault_checkpoint_write: 0.0,
            retry_max_attempts: 3,
            retry_backoff_base_slots: 1,
            retry_backoff_cap_slots: 8,
            degradation_step_factor: 1.5,
            degradation_max_steps: 0,
            degradation_recover_slides: 2,
        }
    }
}

fn get_f64(map: &BTreeMap<String, TomlValue>, key: &str) -> Result<Option<f64>> {
    match map.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_float()
            .map(Some)
            .ok_or_else(|| Error::Config(format!("`{key}` must be a number"))),
    }
}

fn get_usize(map: &BTreeMap<String, TomlValue>, key: &str) -> Result<Option<usize>> {
    match map.get(key) {
        None => Ok(None),
        Some(v) => {
            let i = v
                .as_int()
                .ok_or_else(|| Error::Config(format!("`{key}` must be an integer")))?;
            usize::try_from(i)
                .map(Some)
                .map_err(|_| Error::Config(format!("`{key}` must be non-negative")))
        }
    }
}

impl SystemConfig {
    /// Build from mini-TOML text; missing keys keep their defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let map = parse_toml(text)?;
        let mut cfg = SystemConfig::default();
        if let Some(v) = get_usize(&map, "seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = map.get("mode") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("`mode` must be a string".into()))?;
            cfg.mode = ExecModeSpec::parse(s)?;
        }
        if let Some(v) = get_usize(&map, "window.size")? {
            cfg.window_size = v;
        }
        if let Some(v) = get_usize(&map, "window.slide")? {
            cfg.slide = v;
        }
        if let Some(v) = get_f64(&map, "budget.fraction")? {
            cfg.budget = BudgetSpec::Fraction(v);
        }
        if let Some(per_window) = get_f64(&map, "budget.tokens")? {
            let cost = get_f64(&map, "budget.cost_per_item")?.unwrap_or(1.0);
            cfg.budget = BudgetSpec::Tokens { per_window, cost_per_item: cost };
        }
        if let Some(v) = get_f64(&map, "budget.latency_ms")? {
            cfg.budget = BudgetSpec::LatencyMs(v);
        }
        if let Some(rb) = get_f64(&map, "budget.target_relative_error")? {
            let confidence =
                get_f64(&map, "budget.target_confidence")?.unwrap_or(0.95);
            cfg.budget = BudgetSpec::TargetError { relative_bound: rb, confidence };
        }
        if let Some(v) = get_usize(&map, "sampling.realloc_interval")? {
            cfg.realloc_interval = v;
        }
        if let Some(v) = get_usize(&map, "job.chunk_size")? {
            cfg.chunk_size = v;
        }
        if let Some(v) = get_usize(&map, "job.recompute_epoch")? {
            cfg.recompute_epoch = v;
        }
        if let Some(v) = get_usize(&map, "job.map_rounds")? {
            cfg.map_rounds = v as u32;
        }
        if let Some(v) = get_f64(&map, "stats.confidence")? {
            cfg.confidence = v;
        }
        if let Some(v) = map.get("runtime.use_pjrt") {
            cfg.use_pjrt = v
                .as_bool()
                .ok_or_else(|| Error::Config("`runtime.use_pjrt` must be a bool".into()))?;
        }
        if let Some(v) = map.get("runtime.artifacts_dir") {
            cfg.artifacts_dir = v
                .as_str()
                .ok_or_else(|| Error::Config("`runtime.artifacts_dir` must be a string".into()))?
                .to_string();
        }
        // `job.workers` is the legacy spelling of `job.num_workers`.
        if let Some(v) = get_usize(&map, "job.workers")? {
            cfg.num_workers = v;
        }
        if let Some(v) = get_usize(&map, "job.num_workers")? {
            cfg.num_workers = v;
        }
        if let Some(v) = map.get("job.shard_strategy") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("`job.shard_strategy` must be a string".into()))?;
            cfg.shard_strategy = ShardStrategy::parse(s)?;
        }
        if let Some(v) = get_usize(&map, "pipeline.lag_watermark_slides")? {
            cfg.lag_watermark_slides = v;
        }
        if let Some(v) = get_usize(&map, "pipeline.catchup_factor")? {
            cfg.catchup_factor = v;
        }
        if let Some(v) = get_usize(&map, "pipeline.checkpoint_every_slides")? {
            cfg.checkpoint_every_slides = v;
        }
        if let Some(v) = map.get("job.incremental_slide") {
            cfg.incremental_slide = v
                .as_bool()
                .ok_or_else(|| Error::Config("`job.incremental_slide` must be a bool".into()))?;
        }
        if let Some(v) = get_f64(&map, "fault.memo_loss")? {
            cfg.fault_memo_loss = v;
        }
        if let Some(v) = get_f64(&map, "fault.compute")? {
            cfg.fault_compute = v;
        }
        if let Some(v) = get_f64(&map, "fault.broker")? {
            cfg.fault_broker = v;
        }
        if let Some(v) = get_f64(&map, "fault.checkpoint_write")? {
            cfg.fault_checkpoint_write = v;
        }
        if let Some(v) = get_usize(&map, "retry.max_attempts")? {
            cfg.retry_max_attempts = v;
        }
        if let Some(v) = get_usize(&map, "retry.backoff_base_slots")? {
            cfg.retry_backoff_base_slots = v;
        }
        if let Some(v) = get_usize(&map, "retry.backoff_cap_slots")? {
            cfg.retry_backoff_cap_slots = v;
        }
        if let Some(v) = get_f64(&map, "degradation.step_factor")? {
            cfg.degradation_step_factor = v;
        }
        if let Some(v) = get_usize(&map, "degradation.max_steps")? {
            cfg.degradation_max_steps = v;
        }
        if let Some(v) = get_usize(&map, "degradation.recover_slides")? {
            cfg.degradation_recover_slides = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Check cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.window_size == 0 {
            return Err(Error::Config("window.size must be > 0".into()));
        }
        if self.slide == 0 || self.slide > self.window_size {
            return Err(Error::Config(format!(
                "window.slide must be in 1..={} (got {})",
                self.window_size, self.slide
            )));
        }
        crate::budget::validate_spec(&self.budget)?;
        if !(0.0 < self.confidence && self.confidence < 1.0) {
            return Err(Error::Config("stats.confidence must be in (0, 1)".into()));
        }
        if self.chunk_size == 0 {
            return Err(Error::Config("job.chunk_size must be > 0".into()));
        }
        if self.recompute_epoch == 0 {
            return Err(Error::Config("job.recompute_epoch must be > 0".into()));
        }
        if self.num_workers == 0 {
            return Err(Error::Config("job.num_workers must be > 0".into()));
        }
        if self.lag_watermark_slides == 0 {
            return Err(Error::Config("pipeline.lag_watermark_slides must be > 0".into()));
        }
        if self.catchup_factor == 0 {
            return Err(Error::Config("pipeline.catchup_factor must be > 0".into()));
        }
        // Probability guards: `contains` is false for NaN, so NaN fails
        // them the same way the positive guards in `validate_spec` do.
        if !(0.0..=1.0).contains(&self.fault_memo_loss) {
            return Err(Error::Config("fault.memo_loss must be in [0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&self.fault_compute) {
            return Err(Error::Config("fault.compute must be in [0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&self.fault_broker) {
            return Err(Error::Config("fault.broker must be in [0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&self.fault_checkpoint_write) {
            return Err(Error::Config("fault.checkpoint_write must be in [0, 1]".into()));
        }
        if self.retry_max_attempts == 0 {
            return Err(Error::Config("retry.max_attempts must be ≥ 1".into()));
        }
        if self.retry_backoff_base_slots == 0 {
            return Err(Error::Config("retry.backoff_base_slots must be ≥ 1".into()));
        }
        if self.retry_backoff_cap_slots < self.retry_backoff_base_slots {
            return Err(Error::Config(format!(
                "retry.backoff_cap_slots must be ≥ retry.backoff_base_slots ({} < {})",
                self.retry_backoff_cap_slots, self.retry_backoff_base_slots
            )));
        }
        // Positive guard so NaN fails too (`NaN > 1.0` is false).
        if !(self.degradation_step_factor > 1.0) {
            return Err(Error::Config(format!(
                "degradation.step_factor must be > 1, got {}",
                self.degradation_step_factor
            )));
        }
        if self.degradation_recover_slides == 0 {
            return Err(Error::Config("degradation.recover_slides must be ≥ 1".into()));
        }
        Ok(())
    }

    /// The configured fault spec for the injector's four channels.
    pub fn fault_spec(&self) -> crate::fault::FaultSpec {
        crate::fault::FaultSpec {
            memo_loss_p: self.fault_memo_loss,
            compute_p: self.fault_compute,
            broker_p: self.fault_broker,
            checkpoint_write_p: self.fault_checkpoint_write,
        }
    }

    /// The configured compute retry policy (validated fields).
    pub fn retry_policy(&self) -> crate::fault::RetryPolicy {
        crate::fault::RetryPolicy::new(
            self.retry_max_attempts as u32,
            self.retry_backoff_base_slots as u64,
            self.retry_backoff_cap_slots as u64,
        )
    }

    /// The configured degradation-ladder policy.
    pub fn degradation_policy(&self) -> crate::budget::DegradationPolicy {
        crate::budget::DegradationPolicy {
            step_factor: self.degradation_step_factor,
            max_steps: self.degradation_max_steps as u32,
            recover_slides: self.degradation_recover_slides as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_section5() {
        let cfg = SystemConfig::default();
        assert_eq!(cfg.window_size, 10_000);
        assert_eq!(cfg.slide, 400);
        assert_eq!(cfg.budget, BudgetSpec::Fraction(0.1));
        assert_eq!(cfg.confidence, 0.95);
        cfg.validate().unwrap();
    }

    #[test]
    fn full_roundtrip() {
        let cfg = SystemConfig::from_toml(
            r#"
            seed = 7
            mode = "incapprox"
            [window]
            size = 5000
            slide = 100
            [budget]
            fraction = 0.2
            [sampling]
            realloc_interval = 250
            [job]
            chunk_size = 128
            workers = 2
            [stats]
            confidence = 0.99
            [runtime]
            use_pjrt = true
            artifacts_dir = "artifacts"
            [fault]
            memo_loss = 0.05
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.window_size, 5000);
        assert_eq!(cfg.slide, 100);
        assert_eq!(cfg.budget, BudgetSpec::Fraction(0.2));
        assert_eq!(cfg.realloc_interval, 250);
        assert_eq!(cfg.chunk_size, 128);
        assert_eq!(cfg.num_workers, 2);
        assert_eq!(cfg.confidence, 0.99);
        assert!(cfg.use_pjrt);
        assert_eq!(cfg.fault_memo_loss, 0.05);
    }

    #[test]
    fn token_budget() {
        let cfg = SystemConfig::from_toml("[budget]\ntokens = 2000\ncost_per_item = 2.0").unwrap();
        assert_eq!(
            cfg.budget,
            BudgetSpec::Tokens { per_window: 2000.0, cost_per_item: 2.0 }
        );
    }

    #[test]
    fn latency_budget() {
        let cfg = SystemConfig::from_toml("[budget]\nlatency_ms = 50").unwrap();
        assert_eq!(cfg.budget, BudgetSpec::LatencyMs(50.0));
    }

    #[test]
    fn target_error_budget() {
        let cfg =
            SystemConfig::from_toml("[budget]\ntarget_relative_error = 0.02").unwrap();
        assert_eq!(
            cfg.budget,
            BudgetSpec::TargetError { relative_bound: 0.02, confidence: 0.95 },
            "target confidence defaults to 95%"
        );
        let cfg = SystemConfig::from_toml(
            "[budget]\ntarget_relative_error = 0.05\ntarget_confidence = 0.99",
        )
        .unwrap();
        assert_eq!(
            cfg.budget,
            BudgetSpec::TargetError { relative_bound: 0.05, confidence: 0.99 }
        );
        // Degenerate targets are config errors, not controller panics.
        assert!(SystemConfig::from_toml("[budget]\ntarget_relative_error = 0.0").is_err());
        assert!(SystemConfig::from_toml(
            "[budget]\ntarget_relative_error = 0.02\ntarget_confidence = 1.0"
        )
        .is_err());
    }

    #[test]
    fn mode_parsing() {
        for (s, m) in [
            ("native", ExecModeSpec::Native),
            ("incremental", ExecModeSpec::IncrementalOnly),
            ("approx", ExecModeSpec::ApproxOnly),
            ("incapprox", ExecModeSpec::IncApprox),
        ] {
            assert_eq!(ExecModeSpec::parse(s).unwrap(), m);
            assert_eq!(ExecModeSpec::parse(s).unwrap().name(), s);
        }
        assert!(ExecModeSpec::parse("bogus").is_err());
    }

    #[test]
    fn incremental_slide_defaults_on_and_parses() {
        assert!(SystemConfig::default().incremental_slide, "O(delta) path must be the default");
        let cfg = SystemConfig::from_toml("[job]\nincremental_slide = false").unwrap();
        assert!(!cfg.incremental_slide);
        let cfg = SystemConfig::from_toml("[job]\nincremental_slide = true").unwrap();
        assert!(cfg.incremental_slide);
        assert!(SystemConfig::from_toml("[job]\nincremental_slide = 3").is_err());
    }

    #[test]
    fn num_workers_and_shard_strategy_roundtrip() {
        let cfg = SystemConfig::from_toml(
            "[job]\nnum_workers = 8\nshard_strategy = \"modulo\"",
        )
        .unwrap();
        assert_eq!(cfg.num_workers, 8);
        assert_eq!(cfg.shard_strategy, ShardStrategy::Modulo);
        // Default strategy is hash; legacy `workers` key still accepted.
        let cfg = SystemConfig::from_toml("[job]\nworkers = 3").unwrap();
        assert_eq!(cfg.num_workers, 3);
        assert_eq!(cfg.shard_strategy, ShardStrategy::Hash);
        assert!(SystemConfig::from_toml("[job]\nshard_strategy = \"bogus\"").is_err());
    }

    #[test]
    fn shard_strategy_parsing() {
        assert_eq!(ShardStrategy::parse("hash").unwrap(), ShardStrategy::Hash);
        assert_eq!(ShardStrategy::parse("modulo").unwrap(), ShardStrategy::Modulo);
        assert_eq!(ShardStrategy::parse("round_robin").unwrap(), ShardStrategy::Modulo);
        assert_eq!(ShardStrategy::Hash.name(), "hash");
        assert_eq!(ShardStrategy::Modulo.name(), "modulo");
        assert!(ShardStrategy::parse("nope").is_err());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        assert!(SystemConfig::from_toml("[window]\nsize = 0").is_err());
        assert!(SystemConfig::from_toml("[window]\nsize = 10\nslide = 11").is_err());
        assert!(SystemConfig::from_toml("[budget]\nfraction = 0").is_err());
        assert!(SystemConfig::from_toml("[budget]\nfraction = 1.5").is_err());
        assert!(SystemConfig::from_toml("[stats]\nconfidence = 1.0").is_err());
        assert!(SystemConfig::from_toml("[job]\nworkers = 0").is_err());
        assert!(SystemConfig::from_toml("[fault]\nmemo_loss = 2.0").is_err());
        assert!(SystemConfig::from_toml("mode = \"bogus\"").is_err());
        assert!(SystemConfig::from_toml("[pipeline]\nlag_watermark_slides = 0").is_err());
        assert!(SystemConfig::from_toml("[pipeline]\ncatchup_factor = 0").is_err());
    }

    #[test]
    fn pipeline_backpressure_knobs_default_and_parse() {
        // PR 2-era hardcoded values are the defaults.
        let cfg = SystemConfig::default();
        assert_eq!(cfg.lag_watermark_slides, 4);
        assert_eq!(cfg.catchup_factor, 4);
        let cfg = SystemConfig::from_toml(
            "[pipeline]\nlag_watermark_slides = 2\ncatchup_factor = 8",
        )
        .unwrap();
        assert_eq!(cfg.lag_watermark_slides, 2);
        assert_eq!(cfg.catchup_factor, 8);
    }

    #[test]
    fn fault_retry_degradation_knobs_default_and_roundtrip() {
        let cfg = SystemConfig::default();
        assert_eq!(cfg.fault_compute, 0.0);
        assert_eq!(cfg.fault_broker, 0.0);
        assert_eq!(cfg.fault_checkpoint_write, 0.0);
        assert_eq!(cfg.retry_max_attempts, 3);
        assert_eq!(cfg.retry_backoff_base_slots, 1);
        assert_eq!(cfg.retry_backoff_cap_slots, 8);
        assert_eq!(cfg.degradation_step_factor, 1.5);
        assert_eq!(cfg.degradation_max_steps, 0, "degradation off by default");
        assert_eq!(cfg.degradation_recover_slides, 2);
        let cfg = SystemConfig::from_toml(
            r#"
            [fault]
            memo_loss = 0.1
            compute = 0.2
            broker = 0.05
            checkpoint_write = 0.01
            [retry]
            max_attempts = 5
            backoff_base_slots = 2
            backoff_cap_slots = 32
            [degradation]
            step_factor = 2.0
            max_steps = 4
            recover_slides = 3
            "#,
        )
        .unwrap();
        assert_eq!(cfg.fault_memo_loss, 0.1);
        assert_eq!(cfg.fault_compute, 0.2);
        assert_eq!(cfg.fault_broker, 0.05);
        assert_eq!(cfg.fault_checkpoint_write, 0.01);
        assert_eq!(cfg.retry_max_attempts, 5);
        assert_eq!(cfg.retry_backoff_base_slots, 2);
        assert_eq!(cfg.retry_backoff_cap_slots, 32);
        assert_eq!(cfg.degradation_step_factor, 2.0);
        assert_eq!(cfg.degradation_max_steps, 4);
        assert_eq!(cfg.degradation_recover_slides, 3);
        // Typed builders reflect the parsed knobs.
        assert_eq!(cfg.fault_spec().compute_p, 0.2);
        assert_eq!(cfg.retry_policy().max_attempts, 5);
        assert_eq!(cfg.degradation_policy().max_steps, 4);
    }

    #[test]
    fn fault_retry_degradation_knobs_reject_bad_values() {
        // Out-of-range probabilities.
        assert!(SystemConfig::from_toml("[fault]\ncompute = 1.5").is_err());
        assert!(SystemConfig::from_toml("[fault]\nbroker = -0.1").is_err());
        assert!(SystemConfig::from_toml("[fault]\ncheckpoint_write = 2").is_err());
        // NaN never reaches a constructor panic.
        assert!(SystemConfig::from_toml("[fault]\ncompute = nan").is_err());
        assert!(SystemConfig::from_toml("[degradation]\nstep_factor = nan").is_err());
        // Retry shape.
        assert!(SystemConfig::from_toml("[retry]\nmax_attempts = 0").is_err());
        assert!(SystemConfig::from_toml("[retry]\nbackoff_base_slots = 0").is_err());
        assert!(SystemConfig::from_toml(
            "[retry]\nbackoff_base_slots = 8\nbackoff_cap_slots = 4"
        )
        .is_err());
        // Degradation shape: factor must widen, recovery needs a streak.
        assert!(SystemConfig::from_toml("[degradation]\nstep_factor = 1.0").is_err());
        assert!(SystemConfig::from_toml("[degradation]\nstep_factor = 0.5").is_err());
        assert!(SystemConfig::from_toml("[degradation]\nrecover_slides = 0").is_err());
        // Everything above surfaces as Error::Config.
        let err = SystemConfig::from_toml("[retry]\nmax_attempts = 0").unwrap_err();
        assert!(matches!(err, Error::Config(_)));
    }

    #[test]
    fn checkpoint_knob_defaults_off_and_parses() {
        assert_eq!(SystemConfig::default().checkpoint_every_slides, 0);
        let cfg =
            SystemConfig::from_toml("[pipeline]\ncheckpoint_every_slides = 3").unwrap();
        assert_eq!(cfg.checkpoint_every_slides, 3);
        assert!(SystemConfig::from_toml("[pipeline]\ncheckpoint_every_slides = -1").is_err());
    }
}
