//! Student-t distribution: CDF and quantiles (t-scores).
//!
//! `t_score(confidence, df)` is the paper's `t_{f, 1−α/2}` of Eq 3.2,
//! computed from the regularized incomplete beta exactly as a
//! t-distribution calculator would (§3.5.2 uses Apache Commons Math; this
//! is the same math). Quantiles are found by monotone bisection on the
//! CDF — 80 iterations gives ~1e-13, far below statistical noise.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::stats::special::inc_beta;

/// Quantile cache: the coordinator requests `t_{f,1−α/2}` every window
/// with a df that drifts by a handful between windows; recomputing the
/// 100-step bisection each time made `beta_cf` ~11% of the whole pipeline
/// profile (EXPERIMENTS.md §Perf L3.2). Keyed by (p bits, df bits) after
/// quantization: df > 100 is rounded to the nearest integer (the quantile
/// changes by < 1e-6 per unit df there), smaller dfs are cached exactly.
/// (BTreeMap, not a hash map: `stats/` sits in the determinism cone and
/// the ordered map keeps even incidental iteration reproducible.)
static QUANTILE_CACHE: OnceLock<Mutex<BTreeMap<(u64, u64), f64>>> = OnceLock::new();

fn quantize_df(df: f64) -> f64 {
    if df > 100.0 {
        df.round()
    } else {
        df
    }
}

/// CDF of the t-distribution with `df` degrees of freedom.
pub fn t_cdf(x: f64, df: f64) -> f64 {
    assert!(df > 0.0, "df must be positive");
    if x == 0.0 {
        return 0.5;
    }
    let ib = inc_beta(df / 2.0, 0.5, df / (df + x * x));
    if x > 0.0 {
        1.0 - 0.5 * ib
    } else {
        0.5 * ib
    }
}

/// Quantile (inverse CDF) of the t-distribution: the `x` with
/// `t_cdf(x, df) = p`, for p ∈ (0, 1). Results are cached (df quantized
/// above 100) — see `QUANTILE_CACHE`.
pub fn t_quantile(p: f64, df: f64) -> Result<f64> {
    if !(0.0 < p && p < 1.0) {
        return Err(Error::Stats(format!("quantile needs p in (0,1), got {p}")));
    }
    if df <= 0.0 {
        return Err(Error::Stats(format!("df must be positive, got {df}")));
    }
    if (p - 0.5).abs() < 1e-16 {
        return Ok(0.0);
    }
    let df = quantize_df(df);
    let key = (p.to_bits(), df.to_bits());
    let cache = QUANTILE_CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    // A poisoned lock only means another thread panicked mid-insert; the
    // cache holds plain f64s, so recover the guard rather than panic.
    if let Some(&hit) =
        cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).get(&key)
    {
        return Ok(hit);
    }
    // Symmetric: solve for the upper tail and mirror.
    let upper = p >= 0.5;
    let p_hi = if upper { p } else { 1.0 - p };
    // Bracket: expand until cdf(hi) > p_hi.
    let mut lo = 0.0;
    let mut hi = 1.0;
    while t_cdf(hi, df) < p_hi {
        hi *= 2.0;
        if hi > 1e12 {
            return Err(Error::Stats(format!("quantile bracket failed: p={p} df={df}")));
        }
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if t_cdf(mid, df) < p_hi {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let x = 0.5 * (lo + hi);
    let signed = if upper { x } else { -x };
    let mut cache = cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    if cache.len() > 65_536 {
        cache.clear(); // unbounded-growth backstop; refills on demand
    }
    cache.insert(key, signed);
    Ok(signed)
}

/// The paper's `t_{f, 1−α/2}`: two-sided t-score for a confidence level
/// (e.g. 0.95) and `df` degrees of freedom.
pub fn t_score(confidence: f64, df: f64) -> Result<f64> {
    if !(0.0 < confidence && confidence < 1.0) {
        return Err(Error::Stats(format!(
            "confidence must be in (0,1), got {confidence}"
        )));
    }
    let alpha = 1.0 - confidence;
    t_quantile(1.0 - alpha / 2.0, df)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn cdf_fixed_points() {
        close(t_cdf(0.0, 5.0), 0.5, 1e-15);
        // With df=1 (Cauchy), cdf(1) = 0.75.
        close(t_cdf(1.0, 1.0), 0.75, 1e-12);
        // scipy.stats.t.cdf fixtures.
        close(t_cdf(2.0, 10.0), 0.9633059826146299, 1e-10);
        close(t_cdf(-1.5, 7.0), 0.088649243494985, 1e-10);
        close(t_cdf(3.0, 30.0), 0.9973050179671741, 1e-10);
    }

    #[test]
    fn quantile_inverts_cdf() {
        for &df in &[1.0, 2.0, 5.0, 10.0, 30.0, 120.0] {
            for &p in &[0.01, 0.1, 0.5, 0.9, 0.975, 0.999] {
                let x = t_quantile(p, df).unwrap();
                close(t_cdf(x, df), p, 1e-10);
            }
        }
    }

    #[test]
    fn classic_t_table_values() {
        // Standard two-sided 95% t-table column (α/2 = 0.025).
        let table = [
            (1.0, 12.7062047364),
            (2.0, 4.3026527297),
            (5.0, 2.5705818356),
            (10.0, 2.2281388520),
            (30.0, 2.0422724563),
            (100.0, 1.9839715185),
        ];
        for (df, want) in table {
            close(t_score(0.95, df).unwrap(), want, 1e-8);
        }
        // 99% and 90% for df = 10.
        close(t_score(0.99, 10.0).unwrap(), 3.1692726669, 1e-8);
        close(t_score(0.90, 10.0).unwrap(), 1.8124611228, 1e-8);
    }

    #[test]
    fn approaches_normal_for_large_df() {
        // z_{0.975} = 1.959963985.
        let t = t_score(0.95, 100_000.0).unwrap();
        close(t, 1.959963985, 1e-4);
    }

    #[test]
    fn symmetry() {
        let x = t_quantile(0.2, 7.0).unwrap();
        let y = t_quantile(0.8, 7.0).unwrap();
        close(x, -y, 1e-10);
    }

    #[test]
    fn domain_errors() {
        assert!(t_quantile(0.0, 5.0).is_err());
        assert!(t_quantile(1.0, 5.0).is_err());
        assert!(t_quantile(0.5, 0.0).is_err());
        assert!(t_score(1.0, 5.0).is_err());
        assert!(t_score(0.0, 5.0).is_err());
    }
}
