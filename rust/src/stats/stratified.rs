//! Stratified estimators and error bounds — the paper's Eqs 3.2–3.4.
//!
//! Given per-stratum sample aggregates (bᵢ, Σv, Σv²) and window
//! populations Bᵢ, produce the estimated total τ̂ (or mean), its
//! estimated variance with finite-population correction, the degrees of
//! freedom `f = Σbᵢ − n`, and the confidence interval
//! `output ± t_{f,1−α/2} · √V̂ar` (§3.5.2).

use crate::error::{Error, Result};
use crate::job::moments::Moments;
use crate::stats::tdist::t_score;

/// Per-stratum inputs to the estimator.
#[derive(Debug, Clone, Copy)]
pub struct StratumAgg {
    /// Sample size bᵢ.
    pub b: f64,
    /// Σ of sampled values.
    pub sum: f64,
    /// Σ of squared sampled values.
    pub sumsq: f64,
    /// Window population Bᵢ (items seen in the stratum).
    pub population: f64,
}

impl StratumAgg {
    /// From a combined [`Moments`] plus the stratum population.
    pub fn from_moments(m: &Moments, population: f64) -> Self {
        StratumAgg { b: m.count, sum: m.sum, sumsq: m.sumsq, population }
    }

    /// Unbiased sample variance s²ᵢ.
    pub fn sample_variance(&self) -> f64 {
        if self.b < 2.0 {
            return 0.0;
        }
        ((self.sumsq - self.sum * self.sum / self.b) / (self.b - 1.0)).max(0.0)
    }
}

/// An approximate output with its confidence interval.
#[derive(Debug, Clone, Copy)]
pub struct Estimate {
    /// The point estimate (τ̂ for sums, μ̂ for means).
    pub value: f64,
    /// Margin of error ε: the interval is `value ± margin`.
    pub margin: f64,
    /// Estimated variance of the point estimate (Eq 3.4).
    pub variance: f64,
    /// Degrees of freedom `f = Σbᵢ − n` (Eq 3.3).
    pub df: f64,
    /// The t-score used.
    pub t: f64,
    /// The confidence level requested.
    pub confidence: f64,
}

impl Estimate {
    /// Lower bound of the interval.
    pub fn lo(&self) -> f64 {
        self.value - self.margin
    }

    /// Upper bound of the interval.
    pub fn hi(&self) -> f64 {
        self.value + self.margin
    }

    /// Relative error (margin / |value|); infinite for value = 0.
    pub fn relative_error(&self) -> f64 {
        if self.value == 0.0 {
            if self.margin == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            self.margin / self.value.abs()
        }
    }
}

/// Estimate the population **total** τ (Eq 3.4 variance, Eq 3.2 bound).
///
/// Strata with bᵢ = 0 are skipped (their population was unobserved this
/// window — the sampler guarantees this only happens for empty strata).
/// When `f < 1` (every observed stratum has one sample), the most
/// conservative df = 1 is used rather than failing the window.
pub fn estimate_sum(strata: &[StratumAgg], confidence: f64) -> Result<Estimate> {
    if !(0.0 < confidence && confidence < 1.0) {
        return Err(Error::Stats(format!("confidence must be in (0,1), got {confidence}")));
    }
    let mut tau = 0.0;
    let mut var = 0.0;
    let mut sample_total = 0.0;
    let mut observed = 0usize;
    for s in strata {
        if s.b <= 0.0 {
            continue;
        }
        if s.population < s.b - 1e-9 {
            return Err(Error::Stats(format!(
                "population {} smaller than sample {}",
                s.population, s.b
            )));
        }
        observed += 1;
        sample_total += s.b;
        tau += s.population / s.b * s.sum;
        // FPC: a fully enumerated stratum (b = B) contributes no variance.
        var += s.population * (s.population - s.b) * s.sample_variance() / s.b;
    }
    var = var.max(0.0);
    let df_raw = sample_total - observed as f64; // Eq 3.3
    let df = df_raw.max(1.0);
    let t = t_score(confidence, df)?;
    Ok(Estimate { value: tau, margin: t * var.sqrt(), variance: var, df: df_raw, t, confidence })
}

/// Solve Eq 3.2 **backwards**: the total sample size `n` (under the
/// sampler's proportional allocation, Eq 3.1) whose margin
/// `t·√V̂ar(n)` stays within `target_margin`, finite-population-corrected.
///
/// Per stratum the classic backsolve is `nᵢ ≈ (t·sᵢ/εᵢ)²`; aggregating
/// it under proportional allocation `bᵢ = n·Bᵢ/N` gives
/// `V̂ar(n) = (N/n)·A − A` with `A = Σ Bᵢ·s²ᵢ`, so the requirement
/// `t²·V̂ar(n) ≤ ε²` solves to
///
/// ```text
/// n ≥ t²·N·A / (ε² + t²·A)
/// ```
///
/// — the FPC form (without correction it would be the larger
/// `n₀ = t²·N·A/ε²`; the returned value is `n₀/(1 + n₀/N)`). As
/// `ε → 0` the requirement approaches the census `n = N`, never exceeds
/// it. Returns `None` when no sampling is needed at all: zero observed
/// variance (`A = 0` — every margin is already 0) or a degenerate
/// target/t. Strata with `bᵢ < 2` contribute `s²ᵢ = 0` (no variance
/// estimate yet), so early windows under-ask and the caller's smoothing
/// ramps in the truth.
pub fn required_sample_size(
    strata: &[StratumAgg],
    target_margin: f64,
    t: f64,
) -> Option<f64> {
    if !(target_margin > 0.0) || !(t > 0.0) {
        return None;
    }
    let mut a = 0.0f64; // A = Σ Bᵢ·s²ᵢ
    let mut n_pop = 0.0f64; // N = Σ Bᵢ over observed strata
    for s in strata {
        if s.b <= 0.0 {
            continue;
        }
        n_pop += s.population;
        a += s.population * s.sample_variance();
    }
    if !(a > 0.0) || !(n_pop > 0.0) {
        return None;
    }
    let eps2 = target_margin * target_margin;
    let t2a = t * t * a;
    Some((t2a * n_pop / (eps2 + t2a)).min(n_pop))
}

/// Estimate the population **mean** μ = τ / ΣBᵢ.
pub fn estimate_mean(strata: &[StratumAgg], confidence: f64) -> Result<Estimate> {
    let total_pop: f64 = strata.iter().filter(|s| s.b > 0.0).map(|s| s.population).sum();
    let sum_est = estimate_sum(strata, confidence)?;
    if total_pop <= 0.0 {
        return Ok(Estimate { value: 0.0, margin: 0.0, variance: 0.0, ..sum_est });
    }
    Ok(Estimate {
        value: sum_est.value / total_pop,
        margin: sum_est.margin / total_pop,
        variance: sum_est.variance / (total_pop * total_pop),
        ..sum_est
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn agg(b: f64, sum: f64, sumsq: f64, pop: f64) -> StratumAgg {
        StratumAgg { b, sum, sumsq, population: pop }
    }

    #[test]
    fn census_has_zero_margin() {
        // Sampling the whole stratum: FPC zeroes the variance.
        let s = [agg(10.0, 55.0, 385.0, 10.0)];
        let e = estimate_sum(&s, 0.95).unwrap();
        assert_eq!(e.value, 55.0);
        assert_eq!(e.variance, 0.0);
        assert_eq!(e.margin, 0.0);
        assert_eq!(e.lo(), e.hi());
    }

    #[test]
    fn textbook_stratified_example() {
        // Lohr-style example, hand-computed:
        // Stratum 1: B=100, b=4, values {2,4,6,8}: sum=20, sumsq=120, s²=20/3.
        // Stratum 2: B=200, b=4, values {10,10,20,20}: sum=60, sumsq=1000, s²≈33.333.
        let s = [agg(4.0, 20.0, 120.0, 100.0), agg(4.0, 60.0, 1000.0, 200.0)];
        let e = estimate_sum(&s, 0.95).unwrap();
        // τ̂ = 100/4·20 + 200/4·60 = 500 + 3000 = 3500.
        assert!((e.value - 3500.0).abs() < 1e-9);
        // Var = 100·96·(20/3)/4 + 200·196·33.3333/4 = 16000 + 326666.67.
        assert!((e.variance - (16_000.0 + 980_000.0 / 3.0)).abs() < 1e-6);
        // df = 8 − 2 = 6 → t ≈ 2.4469.
        assert!((e.df - 6.0).abs() < 1e-12);
        assert!((e.t - 2.446911851).abs() < 1e-6);
        assert!((e.margin - e.t * e.variance.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn empty_strata_are_skipped() {
        let s = [agg(0.0, 0.0, 0.0, 50.0), agg(5.0, 25.0, 135.0, 10.0)];
        let e = estimate_sum(&s, 0.95).unwrap();
        assert!((e.value - 50.0).abs() < 1e-12);
        // df counts only observed strata: 5 − 1 = 4.
        assert_eq!(e.df, 4.0);
    }

    #[test]
    fn single_sample_per_stratum_falls_back_conservatively() {
        let s = [agg(1.0, 5.0, 25.0, 10.0), agg(1.0, 7.0, 49.0, 10.0)];
        let e = estimate_sum(&s, 0.95).unwrap();
        // df_raw = 2 − 2 = 0; t computed at df = 1 (Cauchy, widest).
        assert_eq!(e.df, 0.0);
        assert!((e.t - 12.7062047364).abs() < 1e-6);
    }

    #[test]
    fn mean_is_total_over_population() {
        let s = [agg(4.0, 20.0, 120.0, 100.0), agg(4.0, 60.0, 1000.0, 200.0)];
        let total = estimate_sum(&s, 0.95).unwrap();
        let mean = estimate_mean(&s, 0.95).unwrap();
        assert!((mean.value - total.value / 300.0).abs() < 1e-12);
        assert!((mean.margin - total.margin / 300.0).abs() < 1e-12);
    }

    #[test]
    fn population_smaller_than_sample_rejected() {
        let s = [agg(10.0, 10.0, 10.0, 5.0)];
        assert!(estimate_sum(&s, 0.95).is_err());
        assert!(estimate_sum(&[agg(1.0, 1.0, 1.0, 1.0)], 2.0).is_err());
    }

    #[test]
    fn coverage_monte_carlo() {
        // The defining property of a 95% interval: ~95% of intervals
        // contain the true total. 3 strata, 400 trials.
        let mut rng = Rng::new(99);
        let pops = [400usize, 600, 1000];
        let means = [5.0, 10.0, 20.0];
        let mut populations: Vec<Vec<f64>> = Vec::new();
        for (i, &n) in pops.iter().enumerate() {
            populations.push((0..n).map(|_| rng.normal_with(means[i], 3.0)).collect());
        }
        let true_total: f64 = populations.iter().flatten().sum();
        let mut covered = 0;
        let trials = 400;
        for _ in 0..trials {
            let mut aggs = Vec::new();
            for pop in &populations {
                let b = pop.len() / 10;
                let idx = rng.sample_indices(pop.len(), b);
                let vals: Vec<f64> = idx.iter().map(|&i| pop[i]).collect();
                let m = Moments::from_values(&vals);
                aggs.push(StratumAgg::from_moments(&m, pop.len() as f64));
            }
            let e = estimate_sum(&aggs, 0.95).unwrap();
            if e.lo() <= true_total && true_total <= e.hi() {
                covered += 1;
            }
        }
        let rate = covered as f64 / trials as f64;
        assert!((0.90..=0.99).contains(&rate), "coverage {rate}");
    }

    #[test]
    fn margin_shrinks_with_sample_size() {
        let mut rng = Rng::new(7);
        let pop: Vec<f64> = (0..10_000).map(|_| rng.normal_with(10.0, 4.0)).collect();
        let margin_at = |b: usize, rng: &mut Rng| {
            let idx = rng.sample_indices(pop.len(), b);
            let vals: Vec<f64> = idx.iter().map(|&i| pop[i]).collect();
            let m = Moments::from_values(&vals);
            estimate_sum(&[StratumAgg::from_moments(&m, pop.len() as f64)], 0.95)
                .unwrap()
                .margin
        };
        let m_small = margin_at(100, &mut rng);
        let m_big = margin_at(4000, &mut rng);
        assert!(m_big < m_small * 0.4, "margins {m_small} -> {m_big}");
    }

    #[test]
    fn required_sample_size_inverts_the_margin() {
        // Forward-check the backsolve: sample a population at the size the
        // formula demands and the achieved margin must be ≈ the target.
        let mut rng = Rng::new(17);
        let pop: Vec<f64> = (0..20_000).map(|_| rng.normal_with(50.0, 8.0)).collect();
        let probe = |b: usize, rng: &mut Rng| -> (StratumAgg, Estimate) {
            let idx = rng.sample_indices(pop.len(), b);
            let vals: Vec<f64> = idx.iter().map(|&i| pop[i]).collect();
            let m = Moments::from_values(&vals);
            let agg = StratumAgg::from_moments(&m, pop.len() as f64);
            let e = estimate_sum(&[agg], 0.95).unwrap();
            (agg, e)
        };
        // Pilot at 500 samples, then ask for half the pilot's margin.
        let (agg, pilot) = probe(500, &mut rng);
        let target = pilot.margin / 2.0;
        let n = required_sample_size(&[agg], target, pilot.t).unwrap();
        assert!(n > 500.0, "halving the margin must cost more samples");
        let (_, achieved) = probe(n.ceil() as usize, &mut rng);
        assert!(
            achieved.margin <= target * 1.2,
            "achieved {} vs target {target}",
            achieved.margin
        );
        assert!(
            achieved.margin >= target * 0.7,
            "gross over-sampling: achieved {} vs target {target}",
            achieved.margin
        );
    }

    #[test]
    fn required_sample_size_fpc_and_degenerate_cases() {
        let s = [agg(100.0, 5000.0, 256_400.0, 10_000.0)];
        // Tighter targets ask for more, and a vanishing target approaches
        // the census instead of diverging past the population.
        let loose = required_sample_size(&s, 500.0, 1.96).unwrap();
        let tight = required_sample_size(&s, 50.0, 1.96).unwrap();
        let census = required_sample_size(&s, 1e-9, 1.96).unwrap();
        assert!(loose < tight, "{loose} !< {tight}");
        assert!(tight < census);
        assert!((census - 10_000.0).abs() < 1.0, "ε→0 must clamp at N, got {census}");
        // Zero variance, empty strata, or degenerate targets: no demand.
        assert!(required_sample_size(&[agg(10.0, 50.0, 250.0, 100.0)], 1.0, 1.96).is_none());
        assert!(required_sample_size(&s, 0.0, 1.96).is_none());
        assert!(required_sample_size(&s, f64::NAN, 1.96).is_none());
        assert!(required_sample_size(&s, 10.0, 0.0).is_none());
        assert!(required_sample_size(&[], 10.0, 1.96).is_none());
    }

    #[test]
    fn relative_error_sane() {
        let s = [agg(4.0, 20.0, 120.0, 100.0)];
        let e = estimate_sum(&s, 0.95).unwrap();
        assert!((e.relative_error() - e.margin / e.value).abs() < 1e-15);
    }
}
