//! Statistics: error estimation for the approximate output (§3.5).
//!
//! * [`special`] — ln-gamma and the regularized incomplete beta function
//!   (the Apache-Commons-Math role, built from scratch).
//! * [`tdist`] — Student-t CDF and inverse CDF (t-scores).
//! * [`stratified`] — Eqs 3.2–3.4: the stratified total/mean estimators,
//!   their estimated variance with finite-population correction, degrees
//!   of freedom, and the `output ± error bound` confidence interval.

pub mod special;
pub mod stratified;
pub mod tdist;

pub use stratified::{estimate_mean, estimate_sum, Estimate, StratumAgg};
pub use tdist::{t_cdf, t_quantile, t_score};
