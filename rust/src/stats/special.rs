//! Special functions: ln-gamma and the regularized incomplete beta.
//!
//! Implementations follow the classic Lanczos (g = 7) approximation and
//! the Numerical-Recipes continued fraction (modified Lentz), accurate to
//! ~1e-12 over the parameter ranges the t-distribution needs. Validated
//! against scipy-generated fixtures in the tests.

/// Lanczos coefficients, g = 7, n = 9.
const LANCZOS: [f64; 9] = [
    0.999_999_999_999_809_93,
    676.520_368_121_885_1,
    -1_259.139_216_722_402_8,
    771.323_428_777_653_13,
    -176.615_029_162_140_59,
    12.507_343_278_686_905,
    -0.138_571_095_265_720_12,
    9.984_369_578_019_571_6e-6,
    1.505_632_735_149_311_6e-7,
];

/// Natural log of the gamma function for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma needs x > 0, got {x}");
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1−x) = π / sin(πx).
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// Continued fraction for the incomplete beta (NR `betacf`, modified
/// Lentz method).
fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-16;
    const FPMIN: f64 = 1e-300;

    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // Even step.
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularized incomplete beta function I_x(a, b) for a, b > 0 and
/// x ∈ [0, 1].
pub fn inc_beta(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "inc_beta needs a, b > 0");
    assert!((0.0..=1.0).contains(&x), "inc_beta needs x in [0,1], got {x}");
    if x == 0.0 {
        return 0.0;
    }
    if x == 1.0 {
        return 1.0;
    }
    let ln_front =
        ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - front * beta_cf(b, a, 1.0 - x) / b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol * b.abs().max(1.0), "{a} vs {b}");
    }

    #[test]
    fn ln_gamma_integer_factorials() {
        // Γ(n) = (n−1)!
        let facts = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0, 5040.0];
        for (n, &f) in facts.iter().enumerate() {
            close(ln_gamma((n + 1) as f64), (f as f64).ln(), 1e-12);
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Γ(1/2) = √π; Γ(3/2) = √π/2.
        close(ln_gamma(0.5), std::f64::consts::PI.sqrt().ln(), 1e-12);
        close(ln_gamma(1.5), (std::f64::consts::PI.sqrt() / 2.0).ln(), 1e-12);
    }

    #[test]
    fn ln_gamma_scipy_fixtures() {
        // scipy.special.gammaln values.
        close(ln_gamma(10.3), 13.482036786138359, 1e-12);
        close(ln_gamma(0.1), 2.252712651734206, 1e-12);
        close(ln_gamma(123.456), 469.6055471299295, 1e-12);
    }

    #[test]
    fn inc_beta_closed_forms() {
        // I_x(1,1) = x.
        for &x in &[0.0, 0.25, 0.5, 0.75, 1.0] {
            close(inc_beta(1.0, 1.0, x), x, 1e-12);
        }
        // I_x(a,1) = x^a.
        close(inc_beta(3.0, 1.0, 0.4), 0.4f64.powi(3), 1e-12);
        // I_x(1,b) = 1 − (1−x)^b.
        close(inc_beta(1.0, 4.0, 0.3), 1.0 - 0.7f64.powi(4), 1e-12);
        // Symmetry point: I_0.5(a,a) = 0.5.
        close(inc_beta(0.5, 0.5, 0.5), 0.5, 1e-12);
        close(inc_beta(7.0, 7.0, 0.5), 0.5, 1e-12);
    }

    #[test]
    fn inc_beta_scipy_fixtures() {
        // scipy.special.betainc values.
        close(inc_beta(2.0, 3.0, 0.4), 0.5248, 1e-10);
        close(inc_beta(5.0, 2.0, 0.8), 0.65536, 1e-10);
        close(inc_beta(0.5, 0.5, 0.3), 0.36901011956554536, 1e-10);
        close(inc_beta(10.0, 10.0, 0.6), 0.8139079785845882, 1e-9);
    }

    #[test]
    fn inc_beta_monotone_in_x() {
        let mut prev = 0.0;
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            let v = inc_beta(3.5, 2.25, x);
            assert!(v >= prev - 1e-14);
            prev = v;
        }
        assert!((prev - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "x in [0,1]")]
    fn inc_beta_domain_checked() {
        inc_beta(1.0, 1.0, 1.5);
    }
}
