//! Conventional reservoir sampling (CRS) — Algorithm 3's `CRS` subroutine.
//!
//! Algorithm R over a stream of unknown length: keep a fixed-capacity
//! uniform random sample without replacement. Each arriving item is
//! accepted with probability `capacity / seen` and, if accepted, replaces
//! a uniformly random resident.

use crate::util::rng::Rng;
use crate::workload::record::Record;

/// A fixed-capacity uniform reservoir over one stratum's sub-stream.
#[derive(Debug, Clone)]
pub struct Reservoir {
    items: Vec<Record>,
    capacity: usize,
    /// Items of this stratum seen so far (|S_i| in the paper).
    seen: u64,
}

impl Reservoir {
    /// Empty reservoir with the given capacity.
    pub fn new(capacity: usize) -> Self {
        Reservoir { items: Vec::with_capacity(capacity), capacity, seen: 0 }
    }

    /// Offer one item (counts toward `seen`); fills until capacity, then
    /// does probabilistic replacement. Returns true if retained.
    pub fn offer(&mut self, item: Record, rng: &mut Rng) -> bool {
        self.seen += 1;
        if self.items.len() < self.capacity {
            self.items.push(item);
            return true;
        }
        if self.capacity == 0 {
            return false;
        }
        // Inclusion probability |sample[i]| / |S_i|.
        let p = self.capacity as f64 / self.seen as f64;
        if rng.bernoulli(p) {
            let victim = rng.below(self.items.len());
            self.items[victim] = item;
            true
        } else {
            false
        }
    }

    /// Insert unconditionally (the ARS grow path — Algorithm 3's
    /// `sample[i].add(incomingItems.get(j))`), raising capacity if needed.
    pub fn force_insert(&mut self, item: Record) {
        self.seen += 1;
        if self.items.len() >= self.capacity {
            self.capacity = self.items.len() + 1;
        }
        self.items.push(item);
    }

    /// Evict `c` uniformly random residents (the ARS shrink path) and
    /// lower capacity accordingly. Returns the evicted items.
    pub fn evict_random(&mut self, c: usize, rng: &mut Rng) -> Vec<Record> {
        let c = c.min(self.items.len());
        let mut evicted = Vec::with_capacity(c);
        for _ in 0..c {
            let victim = rng.below(self.items.len());
            evicted.push(self.items.swap_remove(victim));
        }
        self.capacity = self.capacity.saturating_sub(c);
        evicted
    }

    /// Change capacity without touching residents (grow) — residents above
    /// a *smaller* capacity must be evicted by the caller via
    /// [`Reservoir::evict_random`] so the eviction is random, not biased.
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity;
    }

    /// Current sample (unordered).
    pub fn items(&self) -> &[Record] {
        &self.items
    }

    /// Number of retained items.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when nothing retained.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Stream length observed so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64) -> Record {
        Record::new(id, 0, 0, 0, id as f64)
    }

    #[test]
    fn fills_to_capacity_first() {
        let mut r = Reservoir::new(5);
        let mut rng = Rng::new(1);
        for i in 0..5 {
            assert!(r.offer(rec(i), &mut rng));
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.seen(), 5);
    }

    #[test]
    fn never_exceeds_capacity() {
        let mut r = Reservoir::new(10);
        let mut rng = Rng::new(2);
        for i in 0..1000 {
            r.offer(rec(i), &mut rng);
        }
        assert_eq!(r.len(), 10);
        assert_eq!(r.seen(), 1000);
    }

    #[test]
    fn uniform_inclusion_probability() {
        // Every item of a length-n stream should appear with p = k/n.
        let (k, n, trials) = (10usize, 100u64, 3000usize);
        let mut counts = vec![0u32; n as usize];
        let mut rng = Rng::new(3);
        for _ in 0..trials {
            let mut r = Reservoir::new(k);
            for i in 0..n {
                r.offer(rec(i), &mut rng);
            }
            for item in r.items() {
                counts[item.id as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64; // 300
        for (id, &c) in counts.iter().enumerate() {
            let z = (c as f64 - expect) / (expect * (1.0 - k as f64 / n as f64)).sqrt();
            assert!(z.abs() < 5.0, "item {id}: count {c}, z={z}");
        }
    }

    #[test]
    fn zero_capacity_rejects_all() {
        let mut r = Reservoir::new(0);
        let mut rng = Rng::new(4);
        assert!(!r.offer(rec(1), &mut rng));
        assert_eq!(r.len(), 0);
    }

    #[test]
    fn evict_random_shrinks() {
        let mut r = Reservoir::new(10);
        let mut rng = Rng::new(5);
        for i in 0..10 {
            r.offer(rec(i), &mut rng);
        }
        let evicted = r.evict_random(4, &mut rng);
        assert_eq!(evicted.len(), 4);
        assert_eq!(r.len(), 6);
        assert_eq!(r.capacity(), 6);
        // Evicting more than resident clamps.
        let evicted = r.evict_random(100, &mut rng);
        assert_eq!(evicted.len(), 6);
        assert!(r.is_empty());
    }

    #[test]
    fn force_insert_grows() {
        let mut r = Reservoir::new(2);
        let mut rng = Rng::new(6);
        for i in 0..2 {
            r.offer(rec(i), &mut rng);
        }
        r.force_insert(rec(99));
        assert_eq!(r.len(), 3);
        assert!(r.capacity() >= 3);
    }
}
