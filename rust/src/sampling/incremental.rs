//! Persistent cross-slide stratified sampling — Algorithm 2 as
//! self-adjusting state.
//!
//! [`StratifiedSampler`](crate::sampling::stratified::StratifiedSampler)
//! is a one-shot streaming sampler: every window re-offers every item, so
//! each slide costs O(window) no matter how small the input change was.
//! This module keeps the sample alive *between* windows instead:
//!
//! * every item gets a deterministic pseudo-random **rank** — a keyed
//!   64-bit avalanche of its id ([`mix64`]) — fixed for the sampler's
//!   lifetime;
//! * each stratum keeps its current-window items ordered by rank;
//! * the per-stratum sample is the `cap_i` lowest-ranked residents, where
//!   `cap_i` is Eq 3.1's proportional allocation
//!   ([`allocate_proportional`]), recomputed from the exact per-stratum
//!   populations in O(strata · log strata) per window — which subsumes
//!   the legacy sampler's `T`-interval re-allocation (the interval
//!   governed when rates were *re-estimated*; here the populations are
//!   exact at every slide, so the allocation can never drift).
//!
//! Sliding is then O(|delta| · log window): remove the evicted items,
//! insert the arrived ones ([`IncrementalSampler::apply_delta`]). Within
//! a stratum, the `cap_i` lowest ranks of independently-ranked items are
//! a uniform random subset without replacement (bottom-k sampling), so
//! the §3.5 stratified error estimator applies unchanged.
//!
//! Because the sample is a pure function of *(window contents, seed)*,
//! the incremental path and the from-scratch path
//! ([`IncrementalSampler::rebuild`]) yield **identical** samples — the
//! coordinator's serial/sharded/incremental equivalence tests and
//! `prop_incremental_sampler_matches_from_scratch` pin this, and it is
//! what lets the O(delta) slide path keep `WindowReport`s byte-identical
//! to the O(window) baseline.
//!
//! The same purity is the checkpoint contract: [`crate::checkpoint`]
//! never serializes the sampler. Restore calls
//! [`IncrementalSampler::rebuild`] on the restored window contents under
//! the same seed and gets back the exact ranked state the crashed run
//! held — one less subsystem whose drift could break the byte-identical
//! restore-equivalence gate (the replay cost is surfaced in
//! [`SlideWork::restore_items`](crate::metrics::SlideWork)).

use std::collections::BTreeMap;

use crate::columnar::ColumnarBatch;
use crate::sampling::stratified::{allocate_proportional, StratifiedSample};
use crate::util::hash::mix64;
use crate::window::WindowDelta;
use crate::workload::record::{Record, StratumId};

/// Deterministic rank of an item under a sampler seed — the retained
/// per-item reference for [`rank_batch`] (the kernel equivalence gate
/// in `tests/columnar_kernels.rs` pins them bit-equal).
#[inline]
pub fn rank(seed: u64, id: u64) -> u64 {
    mix64(seed ^ mix64(id))
}

/// Batched rank kernel: score a dense id column in one pass. `out` is
/// cleared and refilled (callers reuse the scratch across deltas). Pure
/// integer mixing with no cross-element dependency, so the loop
/// auto-vectorizes — this is how `apply_delta` scores a whole delta
/// instead of ranking record by record.
#[inline]
pub fn rank_batch(seed: u64, ids: &[u64], out: &mut Vec<u64>) {
    out.clear();
    out.extend(ids.iter().map(|&id| rank(seed, id)));
}

/// One stratum's current-window items, ordered by (rank, id).
#[derive(Debug, Clone, Default)]
struct RankedStratum {
    by_rank: BTreeMap<(u64, u64), Record>,
}

/// A stratified sampler whose state persists across window slides.
///
/// # Example
///
/// A slide updates the sample in O(delta), and matches a from-scratch
/// rebuild exactly:
///
/// ```
/// use incapprox::sampling::incremental::IncrementalSampler;
/// use incapprox::window::CountWindow;
/// use incapprox::workload::record::Record;
///
/// let mut window = CountWindow::new(1000);
/// let mut sampler = IncrementalSampler::new(7);
///
/// // Warm window: 1000 records over strata 0/1/2, then one slide of 100.
/// let rec = |i: u64| Record::new(i, (i % 3) as u32, i, 0, i as f64);
/// let snap = window.slide((0..1000).map(rec).collect());
/// sampler.apply_delta(&snap.delta);
/// let snap = window.slide((1000..1100).map(rec).collect());
/// let touched = sampler.apply_delta(&snap.delta);
/// assert_eq!(touched, 200); // 100 inserted + 100 evicted, not 1000
///
/// let sample = sampler.sample(100);
/// assert_eq!(sample.total_len(), 100);
///
/// // From-scratch over the same window contents: identical sample.
/// let mut scratch = IncrementalSampler::new(7);
/// scratch.rebuild(snap.items());
/// assert_eq!(format!("{:?}", scratch.sample(100)), format!("{sample:?}"));
/// ```
#[derive(Debug, Clone)]
pub struct IncrementalSampler {
    seed: u64,
    strata: BTreeMap<StratumId, RankedStratum>,
    total: u64,
}

impl IncrementalSampler {
    /// Empty sampler; `seed` keys the item ranks (same seed + same window
    /// contents → same sample, regardless of the slide path taken).
    pub fn new(seed: u64) -> Self {
        IncrementalSampler { seed, strata: BTreeMap::new(), total: 0 }
    }

    /// Items currently tracked (the window population).
    pub fn len(&self) -> usize {
        self.total as usize
    }

    /// True when no items are tracked.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Number of strata currently present.
    pub fn strata_len(&self) -> usize {
        self.strata.len()
    }

    fn insert_ranked(&mut self, rk: u64, r: Record) {
        let key = (rk, r.id);
        let slot = self.strata.entry(r.stratum).or_default();
        let replaced = slot.by_rank.insert(key, r);
        // Ids are globally unique within a window (the `Record::id`
        // contract); a duplicate would silently desynchronize the
        // incremental path from the rebuild path, so make it loud.
        debug_assert!(replaced.is_none(), "duplicate record id {} in window", r.id);
        if replaced.is_none() {
            self.total += 1;
        }
    }

    fn remove_ranked(&mut self, rk: u64, stratum: StratumId, id: u64) {
        let key = (rk, id);
        let mut emptied = false;
        if let Some(slot) = self.strata.get_mut(&stratum) {
            if slot.by_rank.remove(&key).is_some() {
                self.total -= 1;
                emptied = slot.by_rank.is_empty();
            }
        }
        if emptied {
            self.strata.remove(&stratum);
        }
    }

    /// Apply one window slide's change set: insert the arrived items,
    /// remove the evicted ones — O(|delta| · log window). Insertions are
    /// applied first so a batch that flows straight through an oversized
    /// slide (inserted *and* removed in the same delta) nets out.
    /// Returns the number of items touched (the O(delta) work metric).
    pub fn apply_delta(&mut self, delta: &WindowDelta) -> usize {
        let mut ranks = Vec::new();
        let ins = delta.inserted();
        rank_batch(self.seed, ins.ids(), &mut ranks);
        for (i, &rk) in ranks.iter().enumerate() {
            self.insert_ranked(rk, ins.get(i));
        }
        let rem = delta.removed();
        rank_batch(self.seed, rem.ids(), &mut ranks);
        for (i, &rk) in ranks.iter().enumerate() {
            self.remove_ranked(rk, rem.strata()[i], rem.ids()[i]);
        }
        delta.len()
    }

    /// Drop all state and re-index the full window — the O(window)
    /// from-scratch reference path. Returns the number of items touched.
    pub fn rebuild(&mut self, items: &[Record]) -> usize {
        self.strata.clear();
        self.total = 0;
        let ids: Vec<u64> = items.iter().map(|r| r.id).collect();
        let mut ranks = Vec::new();
        rank_batch(self.seed, &ids, &mut ranks);
        for (i, &rk) in ranks.iter().enumerate() {
            self.insert_ranked(rk, items[i]);
        }
        items.len()
    }

    /// [`IncrementalSampler::rebuild`] from a columnar window view: the
    /// rank kernel scores the dense id column directly, with no id
    /// gather. Same resulting state, bit for bit.
    pub fn rebuild_columns(&mut self, cols: &ColumnarBatch) -> usize {
        self.strata.clear();
        self.total = 0;
        let mut ranks = Vec::new();
        rank_batch(self.seed, cols.ids(), &mut ranks);
        for (i, &rk) in ranks.iter().enumerate() {
            self.insert_ranked(rk, cols.get(i));
        }
        cols.len()
    }

    /// Exact per-stratum populations of the tracked window.
    pub fn populations(&self) -> BTreeMap<StratumId, u64> {
        self.strata.iter().map(|(&s, st)| (s, st.by_rank.len() as u64)).collect()
    }

    /// Emit the stratified sample for a total budget of `sample_size`
    /// slots: Eq 3.1 proportional capacities over the exact populations,
    /// then each stratum's `cap_i` lowest-ranked residents, in rank order.
    /// O(sample + strata · log strata); the window is never rescanned.
    pub fn sample(&self, sample_size: usize) -> StratifiedSample {
        let caps = allocate_proportional(sample_size, &self.populations());
        self.sample_allocated(&caps)
    }

    /// Emit the sample under an **externally computed** per-stratum
    /// allocation. This is [`IncrementalSampler::sample`] with the
    /// Eq 3.1 step factored out: the partition merge tier computes one
    /// global allocation over the *merged* populations and hands every
    /// partition its slice, so K disjoint samplers reproduce exactly the
    /// per-stratum capacities a single sampler over the union would
    /// have picked. Strata absent from `caps` contribute zero items;
    /// caps for strata this sampler does not track are ignored.
    pub fn sample_allocated(
        &self,
        caps: &BTreeMap<StratumId, usize>,
    ) -> StratifiedSample {
        let mut out = StratifiedSample::default();
        for (&stratum, st) in &self.strata {
            let cap = caps.get(&stratum).copied().unwrap_or(0);
            let items: Vec<Record> =
                st.by_rank.values().take(cap).copied().collect();
            out.per_stratum.insert(stratum, items);
        }
        out.population = self.populations();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::window::CountWindow;
    use crate::workload::gen::MultiStream;

    fn window_records(n: usize, seed: u64) -> Vec<Record> {
        MultiStream::paper_section5(seed).take_records(n)
    }

    fn sample_ids(s: &StratifiedSample) -> Vec<(StratumId, Vec<u64>)> {
        s.per_stratum
            .iter()
            .map(|(&st, recs)| (st, recs.iter().map(|r| r.id).collect()))
            .collect()
    }

    #[test]
    fn incremental_matches_rebuild_across_slides() {
        let mut w = CountWindow::new(2000);
        let mut inc = IncrementalSampler::new(11);
        let mut gen = MultiStream::paper_section5(3);
        for step in 0..8 {
            let n = if step == 0 { 2000 } else { 250 };
            let snap = w.slide(gen.take_records(n));
            inc.apply_delta(&snap.delta);
            let mut scratch = IncrementalSampler::new(11);
            scratch.rebuild(snap.items());
            let a = inc.sample(200);
            let b = scratch.sample(200);
            assert_eq!(a.population, b.population, "step {step}");
            assert_eq!(sample_ids(&a), sample_ids(&b), "step {step}");
        }
    }

    #[test]
    fn populations_are_exact() {
        let items = window_records(5_000, 5);
        let mut s = IncrementalSampler::new(1);
        s.rebuild(&items);
        let mut want: BTreeMap<StratumId, u64> = BTreeMap::new();
        for r in &items {
            *want.entry(r.stratum).or_default() += 1;
        }
        assert_eq!(s.populations(), want);
        assert_eq!(s.sample(500).population, want);
        // take_records rounds up to whole generator ticks — compare
        // against the actual item count, not the requested one.
        assert_eq!(s.len(), items.len());
    }

    #[test]
    fn sample_size_is_respected() {
        let items = window_records(10_000, 1);
        let mut s = IncrementalSampler::new(2);
        s.rebuild(&items);
        // Populations dwarf the budget → capacities are all satisfiable
        // and the sample is exactly the budget.
        assert_eq!(s.sample(1000).total_len(), 1000);
    }

    #[test]
    fn proportional_allocation_matches_rates() {
        // Rates 3:4:5 → sample shares ≈ 25%, 33%, 42%.
        let items = window_records(12_000, 3);
        let mut s = IncrementalSampler::new(4);
        s.rebuild(&items);
        let sample = s.sample(1200);
        let total = sample.total_len() as f64;
        for (stratum, want) in [(0u32, 3.0 / 12.0), (1, 4.0 / 12.0), (2, 5.0 / 12.0)] {
            let got = sample.stratum(stratum).len() as f64 / total;
            assert!(
                (got - want).abs() < 0.02,
                "stratum {stratum}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn no_duplicates_and_items_from_window() {
        let items = window_records(6_000, 11);
        let mut s = IncrementalSampler::new(12);
        s.rebuild(&items);
        let sample = s.sample(600);
        let window_ids: std::collections::HashSet<u64> =
            items.iter().map(|r| r.id).collect();
        let mut seen = std::collections::HashSet::new();
        for (&stratum, recs) in &sample.per_stratum {
            for r in recs {
                assert_eq!(r.stratum, stratum);
                assert!(window_ids.contains(&r.id));
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
            }
        }
    }

    #[test]
    fn sample_larger_than_window_keeps_everything() {
        let items = window_records(300, 13);
        let mut s = IncrementalSampler::new(14);
        s.rebuild(&items);
        assert_eq!(s.sample(1000).total_len(), items.len());
    }

    #[test]
    fn minority_stratum_not_neglected() {
        let mut items = window_records(9_000, 7);
        for r in items.iter_mut().take(9) {
            r.stratum = 99;
        }
        let mut s = IncrementalSampler::new(8);
        s.rebuild(&items);
        let sample = s.sample(900);
        assert!(!sample.stratum(99).is_empty(), "minority stratum neglected");
    }

    #[test]
    fn uniform_inclusion_within_stratum() {
        // Bottom-k by keyed rank: over many seeds, every item should be
        // included at comparable rates (k/n each).
        let n = 4_000usize;
        let items: Vec<Record> =
            (0..n as u64).map(|i| Record::new(i, 0, 0, 0, 1.0)).collect();
        let k = 400usize;
        let trials = 40u64;
        let mut first_half = 0usize;
        for t in 0..trials {
            let mut s = IncrementalSampler::new(1000 + t);
            s.rebuild(&items);
            first_half +=
                s.sample(k).stratum(0).iter().filter(|r| r.id < n as u64 / 2).count();
        }
        let frac = first_half as f64 / (trials as usize * k) as f64;
        assert!((frac - 0.5).abs() < 0.05, "first-half fraction {frac}");
    }

    #[test]
    fn eviction_and_strata_cleanup() {
        let mut s = IncrementalSampler::new(1);
        let r0 = Record::new(1, 0, 0, 0, 1.0);
        let r1 = Record::new(2, 7, 0, 0, 2.0);
        let delta = WindowDelta::from_rows(vec![r0, r1], vec![]);
        assert_eq!(s.apply_delta(&delta), 2);
        assert_eq!(s.strata_len(), 2);
        let delta = WindowDelta::from_rows(vec![], vec![r1]);
        s.apply_delta(&delta);
        assert_eq!(s.strata_len(), 1);
        assert_eq!(s.len(), 1);
        // Removing an item that was never inserted (e.g. a pre-warm-up
        // resize eviction) is a tolerated no-op.
        s.apply_delta(&WindowDelta::from_rows(vec![], vec![r1]));
        assert_eq!(s.len(), 1);
        assert!(!s.is_empty());
    }

    #[test]
    fn oversized_slide_nets_out() {
        // A batch larger than the window: overflow items appear in both
        // `inserted` and `removed` of the same delta and must net to
        // absent (insert-before-remove ordering).
        let mut w = CountWindow::new(5);
        let mut s = IncrementalSampler::new(9);
        let rec = |i: u64| Record::new(i, 0, i, 0, 1.0);
        let snap = w.slide((0..12).map(rec).collect());
        s.apply_delta(&snap.delta);
        assert_eq!(s.len(), 5);
        let mut scratch = IncrementalSampler::new(9);
        scratch.rebuild(snap.items());
        assert_eq!(sample_ids(&s.sample(3)), sample_ids(&scratch.sample(3)));
    }

    #[test]
    fn empty_sampler_emits_empty_sample() {
        let s = IncrementalSampler::new(0);
        let sample = s.sample(100);
        assert_eq!(sample.total_len(), 0);
        assert!(sample.population.is_empty());
        assert!(s.is_empty());
    }
}
