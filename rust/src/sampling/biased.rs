//! Biased sampling — Algorithm 4, the marriage of the two paradigms.
//!
//! Per stratum, replace stratified-sample items with *memoized* items from
//! the previous window so their sub-computations can be reused, while
//! keeping the per-stratum sample size fixed (proportional allocation is
//! retained). A `HashSet` over item ids guards against duplicates when the
//! fresh sample already contains some memoized items (issue (iii) in
//! §3.3.1).
//!
//! Memoized inputs and biased outputs are [`SampleRun`]s: the memoized
//! run arrives as a zero-copy handle from the memo store, and the id set
//! built here for dedup ships out with the biased run, so downstream
//! planning diffs never rebuild it. The biased run's columnar view is
//! assembled in the same pass ([`crate::columnar::ColumnarBuilder`]), so
//! the chunking kernels downstream start from dense columns without a
//! second transpose.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::columnar::ColumnarBuilder;
use crate::util::hash::FastSet;

use crate::sampling::stratified::StratifiedSample;
use crate::sampling::SampleRun;
use crate::workload::record::{Record, StratumId};

/// Result of biasing one window's stratified sample.
#[derive(Debug, Clone, Default)]
pub struct BiasOutcome {
    /// The biased sample, per stratum. Sizes match the input stratified
    /// sample exactly; each run carries its id set for O(1) membership.
    pub per_stratum: BTreeMap<StratumId, SampleRun>,
    /// Per stratum: how many items in the biased sample carry memoized
    /// results (the reuse the marriage buys — what Fig 5.1 measures).
    pub memo_reused: BTreeMap<StratumId, usize>,
    /// Per stratum: memoized items available before biasing.
    pub memo_available: BTreeMap<StratumId, usize>,
}

impl BiasOutcome {
    /// Total biased-sample size.
    pub fn total_len(&self) -> usize {
        self.per_stratum.values().map(SampleRun::len).sum()
    }

    /// Total memoized items reused.
    pub fn total_reused(&self) -> usize {
        self.memo_reused.values().sum()
    }

    /// Reuse fraction over the whole sample.
    pub fn reuse_fraction(&self) -> f64 {
        let n = self.total_len();
        if n == 0 {
            0.0
        } else {
            self.total_reused() as f64 / n as f64
        }
    }

    /// Items of one stratum.
    pub fn stratum(&self, s: StratumId) -> &[Record] {
        self.per_stratum.get(&s).map(SampleRun::records).unwrap_or(&[])
    }

    /// Flatten to a single vector (stratum order, deterministic).
    pub fn all_items(&self) -> Vec<Record> {
        self.per_stratum.values().flat_map(|r| r.records().iter().copied()).collect()
    }
}

/// Algorithm 4: bias `sample` toward `memo` per stratum.
///
/// `memo` maps stratum → items memoized from the previous window **that
/// are still inside the current window** (Algorithm 1 drops out-of-window
/// memo entries before calling this).
///
/// Per stratum with `x` memoized items and sample size `y`:
/// * `x ≥ y` → biased sample = first `y` memoized items (extra memo
///   neglected);
/// * `x < y` → all `x` memoized items + `y − x` fresh sampled items,
///   skipping duplicates by item id.
pub fn bias_sample(
    sample: &StratifiedSample,
    memo: &BTreeMap<StratumId, SampleRun>,
) -> BiasOutcome {
    let mut out = BiasOutcome::default();
    for (&stratum, fresh) in &sample.per_stratum {
        let y = fresh.len();
        let memoized: &[Record] =
            memo.get(&stratum).map(SampleRun::records).unwrap_or(&[]);
        let x = memoized.len();
        out.memo_available.insert(stratum, x);

        let mut chosen: Vec<Record> = Vec::with_capacity(y);
        let mut cols = ColumnarBuilder::with_capacity(y);
        let mut seen: FastSet<u64> = FastSet::with_capacity_and_hasher(y, Default::default());

        // Give priority to memoized items (they carry reusable results).
        for m in memoized.iter().take(y) {
            if seen.insert(m.id) {
                chosen.push(*m);
                cols.push(m);
            }
        }
        let reused = chosen.len();

        // Fill the remainder from the fresh stratified sample, deduped.
        if chosen.len() < y {
            for f in fresh {
                if chosen.len() >= y {
                    break;
                }
                if seen.insert(f.id) {
                    chosen.push(*f);
                    cols.push(f);
                }
            }
        }

        debug_assert_eq!(chosen.len(), y, "bias must preserve per-stratum size");
        out.memo_reused.insert(stratum, reused);
        // `seen` holds exactly the chosen ids (the fill loop breaks before
        // inserting an id it will not push), so it ships as the run's set;
        // the columnar view built alongside ships pre-transposed.
        out.per_stratum.insert(
            stratum,
            SampleRun::from_parts_with_columns(chosen.into(), Arc::new(seen), cols.finish()),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, stratum: StratumId) -> Record {
        Record::new(id, stratum, 0, 0, id as f64)
    }

    fn sample_of(items: Vec<(StratumId, Vec<u64>)>) -> StratifiedSample {
        let mut s = StratifiedSample::default();
        for (stratum, ids) in items {
            s.population.insert(stratum, ids.len() as u64 * 10);
            s.per_stratum
                .insert(stratum, ids.into_iter().map(|i| rec(i, stratum)).collect());
        }
        s
    }

    fn memo_of(items: Vec<(StratumId, Vec<Record>)>) -> BTreeMap<StratumId, SampleRun> {
        items.into_iter().map(|(s, recs)| (s, SampleRun::from_vec(recs))).collect()
    }

    #[test]
    fn more_memo_than_sample_takes_y_memo_items() {
        let sample = sample_of(vec![(0, vec![1, 2, 3])]);
        let memo =
            memo_of(vec![(0, vec![rec(10, 0), rec(11, 0), rec(12, 0), rec(13, 0)])]);
        let out = bias_sample(&sample, &memo);
        assert_eq!(out.stratum(0).len(), 3);
        assert_eq!(out.memo_reused[&0], 3);
        assert!(out.stratum(0).iter().all(|r| r.id >= 10));
    }

    #[test]
    fn fewer_memo_than_sample_fills_from_fresh() {
        let sample = sample_of(vec![(0, vec![1, 2, 3, 4])]);
        let memo = memo_of(vec![(0, vec![rec(10, 0)])]);
        let out = bias_sample(&sample, &memo);
        assert_eq!(out.stratum(0).len(), 4);
        assert_eq!(out.memo_reused[&0], 1);
        let ids: Vec<u64> = out.stratum(0).iter().map(|r| r.id).collect();
        assert!(ids.contains(&10));
    }

    #[test]
    fn duplicates_between_memo_and_fresh_removed() {
        // Fresh sample already contains memoized item 2.
        let sample = sample_of(vec![(0, vec![1, 2, 3])]);
        let memo = memo_of(vec![(0, vec![rec(2, 0)])]);
        let out = bias_sample(&sample, &memo);
        let mut ids: Vec<u64> = out.stratum(0).iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3]);
        assert_eq!(out.memo_reused[&0], 1);
    }

    #[test]
    fn no_memo_returns_fresh_sample() {
        let sample = sample_of(vec![(0, vec![1, 2]), (1, vec![3])]);
        let out = bias_sample(&sample, &BTreeMap::new());
        assert_eq!(out.total_reused(), 0);
        assert_eq!(out.total_len(), 3);
        assert_eq!(out.reuse_fraction(), 0.0);
    }

    #[test]
    fn per_stratum_sizes_preserved() {
        let sample = sample_of(vec![(0, vec![1, 2, 3]), (1, vec![4, 5]), (2, vec![6])]);
        let memo = memo_of(vec![
            (0, vec![rec(10, 0), rec(11, 0), rec(12, 0), rec(13, 0), rec(14, 0)]),
            (2, vec![rec(20, 2)]),
        ]);
        let out = bias_sample(&sample, &memo);
        assert_eq!(out.stratum(0).len(), 3);
        assert_eq!(out.stratum(1).len(), 2);
        assert_eq!(out.stratum(2).len(), 1);
        assert_eq!(out.memo_reused[&0], 3);
        assert_eq!(out.memo_reused[&1], 0);
        assert_eq!(out.memo_reused[&2], 1);
        assert_eq!(out.memo_available[&0], 5);
    }

    #[test]
    fn biasing_is_per_stratum_no_cross_contamination() {
        // Memo items of stratum 1 must never enter stratum 0's sample.
        let sample = sample_of(vec![(0, vec![1, 2])]);
        let memo = memo_of(vec![(1, vec![rec(10, 1)])]);
        let out = bias_sample(&sample, &memo);
        assert!(out.stratum(0).iter().all(|r| r.stratum == 0));
        assert_eq!(out.memo_reused.get(&1), None);
    }

    #[test]
    fn empty_sample_is_empty_outcome() {
        let out = bias_sample(&StratifiedSample::default(), &BTreeMap::new());
        assert_eq!(out.total_len(), 0);
        assert_eq!(out.reuse_fraction(), 0.0);
    }

    #[test]
    fn biased_run_ships_prebuilt_columns() {
        // The columnar view assembled during biasing must mirror the row
        // run exactly (order included) — chunking consumes it directly.
        let sample = sample_of(vec![(0, vec![1, 2, 3, 4]), (1, vec![5, 6])]);
        let memo = memo_of(vec![(0, vec![rec(2, 0), rec(10, 0)])]);
        let out = bias_sample(&sample, &memo);
        for run in out.per_stratum.values() {
            assert!(run.columns().bit_eq_records(run.records()));
        }
    }

    #[test]
    fn biased_run_carries_usable_id_set() {
        // The run's id set must mirror the chosen records exactly, so the
        // planner can diff without rebuilding sets.
        let sample = sample_of(vec![(0, vec![1, 2, 3, 4])]);
        let memo = memo_of(vec![(0, vec![rec(2, 0), rec(10, 0)])]);
        let out = bias_sample(&sample, &memo);
        let run = &out.per_stratum[&0];
        assert_eq!(run.len(), 4);
        for r in run.records() {
            assert!(run.contains(r.id));
        }
        // An id considered but superseded must not leak into the set.
        let absent: Vec<u64> =
            (1..=10).filter(|id| !run.records().iter().any(|r| r.id == *id)).collect();
        for id in absent {
            assert!(!run.contains(id), "id {id} leaked into the run set");
        }
    }
}
