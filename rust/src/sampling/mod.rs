//! Online sampling — the approximate half of the marriage.
//!
//! * [`reservoir`] — conventional reservoir sampling (CRS), Algorithm 3.
//! * [`stratified`] — stratified reservoir sampling with periodic
//!   proportional re-allocation and adaptive resizing (ARS), Algorithm 2 +
//!   Eq 3.1.
//! * [`biased`] — the marriage itself: per-stratum biasing of the
//!   stratified sample toward memoized items, Algorithm 4.

pub mod biased;
pub mod reservoir;
pub mod stratified;

pub use biased::{bias_sample, BiasOutcome};
pub use reservoir::Reservoir;
pub use stratified::{StratifiedSample, StratifiedSampler};
