//! Online sampling — the approximate half of the marriage.
//!
//! * [`reservoir`] — conventional reservoir sampling (CRS), Algorithm 3.
//! * [`stratified`] — stratified reservoir sampling with periodic
//!   proportional re-allocation and adaptive resizing (ARS), Algorithm 2 +
//!   Eq 3.1 (the one-shot, per-window streaming sampler).
//! * [`incremental`] — Algorithm 2 as self-adjusting state: a persistent
//!   rank-based sampler maintained across window slides in O(delta),
//!   producing samples identical to a from-scratch rebuild.
//! * [`biased`] — the marriage itself: per-stratum biasing of the
//!   stratified sample toward memoized items, Algorithm 4.
//!
//! [`SampleRun`] is the shared currency between the stages: an immutable
//! `Arc`-backed run of sampled records plus its id set, so the bias →
//! plan → memoize plumbing passes samples around without copying records
//! or rebuilding hash sets.

pub mod biased;
pub mod incremental;
pub mod reservoir;
pub mod stratified;

pub use biased::{bias_sample, BiasOutcome};
pub use incremental::IncrementalSampler;
pub use reservoir::Reservoir;
pub use stratified::{allocate_proportional, StratifiedSample, StratifiedSampler};

use std::sync::Arc;
use std::sync::OnceLock;

use crate::columnar::ColumnarBatch;
use crate::util::hash::FastSet;
use crate::workload::record::Record;

/// An immutable run of sampled records shared across pipeline stages.
///
/// Cloning is O(1) (`Arc` bumps): the biased sample, the memo store's
/// per-stratum item lists, and the planner's previous-window view all
/// hand around the *same* allocation, and the id set built once during
/// biasing serves every later membership test — no per-window
/// re-hashing. The columnar view the chunking/sketch kernels consume is
/// transposed at most once per run ([`SampleRun::columns`]) — the bias
/// step pre-populates it for fresh runs, and memo-reused runs carry
/// theirs across windows.
#[derive(Debug, Clone)]
pub struct SampleRun {
    seq: Arc<[Record]>,
    ids: Arc<FastSet<u64>>,
    min_ts: u64,
    cols: OnceLock<ColumnarBatch>,
}

impl Default for SampleRun {
    fn default() -> Self {
        SampleRun {
            seq: Arc::from(Vec::new()),
            ids: Arc::new(FastSet::default()),
            min_ts: u64::MAX,
            cols: OnceLock::new(),
        }
    }
}

fn min_ts_of(seq: &[Record]) -> u64 {
    seq.iter().map(|r| r.timestamp).min().unwrap_or(u64::MAX)
}

impl SampleRun {
    /// Build from an owned record vector (computes the id set).
    pub fn from_vec(seq: Vec<Record>) -> Self {
        Self::from_slice(&seq)
    }

    /// Build from a record slice (copies once, computes the id set).
    pub fn from_slice(seq: &[Record]) -> Self {
        let ids: FastSet<u64> = seq.iter().map(|r| r.id).collect();
        SampleRun {
            min_ts: min_ts_of(seq),
            seq: Arc::from(seq),
            ids: Arc::new(ids),
            cols: OnceLock::new(),
        }
    }

    /// Assemble from pre-built parts (e.g. the bias step, which already
    /// owns the id set it used for dedup). `ids` must be exactly the ids
    /// of `seq`.
    pub fn from_parts(seq: Arc<[Record]>, ids: Arc<FastSet<u64>>) -> Self {
        debug_assert_eq!(seq.len(), ids.len(), "id set must mirror the record run");
        SampleRun { min_ts: min_ts_of(&seq), seq, ids, cols: OnceLock::new() }
    }

    /// [`SampleRun::from_parts`] with the columnar view already built —
    /// the bias step emits both representations in one pass, so the
    /// chunking kernels downstream never transpose. `cols` must be the
    /// exact columnar transpose of `seq`.
    pub fn from_parts_with_columns(
        seq: Arc<[Record]>,
        ids: Arc<FastSet<u64>>,
        cols: ColumnarBatch,
    ) -> Self {
        debug_assert_eq!(seq.len(), ids.len(), "id set must mirror the record run");
        debug_assert_eq!(seq.len(), cols.len(), "columns must mirror the record run");
        let run = SampleRun { min_ts: min_ts_of(&seq), seq, ids, cols: OnceLock::new() };
        let _ = run.cols.set(cols);
        run
    }

    /// The records, in sample (bias) order.
    pub fn records(&self) -> &[Record] {
        &self.seq
    }

    /// The run's struct-of-arrays view, in the same (bias) order —
    /// transposed on first call, then cached for the run's lifetime
    /// (shared by clones made afterwards). The chunk/sketch kernels
    /// consume this.
    pub fn columns(&self) -> &ColumnarBatch {
        self.cols.get_or_init(|| ColumnarBatch::from_records(&self.seq))
    }

    /// O(1) membership test by item id.
    pub fn contains(&self, id: u64) -> bool {
        self.ids.contains(&id)
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.seq.len()
    }

    /// True when the run holds no records.
    pub fn is_empty(&self) -> bool {
        self.seq.is_empty()
    }

    /// Smallest timestamp in the run (`u64::MAX` when empty) — lets
    /// eviction and bias filtering skip untouched runs in O(1).
    pub fn min_ts(&self) -> u64 {
        self.min_ts
    }

    /// The run restricted to records with `timestamp >= start`. Returns a
    /// zero-copy clone when nothing is filtered out.
    pub fn filter_ts(&self, start: u64) -> SampleRun {
        if self.min_ts >= start {
            return self.clone();
        }
        let kept: Vec<Record> =
            self.seq.iter().filter(|r| r.timestamp >= start).copied().collect();
        SampleRun::from_vec(kept)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, ts: u64) -> Record {
        Record::new(id, 0, ts, 0, id as f64)
    }

    #[test]
    fn run_tracks_ids_and_min_ts() {
        let run = SampleRun::from_vec(vec![rec(1, 9), rec(2, 4), rec(3, 7)]);
        assert_eq!(run.len(), 3);
        assert!(!run.is_empty());
        assert!(run.contains(2));
        assert!(!run.contains(9));
        assert_eq!(run.min_ts(), 4);
        assert_eq!(run.records()[0].id, 1);
    }

    #[test]
    fn empty_run_defaults() {
        let run = SampleRun::default();
        assert!(run.is_empty());
        assert_eq!(run.min_ts(), u64::MAX);
        assert!(!run.contains(0));
        let built = SampleRun::from_vec(Vec::new());
        assert_eq!(built.min_ts(), u64::MAX);
    }

    #[test]
    fn filter_ts_is_zero_copy_when_untouched() {
        let run = SampleRun::from_vec(vec![rec(1, 10), rec(2, 12)]);
        let same = run.filter_ts(10);
        assert!(Arc::ptr_eq(&run.seq, &same.seq), "untouched filter must not copy");
        let trimmed = run.filter_ts(11);
        assert_eq!(trimmed.len(), 1);
        assert!(trimmed.contains(2));
        assert!(!trimmed.contains(1));
        assert_eq!(trimmed.min_ts(), 12);
    }

    #[test]
    fn columns_view_is_cached_and_matches_rows() {
        let run = SampleRun::from_vec(vec![rec(1, 9), rec(2, 4), rec(3, 7)]);
        let c = run.columns();
        assert_eq!(c.ids(), &[1, 2, 3]);
        assert_eq!(c.timestamps(), &[9, 4, 7]);
        assert!(std::ptr::eq(c, run.columns()), "columns must transpose once");
        // Pre-built columns are adopted, not re-transposed.
        let records = vec![rec(5, 3), rec(6, 8)];
        let ids: FastSet<u64> = records.iter().map(|r| r.id).collect();
        let cols = ColumnarBatch::from_records(&records);
        let pre =
            SampleRun::from_parts_with_columns(Arc::from(records), Arc::new(ids), cols.clone());
        assert!(pre.columns().ptr_eq(&cols));
    }

    #[test]
    fn from_parts_mirrors_slice_build() {
        let records = vec![rec(5, 3), rec(6, 8)];
        let ids: FastSet<u64> = records.iter().map(|r| r.id).collect();
        let a = SampleRun::from_parts(Arc::from(records.clone()), Arc::new(ids));
        let b = SampleRun::from_slice(&records);
        assert_eq!(a.records(), b.records());
        assert_eq!(a.min_ts(), b.min_ts());
        assert!(a.contains(5) && a.contains(6));
    }
}
