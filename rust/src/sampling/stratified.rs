//! Stratified reservoir sampling — the paper's Algorithm 2.
//!
//! One pass over the window's items. The reservoir of total size
//! `sample_size` is a group of per-stratum sub-reservoirs. Phases:
//!
//! 1. **Fill** — until the whole reservoir holds `sample_size` items,
//!    every item is admitted to its stratum's sub-reservoir.
//! 2. **Steady state** — conventional reservoir sampling (CRS) per
//!    stratum, with a periodic re-allocation every `T` items seen:
//!    sub-reservoir sizes are recomputed proportionally (Eq 3.1,
//!    `|sample[i]| = sample_size · |S_i| / k`), and strata whose size
//!    changed go through adaptive reservoir sampling (ARS): shrink =
//!    evict uniformly random residents now; grow = admit the next `c`
//!    arriving items of that stratum unconditionally.
//!
//! New strata appearing mid-window are picked up and receive capacity at
//! the next re-allocation (guaranteed non-zero share — "no sub-stream is
//! neglected").

use std::collections::BTreeMap;

use crate::sampling::reservoir::Reservoir;
use crate::util::rng::Rng;
use crate::workload::record::{Record, StratumId};

/// Eq 3.1 proportional allocation with largest-remainder rounding:
/// distribute `budget` sample slots over strata proportionally to their
/// `populations`, so capacities sum to exactly `budget` and every seen
/// stratum keeps at least one slot (minority protection — "no sub-stream
/// is neglected") when the budget allows.
///
/// Deterministic: ties in the remainder ranking break by stratum id, and
/// the minority pass donates from the largest allocation. Shared by the
/// streaming [`StratifiedSampler`] (populations = per-reservoir `seen`
/// counts at the `T`-interval re-allocation) and the persistent
/// [`IncrementalSampler`](crate::sampling::incremental::IncrementalSampler)
/// (populations = exact per-stratum window counts, recomputed per slide
/// in O(strata)).
pub fn allocate_proportional(
    budget: usize,
    populations: &BTreeMap<StratumId, u64>,
) -> BTreeMap<StratumId, usize> {
    let k: u64 = populations.values().sum();
    let n_strata = populations.len();
    if k == 0 || n_strata == 0 {
        return BTreeMap::new();
    }
    // Ideal fractional shares.
    let mut shares: Vec<(StratumId, f64)> = populations
        .iter()
        .map(|(&s, &p)| (s, budget as f64 * p as f64 / k as f64))
        .collect();
    // Floor + largest remainder.
    let mut caps: BTreeMap<StratumId, usize> =
        shares.iter().map(|&(s, f)| (s, f.floor() as usize)).collect();
    let assigned: usize = caps.values().sum();
    let mut leftover = budget.saturating_sub(assigned);
    shares.sort_by(|a, b| {
        let fa = a.1 - a.1.floor();
        let fb = b.1 - b.1.floor();
        fb.total_cmp(&fa).then(a.0.cmp(&b.0))
    });
    for (s, _) in shares {
        if leftover == 0 {
            break;
        }
        if let Some(c) = caps.get_mut(&s) {
            *c += 1;
            leftover -= 1;
        }
    }
    // Minority protection: every seen stratum gets ≥ 1 slot if possible,
    // taking slots from the largest allocations.
    if budget >= n_strata {
        loop {
            let zero: Vec<StratumId> =
                caps.iter().filter(|(_, &c)| c == 0).map(|(&s, _)| s).collect();
            if zero.is_empty() {
                break;
            }
            for s in zero {
                let Some((&donor, &donor_cap)) = caps.iter().max_by_key(|(_, &c)| c) else {
                    break;
                };
                if donor_cap <= 1 {
                    break;
                }
                if let Some(c) = caps.get_mut(&donor) {
                    *c -= 1;
                }
                if let Some(c) = caps.get_mut(&s) {
                    *c += 1;
                }
            }
        }
    }
    caps
}

/// Per-stratum state: the sub-reservoir plus the ARS pending-grow credit.
#[derive(Debug)]
struct SubState {
    reservoir: Reservoir,
    /// Items this stratum may still admit unconditionally (ARS grow).
    pending_grow: usize,
}

/// The resulting stratified sample of one window.
#[derive(Debug, Clone, Default)]
pub struct StratifiedSample {
    /// Per-stratum sampled items.
    pub per_stratum: BTreeMap<StratumId, Vec<Record>>,
    /// Per-stratum count of items *seen* in the window (|S_i| — the
    /// population sizes B_i the error estimator needs).
    pub population: BTreeMap<StratumId, u64>,
}

impl StratifiedSample {
    /// Total sampled items across strata.
    pub fn total_len(&self) -> usize {
        self.per_stratum.values().map(Vec::len).sum()
    }

    /// Sampled items of one stratum (empty slice if absent).
    pub fn stratum(&self, s: StratumId) -> &[Record] {
        self.per_stratum.get(&s).map(Vec::as_slice).unwrap_or(&[])
    }
}

/// Streaming stratified reservoir sampler (one instance per window).
///
/// # Example
///
/// One-shot sampling of a window with three equally sized strata:
///
/// ```
/// use incapprox::sampling::stratified::StratifiedSampler;
/// use incapprox::util::rng::Rng;
/// use incapprox::workload::record::Record;
///
/// // 900 records, round-robin across strata 0/1/2 (300 each).
/// let window: Vec<Record> = (0..900u64)
///     .map(|i| Record::new(i, (i % 3) as u32, 0, 0, i as f64))
///     .collect();
///
/// let sample = StratifiedSampler::sample_window(&window, 90, 300, Rng::new(7));
/// assert_eq!(sample.total_len(), 90);
/// for s in 0..3u32 {
///     // Proportional allocation: every stratum gets its ~1/3 share…
///     assert_eq!(sample.stratum(s).len(), 30);
///     // …and the exact population |S_i| is tracked for the estimator.
///     assert_eq!(sample.population[&s], 300);
/// }
/// ```
#[derive(Debug)]
pub struct StratifiedSampler {
    sample_size: usize,
    realloc_interval: usize,
    sub: BTreeMap<StratumId, SubState>,
    /// Total items seen in the window so far (k in Eq 3.1).
    total_seen: u64,
    seen_since_realloc: usize,
    /// Running count of retained items — kept incrementally so the
    /// per-item hot path never walks all strata (perf: §Perf L3.1).
    retained: usize,
    /// Set once the reservoir first reaches `sample_size`. The fill phase
    /// must not re-trigger after a re-allocation shrink — top-ups then
    /// belong exclusively to the ARS grow credits, otherwise the two
    /// mechanisms race and overshoot the budget.
    filled: bool,
    rng: Rng,
}

impl StratifiedSampler {
    /// Sampler for a window, with reservoir size `sample_size` and
    /// re-allocation interval `realloc_interval` (Algorithm 2's `T`).
    pub fn new(sample_size: usize, realloc_interval: usize, rng: Rng) -> Self {
        StratifiedSampler {
            sample_size,
            realloc_interval: realloc_interval.max(1),
            sub: BTreeMap::new(),
            total_seen: 0,
            seen_since_realloc: 0,
            retained: 0,
            filled: false,
            rng,
        }
    }

    /// Retained items across all sub-reservoirs (O(strata); the hot path
    /// uses the incrementally maintained `retained` counter instead, and
    /// debug assertions cross-check the two).
    #[cfg(debug_assertions)]
    fn reservoir_total(&self) -> usize {
        self.sub.values().map(|s| s.reservoir.len()).sum()
    }

    /// Eq 3.1 capacities for the current reservoir state — see
    /// [`allocate_proportional`]. (Per-stratum `seen` counts sum to
    /// `total_seen`, so they are the populations.)
    fn proportional_capacities(&self) -> BTreeMap<StratumId, usize> {
        let populations: BTreeMap<StratumId, u64> =
            self.sub.iter().map(|(&s, st)| (s, st.reservoir.seen())).collect();
        allocate_proportional(self.sample_size, &populations)
    }

    /// Re-allocate sub-reservoir sizes (the `T`-interval branch of
    /// Algorithm 2): shrink via random eviction now, grow via ARS credit.
    fn reallocate(&mut self) {
        let caps = self.proportional_capacities();
        for (&s, cap) in &caps {
            let Some(st) = self.sub.get_mut(&s) else { continue };
            let cur = st.reservoir.len();
            if *cap < cur {
                st.reservoir.evict_random(cur - *cap, &mut self.rng);
                self.retained -= cur - *cap;
                st.reservoir.set_capacity(*cap);
                st.pending_grow = 0;
            } else {
                st.reservoir.set_capacity(*cap);
                st.pending_grow = *cap - cur;
            }
        }
    }

    /// Offer the next item of the window stream.
    pub fn offer(&mut self, item: Record) {
        let stratum = item.stratum;
        self.total_seen += 1;
        self.seen_since_realloc += 1;

        #[cfg(debug_assertions)]
        debug_assert_eq!(self.retained, self.reservoir_total());
        // Add new stratum seen to S.
        if !self.filled && self.retained >= self.sample_size {
            self.filled = true;
        }
        let filling = !self.filled;
        let st = self.sub.entry(stratum).or_insert_with(|| SubState {
            reservoir: Reservoir::new(0),
            pending_grow: 0,
        });

        if filling {
            // Fill phase: admit unconditionally (only until the reservoir
            // first becomes full).
            st.reservoir.force_insert(item);
            self.retained += 1;
            return;
        }

        if st.pending_grow > 0 {
            // ARS grow: admit the next arriving items of this stratum.
            st.pending_grow -= 1;
            st.reservoir.force_insert(item);
            self.retained += 1;
        } else {
            // CRS replacement keeps the retained count constant.
            st.reservoir.offer(item, &mut self.rng);
        }

        if self.seen_since_realloc >= self.realloc_interval {
            self.seen_since_realloc = 0;
            self.reallocate();
        }
    }

    /// Offer a whole batch.
    pub fn offer_all(&mut self, items: impl IntoIterator<Item = Record>) {
        for item in items {
            self.offer(item);
        }
    }

    /// Finish the window and emit the sample.
    ///
    /// No final re-allocation is performed: an ARS *grow* credit issued at
    /// window end could never be filled (no more incoming items), so a
    /// terminal shrink/grow pass would only shed sample slots. The
    /// periodic `T`-interval re-allocations already keep proportions
    /// aligned with the whole-window stratum sizes (Algorithm 2's loop
    /// invariant).
    pub fn finish(self) -> StratifiedSample {
        let mut out = StratifiedSample::default();
        for (s, st) in self.sub {
            out.population.insert(s, st.reservoir.seen());
            out.per_stratum.insert(s, st.reservoir.items().to_vec());
        }
        out
    }

    /// One-shot convenience: sample a full window slice.
    pub fn sample_window(
        items: &[Record],
        sample_size: usize,
        realloc_interval: usize,
        rng: Rng,
    ) -> StratifiedSample {
        let mut sampler = StratifiedSampler::new(sample_size, realloc_interval, rng);
        sampler.offer_all(items.iter().copied());
        sampler.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::MultiStream;

    fn window(n: usize, seed: u64) -> Vec<Record> {
        MultiStream::paper_section5(seed).take_records(n)
    }

    #[test]
    fn sample_size_is_respected() {
        let items = window(10_000, 1);
        let s = StratifiedSampler::sample_window(&items[..10_000], 1000, 500, Rng::new(2));
        assert_eq!(s.total_len(), 1000);
    }

    #[test]
    fn proportional_allocation_matches_rates() {
        // Rates 3:4:5 → sample shares ≈ 25%, 33%, 42%.
        let items = window(12_000, 3);
        let s = StratifiedSampler::sample_window(&items[..12_000], 1200, 500, Rng::new(4));
        let total = s.total_len() as f64;
        for (stratum, want) in [(0u32, 3.0 / 12.0), (1, 4.0 / 12.0), (2, 5.0 / 12.0)] {
            let got = s.stratum(stratum).len() as f64 / total;
            assert!(
                (got - want).abs() < 0.03,
                "stratum {stratum}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn population_counts_are_exact() {
        let items = window(5_000, 5);
        let items = &items[..5_000];
        let s = StratifiedSampler::sample_window(items, 500, 250, Rng::new(6));
        let mut true_counts: BTreeMap<StratumId, u64> = BTreeMap::new();
        for r in items {
            *true_counts.entry(r.stratum).or_default() += 1;
        }
        assert_eq!(s.population, true_counts);
    }

    #[test]
    fn no_stratum_neglected() {
        // A tiny minority stratum must still land in the sample.
        let mut items = window(9_000, 7);
        items.truncate(9_000);
        for (i, r) in items.iter_mut().enumerate().take(9) {
            // Make 9 items of a rare stratum 99, spread through the window.
            if i % 1 == 0 {
                r.stratum = 99;
            }
        }
        let s = StratifiedSampler::sample_window(&items, 900, 300, Rng::new(8));
        assert!(
            !s.stratum(99).is_empty(),
            "minority stratum neglected: {:?}",
            s.per_stratum.keys().collect::<Vec<_>>()
        );
    }

    #[test]
    fn sampled_items_come_from_window() {
        let items = window(4_000, 9);
        let items = &items[..4_000];
        let ids: std::collections::HashSet<u64> = items.iter().map(|r| r.id).collect();
        let s = StratifiedSampler::sample_window(items, 400, 200, Rng::new(10));
        for recs in s.per_stratum.values() {
            for r in recs {
                assert!(ids.contains(&r.id));
            }
        }
    }

    #[test]
    fn no_duplicates_in_sample() {
        let items = window(6_000, 11);
        let s = StratifiedSampler::sample_window(&items[..6_000], 600, 300, Rng::new(12));
        let mut ids: Vec<u64> =
            s.per_stratum.values().flatten().map(|r| r.id).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn sample_larger_than_window_keeps_everything() {
        let items = window(300, 13);
        let items = &items[..300];
        let s = StratifiedSampler::sample_window(items, 1000, 100, Rng::new(14));
        assert_eq!(s.total_len(), items.len());
    }

    #[test]
    fn allocate_proportional_sums_and_protects_minorities() {
        // Direct Eq 3.1 checks (shared by the streaming and persistent
        // samplers): capacities sum to the budget exactly, shares track
        // populations, tiny strata keep a slot when the budget allows.
        let pops = BTreeMap::from([(0u32, 3000u64), (1, 4000), (2, 5000), (9, 2)]);
        let caps = allocate_proportional(120, &pops);
        assert_eq!(caps.values().sum::<usize>(), 120);
        assert!(caps[&9] >= 1, "minority stratum starved: {caps:?}");
        assert!(caps[&2] > caps[&0], "shares must track populations");
        // Determinism.
        assert_eq!(caps, allocate_proportional(120, &pops));
        // Degenerate inputs.
        assert!(allocate_proportional(10, &BTreeMap::new()).is_empty());
        assert!(allocate_proportional(10, &BTreeMap::from([(0u32, 0u64)])).is_empty());
        let one = allocate_proportional(0, &BTreeMap::from([(0u32, 5u64)]));
        assert_eq!(one.values().sum::<usize>(), 0);
    }

    #[test]
    fn capacities_sum_to_sample_size() {
        let mut sampler = StratifiedSampler::new(777, 100, Rng::new(15));
        sampler.offer_all(window(3_000, 16).into_iter().take(3_000));
        let caps = sampler.proportional_capacities();
        assert_eq!(caps.values().sum::<usize>(), 777);
    }

    #[test]
    fn late_stratum_gets_slots_after_realloc() {
        // Stratum 5 appears only in the second half of the window.
        let mut items = window(4_000, 17);
        items.truncate(4_000);
        for r in items.iter_mut().skip(2_000).take(1_000) {
            r.stratum = 5;
        }
        let s = StratifiedSampler::sample_window(&items, 400, 200, Rng::new(18));
        let share = s.stratum(5).len() as f64 / s.total_len() as f64;
        // 1000/4000 = 25% of the window.
        assert!(share > 0.15, "late stratum share {share}");
    }

    #[test]
    fn uniformity_within_stratum() {
        // Within one stratum, first-half and second-half items should be
        // sampled at comparable rates (reservoir uniformity).
        let n = 20_000;
        let items: Vec<Record> =
            (0..n).map(|i| Record::new(i as u64, 0, 0, 0, 1.0)).collect();
        let mut first_half = 0usize;
        let trials = 40;
        for t in 0..trials {
            let s =
                StratifiedSampler::sample_window(&items, 1000, 500, Rng::new(100 + t));
            first_half += s.stratum(0).iter().filter(|r| r.id < n as u64 / 2).count();
        }
        let frac = first_half as f64 / (trials as usize * 1000) as f64;
        assert!((frac - 0.5).abs() < 0.05, "first-half fraction {frac}");
    }
}
