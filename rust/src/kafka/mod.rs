//! In-process stream-aggregator substrate (the paper's Apache Kafka role).
//!
//! IncApprox only relies on Kafka for: (i) merging many producer
//! sub-streams into per-topic partitioned logs, (ii) offset-tracked *pull*
//! consumption, and (iii) replayability. This module provides exactly
//! those semantics in-process and thread-safe: [`Broker`] owns topics,
//! each topic owns partitioned append-only logs, [`Producer`]s publish
//! (keyed or round-robin partitioning), [`Consumer`]s pull from committed
//! offsets. Payloads are generic — the pipeline uses
//! [`crate::workload::Record`].

pub mod broker;
pub mod consumer;
pub mod log;
pub mod producer;

pub use broker::Broker;
pub use consumer::Consumer;
pub use log::{Message, PartitionLog};
pub use producer::{Partitioner, Producer};
