//! In-process stream-aggregator substrate (the paper's Apache Kafka role).
//!
//! IncApprox only relies on Kafka for: (i) merging many producer
//! sub-streams into per-topic partitioned logs, (ii) offset-tracked *pull*
//! consumption, and (iii) replayability. This module provides exactly
//! those semantics in-process and thread-safe: [`Broker`] owns topics,
//! each topic owns partitioned append-only logs, [`Producer`]s publish
//! (keyed or round-robin partitioning), [`Consumer`]s pull from committed
//! offsets. Payloads are generic — the pipeline uses
//! [`crate::workload::Record`].
//!
//! # Example
//!
//! The full produce → partition → pull cycle the session runs per slide:
//!
//! ```
//! use incapprox::kafka::{Broker, Consumer, Partitioner, Producer};
//!
//! let broker = Broker::new();
//! broker.create_topic("events", 2)?;
//!
//! // Keyed partitioning: all messages of one key stay in one partition,
//! // preserving per-sub-stream order (the paper's per-stratum streams).
//! let mut producer = Producer::new(&broker, "events", Partitioner::Keyed)?;
//! for tick in 0..6u64 {
//!     producer.send(Some(tick % 2), tick, format!("event-{tick}"))?;
//! }
//!
//! // A consumer pulls the merged stream in timestamp order and tracks
//! // its own offsets; `lag` is the backpressure signal.
//! let mut consumer = Consumer::new();
//! consumer.subscribe(&broker, "events")?;
//! assert_eq!(consumer.lag()?, 6);
//! let batch = consumer.poll(4)?;
//! assert_eq!(batch.len(), 4);
//! assert!(batch.windows(2).all(|w| w[0].timestamp <= w[1].timestamp));
//! assert_eq!(consumer.lag()?, 2); // two messages still queued
//! # Ok::<(), incapprox::Error>(())
//! ```

pub mod broker;
pub mod consumer;
pub mod log;
pub mod producer;

pub use broker::Broker;
pub use consumer::Consumer;
pub use log::{Message, PartitionLog};
pub use producer::{Partitioner, Producer};
