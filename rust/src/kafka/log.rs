//! Partitioned append-only message log.

/// A message as stored in a partition: payload plus broker metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct Message<T> {
    /// Monotonic per-partition offset.
    pub offset: u64,
    /// Producer-supplied event timestamp (logical ticks).
    pub timestamp: u64,
    /// Application payload.
    pub payload: T,
}

/// Append-only log for a single partition.
#[derive(Debug)]
pub struct PartitionLog<T> {
    records: Vec<Message<T>>,
    /// Offset of `records[0]` (> 0 once truncated).
    base_offset: u64,
}

impl<T> Default for PartitionLog<T> {
    fn default() -> Self {
        PartitionLog { records: Vec::new(), base_offset: 0 }
    }
}

impl<T: Clone> PartitionLog<T> {
    /// Empty log.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a payload; returns its offset.
    pub fn append(&mut self, timestamp: u64, payload: T) -> u64 {
        let offset = self.base_offset + self.records.len() as u64;
        self.records.push(Message { offset, timestamp, payload });
        offset
    }

    /// Next offset to be assigned (== log end offset).
    pub fn end_offset(&self) -> u64 {
        self.base_offset + self.records.len() as u64
    }

    /// Earliest retained offset.
    pub fn start_offset(&self) -> u64 {
        self.base_offset
    }

    /// Fetch up to `max` messages starting at `from` (clamped into the
    /// retained range, matching Kafka's auto-reset-to-earliest).
    pub fn fetch(&self, from: u64, max: usize) -> Vec<Message<T>> {
        let from = from.max(self.base_offset);
        if from >= self.end_offset() {
            return Vec::new();
        }
        let start = (from - self.base_offset) as usize;
        // Saturate: callers like `Consumer::backlog` pass usize::MAX to
        // mean "everything", which must not overflow past `start`.
        let end = start.saturating_add(max).min(self.records.len());
        self.records[start..end].to_vec()
    }

    /// Drop all messages with offset < `upto` (retention).
    pub fn truncate_before(&mut self, upto: u64) {
        if upto <= self.base_offset {
            return;
        }
        let n = ((upto - self.base_offset) as usize).min(self.records.len());
        self.records.drain(..n);
        self.base_offset += n as u64;
    }

    /// Number of retained messages.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True if no messages are retained.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_assigns_monotonic_offsets() {
        let mut log = PartitionLog::new();
        assert_eq!(log.append(0, "a"), 0);
        assert_eq!(log.append(1, "b"), 1);
        assert_eq!(log.append(2, "c"), 2);
        assert_eq!(log.end_offset(), 3);
    }

    #[test]
    fn fetch_respects_from_and_max() {
        let mut log = PartitionLog::new();
        for i in 0..10 {
            log.append(i, i);
        }
        let got = log.fetch(4, 3);
        assert_eq!(got.iter().map(|m| m.offset).collect::<Vec<_>>(), vec![4, 5, 6]);
        assert_eq!(got[0].payload, 4);
        assert!(log.fetch(10, 5).is_empty());
        assert_eq!(log.fetch(8, 100).len(), 2);
    }

    #[test]
    fn fetch_unbounded_max_from_mid_offset_does_not_overflow() {
        // `Consumer::backlog` passes usize::MAX as "everything"; a
        // non-zero start must saturate, not overflow `start + max`.
        let mut log = PartitionLog::new();
        for i in 0..5u64 {
            log.append(i, i);
        }
        let got = log.fetch(2, usize::MAX);
        assert_eq!(got.iter().map(|m| m.offset).collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    #[test]
    fn truncation_moves_base_and_clamps_fetch() {
        let mut log = PartitionLog::new();
        for i in 0..10 {
            log.append(i, i);
        }
        log.truncate_before(6);
        assert_eq!(log.start_offset(), 6);
        assert_eq!(log.len(), 4);
        // Fetching below the retained range resets to earliest.
        let got = log.fetch(0, 2);
        assert_eq!(got[0].offset, 6);
        // Offsets keep increasing after truncation.
        assert_eq!(log.append(99, 42), 10);
        // Truncating before base is a no-op; beyond end clears all.
        log.truncate_before(3);
        assert_eq!(log.start_offset(), 6);
        log.truncate_before(100);
        assert!(log.is_empty());
        assert_eq!(log.end_offset(), 11);
    }
}
