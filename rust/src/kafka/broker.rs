//! Thread-safe broker: named topics over partitioned logs.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use crate::error::{Error, Result};
use crate::kafka::log::{Message, PartitionLog};

/// A topic: a fixed set of partitioned logs.
pub struct Topic<T> {
    partitions: Vec<Mutex<PartitionLog<T>>>,
}

impl<T: Clone> Topic<T> {
    fn new(partitions: usize) -> Self {
        Topic {
            partitions: (0..partitions).map(|_| Mutex::new(PartitionLog::new())).collect(),
        }
    }

    /// Number of partitions.
    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// Append to one partition; returns the offset.
    pub fn append(&self, partition: usize, timestamp: u64, payload: T) -> Result<u64> {
        let log = self
            .partitions
            .get(partition)
            .ok_or_else(|| Error::Kafka(format!("partition {partition} out of range")))?;
        Ok(log.lock().unwrap().append(timestamp, payload))
    }

    /// Fetch from one partition.
    pub fn fetch(&self, partition: usize, from: u64, max: usize) -> Result<Vec<Message<T>>> {
        let log = self
            .partitions
            .get(partition)
            .ok_or_else(|| Error::Kafka(format!("partition {partition} out of range")))?;
        Ok(log.lock().unwrap().fetch(from, max))
    }

    /// Log-end offset of one partition.
    pub fn end_offset(&self, partition: usize) -> Result<u64> {
        let log = self
            .partitions
            .get(partition)
            .ok_or_else(|| Error::Kafka(format!("partition {partition} out of range")))?;
        Ok(log.lock().unwrap().end_offset())
    }

    /// Apply retention to every partition.
    pub fn truncate_before(&self, upto: u64) {
        for log in &self.partitions {
            log.lock().unwrap().truncate_before(upto);
        }
    }
}

/// The broker: a registry of topics. Cheap to clone via `Arc`.
pub struct Broker<T> {
    topics: RwLock<HashMap<String, Arc<Topic<T>>>>,
}

impl<T: Clone> Default for Broker<T> {
    fn default() -> Self {
        Broker { topics: RwLock::new(HashMap::new()) }
    }
}

impl<T: Clone> Broker<T> {
    /// Empty broker.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Create a topic (idempotent if the partition count matches).
    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<Arc<Topic<T>>> {
        if partitions == 0 {
            return Err(Error::Kafka("topic needs at least one partition".into()));
        }
        let mut topics = self.topics.write().unwrap();
        if let Some(existing) = topics.get(name) {
            if existing.partition_count() != partitions {
                return Err(Error::Kafka(format!(
                    "topic `{name}` exists with {} partitions",
                    existing.partition_count()
                )));
            }
            return Ok(existing.clone());
        }
        let topic = Arc::new(Topic::new(partitions));
        topics.insert(name.to_string(), topic.clone());
        Ok(topic)
    }

    /// Look up a topic.
    pub fn topic(&self, name: &str) -> Result<Arc<Topic<T>>> {
        self.topics
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Kafka(format!("unknown topic `{name}`")))
    }

    /// All topic names (sorted, deterministic).
    pub fn topic_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.topics.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_and_publish() {
        let broker = Broker::new();
        let topic = broker.create_topic("flows", 2).unwrap();
        topic.append(0, 1, "a").unwrap();
        topic.append(1, 1, "b").unwrap();
        assert_eq!(topic.fetch(0, 0, 10).unwrap().len(), 1);
        assert_eq!(topic.fetch(1, 0, 10).unwrap()[0].payload, "b");
    }

    #[test]
    fn create_topic_idempotent_same_partitions() {
        let broker = Broker::<u32>::new();
        broker.create_topic("t", 3).unwrap();
        assert!(broker.create_topic("t", 3).is_ok());
        assert!(broker.create_topic("t", 4).is_err());
        assert!(broker.create_topic("zero", 0).is_err());
    }

    #[test]
    fn unknown_topic_and_partition_errors() {
        let broker = Broker::<u32>::new();
        assert!(broker.topic("missing").is_err());
        let t = broker.create_topic("t", 1).unwrap();
        assert!(t.append(5, 0, 1).is_err());
        assert!(t.fetch(5, 0, 1).is_err());
    }

    #[test]
    fn concurrent_producers_keep_all_messages() {
        let broker = Broker::new();
        let topic = broker.create_topic("t", 4).unwrap();
        std::thread::scope(|scope| {
            for w in 0..8u64 {
                let topic = topic.clone();
                scope.spawn(move || {
                    for i in 0..250u64 {
                        topic.append((w as usize + i as usize) % 4, i, w * 1000 + i).unwrap();
                    }
                });
            }
        });
        let total: usize = (0..4)
            .map(|p| topic.fetch(p, 0, usize::MAX).unwrap().len())
            .sum();
        assert_eq!(total, 2000);
    }

    #[test]
    fn topic_names_sorted() {
        let broker = Broker::<u8>::new();
        broker.create_topic("zeta", 1).unwrap();
        broker.create_topic("alpha", 1).unwrap();
        assert_eq!(broker.topic_names(), vec!["alpha", "zeta"]);
    }
}
