//! Thread-safe broker: named topics over partitioned logs.
//!
//! Every fallible broker path reachable from library code returns a
//! typed [`Error::Kafka`] — out-of-range partitions, poisoned locks (a
//! producer panicking mid-append), and operations on a dropped topic all
//! surface as errors, never panics. Consumers hold `Arc<Topic>` handles,
//! so [`Broker::drop_topic`] marks the topic dropped instead of freeing
//! it: in-flight handles see the error on their next operation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};

use crate::error::{Error, Result};
use crate::kafka::log::{Message, PartitionLog};

/// A topic: a fixed set of partitioned logs.
pub struct Topic<T> {
    partitions: Vec<Mutex<PartitionLog<T>>>,
    /// Set by [`Broker::drop_topic`]; checked by every operation so
    /// consumers still holding an `Arc` to this topic get a typed error
    /// instead of silently reading a zombie log.
    dropped: AtomicBool,
}

impl<T: Clone> Topic<T> {
    fn new(partitions: usize) -> Self {
        Topic {
            partitions: (0..partitions).map(|_| Mutex::new(PartitionLog::new())).collect(),
            dropped: AtomicBool::new(false),
        }
    }

    /// Number of partitions.
    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// Typed-error guard for operations on a dropped topic.
    fn check_live(&self) -> Result<()> {
        if self.dropped.load(Ordering::Acquire) {
            return Err(Error::Kafka("topic was dropped".into()));
        }
        Ok(())
    }

    /// Lock one partition's log, converting an out-of-range index or a
    /// poisoned lock (a writer panicked mid-operation) into a typed
    /// error.
    fn partition(&self, partition: usize) -> Result<MutexGuard<'_, PartitionLog<T>>> {
        self.partitions
            .get(partition)
            .ok_or_else(|| Error::Kafka(format!("partition {partition} out of range")))?
            .lock()
            .map_err(|_| Error::Kafka(format!("partition {partition} lock poisoned")))
    }

    /// Append to one partition; returns the offset.
    pub fn append(&self, partition: usize, timestamp: u64, payload: T) -> Result<u64> {
        self.check_live()?;
        Ok(self.partition(partition)?.append(timestamp, payload))
    }

    /// Fetch from one partition.
    pub fn fetch(&self, partition: usize, from: u64, max: usize) -> Result<Vec<Message<T>>> {
        self.check_live()?;
        Ok(self.partition(partition)?.fetch(from, max))
    }

    /// Log-end offset of one partition.
    pub fn end_offset(&self, partition: usize) -> Result<u64> {
        self.check_live()?;
        Ok(self.partition(partition)?.end_offset())
    }

    /// Apply retention to every partition. Skips poisoned partitions
    /// (retention is best-effort) and is a no-op on a dropped topic.
    pub fn truncate_before(&self, upto: u64) {
        if self.dropped.load(Ordering::Acquire) {
            return;
        }
        for log in &self.partitions {
            if let Ok(mut guard) = log.lock() {
                guard.truncate_before(upto);
            }
        }
    }
}

/// The broker: a registry of topics. Cheap to clone via `Arc`.
pub struct Broker<T> {
    topics: RwLock<HashMap<String, Arc<Topic<T>>>>,
}

impl<T: Clone> Default for Broker<T> {
    fn default() -> Self {
        Broker { topics: RwLock::new(HashMap::new()) }
    }
}

impl<T: Clone> Broker<T> {
    /// Empty broker.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Create a topic (idempotent if the partition count matches).
    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<Arc<Topic<T>>> {
        if partitions == 0 {
            return Err(Error::Kafka("topic needs at least one partition".into()));
        }
        let mut topics = self
            .topics
            .write()
            .map_err(|_| Error::Kafka("broker registry lock poisoned".into()))?;
        if let Some(existing) = topics.get(name) {
            if existing.partition_count() != partitions {
                return Err(Error::Kafka(format!(
                    "topic `{name}` exists with {} partitions",
                    existing.partition_count()
                )));
            }
            return Ok(existing.clone());
        }
        let topic = Arc::new(Topic::new(partitions));
        topics.insert(name.to_string(), topic.clone());
        Ok(topic)
    }

    /// Remove a topic from the registry and mark it dropped. Consumers
    /// still holding a subscription see [`Error::Kafka`] on their next
    /// poll / lag / backlog call instead of reading a zombie log.
    /// Returns an error if the topic does not exist.
    pub fn drop_topic(&self, name: &str) -> Result<()> {
        let mut topics = self
            .topics
            .write()
            .map_err(|_| Error::Kafka("broker registry lock poisoned".into()))?;
        match topics.remove(name) {
            Some(topic) => {
                topic.dropped.store(true, Ordering::Release);
                Ok(())
            }
            None => Err(Error::Kafka(format!("unknown topic `{name}`"))),
        }
    }

    /// Look up a topic.
    pub fn topic(&self, name: &str) -> Result<Arc<Topic<T>>> {
        self.topics
            .read()
            .map_err(|_| Error::Kafka("broker registry lock poisoned".into()))?
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Kafka(format!("unknown topic `{name}`")))
    }

    /// All topic names (sorted, deterministic). Returns empty on a
    /// poisoned registry (monitoring surface, best-effort).
    pub fn topic_names(&self) -> Vec<String> {
        let mut names: Vec<String> = match self.topics.read() {
            Ok(topics) => topics.keys().cloned().collect(),
            Err(_) => Vec::new(),
        };
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_and_publish() {
        let broker = Broker::new();
        let topic = broker.create_topic("flows", 2).unwrap();
        topic.append(0, 1, "a").unwrap();
        topic.append(1, 1, "b").unwrap();
        assert_eq!(topic.fetch(0, 0, 10).unwrap().len(), 1);
        assert_eq!(topic.fetch(1, 0, 10).unwrap()[0].payload, "b");
    }

    #[test]
    fn create_topic_idempotent_same_partitions() {
        let broker = Broker::<u32>::new();
        broker.create_topic("t", 3).unwrap();
        assert!(broker.create_topic("t", 3).is_ok());
        assert!(broker.create_topic("t", 4).is_err());
        assert!(broker.create_topic("zero", 0).is_err());
    }

    #[test]
    fn unknown_topic_and_partition_errors() {
        let broker = Broker::<u32>::new();
        assert!(broker.topic("missing").is_err());
        let t = broker.create_topic("t", 1).unwrap();
        assert!(t.append(5, 0, 1).is_err());
        assert!(t.fetch(5, 0, 1).is_err());
    }

    #[test]
    fn dropped_topic_errors_on_every_operation() {
        let broker = Broker::<u32>::new();
        let t = broker.create_topic("t", 2).unwrap();
        t.append(0, 1, 7).unwrap();
        broker.drop_topic("t").unwrap();
        // The registry forgets it; held handles get typed errors.
        assert!(broker.topic("t").is_err());
        assert!(t.append(0, 2, 8).is_err());
        assert!(t.fetch(0, 0, 10).is_err());
        assert!(t.end_offset(0).is_err());
        // Dropping twice is an unknown-topic error, not a panic.
        assert!(broker.drop_topic("t").is_err());
        // The name is free for reuse with a fresh log.
        let t2 = broker.create_topic("t", 1).unwrap();
        assert_eq!(t2.end_offset(0).unwrap(), 0);
    }

    #[test]
    fn concurrent_producers_keep_all_messages() {
        let broker = Broker::new();
        let topic = broker.create_topic("t", 4).unwrap();
        std::thread::scope(|scope| {
            for w in 0..8u64 {
                let topic = topic.clone();
                scope.spawn(move || {
                    for i in 0..250u64 {
                        topic.append((w as usize + i as usize) % 4, i, w * 1000 + i).unwrap();
                    }
                });
            }
        });
        let total: usize = (0..4)
            .map(|p| topic.fetch(p, 0, usize::MAX).unwrap().len())
            .sum();
        assert_eq!(total, 2000);
    }

    #[test]
    fn topic_names_sorted() {
        let broker = Broker::<u8>::new();
        broker.create_topic("zeta", 1).unwrap();
        broker.create_topic("alpha", 1).unwrap();
        assert_eq!(broker.topic_names(), vec!["alpha", "zeta"]);
    }
}
