//! Producers: publish payloads to a topic with pluggable partitioning.

use std::sync::Arc;

use crate::error::Result;
use crate::kafka::broker::{Broker, Topic};
use crate::util::hash::mix64;

/// How a producer maps a message to a partition.
#[derive(Debug, Clone, Copy)]
pub enum Partitioner {
    /// Cycle through partitions (default Kafka behaviour for unkeyed sends).
    RoundRobin,
    /// Stable hash of a message key — all messages with one key land in
    /// one partition (per-sub-stream ordering).
    Keyed,
}

/// A producer bound to one topic.
pub struct Producer<T> {
    topic: Arc<Topic<T>>,
    partitioner: Partitioner,
    rr_next: usize,
}

impl<T: Clone> Producer<T> {
    /// Bind a producer to `topic` on `broker`.
    pub fn new(broker: &Broker<T>, topic: &str, partitioner: Partitioner) -> Result<Self> {
        Ok(Producer { topic: broker.topic(topic)?, partitioner, rr_next: 0 })
    }

    fn pick_partition(&mut self, key: Option<u64>) -> usize {
        let n = self.topic.partition_count();
        match (self.partitioner, key) {
            (Partitioner::Keyed, Some(k)) => (mix64(k) % n as u64) as usize,
            _ => {
                let p = self.rr_next % n;
                self.rr_next = self.rr_next.wrapping_add(1);
                p
            }
        }
    }

    /// Publish one payload; returns `(partition, offset)`.
    pub fn send(&mut self, key: Option<u64>, timestamp: u64, payload: T) -> Result<(usize, u64)> {
        let partition = self.pick_partition(key);
        let offset = self.topic.append(partition, timestamp, payload)?;
        Ok((partition, offset))
    }

    /// Publish a batch, preserving order.
    pub fn send_batch(
        &mut self,
        items: impl IntoIterator<Item = (Option<u64>, u64, T)>,
    ) -> Result<usize> {
        let mut n = 0;
        for (key, ts, payload) in items {
            self.send(key, ts, payload)?;
            n += 1;
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_spreads_evenly() {
        let broker = Broker::new();
        broker.create_topic("t", 4).unwrap();
        let mut p = Producer::new(&broker, "t", Partitioner::RoundRobin).unwrap();
        for i in 0..100u64 {
            p.send(None, i, i).unwrap();
        }
        let topic = broker.topic("t").unwrap();
        for part in 0..4 {
            assert_eq!(topic.fetch(part, 0, usize::MAX).unwrap().len(), 25);
        }
    }

    #[test]
    fn keyed_is_sticky_per_key() {
        let broker = Broker::new();
        broker.create_topic("t", 4).unwrap();
        let mut p = Producer::new(&broker, "t", Partitioner::Keyed).unwrap();
        let mut first_partition = None;
        for i in 0..50u64 {
            let (part, _) = p.send(Some(7), i, i).unwrap();
            match first_partition {
                None => first_partition = Some(part),
                Some(fp) => assert_eq!(part, fp),
            }
        }
    }

    #[test]
    fn keyed_without_key_falls_back_to_rr() {
        let broker = Broker::new();
        broker.create_topic("t", 2).unwrap();
        let mut p = Producer::new(&broker, "t", Partitioner::Keyed).unwrap();
        let (p0, _) = p.send(None, 0, 0u32).unwrap();
        let (p1, _) = p.send(None, 1, 1u32).unwrap();
        assert_ne!(p0, p1);
    }

    #[test]
    fn batch_send_counts() {
        let broker = Broker::new();
        broker.create_topic("t", 1).unwrap();
        let mut p = Producer::new(&broker, "t", Partitioner::RoundRobin).unwrap();
        let n = p
            .send_batch((0..10u64).map(|i| (None, i, i)))
            .unwrap();
        assert_eq!(n, 10);
    }
}
