//! Pull-based consumer with per-partition offset tracking.
//!
//! Matches the paper's §4.1.1 usage: a single consumer subscribes to one
//! or more topics and iterates over the merged message stream. Merging is
//! timestamp-ordered across partitions so the coordinator sees a single
//! coherent sub-stream-tagged stream.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::kafka::broker::{Broker, Topic};
use crate::kafka::log::Message;

struct Subscription<T> {
    topic_name: String,
    topic: Arc<Topic<T>>,
    /// Next offset to fetch, per partition.
    offsets: Vec<u64>,
}

/// A consumer over one or more topics.
pub struct Consumer<T> {
    subs: Vec<Subscription<T>>,
}

impl<T: Clone> Default for Consumer<T> {
    fn default() -> Self {
        Consumer { subs: Vec::new() }
    }
}

impl<T: Clone> Consumer<T> {
    /// Consumer with no subscriptions.
    pub fn new() -> Self {
        Self::default()
    }

    /// Subscribe to a topic from the earliest retained offset.
    /// Subscribing to the same topic twice is a typed error — a
    /// duplicate subscription would double-deliver every message
    /// through the merged stream.
    pub fn subscribe(&mut self, broker: &Broker<T>, topic: &str) -> Result<()> {
        if self.subs.iter().any(|s| s.topic_name == topic) {
            return Err(Error::Kafka(format!(
                "already subscribed to topic `{topic}` (a duplicate subscription \
                 would double-deliver every message)"
            )));
        }
        let t = broker.topic(topic)?;
        let offsets = vec![0; t.partition_count()];
        self.subs.push(Subscription { topic_name: topic.to_string(), topic: t, offsets });
        Ok(())
    }

    /// Fetch up to `max` messages per partition past the committed
    /// offsets and merge them in `(timestamp, subscription, partition,
    /// offset)` order — the deterministic delivery order shared by
    /// [`Consumer::poll`] and [`Consumer::backlog`]. Does not advance
    /// offsets.
    fn fetch_merged(&self, max: usize) -> Result<Vec<(usize, usize, Message<T>)>> {
        let mut out: Vec<(usize, usize, Message<T>)> = Vec::new();
        for (si, sub) in self.subs.iter().enumerate() {
            for (pi, &from) in sub.offsets.iter().enumerate() {
                for msg in sub.topic.fetch(pi, from, max)? {
                    out.push((si, pi, msg));
                }
            }
        }
        out.sort_by(|a, b| {
            (a.2.timestamp, a.0, a.1, a.2.offset).cmp(&(b.2.timestamp, b.0, b.1, b.2.offset))
        });
        Ok(out)
    }

    /// Pull up to `max` messages, merged across all subscriptions in
    /// timestamp order (ties broken by topic/partition for determinism).
    /// Advances offsets past everything returned.
    pub fn poll(&mut self, max: usize) -> Result<Vec<Message<T>>> {
        let mut out = self.fetch_merged(max)?;
        out.truncate(max);
        let mut result = Vec::with_capacity(out.len());
        for (si, pi, msg) in out {
            self.subs[si].offsets[pi] = self.subs[si].offsets[pi].max(msg.offset + 1);
            result.push(msg);
        }
        Ok(result)
    }

    /// Every message published but not yet polled, in exactly the order
    /// [`Consumer::poll`] would deliver it, **without** advancing the
    /// committed offsets. Session checkpoints capture in-flight records
    /// this way, so a restored session replays them instead of losing
    /// them.
    pub fn backlog(&self) -> Result<Vec<Message<T>>> {
        Ok(self.fetch_merged(usize::MAX)?.into_iter().map(|(_, _, m)| m).collect())
    }

    /// Total backlog (messages available but not yet consumed) — the
    /// coordinator's backpressure signal.
    pub fn lag(&self) -> Result<u64> {
        let mut lag = 0;
        for sub in &self.subs {
            for (pi, &from) in sub.offsets.iter().enumerate() {
                lag += sub.topic.end_offset(pi)?.saturating_sub(from);
            }
        }
        Ok(lag)
    }

    /// Names of subscribed topics.
    pub fn subscriptions(&self) -> Vec<&str> {
        self.subs.iter().map(|s| s.topic_name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kafka::producer::{Partitioner, Producer};

    #[test]
    fn poll_merges_by_timestamp() {
        let broker = Broker::new();
        broker.create_topic("a", 1).unwrap();
        broker.create_topic("b", 1).unwrap();
        let mut pa = Producer::new(&broker, "a", Partitioner::RoundRobin).unwrap();
        let mut pb = Producer::new(&broker, "b", Partitioner::RoundRobin).unwrap();
        pa.send(None, 10, "a10").unwrap();
        pa.send(None, 30, "a30").unwrap();
        pb.send(None, 20, "b20").unwrap();
        let mut c = Consumer::new();
        c.subscribe(&broker, "a").unwrap();
        c.subscribe(&broker, "b").unwrap();
        let msgs = c.poll(10).unwrap();
        let ts: Vec<u64> = msgs.iter().map(|m| m.timestamp).collect();
        assert_eq!(ts, vec![10, 20, 30]);
    }

    #[test]
    fn poll_advances_offsets_no_redelivery() {
        let broker = Broker::new();
        broker.create_topic("t", 2).unwrap();
        let mut p = Producer::new(&broker, "t", Partitioner::RoundRobin).unwrap();
        for i in 0..20u64 {
            p.send(None, i, i).unwrap();
        }
        let mut c = Consumer::new();
        c.subscribe(&broker, "t").unwrap();
        let first = c.poll(8).unwrap();
        let second = c.poll(100).unwrap();
        assert_eq!(first.len(), 8);
        assert_eq!(second.len(), 12);
        let mut all: Vec<u64> = first.iter().chain(&second).map(|m| m.payload).collect();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn lag_tracks_backlog() {
        let broker = Broker::new();
        broker.create_topic("t", 1).unwrap();
        let mut p = Producer::new(&broker, "t", Partitioner::RoundRobin).unwrap();
        let mut c = Consumer::new();
        c.subscribe(&broker, "t").unwrap();
        assert_eq!(c.lag().unwrap(), 0);
        for i in 0..5u64 {
            p.send(None, i, i).unwrap();
        }
        assert_eq!(c.lag().unwrap(), 5);
        c.poll(3).unwrap();
        assert_eq!(c.lag().unwrap(), 2);
    }

    #[test]
    fn backlog_previews_poll_order_without_advancing() {
        let broker = Broker::new();
        broker.create_topic("t", 2).unwrap();
        let mut p = Producer::new(&broker, "t", Partitioner::Keyed).unwrap();
        for i in 0..10u64 {
            p.send(Some(i % 3), i, i).unwrap();
        }
        let mut c = Consumer::new();
        c.subscribe(&broker, "t").unwrap();
        c.poll(4).unwrap();
        let preview: Vec<u64> =
            c.backlog().unwrap().into_iter().map(|m| m.payload).collect();
        assert_eq!(preview.len(), 6);
        assert_eq!(c.lag().unwrap(), 6, "backlog must not advance offsets");
        let polled: Vec<u64> =
            c.poll(100).unwrap().into_iter().map(|m| m.payload).collect();
        assert_eq!(preview, polled, "backlog must mirror poll order exactly");
        assert!(c.backlog().unwrap().is_empty());
    }

    #[test]
    fn empty_poll_ok() {
        let broker = Broker::<u8>::new();
        broker.create_topic("t", 1).unwrap();
        let mut c = Consumer::new();
        c.subscribe(&broker, "t").unwrap();
        assert!(c.poll(4).unwrap().is_empty());
    }
}
