//! Stratification of unlabeled sub-streams (§6.1).
//!
//! The paper assumes the input is pre-stratified by event source; §6.1
//! sketches bootstrap-based classification for when it is not. This
//! module implements that substrate: [`BootstrapStratifier`] fits value
//! quantile cut-points on an initial reservoir using bootstrap resampling
//! (robust to the reservoir being a small sample of the stream), then
//! assigns each record a stratum by value bin.

use crate::util::rng::Rng;
use crate::workload::record::{Record, StratumId};

/// A fitted value-quantile stratifier.
#[derive(Debug, Clone)]
pub struct BootstrapStratifier {
    /// Ascending cut points; values ≤ cut[i] fall in stratum i.
    cuts: Vec<f64>,
}

impl BootstrapStratifier {
    /// Fit `strata` bins on `training` values using `resamples` bootstrap
    /// rounds: each round resamples with replacement and computes the
    /// within-round quantiles; the cut points are the bootstrap means —
    /// more stable than single-shot quantiles on small reservoirs.
    pub fn fit(training: &[f64], strata: usize, resamples: usize, rng: &mut Rng) -> Self {
        assert!(strata >= 1, "need at least one stratum");
        assert!(!training.is_empty(), "cannot fit on empty training set");
        let n = training.len();
        let n_cuts = strata - 1;
        let mut cut_sums = vec![0.0; n_cuts];
        let mut resampled = vec![0.0; n];
        for _ in 0..resamples.max(1) {
            for slot in resampled.iter_mut() {
                *slot = training[rng.below(n)];
            }
            resampled.sort_by(f64::total_cmp);
            for (ci, sum) in cut_sums.iter_mut().enumerate() {
                let q = (ci + 1) as f64 / strata as f64;
                let idx = ((q * (n - 1) as f64).round() as usize).min(n - 1);
                *sum += resampled[idx];
            }
        }
        let cuts = cut_sums.iter().map(|s| s / resamples.max(1) as f64).collect();
        BootstrapStratifier { cuts }
    }

    /// Stratum for a value.
    pub fn classify_value(&self, v: f64) -> StratumId {
        match self.cuts.iter().position(|&c| v <= c) {
            Some(i) => i as StratumId,
            None => self.cuts.len() as StratumId,
        }
    }

    /// Relabel a record's stratum by its value.
    pub fn classify(&self, mut r: Record) -> Record {
        r.stratum = self.classify_value(r.value);
        r
    }

    /// Number of strata this classifier produces.
    pub fn strata(&self) -> usize {
        self.cuts.len() + 1
    }

    /// The fitted cut points.
    pub fn cuts(&self) -> &[f64] {
        &self.cuts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_respects_cuts() {
        let s = BootstrapStratifier { cuts: vec![1.0, 2.0] };
        assert_eq!(s.classify_value(0.5), 0);
        assert_eq!(s.classify_value(1.0), 0);
        assert_eq!(s.classify_value(1.5), 1);
        assert_eq!(s.classify_value(99.0), 2);
        assert_eq!(s.strata(), 3);
    }

    #[test]
    fn fit_produces_balanced_strata_on_uniform() {
        let mut rng = Rng::new(1);
        let training: Vec<f64> = (0..5000).map(|_| rng.f64() * 100.0).collect();
        let s = BootstrapStratifier::fit(&training, 4, 50, &mut rng);
        // Cuts near 25/50/75.
        for (cut, want) in s.cuts().iter().zip([25.0, 50.0, 75.0]) {
            assert!((cut - want).abs() < 3.0, "cuts {:?}", s.cuts());
        }
        // Classification of a fresh sample is ~uniform across strata.
        let mut counts = [0usize; 4];
        for _ in 0..8000 {
            counts[s.classify_value(rng.f64() * 100.0) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 / 8000.0 - 0.25).abs() < 0.03, "{counts:?}");
        }
    }

    #[test]
    fn single_stratum_fit_has_no_cuts() {
        let mut rng = Rng::new(2);
        let s = BootstrapStratifier::fit(&[1.0, 2.0, 3.0], 1, 10, &mut rng);
        assert_eq!(s.strata(), 1);
        assert_eq!(s.classify_value(-5.0), 0);
        assert_eq!(s.classify_value(500.0), 0);
    }

    #[test]
    fn classify_record_relabels() {
        let s = BootstrapStratifier { cuts: vec![10.0] };
        let r = Record::new(1, 99, 0, 0, 3.0);
        assert_eq!(s.classify(r).stratum, 0);
        let r = Record::new(2, 99, 0, 0, 30.0);
        assert_eq!(s.classify(r).stratum, 1);
    }

    #[test]
    fn bootstrap_stabilizes_small_samples() {
        // With a tiny training set, bootstrap-averaged cuts vary less
        // across fits than single-shot (resamples=1) cuts.
        let mut rng = Rng::new(3);
        let training: Vec<f64> = (0..40).map(|_| rng.normal_with(50.0, 10.0)).collect();
        let spread = |resamples: usize, rng: &mut Rng| {
            let cuts: Vec<f64> = (0..30)
                .map(|_| BootstrapStratifier::fit(&training, 2, resamples, rng).cuts()[0])
                .collect();
            let m = cuts.iter().sum::<f64>() / cuts.len() as f64;
            (cuts.iter().map(|c| (c - m).powi(2)).sum::<f64>() / cuts.len() as f64).sqrt()
        };
        let single = spread(1, &mut rng);
        let boot = spread(60, &mut rng);
        assert!(boot < single, "bootstrap {boot} vs single {single}");
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_training_panics() {
        let mut rng = Rng::new(4);
        BootstrapStratifier::fit(&[], 2, 10, &mut rng);
    }
}
