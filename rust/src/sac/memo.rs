//! The memoization store — sharded per stratum.
//!
//! Holds (i) per-chunk sub-computation results keyed by stable content
//! hash — the map-task memo of Figure 3.1 — and (ii) the per-stratum
//! [`SampleRun`]s of the previous window's biased sample, which
//! Algorithm 4 biases the next sample toward. Algorithm 1's first step
//! (drop items older than the window start *and the dependent results*)
//! is [`MemoStore::evict_older_than`].
//!
//! Item lists are stored as `Arc`-backed [`SampleRun`]s: memoizing a
//! window's sample, reading it back for the next window's diff
//! ([`MemoStore::items_all`]) and for biasing
//! ([`MemoStore::items_for_bias`]) are all O(strata) refcount bumps —
//! no per-window record copies, and the id set built at bias time rides
//! along for O(1) membership tests in the planner.
//!
//! ## Sharding
//!
//! State is partitioned into per-stratum **shards** behind `Arc` so the
//! coordinator's parallel planning phase can read concurrently without
//! locks: a shard handle ([`MemoStore::shard`]) is a plain shared
//! reference whose only mutation is relaxed atomic hit/miss counters —
//! the memo-hit path never takes a lock. All writes (eviction,
//! memoization) happen in the serial sections of the window loop through
//! [`Arc::make_mut`] copy-on-write, which also makes
//! [`MemoStore::snapshot`] an O(shards) `Arc` clone instead of a deep
//! copy (the §6.3 replication policy snapshots every window).
//!
//! A store built with [`MemoStore::new`] has a single shard and behaves
//! exactly like the unsharded original; the coordinator builds one shard
//! per worker via [`MemoStore::sharded`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::system::ShardStrategy;
use crate::util::hash::{mix64, FastMap};

use crate::job::moments::Moments;
use crate::job::sketch::SketchBundle;
use crate::sampling::SampleRun;
use crate::workload::record::{Record, StratumId};

/// A memoized map-task result.
#[derive(Debug, Clone)]
pub struct MemoEntry {
    /// The chunk's moments.
    pub moments: Moments,
    /// Earliest item timestamp in the chunk (eviction key).
    pub min_timestamp: u64,
    /// Window that produced the entry (diagnostics / LRU-ish eviction).
    pub window_id: u64,
    /// Stratum whose sample produced the chunk. Shard placement is
    /// derived from this, so a checkpoint can re-place entries under a
    /// different shard count at restore (entries stored through the
    /// legacy stratum-less [`MemoStore::put_chunk`] carry stratum 0,
    /// which maps to shard 0 under both strategies).
    pub stratum: StratumId,
}

/// A memoized per-chunk sketch bundle (the synopsis behind the
/// `Quantile` / `TopK` / `DistinctCount` aggregate kinds), keyed by the
/// same content hash as the chunk's [`MemoEntry`]. Kept in a *side map*
/// rather than inside `MemoEntry` so windows that never register a
/// sketch query pay nothing — and so sketch lookups stay invisible to
/// [`MemoStats`] (the flat-substrate gate asserts hit/miss/evicted
/// equality across query mixes).
#[derive(Debug, Clone)]
pub struct SketchEntry {
    /// The chunk's sketches.
    pub bundle: SketchBundle,
    /// Earliest item timestamp in the chunk (eviction key).
    pub min_timestamp: u64,
    /// Window that produced the entry.
    pub window_id: u64,
    /// Stratum whose sample produced the chunk (restore re-placement).
    pub stratum: StratumId,
}

/// One stratum's memoized state, detached by
/// [`MemoStore::extract_stratum`] for shipping to another partition
/// (rebalance) and re-attached with [`MemoStore::absorb_stratum`].
/// Chunk and sketch entries are sorted by content hash so the export is
/// deterministic regardless of map-internal order.
#[derive(Debug, Clone, Default)]
pub struct StratumExport {
    /// Memoized chunk results, `(hash, entry)` sorted by hash.
    pub chunks: Vec<(u64, MemoEntry)>,
    /// Memoized chunk sketches, `(hash, entry)` sorted by hash.
    pub sketches: Vec<(u64, SketchEntry)>,
    /// The stratum's memoized sample run, if any.
    pub items: Option<SampleRun>,
    /// The stratum's combined moments, if stored.
    pub moments: Option<Moments>,
}

/// Hit/miss counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Chunk lookups that found a memoized result.
    pub hits: u64,
    /// Chunk lookups that required fresh execution.
    pub misses: u64,
    /// Entries evicted because they aged out of the window.
    pub evicted: u64,
}

impl MemoStats {
    /// hits / (hits + misses), 0 when empty.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One shard of the store: the chunk results, memoized sample runs, and
/// per-stratum moments of the strata mapped to it. Reads are `&self` and
/// lock-free (counters are relaxed atomics); all mutation goes through
/// the owning [`MemoStore`].
#[derive(Debug, Default)]
pub struct MemoShard {
    chunks: FastMap<u64, MemoEntry>,
    sketches: FastMap<u64, SketchEntry>,
    items: BTreeMap<StratumId, SampleRun>,
    stratum_moments: BTreeMap<StratumId, Moments>,
    hits: AtomicU64,
    misses: AtomicU64,
    evicted: AtomicU64,
}

impl Clone for MemoShard {
    fn clone(&self) -> Self {
        MemoShard {
            chunks: self.chunks.clone(),
            sketches: self.sketches.clone(),
            items: self.items.clone(),
            stratum_moments: self.stratum_moments.clone(),
            hits: AtomicU64::new(self.hits.load(Ordering::Relaxed)),
            misses: AtomicU64::new(self.misses.load(Ordering::Relaxed)),
            evicted: AtomicU64::new(self.evicted.load(Ordering::Relaxed)),
        }
    }
}

impl MemoShard {
    /// Look up a chunk result by content hash (counts hit/miss with
    /// relaxed atomics — the lock-free memo-hit path).
    pub fn get_chunk(&self, hash: u64) -> Option<Moments> {
        match self.chunks.get(&hash) {
            Some(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.moments)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching counters (planning diagnostics).
    pub fn contains_chunk(&self, hash: u64) -> bool {
        self.chunks.contains_key(&hash)
    }

    /// Look up a chunk's sketch bundle by content hash. Deliberately
    /// **silent** — no hit/miss accounting: [`MemoStats`] must be
    /// byte-identical whether or not sketch queries are registered
    /// (the flat-substrate gate compares stats across query mixes).
    pub fn get_chunk_sketch(&self, hash: u64) -> Option<SketchBundle> {
        self.sketches.get(&hash).map(|e| e.bundle.clone())
    }

    /// Combined moments of one stratum's previous sample, if stored.
    pub fn stratum_moments(&self, s: StratumId) -> Option<Moments> {
        self.stratum_moments.get(&s).copied()
    }

    /// Memoized items of one stratum (empty slice if absent).
    pub fn items(&self, s: StratumId) -> &[Record] {
        self.items.get(&s).map(SampleRun::records).unwrap_or(&[])
    }

    /// Number of memoized chunk results in this shard.
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }
}

/// A full copy of the store's state, for replication-based recovery
/// (§6.3 option iii). Snapshots are copy-on-write `Arc` handles — taking
/// one is O(shards); the store clones a shard lazily on its next write.
#[derive(Debug, Clone, Default)]
pub struct MemoSnapshot {
    shards: Vec<Arc<MemoShard>>,
    strategy: ShardStrategy,
}

/// The memoization store of one coordinator.
///
/// # Example
///
/// Chunk memo round-trip plus Algorithm 1's eviction:
///
/// ```
/// use incapprox::job::moments::Moments;
/// use incapprox::sac::memo::MemoStore;
///
/// let mut memo = MemoStore::new();
/// assert_eq!(memo.get_chunk(0xFEED), None); // cold: a miss
///
/// // Memoize a chunk result (min item timestamp 5, window 0)…
/// memo.put_chunk(0xFEED, Moments::from_values(&[1.0, 2.0]), 5, 0);
/// let hit = memo.get_chunk(0xFEED).expect("memoized");
/// assert_eq!(hit.count, 2.0);
/// assert_eq!(memo.stats().hits, 1);
///
/// // …then the window slides past it: the entry ages out.
/// memo.evict_older_than(10);
/// assert_eq!(memo.get_chunk(0xFEED), None);
/// assert_eq!(memo.stats().evicted, 1);
/// ```
#[derive(Debug, Clone)]
pub struct MemoStore {
    shards: Vec<Arc<MemoShard>>,
    strategy: ShardStrategy,
}

impl Default for MemoStore {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoStore {
    /// Empty single-shard store (identical behavior to the unsharded
    /// original).
    pub fn new() -> Self {
        Self::sharded(1, ShardStrategy::default())
    }

    /// Empty store with `shards` per-stratum shards (clamped to ≥ 1)
    /// assigned by `strategy`.
    pub fn sharded(shards: usize, strategy: ShardStrategy) -> Self {
        let n = shards.max(1);
        MemoStore {
            shards: (0..n).map(|_| Arc::new(MemoShard::default())).collect(),
            strategy,
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Index of the shard holding `stratum`'s state.
    pub fn shard_for(&self, stratum: StratumId) -> usize {
        let n = self.shards.len() as u64;
        match self.strategy {
            ShardStrategy::Hash => (mix64(stratum as u64) % n) as usize,
            ShardStrategy::Modulo => (stratum as u64 % n) as usize,
        }
    }

    /// Lock-free read handle to the shard holding `stratum` — the
    /// parallel planning phase's entry point.
    pub fn shard(&self, stratum: StratumId) -> &MemoShard {
        &self.shards[self.shard_for(stratum)]
    }

    fn shard_mut(&mut self, idx: usize) -> &mut MemoShard {
        Arc::make_mut(&mut self.shards[idx])
    }

    /// Look up a chunk result by content hash alone, searching shards in
    /// order (counts one hit or miss in total). Callers that know the
    /// stratum should use `shard(stratum).get_chunk(hash)` instead — a
    /// single map lookup.
    pub fn get_chunk(&self, hash: u64) -> Option<Moments> {
        for shard in &self.shards {
            if let Some(e) = shard.chunks.get(&hash) {
                shard.hits.fetch_add(1, Ordering::Relaxed);
                return Some(e.moments);
            }
        }
        self.shards[0].misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Peek without touching counters (planning phase).
    pub fn contains_chunk(&self, hash: u64) -> bool {
        self.shards.iter().any(|s| s.chunks.contains_key(&hash))
    }

    /// Memoize one chunk result under its stratum's shard.
    pub fn put_chunk_for(
        &mut self,
        stratum: StratumId,
        hash: u64,
        moments: Moments,
        min_timestamp: u64,
        window_id: u64,
    ) {
        let idx = self.shard_for(stratum);
        self.shard_mut(idx)
            .chunks
            .insert(hash, MemoEntry { moments, min_timestamp, window_id, stratum });
    }

    /// Memoize one chunk result without a stratum (stored in shard 0;
    /// pairs with the hash-only [`MemoStore::get_chunk`]).
    pub fn put_chunk(&mut self, hash: u64, moments: Moments, min_timestamp: u64, window_id: u64) {
        self.shard_mut(0)
            .chunks
            .insert(hash, MemoEntry { moments, min_timestamp, window_id, stratum: 0 });
    }

    /// Memoize one chunk's sketch bundle under its stratum's shard. Like
    /// [`MemoShard::get_chunk_sketch`], this never touches the hit/miss
    /// counters — sketch state is a silent side map.
    pub fn put_chunk_sketch_for(
        &mut self,
        stratum: StratumId,
        hash: u64,
        bundle: SketchBundle,
        min_timestamp: u64,
        window_id: u64,
    ) {
        let idx = self.shard_for(stratum);
        self.shard_mut(idx)
            .sketches
            .insert(hash, SketchEntry { bundle, min_timestamp, window_id, stratum });
    }

    /// Detach one stratum's memoized state — chunk results, chunk
    /// sketches, the memoized sample run, and the combined moments —
    /// removing it from this store. The partition rebalance path ships
    /// the export to the stratum's new owner, which re-attaches it with
    /// [`MemoStore::absorb_stratum`]. All of a stratum's entries live on
    /// its shard (`put_*` routes by stratum), so only that shard pays a
    /// COW write.
    pub fn extract_stratum(&mut self, s: StratumId) -> StratumExport {
        let idx = self.shard_for(s);
        let shard = self.shard_mut(idx);
        let mut out = StratumExport::default();
        let hashes: Vec<u64> = shard
            .chunks
            .iter()
            .filter(|(_, e)| e.stratum == s)
            .map(|(&h, _)| h)
            .collect();
        for h in hashes {
            if let Some(e) = shard.chunks.remove(&h) {
                out.chunks.push((h, e));
            }
        }
        out.chunks.sort_by_key(|(h, _)| *h);
        let hashes: Vec<u64> = shard
            .sketches
            .iter()
            .filter(|(_, e)| e.stratum == s)
            .map(|(&h, _)| h)
            .collect();
        for h in hashes {
            if let Some(e) = shard.sketches.remove(&h) {
                out.sketches.push((h, e));
            }
        }
        out.sketches.sort_by_key(|(h, _)| *h);
        out.items = shard.items.remove(&s);
        out.moments = shard.stratum_moments.remove(&s);
        out
    }

    /// Re-attach a stratum export detached by
    /// [`MemoStore::extract_stratum`] (possibly on a store with a
    /// different shard count — entries are re-placed by stratum, like
    /// the checkpoint restore path).
    pub fn absorb_stratum(&mut self, s: StratumId, export: StratumExport) {
        for (h, e) in export.chunks {
            self.put_chunk_for(s, h, e.moments, e.min_timestamp, e.window_id);
        }
        for (h, e) in export.sketches {
            self.put_chunk_sketch_for(s, h, e.bundle, e.min_timestamp, e.window_id);
        }
        if let Some(run) = export.items {
            let idx = self.shard_for(s);
            self.shard_mut(idx).items.insert(s, run);
        }
        if let Some(m) = export.moments {
            self.put_stratum_moments(s, m);
        }
    }

    /// Iterate every memoized chunk entry as `(hash, entry)`, across all
    /// shards — the checkpoint export path. Order is shard-major and
    /// hash-map-internal within a shard; consumers that need determinism
    /// (the checkpoint encoder does, for stable artifact bytes) sort by
    /// hash themselves.
    pub fn chunk_entries(&self) -> impl Iterator<Item = (u64, &MemoEntry)> + '_ {
        self.shards.iter().flat_map(|s| s.chunks.iter().map(|(&h, e)| (h, e)))
    }

    /// Iterate every memoized chunk sketch as `(hash, entry)` — the
    /// checkpoint export path for sketch state. Same ordering caveat as
    /// [`MemoStore::chunk_entries`]: the encoder sorts by hash.
    pub fn sketch_entries(&self) -> impl Iterator<Item = (u64, &SketchEntry)> + '_ {
        self.shards.iter().flat_map(|s| s.sketches.iter().map(|(&h, e)| (h, e)))
    }

    /// All per-stratum combined moments currently stored (checkpoint
    /// export; pairs with [`MemoStore::put_stratum_moments`]).
    pub fn stratum_moments_all(&self) -> BTreeMap<StratumId, Moments> {
        let mut out = BTreeMap::new();
        for shard in &self.shards {
            for (&s, &m) in &shard.stratum_moments {
                out.insert(s, m);
            }
        }
        out
    }

    /// Replace the memoized sample runs with this window's biased sample
    /// (Algorithm 1's `memo ← memoize(biasedSample)`). Runs are stored as
    /// `Arc` clones — no record copies.
    pub fn memoize_items(&mut self, per_stratum: &BTreeMap<StratumId, SampleRun>) {
        // Only touch shards that hold items now or will after — a
        // `shard_mut` on an untouched shard would still pay the COW
        // clone whenever a snapshot replica is alive.
        let mut dirty: Vec<bool> = self.shards.iter().map(|s| !s.items.is_empty()).collect();
        for &s in per_stratum.keys() {
            dirty[self.shard_for(s)] = true;
        }
        for (i, d) in dirty.into_iter().enumerate() {
            if d {
                self.shard_mut(i).items.clear();
            }
        }
        for (&s, run) in per_stratum {
            let idx = self.shard_for(s);
            self.shard_mut(idx).items.insert(s, run.clone());
        }
    }

    /// All memoized sample runs, pre-eviction — the inverse-reduce path
    /// diffs the new sample against this to find added/removed items.
    /// O(strata) `Arc` clones.
    pub fn items_all(&self) -> BTreeMap<StratumId, SampleRun> {
        let mut out = BTreeMap::new();
        for shard in &self.shards {
            for (&s, run) in &shard.items {
                out.insert(s, run.clone());
            }
        }
        out
    }

    /// Per-stratum combined moments of the previous window's sample.
    pub fn stratum_moments(&self, s: StratumId) -> Option<Moments> {
        self.shard(s).stratum_moments.get(&s).copied()
    }

    /// Store a stratum's combined moments for the next window's
    /// inverse-reduce update.
    pub fn put_stratum_moments(&mut self, s: StratumId, m: Moments) {
        let idx = self.shard_for(s);
        self.shard_mut(idx).stratum_moments.insert(s, m);
    }

    /// Memoized sample runs still valid for biasing the next window:
    /// items with `timestamp ≥ window_start` (older ones just aged out).
    /// Untouched runs — the common case once
    /// [`MemoStore::evict_older_than`] has already pruned — come back as
    /// zero-copy `Arc` clones.
    pub fn items_for_bias(&self, window_start: u64) -> BTreeMap<StratumId, SampleRun> {
        let mut out = BTreeMap::new();
        for shard in &self.shards {
            for (&s, run) in &shard.items {
                let valid = run.filter_ts(window_start);
                if !valid.is_empty() {
                    out.insert(s, valid);
                }
            }
        }
        out
    }

    /// Algorithm 1's eviction: drop memoized items older than `t` and all
    /// chunk results whose input contains such items. Shards with nothing
    /// old enough are skipped without a COW write (run `min_ts` makes the
    /// item check O(strata)).
    pub fn evict_older_than(&mut self, t: u64) {
        for i in 0..self.shards.len() {
            let needs_items = self.shards[i].items.values().any(|r| r.min_ts() < t);
            let needs_chunks =
                self.shards[i].chunks.values().any(|e| e.min_timestamp < t);
            let needs_sketches =
                self.shards[i].sketches.values().any(|e| e.min_timestamp < t);
            if !needs_items && !needs_chunks && !needs_sketches {
                continue; // nothing to evict; skip the COW clone
            }
            let shard = self.shard_mut(i);
            if needs_items {
                for run in shard.items.values_mut() {
                    if run.min_ts() < t {
                        *run = run.filter_ts(t);
                    }
                }
                shard.items.retain(|_, run| !run.is_empty());
            }
            if needs_chunks {
                let before = shard.chunks.len();
                shard.chunks.retain(|_, e| e.min_timestamp >= t);
                let gone = (before - shard.chunks.len()) as u64;
                shard.evicted.fetch_add(gone, Ordering::Relaxed);
            }
            if needs_sketches {
                // Sketch entries age out with their chunk but are not
                // counted: `evicted` must match across query mixes.
                shard.sketches.retain(|_, e| e.min_timestamp >= t);
            }
        }
    }

    /// Drop every chunk whose producing window is older than
    /// `min_window_id` — a size-bounding secondary eviction for workloads
    /// with sparse timestamps.
    pub fn evict_windows_before(&mut self, min_window_id: u64) {
        for i in 0..self.shards.len() {
            if self.shards[i].chunks.is_empty() && self.shards[i].sketches.is_empty() {
                continue;
            }
            let shard = self.shard_mut(i);
            let before = shard.chunks.len();
            shard.chunks.retain(|_, e| e.window_id >= min_window_id);
            let gone = (before - shard.chunks.len()) as u64;
            shard.evicted.fetch_add(gone, Ordering::Relaxed);
            shard.sketches.retain(|_, e| e.window_id >= min_window_id);
        }
    }

    /// Lose everything (fault injection / §6.3). Counters survive.
    pub fn clear(&mut self) {
        for i in 0..self.shards.len() {
            let shard = self.shard_mut(i);
            shard.chunks.clear();
            shard.sketches.clear();
            shard.items.clear();
            shard.stratum_moments.clear();
        }
    }

    /// Snapshot for replication-based recovery (§6.3 option iii) —
    /// O(shards) copy-on-write `Arc` clones, not a deep copy.
    pub fn snapshot(&self) -> MemoSnapshot {
        MemoSnapshot { shards: self.shards.clone(), strategy: self.strategy }
    }

    /// Restore from a snapshot (the store adopts the snapshot's shard
    /// layout).
    pub fn restore(&mut self, snap: MemoSnapshot) {
        if snap.shards.is_empty() {
            let n = self.shards.len();
            *self = MemoStore::sharded(n, self.strategy);
            return;
        }
        self.shards = snap.shards;
        self.strategy = snap.strategy;
    }

    /// Number of memoized chunk results.
    pub fn chunk_count(&self) -> usize {
        self.shards.iter().map(|s| s.chunks.len()).sum()
    }

    /// Number of memoized chunk sketch bundles.
    pub fn sketch_count(&self) -> usize {
        self.shards.iter().map(|s| s.sketches.len()).sum()
    }

    /// Total memoized items across strata.
    pub fn item_count(&self) -> usize {
        self.shards.iter().flat_map(|s| s.items.values()).map(SampleRun::len).sum()
    }

    /// Counters, summed across shards.
    pub fn stats(&self) -> MemoStats {
        let mut out = MemoStats::default();
        for s in &self.shards {
            out.hits += s.hits.load(Ordering::Relaxed);
            out.misses += s.misses.load(Ordering::Relaxed);
            out.evicted += s.evicted.load(Ordering::Relaxed);
        }
        out
    }

    /// Reset counters (per-experiment isolation). Goes through the COW
    /// path so counters of live snapshots are not clobbered.
    pub fn reset_stats(&mut self) {
        for i in 0..self.shards.len() {
            let shard = self.shard_mut(i);
            shard.hits.store(0, Ordering::Relaxed);
            shard.misses.store(0, Ordering::Relaxed);
            shard.evicted.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, stratum: StratumId, ts: u64) -> Record {
        Record::new(id, stratum, ts, 0, id as f64)
    }

    fn runs(items: &[(StratumId, Vec<Record>)]) -> BTreeMap<StratumId, SampleRun> {
        items
            .iter()
            .map(|(s, recs)| (*s, SampleRun::from_vec(recs.clone())))
            .collect()
    }

    #[test]
    fn chunk_hit_miss_accounting() {
        let mut m = MemoStore::new();
        assert_eq!(m.get_chunk(1), None);
        m.put_chunk(1, Moments::from_values(&[1.0]), 0, 0);
        assert!(m.get_chunk(1).is_some());
        assert_eq!(m.stats(), MemoStats { hits: 1, misses: 1, evicted: 0 });
        assert!((m.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn eviction_by_timestamp() {
        let mut m = MemoStore::new();
        m.put_chunk(1, Moments::EMPTY, 5, 0);
        m.put_chunk(2, Moments::EMPTY, 15, 0);
        m.evict_older_than(10);
        assert!(!m.contains_chunk(1));
        assert!(m.contains_chunk(2));
        assert_eq!(m.stats().evicted, 1);
    }

    #[test]
    fn items_for_bias_filters_by_window_start() {
        let mut m = MemoStore::new();
        let items = runs(&[
            (0u32, vec![rec(1, 0, 5), rec(2, 0, 20)]),
            (1u32, vec![rec(3, 1, 2)]),
        ]);
        m.memoize_items(&items);
        let valid = m.items_for_bias(10);
        assert_eq!(valid.len(), 1);
        assert_eq!(valid[&0].len(), 1);
        assert_eq!(valid[&0].records()[0].id, 2);
    }

    #[test]
    fn items_for_bias_is_zero_copy_when_untouched() {
        let mut m = MemoStore::new();
        m.memoize_items(&runs(&[(0u32, vec![rec(1, 0, 50), rec(2, 0, 60)])]));
        let valid = m.items_for_bias(10);
        // Same Arc allocation as the stored run: no records copied.
        assert_eq!(valid[&0].records().as_ptr(), m.shard(0).items(0).as_ptr());
    }

    #[test]
    fn evict_older_than_prunes_item_lists_too() {
        let mut m = MemoStore::new();
        m.memoize_items(&runs(&[(0u32, vec![rec(1, 0, 5), rec(2, 0, 20)])]));
        m.evict_older_than(10);
        assert_eq!(m.item_count(), 1);
    }

    #[test]
    fn window_id_eviction() {
        let mut m = MemoStore::new();
        m.put_chunk(1, Moments::EMPTY, 0, 3);
        m.put_chunk(2, Moments::EMPTY, 0, 7);
        m.evict_windows_before(5);
        assert!(!m.contains_chunk(1));
        assert!(m.contains_chunk(2));
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut m = MemoStore::new();
        m.put_chunk(1, Moments::from_values(&[2.0]), 0, 0);
        m.memoize_items(&runs(&[(0u32, vec![rec(1, 0, 0)])]));
        let snap = m.snapshot();
        m.clear();
        assert_eq!(m.chunk_count(), 0);
        m.restore(snap);
        assert_eq!(m.chunk_count(), 1);
        assert_eq!(m.item_count(), 1);
    }

    #[test]
    fn snapshot_is_isolated_from_later_writes() {
        // The COW snapshot must not see writes made after it was taken.
        let mut m = MemoStore::sharded(4, ShardStrategy::Hash);
        m.put_chunk_for(0, 10, Moments::EMPTY, 0, 0);
        let snap = m.snapshot();
        m.put_chunk_for(0, 11, Moments::EMPTY, 0, 1);
        m.clear();
        assert_eq!(m.chunk_count(), 0);
        m.restore(snap);
        assert_eq!(m.chunk_count(), 1);
        assert!(m.contains_chunk(10));
        assert!(!m.contains_chunk(11));
    }

    #[test]
    fn sharded_state_is_stratum_partitioned() {
        let mut m = MemoStore::sharded(4, ShardStrategy::Modulo);
        assert_eq!(m.shard_count(), 4);
        for s in 0..8u32 {
            m.put_chunk_for(s, 100 + s as u64, Moments::from_values(&[s as f64]), 0, 0);
            m.put_stratum_moments(s, Moments::from_values(&[s as f64]));
        }
        m.memoize_items(&runs(&[
            (0u32, vec![rec(1, 0, 0)]),
            (5u32, vec![rec(2, 5, 0), rec(3, 5, 0)]),
        ]));
        // Shard-local lookups find each stratum's state.
        for s in 0..8u32 {
            assert!(m.shard(s).get_chunk(100 + s as u64).is_some());
            assert!(m.shard(s).stratum_moments(s).is_some());
            assert_eq!(m.stratum_moments(s).unwrap().count, 1.0);
        }
        assert_eq!(m.shard(0).items(0).len(), 1);
        assert_eq!(m.shard(5).items(5).len(), 2);
        assert_eq!(m.item_count(), 3);
        assert_eq!(m.chunk_count(), 8);
        // Modulo strategy: strata 0 and 4 share a shard.
        assert_eq!(m.shard_for(0), m.shard_for(4));
        assert_ne!(m.shard_for(0), m.shard_for(1));
        // The hash-only legacy lookup still finds everything.
        assert!(m.get_chunk(105).is_some());
    }

    #[test]
    fn items_all_returns_shared_runs() {
        let mut m = MemoStore::sharded(2, ShardStrategy::Modulo);
        m.memoize_items(&runs(&[
            (0u32, vec![rec(1, 0, 3)]),
            (1u32, vec![rec(2, 1, 4), rec(3, 1, 9)]),
        ]));
        let all = m.items_all();
        assert_eq!(all.len(), 2);
        assert_eq!(all[&1].len(), 2);
        assert!(all[&1].contains(3));
        // Zero-copy: the run points at the stored allocation.
        assert_eq!(all[&0].records().as_ptr(), m.shard(0).items(0).as_ptr());
    }

    #[test]
    fn chunk_entries_export_carries_strata_for_resharding() {
        // Export from a 4-shard store and re-place into a 2-shard store:
        // every entry must land on its stratum's shard and stay findable.
        let mut m = MemoStore::sharded(4, ShardStrategy::Hash);
        for s in 0..8u32 {
            m.put_chunk_for(s, 200 + s as u64, Moments::from_values(&[s as f64]), s as u64, 1);
        }
        m.put_stratum_moments(3, Moments::from_values(&[1.0, 2.0]));
        let mut entries: Vec<(u64, MemoEntry)> =
            m.chunk_entries().map(|(h, e)| (h, e.clone())).collect();
        entries.sort_by_key(|(h, _)| *h);
        assert_eq!(entries.len(), 8);
        let mut resharded = MemoStore::sharded(2, ShardStrategy::Modulo);
        for (h, e) in &entries {
            resharded.put_chunk_for(e.stratum, *h, e.moments, e.min_timestamp, e.window_id);
        }
        for s in 0..8u32 {
            assert!(resharded.shard(s).contains_chunk(200 + s as u64), "stratum {s}");
        }
        assert_eq!(m.stratum_moments_all().len(), 1);
        assert_eq!(m.stratum_moments_all()[&3].count, 2.0);
    }

    #[test]
    fn extract_absorb_moves_exactly_one_stratum() {
        let mut src = MemoStore::sharded(4, ShardStrategy::Hash);
        for s in 0..3u32 {
            src.put_chunk_for(s, 400 + s as u64, Moments::from_values(&[s as f64]), 1, 0);
            src.put_chunk_sketch_for(s, 400 + s as u64, bundle(7, &[rec(s as u64, s, 1)]), 1, 0);
            src.put_stratum_moments(s, Moments::from_values(&[s as f64]));
        }
        src.memoize_items(&runs(&[
            (1u32, vec![rec(10, 1, 2), rec(11, 1, 3)]),
            (2u32, vec![rec(12, 2, 2)]),
        ]));
        let export = src.extract_stratum(1);
        assert_eq!(export.chunks.len(), 1);
        assert_eq!(export.sketches.len(), 1);
        assert_eq!(export.items.as_ref().map(SampleRun::len), Some(2));
        assert!(export.moments.is_some());
        // Gone from the source; other strata untouched.
        assert!(!src.contains_chunk(401));
        assert!(src.contains_chunk(400) && src.contains_chunk(402));
        assert!(src.stratum_moments(1).is_none());
        assert_eq!(src.item_count(), 1);
        // Re-attach on a store with a different shard count.
        let mut dst = MemoStore::sharded(2, ShardStrategy::Modulo);
        dst.absorb_stratum(1, export);
        assert!(dst.shard(1).contains_chunk(401));
        assert!(dst.shard(1).get_chunk_sketch(401).is_some());
        assert_eq!(dst.shard(1).items(1).len(), 2);
        assert_eq!(dst.stratum_moments(1).unwrap().count, 1.0);
        // Extracting an absent stratum is an empty export, not an error.
        let empty = src.extract_stratum(9);
        assert!(empty.chunks.is_empty() && empty.items.is_none() && empty.moments.is_none());
    }

    fn bundle(seed: u64, recs: &[Record]) -> SketchBundle {
        SketchBundle::from_records(seed, recs)
    }

    #[test]
    fn sketch_side_map_is_invisible_to_stats() {
        let mut m = MemoStore::new();
        let before = m.stats();
        // A miss, a put, then a hit — none of it shows up in MemoStats.
        assert!(m.shard(0).get_chunk_sketch(0xABC).is_none());
        m.put_chunk_sketch_for(0, 0xABC, bundle(7, &[rec(1, 0, 5)]), 5, 0);
        let got = m.shard(0).get_chunk_sketch(0xABC).expect("memoized");
        assert!(!got.is_empty());
        assert_eq!(m.sketch_count(), 1);
        assert_eq!(m.stats(), before, "sketch traffic must not move hit/miss/evicted");
    }

    #[test]
    fn sketch_entries_age_out_with_their_chunk_uncounted() {
        let mut m = MemoStore::new();
        m.put_chunk(1, Moments::EMPTY, 5, 0);
        m.put_chunk_sketch_for(0, 1, bundle(7, &[rec(1, 0, 5)]), 5, 0);
        m.put_chunk_sketch_for(0, 2, bundle(7, &[rec(2, 0, 15)]), 15, 0);
        m.evict_older_than(10);
        assert!(m.shard(0).get_chunk_sketch(1).is_none());
        assert!(m.shard(0).get_chunk_sketch(2).is_some());
        // Only the chunk result counts toward `evicted`.
        assert_eq!(m.stats().evicted, 1);
        // A sketch-only shard still gets pruned (no chunk to trigger it).
        m.evict_older_than(20);
        assert_eq!(m.sketch_count(), 0);
        assert_eq!(m.stats().evicted, 1);
    }

    #[test]
    fn sketch_entries_respect_window_eviction_and_clear() {
        let mut m = MemoStore::new();
        m.put_chunk_sketch_for(0, 1, bundle(7, &[rec(1, 0, 0)]), 0, 3);
        m.put_chunk_sketch_for(0, 2, bundle(7, &[rec(2, 0, 0)]), 0, 7);
        m.evict_windows_before(5);
        assert!(m.shard(0).get_chunk_sketch(1).is_none());
        assert!(m.shard(0).get_chunk_sketch(2).is_some());
        m.clear();
        assert_eq!(m.sketch_count(), 0);
    }

    #[test]
    fn sketch_entries_export_replaces_under_a_different_shard_count() {
        let mut m = MemoStore::sharded(4, ShardStrategy::Hash);
        for s in 0..6u32 {
            m.put_chunk_sketch_for(s, 300 + s as u64, bundle(9, &[rec(s as u64, s, 1)]), 1, 2);
        }
        let mut entries: Vec<(u64, SketchEntry)> =
            m.sketch_entries().map(|(h, e)| (h, e.clone())).collect();
        entries.sort_by_key(|(h, _)| *h);
        assert_eq!(entries.len(), 6);
        let mut resharded = MemoStore::sharded(2, ShardStrategy::Modulo);
        for (h, e) in entries {
            resharded.put_chunk_sketch_for(e.stratum, h, e.bundle, e.min_timestamp, e.window_id);
        }
        for s in 0..6u32 {
            assert!(
                resharded.shard(s).get_chunk_sketch(300 + s as u64).is_some(),
                "stratum {s}"
            );
        }
    }

    #[test]
    fn snapshot_covers_sketch_state() {
        let mut m = MemoStore::new();
        m.put_chunk_sketch_for(0, 1, bundle(7, &[rec(1, 0, 0)]), 0, 0);
        let snap = m.snapshot();
        m.put_chunk_sketch_for(0, 2, bundle(7, &[rec(2, 0, 0)]), 0, 1);
        m.clear();
        m.restore(snap);
        assert!(m.shard(0).get_chunk_sketch(1).is_some());
        assert!(m.shard(0).get_chunk_sketch(2).is_none());
    }

    #[test]
    fn concurrent_shard_reads_are_safe() {
        // The lock-free read path: many threads hammer shard handles
        // while the store is immutable.
        let mut m = MemoStore::sharded(4, ShardStrategy::Hash);
        for s in 0..16u32 {
            m.put_chunk_for(s, s as u64, Moments::from_values(&[1.0]), 0, 0);
        }
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let store = &m;
                scope.spawn(move || {
                    for round in 0..200u64 {
                        for s in 0..16u32 {
                            let hit = store.shard(s).get_chunk(s as u64);
                            assert!(hit.is_some(), "round {round}");
                        }
                    }
                });
            }
        });
        let stats = m.stats();
        assert_eq!(stats.hits, 8 * 200 * 16);
        assert_eq!(stats.misses, 0);
    }
}
