//! The memoization store.
//!
//! Holds (i) per-chunk sub-computation results keyed by stable content
//! hash — the map-task memo of Figure 3.1 — and (ii) the per-stratum item
//! lists of the previous window's biased sample, which Algorithm 4 biases
//! the next sample toward. Algorithm 1's first step (drop items older
//! than the window start *and the dependent results*) is
//! [`MemoStore::evict_older_than`].

use std::collections::BTreeMap;

use crate::util::hash::FastMap;

use crate::job::moments::Moments;
use crate::workload::record::{Record, StratumId};

/// A memoized map-task result.
#[derive(Debug, Clone)]
pub struct MemoEntry {
    /// The chunk's moments.
    pub moments: Moments,
    /// Earliest item timestamp in the chunk (eviction key).
    pub min_timestamp: u64,
    /// Window that produced the entry (diagnostics / LRU-ish eviction).
    pub window_id: u64,
}

/// Hit/miss counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Chunk lookups that found a memoized result.
    pub hits: u64,
    /// Chunk lookups that required fresh execution.
    pub misses: u64,
    /// Entries evicted because they aged out of the window.
    pub evicted: u64,
}

impl MemoStats {
    /// hits / (hits + misses), 0 when empty.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A full copy of the store's state, for replication-based recovery
/// (§6.3 option iii).
#[derive(Debug, Clone, Default)]
pub struct MemoSnapshot {
    chunks: FastMap<u64, MemoEntry>,
    items: BTreeMap<StratumId, Vec<Record>>,
    stratum_moments: BTreeMap<StratumId, Moments>,
}

/// The memoization store of one coordinator.
#[derive(Debug, Default)]
pub struct MemoStore {
    chunks: FastMap<u64, MemoEntry>,
    /// Items of the previous window's biased sample, per stratum —
    /// Algorithm 1's `memo` list.
    items: BTreeMap<StratumId, Vec<Record>>,
    /// Combined per-stratum moments of the previous window's sample —
    /// the state the §4.2.2 reduce/inverse-reduce path updates.
    stratum_moments: BTreeMap<StratumId, Moments>,
    stats: MemoStats,
}

impl MemoStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up a chunk result by content hash (counts hit/miss).
    pub fn get_chunk(&mut self, hash: u64) -> Option<Moments> {
        match self.chunks.get(&hash) {
            Some(e) => {
                self.stats.hits += 1;
                Some(e.moments)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Peek without touching counters (planning phase).
    pub fn contains_chunk(&self, hash: u64) -> bool {
        self.chunks.contains_key(&hash)
    }

    /// Memoize one chunk result.
    pub fn put_chunk(&mut self, hash: u64, moments: Moments, min_timestamp: u64, window_id: u64) {
        self.chunks.insert(hash, MemoEntry { moments, min_timestamp, window_id });
    }

    /// Replace the memoized item lists with this window's biased sample
    /// (Algorithm 1's `memo ← memoize(biasedSample)`).
    pub fn memoize_items(&mut self, per_stratum: &BTreeMap<StratumId, Vec<Record>>) {
        self.items = per_stratum.clone();
    }

    /// All memoized items, pre-eviction — the inverse-reduce path diffs
    /// the new sample against this to find added/removed items.
    pub fn items_all(&self) -> BTreeMap<StratumId, Vec<Record>> {
        self.items.clone()
    }

    /// Per-stratum combined moments of the previous window's sample.
    pub fn stratum_moments(&self, s: StratumId) -> Option<Moments> {
        self.stratum_moments.get(&s).copied()
    }

    /// Store a stratum's combined moments for the next window's
    /// inverse-reduce update.
    pub fn put_stratum_moments(&mut self, s: StratumId, m: Moments) {
        self.stratum_moments.insert(s, m);
    }

    /// Memoized items still valid for biasing the next window: items with
    /// `timestamp ≥ window_start` (older ones just aged out).
    pub fn items_for_bias(&self, window_start: u64) -> BTreeMap<StratumId, Vec<Record>> {
        let mut out = BTreeMap::new();
        for (&s, recs) in &self.items {
            let valid: Vec<Record> =
                recs.iter().filter(|r| r.timestamp >= window_start).copied().collect();
            if !valid.is_empty() {
                out.insert(s, valid);
            }
        }
        out
    }

    /// Algorithm 1's eviction: drop memoized items older than `t` and all
    /// chunk results whose input contains such items.
    pub fn evict_older_than(&mut self, t: u64) {
        for recs in self.items.values_mut() {
            recs.retain(|r| r.timestamp >= t);
        }
        self.items.retain(|_, recs| !recs.is_empty());
        let before = self.chunks.len();
        self.chunks.retain(|_, e| e.min_timestamp >= t);
        self.stats.evicted += (before - self.chunks.len()) as u64;
    }

    /// Drop every chunk whose producing window is older than
    /// `min_window_id` — a size-bounding secondary eviction for workloads
    /// with sparse timestamps.
    pub fn evict_windows_before(&mut self, min_window_id: u64) {
        let before = self.chunks.len();
        self.chunks.retain(|_, e| e.window_id >= min_window_id);
        self.stats.evicted += (before - self.chunks.len()) as u64;
    }

    /// Lose everything (fault injection / §6.3).
    pub fn clear(&mut self) {
        self.chunks.clear();
        self.items.clear();
        self.stratum_moments.clear();
    }

    /// Snapshot for replication-based recovery (§6.3 option iii).
    pub fn snapshot(&self) -> MemoSnapshot {
        MemoSnapshot {
            chunks: self.chunks.clone(),
            items: self.items.clone(),
            stratum_moments: self.stratum_moments.clone(),
        }
    }

    /// Restore from a snapshot.
    pub fn restore(&mut self, snap: MemoSnapshot) {
        self.chunks = snap.chunks;
        self.items = snap.items;
        self.stratum_moments = snap.stratum_moments;
    }

    /// Number of memoized chunk results.
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// Total memoized items across strata.
    pub fn item_count(&self) -> usize {
        self.items.values().map(Vec::len).sum()
    }

    /// Counters.
    pub fn stats(&self) -> MemoStats {
        self.stats
    }

    /// Reset counters (per-experiment isolation).
    pub fn reset_stats(&mut self) {
        self.stats = MemoStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, stratum: StratumId, ts: u64) -> Record {
        Record::new(id, stratum, ts, 0, id as f64)
    }

    #[test]
    fn chunk_hit_miss_accounting() {
        let mut m = MemoStore::new();
        assert_eq!(m.get_chunk(1), None);
        m.put_chunk(1, Moments::from_values(&[1.0]), 0, 0);
        assert!(m.get_chunk(1).is_some());
        assert_eq!(m.stats(), MemoStats { hits: 1, misses: 1, evicted: 0 });
        assert!((m.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn eviction_by_timestamp() {
        let mut m = MemoStore::new();
        m.put_chunk(1, Moments::EMPTY, 5, 0);
        m.put_chunk(2, Moments::EMPTY, 15, 0);
        m.evict_older_than(10);
        assert!(!m.contains_chunk(1));
        assert!(m.contains_chunk(2));
        assert_eq!(m.stats().evicted, 1);
    }

    #[test]
    fn items_for_bias_filters_by_window_start() {
        let mut m = MemoStore::new();
        let items = BTreeMap::from([
            (0u32, vec![rec(1, 0, 5), rec(2, 0, 20)]),
            (1u32, vec![rec(3, 1, 2)]),
        ]);
        m.memoize_items(&items);
        let valid = m.items_for_bias(10);
        assert_eq!(valid.len(), 1);
        assert_eq!(valid[&0].len(), 1);
        assert_eq!(valid[&0][0].id, 2);
    }

    #[test]
    fn evict_older_than_prunes_item_lists_too() {
        let mut m = MemoStore::new();
        m.memoize_items(&BTreeMap::from([(0u32, vec![rec(1, 0, 5), rec(2, 0, 20)])]));
        m.evict_older_than(10);
        assert_eq!(m.item_count(), 1);
    }

    #[test]
    fn window_id_eviction() {
        let mut m = MemoStore::new();
        m.put_chunk(1, Moments::EMPTY, 0, 3);
        m.put_chunk(2, Moments::EMPTY, 0, 7);
        m.evict_windows_before(5);
        assert!(!m.contains_chunk(1));
        assert!(m.contains_chunk(2));
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut m = MemoStore::new();
        m.put_chunk(1, Moments::from_values(&[2.0]), 0, 0);
        m.memoize_items(&BTreeMap::from([(0u32, vec![rec(1, 0, 0)])]));
        let snap = m.snapshot();
        m.clear();
        assert_eq!(m.chunk_count(), 0);
        m.restore(snap);
        assert_eq!(m.chunk_count(), 1);
        assert_eq!(m.item_count(), 1);
    }
}
