//! Self-adjusting computation (§3.4) — the incremental half of the
//! marriage.
//!
//! * [`ddg`] — the dynamic dependence graph: sub-computations as nodes,
//!   data/control dependencies as edges, and change propagation that
//!   marks exactly the transitively affected nodes for re-execution.
//! * [`memo`] — the memoization store: per-chunk sub-computation results
//!   keyed by stable content hash, plus the per-stratum item lists the
//!   biased sampler draws from; eviction of out-of-window entries
//!   (Algorithm 1's `memo.remove(element)` step).

pub mod ddg;
pub mod memo;

pub use ddg::{Ddg, NodeId, NodeKind};
pub use memo::{MemoEntry, MemoShard, MemoSnapshot, MemoStats, MemoStore};
