//! Dynamic dependence graph + change propagation.
//!
//! The DDG records sub-computations (nodes) and the data/control
//! dependencies between them (directed edges producer → consumer). Given
//! the set of input changes, [`Ddg::propagate`] returns, in dependency
//! order, exactly the nodes that must re-execute: the changed nodes and
//! everything transitively reachable from them. Unaffected nodes keep
//! their memoized results (Figure 3.1: fresh maps M5, M6 invalidate only
//! reduces R3, R5; R1, R2, R4 are reused).
//!
//! # Example
//!
//! A two-map, two-reduce job where only one map's input changed: the
//! untouched reduce keeps its memoized result.
//!
//! ```
//! use incapprox::sac::ddg::{Ddg, NodeKind};
//!
//! let mut g = Ddg::new();
//! let m0 = g.add_node(NodeKind::Map { chunk_hash: 0xA });
//! let m1 = g.add_node(NodeKind::Map { chunk_hash: 0xB });
//! let r0 = g.add_node(NodeKind::Reduce { group: 0 });
//! let r1 = g.add_node(NodeKind::Reduce { group: 1 });
//! let out = g.add_node(NodeKind::Output);
//! g.add_edge(m0, r0);
//! g.add_edge(m1, r1);
//! g.add_edge(r0, out);
//! g.add_edge(r1, out);
//!
//! // Only m1's chunk changed: m1 → r1 → out re-execute, in that order.
//! let affected = g.propagate(&[m1]);
//! assert_eq!(affected, vec![m1, r1, out]);
//! // m0 and r0 reuse their memoized results.
//! assert_eq!(g.reusable(&[m1]), vec![m0, r0]);
//! ```

use std::collections::VecDeque;

/// Index of a node in the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// What a node computes — mirrors the data-parallel job structure of
/// Figure 3.1 plus a generic variant for other pipelines.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeKind {
    /// A map task over one input chunk (content hash identifies it).
    Map {
        /// The chunk's stable content hash (memo key).
        chunk_hash: u64,
    },
    /// A reduce task combining map outputs (e.g. one per stratum).
    Reduce {
        /// Reduce group id (stratum for this pipeline).
        group: u64,
    },
    /// The final output node.
    Output,
    /// Anything else.
    Other(String),
}

#[derive(Debug)]
struct Node {
    kind: NodeKind,
    dependents: Vec<NodeId>,
    in_degree: usize,
}

/// The dependence graph of one job.
#[derive(Debug, Default)]
pub struct Ddg {
    nodes: Vec<Node>,
}

impl Ddg {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a sub-computation node.
    pub fn add_node(&mut self, kind: NodeKind) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.nodes.push(Node { kind, dependents: Vec::new(), in_degree: 0 });
        id
    }

    /// Record that `consumer` depends on `producer`'s output.
    pub fn add_edge(&mut self, producer: NodeId, consumer: NodeId) {
        assert!(producer.0 < self.nodes.len() && consumer.0 < self.nodes.len());
        assert_ne!(producer, consumer, "self-dependency");
        self.nodes[producer.0].dependents.push(consumer);
        self.nodes[consumer.0].in_degree += 1;
    }

    /// Node kind accessor.
    pub fn kind(&self, id: NodeId) -> &NodeKind {
        &self.nodes[id.0].kind
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Change propagation: given directly changed nodes, return all
    /// transitively affected nodes in dependency (topological) order.
    ///
    /// Every returned node must re-execute; every node *not* returned may
    /// reuse its memoized result.
    pub fn propagate(&self, changed: &[NodeId]) -> Vec<NodeId> {
        let n = self.nodes.len();
        let mut affected = vec![false; n];
        let mut queue: VecDeque<NodeId> = VecDeque::new();
        for &c in changed {
            if !affected[c.0] {
                affected[c.0] = true;
                queue.push_back(c);
            }
        }
        while let Some(node) = queue.pop_front() {
            for &dep in &self.nodes[node.0].dependents {
                if !affected[dep.0] {
                    affected[dep.0] = true;
                    queue.push_back(dep);
                }
            }
        }
        // Kahn topological order restricted to the affected set.
        let mut in_deg = vec![0usize; n];
        for (i, node) in self.nodes.iter().enumerate() {
            if !affected[i] {
                continue;
            }
            for &dep in &node.dependents {
                if affected[dep.0] {
                    in_deg[dep.0] += 1;
                }
            }
        }
        let mut ready: VecDeque<NodeId> = (0..n)
            .filter(|&i| affected[i] && in_deg[i] == 0)
            .map(NodeId)
            .collect();
        let mut order = Vec::new();
        while let Some(node) = ready.pop_front() {
            order.push(node);
            for &dep in &self.nodes[node.0].dependents {
                if affected[dep.0] {
                    in_deg[dep.0] -= 1;
                    if in_deg[dep.0] == 0 {
                        ready.push_back(dep);
                    }
                }
            }
        }
        debug_assert_eq!(
            order.len(),
            affected.iter().filter(|&&a| a).count(),
            "cycle in DDG"
        );
        order
    }

    /// Nodes *not* affected by the change set — the reuse set.
    pub fn reusable(&self, changed: &[NodeId]) -> Vec<NodeId> {
        let affected: crate::util::hash::FastSet<NodeId> =
            self.propagate(changed).into_iter().collect();
        (0..self.nodes.len())
            .map(NodeId)
            .filter(|id| !affected.contains(id))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build the Figure 3.1 graph: 6 maps, 5 reduces.
    /// M0 (removed), M1..M4 reused, M5/M6 new.
    /// Edges: M0→R3, M1→R1, M2→{R1,R2}, M3→R4, M4→{R2,R4}, M5→{R3,R5}, M6→R5.
    fn figure_3_1() -> (Ddg, Vec<NodeId>, Vec<NodeId>) {
        let mut g = Ddg::new();
        let maps: Vec<NodeId> =
            (0..7).map(|i| g.add_node(NodeKind::Map { chunk_hash: i })).collect();
        let reduces: Vec<NodeId> =
            (1..=5).map(|i| g.add_node(NodeKind::Reduce { group: i })).collect();
        let r = |i: usize| reduces[i - 1];
        g.add_edge(maps[0], r(3));
        g.add_edge(maps[1], r(1));
        g.add_edge(maps[2], r(1));
        g.add_edge(maps[2], r(2));
        g.add_edge(maps[3], r(4));
        g.add_edge(maps[4], r(2));
        g.add_edge(maps[4], r(4));
        g.add_edge(maps[5], r(3));
        g.add_edge(maps[5], r(5));
        g.add_edge(maps[6], r(5));
        (g, maps, reduces)
    }

    #[test]
    fn figure_3_1_change_propagation() {
        let (g, maps, reduces) = figure_3_1();
        // Changes: M0 removed, M5 and M6 newly computed.
        let affected = g.propagate(&[maps[0], maps[5], maps[6]]);
        let affected: std::collections::HashSet<NodeId> = affected.into_iter().collect();
        // R3 and R5 re-execute; R1, R2, R4 are reused.
        assert!(affected.contains(&reduces[2])); // R3
        assert!(affected.contains(&reduces[4])); // R5
        assert!(!affected.contains(&reduces[0])); // R1
        assert!(!affected.contains(&reduces[1])); // R2
        assert!(!affected.contains(&reduces[3])); // R4
    }

    #[test]
    fn reusable_is_complement() {
        let (g, maps, _) = figure_3_1();
        let changed = vec![maps[0], maps[5], maps[6]];
        let affected = g.propagate(&changed);
        let reusable = g.reusable(&changed);
        assert_eq!(affected.len() + reusable.len(), g.len());
    }

    #[test]
    fn topological_order_respected() {
        let mut g = Ddg::new();
        let a = g.add_node(NodeKind::Map { chunk_hash: 0 });
        let b = g.add_node(NodeKind::Reduce { group: 0 });
        let c = g.add_node(NodeKind::Output);
        g.add_edge(a, b);
        g.add_edge(b, c);
        let order = g.propagate(&[a]);
        assert_eq!(order, vec![a, b, c]);
    }

    #[test]
    fn diamond_visits_once() {
        let mut g = Ddg::new();
        let src = g.add_node(NodeKind::Map { chunk_hash: 0 });
        let l = g.add_node(NodeKind::Reduce { group: 0 });
        let r = g.add_node(NodeKind::Reduce { group: 1 });
        let sink = g.add_node(NodeKind::Output);
        g.add_edge(src, l);
        g.add_edge(src, r);
        g.add_edge(l, sink);
        g.add_edge(r, sink);
        let order = g.propagate(&[src]);
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], src);
        assert_eq!(*order.last().unwrap(), sink);
    }

    #[test]
    fn no_changes_no_work() {
        let (g, _, _) = figure_3_1();
        assert!(g.propagate(&[]).is_empty());
        assert_eq!(g.reusable(&[]).len(), g.len());
    }

    #[test]
    fn duplicate_changes_deduped() {
        let (g, maps, _) = figure_3_1();
        let a = g.propagate(&[maps[5], maps[5], maps[5]]);
        let b = g.propagate(&[maps[5]]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "self-dependency")]
    fn self_edge_rejected() {
        let mut g = Ddg::new();
        let a = g.add_node(NodeKind::Output);
        g.add_edge(a, a);
    }
}
