//! Sliding-window computation model (§2.3.2, Figure 2.3) — delta-first.
//!
//! The coordinator consumes the aggregated stream in slide-sized batches;
//! the window manager maintains the current computation window and reports
//! the **delta** (inserted / removed items) between adjacent windows — the
//! input-change set that drives change propagation in `sac/` and the
//! persistent sampler in `sampling::incremental`.
//!
//! Snapshots are **delta-first**: the change set, the window length, and
//! the eviction horizon (`start_ts`) are always present and cost O(delta)
//! to produce; the full view is materialized (as a [`ColumnarBatch`]
//! with a cached row slice) only when a consumer asks for it — the exact
//! modes and the from-scratch baseline do, the incremental O(delta)
//! slide path does not, so a slide never pays an O(window) copy it
//! doesn't need. Deltas likewise ship columnar (the batched rank and
//! inverse-chunk kernels consume the columns directly), with lazy row
//! views for legacy callers.
//!
//! Two window kinds:
//! * [`CountWindow`] — fixed item count with item-count slide. This is what
//!   §5's figures parameterize ("window of 10 000 items, slide 4%"), and
//!   what the benches use.
//! * [`TimeWindow`] — time length + slide in ticks; item counts per window
//!   vary with arrival rate (the paper's stated general model, §2.3.3).

use std::collections::VecDeque;

use crate::columnar::ColumnarBatch;
use crate::workload::record::{Record, StratumId};

/// The change set between two adjacent windows, stored columnar: the
/// batched rank kernel scores `inserted().ids()` in one pass and the
/// inverse-reduce planner chunks the removal columns directly. Row views
/// are lazy ([`ColumnarBatch::rows`]) for legacy callers.
#[derive(Debug, Clone, Default)]
pub struct WindowDelta {
    inserted: ColumnarBatch,
    removed: ColumnarBatch,
}

impl WindowDelta {
    /// Build from row vectors (transposes once) — the windows construct
    /// deltas here, and tests hand-roll change sets through it.
    pub fn from_rows(inserted: Vec<Record>, removed: Vec<Record>) -> Self {
        WindowDelta {
            inserted: ColumnarBatch::from_vec(inserted),
            removed: ColumnarBatch::from_vec(removed),
        }
    }

    /// Items that entered the window this slide (slide order).
    pub fn inserted(&self) -> &ColumnarBatch {
        &self.inserted
    }

    /// Items that fell out of the window this slide (eviction order).
    pub fn removed(&self) -> &ColumnarBatch {
        &self.removed
    }

    /// |inserted| + |removed| — the input-change size that O(delta) work
    /// is proportional to.
    pub fn len(&self) -> usize {
        self.inserted.len() + self.removed.len()
    }

    /// True when the window did not change.
    pub fn is_empty(&self) -> bool {
        self.inserted.is_empty() && self.removed.is_empty()
    }
}

/// A window snapshot handed to the sampling stage.
///
/// Always carries the delta, the item count, and the smallest in-window
/// timestamp; the full view is optional (see module docs), columnar, and
/// `Arc`-backed so cloning a snapshot never copies records. The row
/// slice the exact modes consume is cached inside the batch at
/// materialization time, so neither representation pays for the other.
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    /// Monotonic window sequence number.
    pub window_id: u64,
    /// Number of items currently in the window.
    pub len: usize,
    /// Smallest timestamp in the window (0 when empty) — Algorithm 1's
    /// memo-eviction horizon.
    pub start_ts: u64,
    /// Change set vs. the previous window.
    pub delta: WindowDelta,
    /// Full columnar view, present only when the slide materialized it.
    columns: Option<ColumnarBatch>,
}

impl WindowSnapshot {
    /// The full window view, if this snapshot materialized one.
    pub fn full_view(&self) -> Option<&[Record]> {
        self.columns.as_ref().map(ColumnarBatch::rows)
    }

    /// The full columnar view, if this snapshot materialized one — what
    /// the sampler rebuild and sketch/chunk kernels consume.
    pub fn columns(&self) -> Option<&ColumnarBatch> {
        self.columns.as_ref()
    }

    /// The full window view; panics when the snapshot was taken
    /// delta-only (use [`WindowSnapshot::full_view`] to probe).
    pub fn items(&self) -> &[Record] {
        // lint:allow(panic-freedom) -- documented panicking accessor; full_view() is the probing sibling
        self.full_view().expect("window snapshot has no full view (delta-only slide)")
    }

    /// Whether the full view was materialized.
    pub fn has_full_view(&self) -> bool {
        self.columns.is_some()
    }

    /// True when the window holds no items.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Count-based sliding window.
#[derive(Debug)]
pub struct CountWindow {
    size: usize,
    buf: VecDeque<Record>,
    /// Monotonic `(timestamp, id)` queue: the front is the minimum
    /// timestamp of the buffered window, maintained in O(1) amortized per
    /// slide item, so a delta-only snapshot never scans the window.
    min_ts: VecDeque<(u64, u64)>,
    /// Items evicted by [`CountWindow::resize`], reported in the next
    /// slide's delta so downstream incremental state stays consistent.
    pending_removed: Vec<Record>,
    next_window_id: u64,
}

impl CountWindow {
    /// Window holding exactly `size` items once warm.
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        CountWindow {
            size,
            buf: VecDeque::with_capacity(size + 1),
            min_ts: VecDeque::new(),
            pending_removed: Vec::new(),
            next_window_id: 0,
        }
    }

    fn push(&mut self, r: Record) {
        while self.min_ts.back().map_or(false, |&(ts, _)| ts > r.timestamp) {
            self.min_ts.pop_back();
        }
        self.min_ts.push_back((r.timestamp, r.id));
        self.buf.push_back(r);
    }

    /// Pop the oldest buffered record, maintaining the min-timestamp
    /// deque; `None` on an empty buffer.
    fn evict_front(&mut self) -> Option<Record> {
        let r = self.buf.pop_front()?;
        if self.min_ts.front().map_or(false, |&(_, id)| id == r.id) {
            self.min_ts.pop_front();
        }
        Some(r)
    }

    /// Push one slide's worth of new items; returns the new window
    /// snapshot with the full item view materialized. Items beyond
    /// `size` fall out FIFO (oldest first).
    pub fn slide(&mut self, batch: Vec<Record>) -> WindowSnapshot {
        self.slide_with(batch, true)
    }

    /// [`CountWindow::slide`] with explicit control over the full view:
    /// `materialize = false` skips the O(window) item copy and produces a
    /// delta-only snapshot (`len` and `start_ts` are still exact) — the
    /// incremental slide path of the coordinator.
    pub fn slide_with(&mut self, batch: Vec<Record>, materialize: bool) -> WindowSnapshot {
        let mut removed = std::mem::take(&mut self.pending_removed);
        for r in &batch {
            self.push(*r);
            if self.buf.len() > self.size {
                if let Some(evicted) = self.evict_front() {
                    removed.push(evicted);
                }
            }
        }
        let id = self.next_window_id;
        self.next_window_id += 1;
        WindowSnapshot {
            window_id: id,
            len: self.buf.len(),
            start_ts: self.min_ts.front().map_or(0, |&(ts, _)| ts),
            columns: materialize
                .then(|| ColumnarBatch::from_rows_cached(self.buf.iter().copied().collect())),
            delta: WindowDelta::from_rows(batch, removed),
        }
    }

    /// Externally-driven slide for **partitioned** windows: push `batch`
    /// and evict exactly `evict` items FIFO, regardless of the
    /// configured size. The partition merge tier routes records by
    /// stratum and computes per-partition eviction counts by simulating
    /// the *global* FIFO window, so capacity is enforced globally — a
    /// partition's buffer is the global window restricted to its strata
    /// and never exceeds the global size on its own.
    ///
    /// Batch-then-evict is equivalent to the interleaved push/evict of
    /// [`CountWindow::slide_with`]: eviction is FIFO, so the evicted
    /// records and their order depend only on the count, never on how
    /// pushes and evictions interleave within one slide.
    pub fn slide_external(
        &mut self,
        batch: Vec<Record>,
        evict: usize,
        materialize: bool,
    ) -> WindowSnapshot {
        let mut removed = std::mem::take(&mut self.pending_removed);
        for r in &batch {
            self.push(*r);
        }
        for _ in 0..evict {
            if let Some(evicted) = self.evict_front() {
                removed.push(evicted);
            }
        }
        let id = self.next_window_id;
        self.next_window_id += 1;
        WindowSnapshot {
            window_id: id,
            len: self.buf.len(),
            start_ts: self.min_ts.front().map_or(0, |&(ts, _)| ts),
            columns: materialize
                .then(|| ColumnarBatch::from_rows_cached(self.buf.iter().copied().collect())),
            delta: WindowDelta::from_rows(batch, removed),
        }
    }

    /// Remove and return every buffered record of `stratum` (in buffer
    /// order), rebuilding the min-timestamp deque over the survivors —
    /// the window half of shipping a stratum to another partition.
    /// Pending resize evictions are untouched (partitioned windows do
    /// not resize).
    pub fn extract_stratum(&mut self, stratum: StratumId) -> Vec<Record> {
        let mut taken = Vec::new();
        let mut kept = Vec::with_capacity(self.buf.len());
        for r in self.buf.drain(..) {
            if r.stratum == stratum {
                taken.push(r);
            } else {
                kept.push(r);
            }
        }
        self.min_ts.clear();
        for r in kept {
            self.push(r);
        }
        taken
    }

    /// Merge records exported by [`CountWindow::extract_stratum`] on
    /// another partition into this buffer, restoring global arrival
    /// order by sorting on `(timestamp, id)` — valid because the
    /// workload generator assigns ids monotonically in arrival order, so
    /// `(timestamp, id)` *is* arrival order. The min-timestamp deque is
    /// rebuilt from scratch.
    pub fn splice_records(&mut self, incoming: Vec<Record>) {
        let mut all: Vec<Record> = self.buf.drain(..).collect();
        all.extend(incoming);
        all.sort_by_key(|r| (r.timestamp, r.id));
        self.min_ts.clear();
        for r in all {
            self.push(r);
        }
    }

    /// Change the target size (Fig 5.1(c) varies window size between
    /// adjacent windows). Shrinking evicts oldest items immediately; the
    /// evicted items are returned **and** queued into the *next* slide's
    /// `delta.removed`, so delta-driven consumers (persistent sampler,
    /// inverse-reduce planning) observe the eviction exactly once.
    ///
    /// The return value is for inspection only — the snapshot deltas are
    /// the single source of truth. Do not feed the returned records into
    /// a delta-driven consumer as well, or the eviction is applied twice.
    pub fn resize(&mut self, new_size: usize) -> Vec<Record> {
        assert!(new_size > 0);
        self.size = new_size;
        let mut evicted = Vec::new();
        while self.buf.len() > self.size {
            let Some(r) = self.evict_front() else { break };
            evicted.push(r);
        }
        self.pending_removed.extend(evicted.iter().copied());
        evicted
    }

    /// Current item count.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no items buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Configured capacity.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Sequence number the next slide's snapshot will carry.
    pub fn next_window_id(&self) -> u64 {
        self.next_window_id
    }

    /// Export the window's durable state for checkpointing: the buffered
    /// records in insertion order, plus resize evictions still pending
    /// for the next slide's delta. The min-timestamp deque is *not*
    /// exported — it is a pure function of the buffer order and
    /// [`CountWindow::restore_parts`] rebuilds it.
    pub fn checkpoint_parts(&self) -> (Vec<Record>, Vec<Record>) {
        (self.buf.iter().copied().collect(), self.pending_removed.clone())
    }

    /// Rebuild a window from state exported by
    /// [`CountWindow::checkpoint_parts`] (plus the configured `size` and
    /// the [`CountWindow::next_window_id`] sequence number). Records are
    /// re-pushed in order, which reconstructs the exact monotonic
    /// min-timestamp deque the live window held.
    pub fn restore_parts(
        size: usize,
        buf: Vec<Record>,
        pending_removed: Vec<Record>,
        next_window_id: u64,
    ) -> Self {
        let mut w = CountWindow::new(size.max(1));
        for r in buf {
            w.push(r);
        }
        w.pending_removed = pending_removed;
        w.next_window_id = next_window_id;
        w
    }
}

/// Time-based sliding window (length and slide in logical ticks).
///
/// The buffer is kept in non-decreasing timestamp order (enforced by a
/// debug assertion in [`TimeWindow::ingest`]); window membership and the
/// delta are derived positionally, so one emit costs O(delta) plus a
/// binary search — not a scan of the buffer.
#[derive(Debug)]
pub struct TimeWindow {
    length: u64,
    slide: u64,
    /// Exclusive end of the last emitted window.
    next_end: u64,
    buf: VecDeque<Record>,
    /// Length of the buffered prefix that belonged to the previously
    /// emitted window — the positional anchor the delta is derived from.
    in_window: usize,
    next_window_id: u64,
}

impl TimeWindow {
    /// Window covering `[end-length, end)` sliding by `slide` ticks.
    pub fn new(length: u64, slide: u64) -> Self {
        assert!(length > 0 && slide > 0 && slide <= length);
        TimeWindow {
            length,
            slide,
            next_end: length,
            buf: VecDeque::new(),
            in_window: 0,
            next_window_id: 0,
        }
    }

    /// Feed records (must arrive in non-decreasing timestamp order).
    pub fn ingest(&mut self, records: impl IntoIterator<Item = Record>) {
        for r in records {
            debug_assert!(self.buf.back().map_or(true, |b| b.timestamp <= r.timestamp));
            self.buf.push_back(r);
        }
    }

    /// Emit the next window if all its data (ticks < end) has been seen,
    /// i.e. `now >= end`, with the full item view materialized. Removes
    /// items older than the new start.
    pub fn try_emit(&mut self, now: u64) -> Option<WindowSnapshot> {
        self.try_emit_with(now, true)
    }

    /// [`TimeWindow::try_emit`] with explicit control over the full view
    /// (`materialize = false` produces a delta-only snapshot, skipping
    /// the O(window) copy).
    pub fn try_emit_with(&mut self, now: u64, materialize: bool) -> Option<WindowSnapshot> {
        if now < self.next_end {
            return None;
        }
        let end = self.next_end;
        let start = end.saturating_sub(self.length);
        // Remove all old items from the window (Algorithm 1: timestamp
        // < t). Only items that belonged to the previously emitted window
        // are reported as removed; pre-window stragglers just drop.
        let mut removed = Vec::new();
        while let Some(front) = self.buf.front() {
            if front.timestamp >= start {
                break;
            }
            let Some(r) = self.buf.pop_front() else { break };
            if self.in_window > 0 {
                self.in_window -= 1;
                removed.push(r);
            }
        }
        // The window is the buffered prefix with timestamp < end (the
        // buffer is timestamp-ordered).
        let cut = self.buf.partition_point(|r| r.timestamp < end);
        // Inserted this slide: exactly the in-window items beyond the
        // previous window's surviving prefix. Positional, so items that
        // were already buffered ahead of the previous window's end are
        // picked up when the window reaches them.
        let inserted: Vec<Record> = self.buf.range(self.in_window..cut).copied().collect();
        let start_ts = if cut > 0 { self.buf.front().map_or(0, |r| r.timestamp) } else { 0 };
        let columns = materialize
            .then(|| ColumnarBatch::from_rows_cached(self.buf.range(..cut).copied().collect()));
        self.in_window = cut;
        let id = self.next_window_id;
        self.next_window_id += 1;
        self.next_end += self.slide;
        Some(WindowSnapshot {
            window_id: id,
            len: cut,
            start_ts,
            columns,
            delta: WindowDelta::from_rows(inserted, removed),
        })
    }

    /// Configured (length, slide).
    pub fn params(&self) -> (u64, u64) {
        (self.length, self.slide)
    }

    /// Sequence number the next emitted snapshot will carry.
    pub fn next_window_id(&self) -> u64 {
        self.next_window_id
    }

    /// Export the window's durable state for checkpointing: the buffered
    /// records (timestamp order, including records buffered ahead of the
    /// current window), the exclusive end of the next window, and the
    /// length of the prefix belonging to the previously emitted window.
    pub fn checkpoint_parts(&self) -> (Vec<Record>, u64, usize) {
        (self.buf.iter().copied().collect(), self.next_end, self.in_window)
    }

    /// Rebuild a window from state exported by
    /// [`TimeWindow::checkpoint_parts`] plus the constructor params and
    /// the [`TimeWindow::next_window_id`] sequence number.
    pub fn restore_parts(
        length: u64,
        slide: u64,
        buf: Vec<Record>,
        next_end: u64,
        in_window: usize,
        next_window_id: u64,
    ) -> Self {
        let mut w = TimeWindow::new(length.max(1), slide.clamp(1, length.max(1)));
        w.buf = buf.into();
        w.next_end = next_end;
        w.in_window = in_window.min(w.buf.len());
        w.next_window_id = next_window_id;
        w
    }

    /// The records of the previously emitted window (the prefix the
    /// positional delta anchors on) — what a restored coordinator rebuilds
    /// its persistent sampler from.
    pub fn window_records(&self) -> Vec<Record> {
        self.buf.range(..self.in_window).copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, ts: u64) -> Record {
        Record::new(id, 0, ts, 0, id as f64)
    }

    /// Check a materialized snapshot's derived fields against its items.
    fn assert_consistent(snap: &WindowSnapshot) {
        let items = snap.items();
        assert_eq!(snap.len, items.len());
        let want_start = items.iter().map(|r| r.timestamp).min().unwrap_or(0);
        assert_eq!(snap.start_ts, want_start);
    }

    #[test]
    fn count_window_warms_then_slides() {
        let mut w = CountWindow::new(10);
        let snap = w.slide((0..10).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items().len(), 10);
        assert!(snap.delta.removed().is_empty());
        assert_consistent(&snap);
        let snap = w.slide((10..14).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items().len(), 10);
        assert_eq!(snap.delta.inserted().len(), 4);
        assert_eq!(
            snap.delta.removed().ids().to_vec(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(snap.items()[0].id, 4);
        assert_consistent(&snap);
    }

    #[test]
    fn count_window_overlap_invariant() {
        // |overlap| == size - slide for a warm window.
        let mut w = CountWindow::new(100);
        w.slide((0..100).map(|i| rec(i, 0)).collect());
        let s2 = w.slide((100..116).map(|i| rec(i, 1)).collect());
        let overlap = s2.items().iter().filter(|r| r.id < 100).count();
        assert_eq!(overlap, 84);
    }

    #[test]
    fn count_window_resize_evicts_oldest() {
        let mut w = CountWindow::new(10);
        w.slide((0..10).map(|i| rec(i, i)).collect());
        let evicted = w.resize(6);
        assert_eq!(evicted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(w.len(), 6);
        assert!(w.resize(20).is_empty());
    }

    #[test]
    fn count_window_resize_reports_evictions_in_next_delta() {
        // Delta consumers must observe resize evictions exactly once, in
        // the next slide's `removed`.
        let mut w = CountWindow::new(10);
        w.slide((0..10).map(|i| rec(i, i)).collect());
        let evicted = w.resize(6);
        assert_eq!(evicted.len(), 4);
        let snap = w.slide(vec![rec(100, 100)]);
        let removed_ids: Vec<u64> = snap.delta.removed().ids().to_vec();
        assert_eq!(removed_ids, vec![0, 1, 2, 3, 4]); // 4 resized out + 1 slid out
        assert_eq!(snap.len, 6);
        assert_consistent(&snap);
        // Nothing double-reported on the following slide.
        let snap = w.slide(vec![]);
        assert!(snap.delta.removed().is_empty());
    }

    #[test]
    fn window_ids_monotone() {
        let mut w = CountWindow::new(4);
        let a = w.slide(vec![rec(0, 0)]);
        let b = w.slide(vec![rec(1, 1)]);
        assert_eq!(a.window_id, 0);
        assert_eq!(b.window_id, 1);
    }

    #[test]
    fn count_window_empty_slide_and_empty_window() {
        // Edge: sliding with no new items — including on a cold window —
        // must produce a well-formed (possibly empty) snapshot.
        let mut w = CountWindow::new(4);
        let snap = w.slide(vec![]);
        assert_eq!(snap.window_id, 0);
        assert!(snap.items().is_empty());
        assert!(snap.is_empty());
        assert_eq!(snap.start_ts, 0);
        assert!(snap.delta.is_empty());
        // Warm it, then empty-slide again: contents unchanged, id advances.
        w.slide(vec![rec(0, 0), rec(1, 1)]);
        let snap = w.slide(vec![]);
        assert_eq!(snap.window_id, 2);
        assert_eq!(snap.items().len(), 2);
        assert!(snap.delta.inserted().is_empty() && snap.delta.removed().is_empty());
    }

    #[test]
    fn count_window_slide_larger_than_window_size() {
        // Edge: one slide delivers more items than the window holds — the
        // overflow (including items from this very batch) falls out FIFO.
        let mut w = CountWindow::new(5);
        let snap = w.slide((0..12).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items().len(), 5);
        assert_eq!(snap.items().iter().map(|r| r.id).collect::<Vec<_>>(), vec![7, 8, 9, 10, 11]);
        assert_eq!(snap.delta.inserted().len(), 12);
        assert_eq!(snap.delta.removed().len(), 7);
        assert_consistent(&snap);
        // A second oversized slide removes the entire previous window.
        let snap = w.slide((12..22).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items().iter().map(|r| r.id).collect::<Vec<_>>(), vec![17, 18, 19, 20, 21]);
        assert!(snap.delta.removed().ids().contains(&7), "old window evicted");
    }

    #[test]
    fn count_window_single_stratum_degenerate() {
        // Degenerate stratification: all items in one stratum; the window
        // must still report exact deltas (the coordinator's single-shard
        // path builds on this).
        let mut w = CountWindow::new(6);
        w.slide((0..6).map(|i| Record::new(i, 0, i, 0, 1.0)).collect());
        let snap = w.slide((6..9).map(|i| Record::new(i, 0, i, 0, 1.0)).collect());
        assert!(snap.items().iter().all(|r| r.stratum == 0));
        assert_eq!(snap.delta.inserted().len(), 3);
        assert_eq!(snap.delta.removed().ids().to_vec(), vec![0, 1, 2]);
    }

    #[test]
    fn count_window_delta_only_snapshot() {
        // The O(delta) path: no full view, but len / start_ts / delta are
        // identical to the materializing slide.
        let mut a = CountWindow::new(8);
        let mut b = CountWindow::new(8);
        for step in 0..5u64 {
            let batch: Vec<Record> =
                (step * 3..step * 3 + 3).map(|i| rec(i, i)).collect();
            let full = a.slide_with(batch.clone(), true);
            let lazy = b.slide_with(batch, false);
            assert!(full.has_full_view());
            assert!(!lazy.has_full_view());
            assert!(lazy.full_view().is_none());
            assert_eq!(full.len, lazy.len);
            assert_eq!(full.start_ts, lazy.start_ts);
            assert_eq!(full.window_id, lazy.window_id);
            let ids = |d: &[Record]| d.iter().map(|r| r.id).collect::<Vec<_>>();
            assert_eq!(full.delta.inserted().ids(), lazy.delta.inserted().ids());
            assert_eq!(full.delta.removed().ids(), lazy.delta.removed().ids());
            assert_consistent(&full);
        }
    }

    #[test]
    fn materialized_snapshot_columns_mirror_items() {
        // The columnar view and the row view of a materialized snapshot
        // are the same data; the row slice must be the cached one (no
        // re-transpose on access).
        let mut w = CountWindow::new(6);
        let snap = w.slide((0..8).map(|i| rec(i, i)).collect());
        let cols = snap.columns().expect("materialized slide has columns");
        assert!(cols.bit_eq_records(snap.items()));
        assert_eq!(cols.ids(), snap.items().iter().map(|r| r.id).collect::<Vec<_>>());
        assert!(std::ptr::eq(snap.items().as_ptr(), cols.rows().as_ptr()));
        let lazy = w.slide_with(vec![rec(9, 9)], false);
        assert!(lazy.columns().is_none());
    }

    #[test]
    fn count_window_min_ts_tracks_unordered_timestamps() {
        // CountWindow makes no ordering assumption on timestamps; the
        // monotonic deque must still report the exact minimum.
        let ts = [9u64, 3, 7, 3, 11, 2, 5, 8, 2, 10, 6, 1, 4];
        let mut w = CountWindow::new(4);
        for (i, &t) in ts.iter().enumerate() {
            let snap = w.slide(vec![rec(i as u64, t)]);
            assert_consistent(&snap);
        }
    }

    #[test]
    fn time_window_empty_window_still_emits() {
        // Edge: a boundary with no data in range emits an empty snapshot
        // (the stream went quiet), not None.
        let mut w = TimeWindow::new(10, 5);
        let snap = w.try_emit(10).expect("boundary reached");
        assert_eq!(snap.window_id, 0);
        assert!(snap.items().is_empty());
        assert_eq!(snap.start_ts, 0);
        assert!(snap.delta.inserted().is_empty() && snap.delta.removed().is_empty());
        // Data arriving later lands in subsequent windows.
        w.ingest(vec![rec(1, 12)]);
        let snap = w.try_emit(15).expect("next boundary");
        assert_eq!(snap.items().len(), 1);
        assert_consistent(&snap);
    }

    #[test]
    fn time_window_slide_equals_length_tumbles() {
        // slide == length is the largest legal slide: tumbling windows
        // with no overlap.
        let mut w = TimeWindow::new(4, 4);
        w.ingest((0..8).map(|i| rec(i, i)));
        let s0 = w.try_emit(4).unwrap();
        let s1 = w.try_emit(8).unwrap();
        assert_eq!(s0.items().len(), 4);
        assert_eq!(s1.items().len(), 4);
        let ids0: Vec<u64> = s0.items().iter().map(|r| r.id).collect();
        let ids1: Vec<u64> = s1.items().iter().map(|r| r.id).collect();
        assert!(ids0.iter().all(|id| !ids1.contains(id)), "tumbling windows overlap");
    }

    #[test]
    #[should_panic]
    fn time_window_slide_larger_than_length_rejected() {
        // slide > length would skip data; the constructor forbids it.
        TimeWindow::new(10, 11);
    }

    #[test]
    fn time_window_single_stratum_degenerate() {
        let mut w = TimeWindow::new(6, 3);
        w.ingest((0..12).map(|i| Record::new(i, 0, i, 0, 2.0)));
        let s0 = w.try_emit(6).unwrap();
        assert!(s0.items().iter().all(|r| r.stratum == 0));
        assert_eq!(s0.items().len(), 6);
        let s1 = w.try_emit(9).unwrap();
        assert_eq!(s1.delta.removed().len(), 3);
        assert_eq!(s1.delta.inserted().len(), 3);
        assert!(s1.items().iter().all(|r| r.stratum == 0));
    }

    #[test]
    fn time_window_emits_at_boundaries() {
        let mut w = TimeWindow::new(10, 5);
        w.ingest((0..20).map(|i| rec(i, i)));
        assert!(w.try_emit(9).is_none());
        let s0 = w.try_emit(10).unwrap();
        assert_eq!(s0.items().iter().map(|r| r.timestamp).max(), Some(9));
        assert_eq!(s0.items().len(), 10);
        assert_eq!(s0.delta.inserted().len(), 10); // first window: all new
        assert_consistent(&s0);
        let s1 = w.try_emit(15).unwrap();
        // Window [5, 15): removed ts 0–4, inserted ts 10–14.
        assert_eq!(s1.delta.removed().len(), 5);
        assert_eq!(s1.delta.inserted().len(), 5);
        assert_eq!(s1.items().len(), 10);
        assert!(s1.items().iter().all(|r| (5..15).contains(&r.timestamp)));
        assert_consistent(&s1);
    }

    #[test]
    fn time_window_variable_arrival_counts() {
        let mut w = TimeWindow::new(4, 2);
        // 2 records at tick 0, none at 1, 3 at tick 2, 1 at tick 3.
        w.ingest(vec![rec(0, 0), rec(1, 0), rec(2, 2), rec(3, 2), rec(4, 2), rec(5, 3)]);
        let s = w.try_emit(4).unwrap();
        assert_eq!(s.items().len(), 6);
        let s = w.try_emit(6).unwrap(); // window [2,6): drops ts<2
        assert_eq!(s.items().len(), 4);
        assert_eq!(s.delta.removed().len(), 2);
    }

    #[test]
    fn time_window_delta_only_snapshot_matches_full() {
        let mut a = TimeWindow::new(10, 5);
        let mut b = TimeWindow::new(10, 5);
        let records: Vec<Record> = (0..40).map(|i| rec(i, i)).collect();
        a.ingest(records.clone());
        b.ingest(records);
        for boundary in [10u64, 15, 20, 25, 30] {
            let full = a.try_emit_with(boundary, true).unwrap();
            let lazy = b.try_emit_with(boundary, false).unwrap();
            assert!(!lazy.has_full_view());
            assert_eq!(full.len, lazy.len);
            assert_eq!(full.start_ts, lazy.start_ts);
            let ids = |d: &[Record]| d.iter().map(|r| r.id).collect::<Vec<_>>();
            assert_eq!(full.delta.inserted().ids(), lazy.delta.inserted().ids());
            assert_eq!(full.delta.removed().ids(), lazy.delta.removed().ids());
            assert_consistent(&full);
        }
    }

    #[test]
    fn slide_external_matches_interleaved_fifo_eviction() {
        // A single-partition external slide driven by the counts a
        // global FIFO simulation produces must equal the ordinary
        // interleaved slide, field for field — including an oversized
        // batch where records from the batch itself fall out.
        for batch_sizes in [vec![4usize, 3, 4, 2], vec![12, 10]] {
            let mut solo = CountWindow::new(5);
            let mut ext = CountWindow::new(5);
            let mut next = 0u64;
            for n in batch_sizes {
                let batch: Vec<Record> =
                    (next..next + n as u64).map(|i| rec(i, i % 7)).collect();
                next += n as u64;
                let evict = (ext.len() + n).saturating_sub(5);
                let a = solo.slide_with(batch.clone(), true);
                let b = ext.slide_external(batch, evict, true);
                assert_eq!(a.window_id, b.window_id);
                assert_eq!(a.len, b.len);
                assert_eq!(a.start_ts, b.start_ts);
                let ids = |d: &[Record]| d.iter().map(|r| r.id).collect::<Vec<_>>();
                assert_eq!(a.delta.inserted().ids(), b.delta.inserted().ids());
                assert_eq!(a.delta.removed().ids(), b.delta.removed().ids());
                assert_eq!(ids(a.items()), ids(b.items()));
                assert_consistent(&b);
            }
        }
    }

    #[test]
    fn extract_then_splice_restores_the_window() {
        // Ship stratum 1 out of one window and into another: the donor
        // keeps exact deltas for its survivors, and the recipient's
        // buffer equals what it would hold had it owned the stratum all
        // along (ids are arrival order here, as in the generator).
        let mut donor = CountWindow::new(100);
        let mut native = CountWindow::new(100);
        let recs: Vec<Record> =
            (0..30).map(|i| Record::new(i, (i % 3) as StratumId, i, 0, 1.0)).collect();
        donor.slide(recs.clone());
        native.slide(recs.iter().copied().filter(|r| r.stratum == 1).collect());
        let moved = donor.extract_stratum(1);
        assert_eq!(moved.len(), 10);
        assert!(donor.extract_stratum(1).is_empty());
        let mut recipient = CountWindow::new(100);
        recipient.slide_external(Vec::new(), 0, false);
        recipient.splice_records(moved);
        let (got, _) = recipient.checkpoint_parts();
        let (want, _) = native.checkpoint_parts();
        assert_eq!(
            got.iter().map(|r| r.id).collect::<Vec<_>>(),
            want.iter().map(|r| r.id).collect::<Vec<_>>()
        );
        // Donor min-ts deque rebuilt correctly over survivors.
        let snap = donor.slide(vec![]);
        assert_consistent(&snap);
    }

    #[test]
    fn count_window_checkpoint_roundtrip_continues_identically() {
        // Export/import mid-stream (with a pending resize eviction and
        // unordered timestamps) and drive both windows forward: every
        // subsequent snapshot must match field for field.
        let ts = [9u64, 3, 7, 3, 11, 2, 5, 8];
        let mut live = CountWindow::new(6);
        for (i, &t) in ts.iter().enumerate() {
            live.slide(vec![rec(i as u64, t)]);
        }
        live.resize(4); // leaves pending_removed for the next delta
        let (buf, pending) = live.checkpoint_parts();
        assert!(!pending.is_empty());
        let mut restored =
            CountWindow::restore_parts(live.size(), buf, pending, live.next_window_id());
        assert_eq!(restored.len(), live.len());
        for step in 0..6u64 {
            let batch: Vec<Record> =
                (100 + step * 2..102 + step * 2).map(|i| rec(i, i % 7)).collect();
            let a = live.slide(batch.clone());
            let b = restored.slide(batch);
            assert_eq!(a.window_id, b.window_id);
            assert_eq!(a.len, b.len);
            assert_eq!(a.start_ts, b.start_ts);
            let ids = |d: &[Record]| d.iter().map(|r| r.id).collect::<Vec<_>>();
            assert_eq!(a.delta.inserted().ids(), b.delta.inserted().ids());
            assert_eq!(a.delta.removed().ids(), b.delta.removed().ids());
            assert_eq!(ids(a.items()), ids(b.items()));
        }
    }

    #[test]
    fn time_window_checkpoint_roundtrip_continues_identically() {
        let mut live = TimeWindow::new(10, 5);
        live.ingest((0..18).map(|i| rec(i, i))); // some buffered ahead
        live.try_emit(10).unwrap();
        let (buf, next_end, in_window) = live.checkpoint_parts();
        assert_eq!(live.window_records().len(), in_window);
        let (length, slide) = live.params();
        let mut restored = TimeWindow::restore_parts(
            length,
            slide,
            buf,
            next_end,
            in_window,
            live.next_window_id(),
        );
        let mut next_id = 18u64;
        for boundary in [15u64, 20, 25, 30] {
            let batch: Vec<Record> =
                (0..3).map(|k| rec(next_id + k, boundary - 3 + k)).collect();
            next_id += 3;
            live.ingest(batch.clone());
            restored.ingest(batch);
            let a = live.try_emit(boundary).unwrap();
            let b = restored.try_emit(boundary).unwrap();
            assert_eq!(a.window_id, b.window_id);
            assert_eq!(a.len, b.len);
            assert_eq!(a.start_ts, b.start_ts);
            let ids = |d: &[Record]| d.iter().map(|r| r.id).collect::<Vec<_>>();
            assert_eq!(a.delta.inserted().ids(), b.delta.inserted().ids());
            assert_eq!(a.delta.removed().ids(), b.delta.removed().ids());
            assert_eq!(ids(a.items()), ids(b.items()));
        }
    }

    #[test]
    fn time_window_buffered_ahead_items_enter_delta_when_reached() {
        // Records buffered beyond the current window's end must show up in
        // `inserted` when a later window covers them — the positional
        // delta picks them up even though their timestamps pre-date the
        // final slide interval.
        let mut w = TimeWindow::new(10, 5);
        w.ingest((0..18).map(|i| rec(i, i))); // ts 0..17 buffered up-front
        let s0 = w.try_emit(10).unwrap(); // window [0,10)
        assert_eq!(s0.delta.inserted().len(), 10);
        let s1 = w.try_emit(15).unwrap(); // window [5,15): ts 10..14 arrive
        assert_eq!(s1.delta.inserted().timestamps(), &[10, 11, 12, 13, 14]);
        let s2 = w.try_emit(20).unwrap(); // window [10,20): ts 15..17 arrive
        assert_eq!(s2.delta.inserted().timestamps(), &[15, 16, 17]);
    }
}
