//! Sliding-window computation model (§2.3.2, Figure 2.3).
//!
//! The coordinator consumes the aggregated stream in slide-sized batches;
//! the window manager maintains the current computation window and reports
//! the **delta** (inserted / removed items) between adjacent windows — the
//! input-change set that drives change propagation in `sac/`.
//!
//! Two window kinds:
//! * [`CountWindow`] — fixed item count with item-count slide. This is what
//!   §5's figures parameterize ("window of 10 000 items, slide 4%"), and
//!   what the benches use.
//! * [`TimeWindow`] — time length + slide in ticks; item counts per window
//!   vary with arrival rate (the paper's stated general model, §2.3.3).

use std::collections::VecDeque;

use crate::workload::record::Record;

/// The change set between two adjacent windows.
#[derive(Debug, Clone, Default)]
pub struct WindowDelta {
    /// Items that entered the window this slide.
    pub inserted: Vec<Record>,
    /// Items that fell out of the window this slide.
    pub removed: Vec<Record>,
}

/// A full window snapshot handed to the sampling stage.
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    /// Monotonic window sequence number.
    pub window_id: u64,
    /// Items currently in the window, oldest first.
    pub items: Vec<Record>,
    /// Change set vs. the previous window.
    pub delta: WindowDelta,
}

/// Count-based sliding window.
#[derive(Debug)]
pub struct CountWindow {
    size: usize,
    buf: VecDeque<Record>,
    next_window_id: u64,
}

impl CountWindow {
    /// Window holding exactly `size` items once warm.
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        CountWindow { size, buf: VecDeque::with_capacity(size + 1), next_window_id: 0 }
    }

    /// Push one slide's worth of new items; returns the new window
    /// snapshot. Items beyond `size` fall out FIFO (oldest first).
    pub fn slide(&mut self, batch: Vec<Record>) -> WindowSnapshot {
        let mut removed = Vec::new();
        for r in &batch {
            self.buf.push_back(*r);
            if self.buf.len() > self.size {
                removed.push(self.buf.pop_front().expect("non-empty"));
            }
        }
        let id = self.next_window_id;
        self.next_window_id += 1;
        WindowSnapshot {
            window_id: id,
            items: self.buf.iter().copied().collect(),
            delta: WindowDelta { inserted: batch, removed },
        }
    }

    /// Change the target size (Fig 5.1(c) varies window size between
    /// adjacent windows). Shrinking evicts oldest items immediately;
    /// the evicted items are reported by the *next* `slide`'s delta via
    /// the returned vector here.
    pub fn resize(&mut self, new_size: usize) -> Vec<Record> {
        assert!(new_size > 0);
        self.size = new_size;
        let mut evicted = Vec::new();
        while self.buf.len() > self.size {
            evicted.push(self.buf.pop_front().expect("non-empty"));
        }
        evicted
    }

    /// Current item count.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no items buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Configured capacity.
    pub fn size(&self) -> usize {
        self.size
    }
}

/// Time-based sliding window (length and slide in logical ticks).
#[derive(Debug)]
pub struct TimeWindow {
    length: u64,
    slide: u64,
    /// Exclusive end of the last emitted window.
    next_end: u64,
    buf: VecDeque<Record>,
    next_window_id: u64,
}

impl TimeWindow {
    /// Window covering `[end-length, end)` sliding by `slide` ticks.
    pub fn new(length: u64, slide: u64) -> Self {
        assert!(length > 0 && slide > 0 && slide <= length);
        TimeWindow { length, slide, next_end: length, buf: VecDeque::new(), next_window_id: 0 }
    }

    /// Feed records (must arrive in non-decreasing timestamp order).
    pub fn ingest(&mut self, records: impl IntoIterator<Item = Record>) {
        for r in records {
            debug_assert!(self.buf.back().map_or(true, |b| b.timestamp <= r.timestamp));
            self.buf.push_back(r);
        }
    }

    /// Emit the next window if all its data (ticks < end) has been seen,
    /// i.e. `now >= end`. Removes items older than the new start.
    pub fn try_emit(&mut self, now: u64) -> Option<WindowSnapshot> {
        if now < self.next_end {
            return None;
        }
        let end = self.next_end;
        let start = end.saturating_sub(self.length);
        let prev_start = start.saturating_sub(self.slide);
        // Remove all old items from the window (Algorithm 1: timestamp < t).
        let mut removed = Vec::new();
        while let Some(front) = self.buf.front() {
            if front.timestamp < start {
                removed.push(self.buf.pop_front().expect("non-empty"));
            } else {
                break;
            }
        }
        // Inserted this slide: timestamps in [end - slide, end) — plus, for
        // the first window, everything.
        let ins_from = if self.next_window_id == 0 { 0 } else { end - self.slide };
        let items: Vec<Record> =
            self.buf.iter().filter(|r| r.timestamp < end).copied().collect();
        let inserted =
            items.iter().filter(|r| r.timestamp >= ins_from).copied().collect();
        // Items removed must have been in the previous window.
        removed.retain(|r| r.timestamp >= prev_start);
        let id = self.next_window_id;
        self.next_window_id += 1;
        self.next_end += self.slide;
        Some(WindowSnapshot { window_id: id, items, delta: WindowDelta { inserted, removed } })
    }

    /// Configured (length, slide).
    pub fn params(&self) -> (u64, u64) {
        (self.length, self.slide)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, ts: u64) -> Record {
        Record::new(id, 0, ts, 0, id as f64)
    }

    #[test]
    fn count_window_warms_then_slides() {
        let mut w = CountWindow::new(10);
        let snap = w.slide((0..10).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items.len(), 10);
        assert!(snap.delta.removed.is_empty());
        let snap = w.slide((10..14).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items.len(), 10);
        assert_eq!(snap.delta.inserted.len(), 4);
        assert_eq!(
            snap.delta.removed.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(snap.items[0].id, 4);
    }

    #[test]
    fn count_window_overlap_invariant() {
        // |overlap| == size - slide for a warm window.
        let mut w = CountWindow::new(100);
        w.slide((0..100).map(|i| rec(i, 0)).collect());
        let s2 = w.slide((100..116).map(|i| rec(i, 1)).collect());
        let overlap = s2.items.iter().filter(|r| r.id < 100).count();
        assert_eq!(overlap, 84);
    }

    #[test]
    fn count_window_resize_evicts_oldest() {
        let mut w = CountWindow::new(10);
        w.slide((0..10).map(|i| rec(i, i)).collect());
        let evicted = w.resize(6);
        assert_eq!(evicted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(w.len(), 6);
        assert!(w.resize(20).is_empty());
    }

    #[test]
    fn window_ids_monotone() {
        let mut w = CountWindow::new(4);
        let a = w.slide(vec![rec(0, 0)]);
        let b = w.slide(vec![rec(1, 1)]);
        assert_eq!(a.window_id, 0);
        assert_eq!(b.window_id, 1);
    }

    #[test]
    fn count_window_empty_slide_and_empty_window() {
        // Edge: sliding with no new items — including on a cold window —
        // must produce a well-formed (possibly empty) snapshot.
        let mut w = CountWindow::new(4);
        let snap = w.slide(vec![]);
        assert_eq!(snap.window_id, 0);
        assert!(snap.items.is_empty());
        assert!(snap.delta.inserted.is_empty() && snap.delta.removed.is_empty());
        // Warm it, then empty-slide again: contents unchanged, id advances.
        w.slide(vec![rec(0, 0), rec(1, 1)]);
        let snap = w.slide(vec![]);
        assert_eq!(snap.window_id, 2);
        assert_eq!(snap.items.len(), 2);
        assert!(snap.delta.inserted.is_empty() && snap.delta.removed.is_empty());
    }

    #[test]
    fn count_window_slide_larger_than_window_size() {
        // Edge: one slide delivers more items than the window holds — the
        // overflow (including items from this very batch) falls out FIFO.
        let mut w = CountWindow::new(5);
        let snap = w.slide((0..12).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items.len(), 5);
        assert_eq!(snap.items.iter().map(|r| r.id).collect::<Vec<_>>(), vec![7, 8, 9, 10, 11]);
        assert_eq!(snap.delta.inserted.len(), 12);
        assert_eq!(snap.delta.removed.len(), 7);
        // A second oversized slide removes the entire previous window.
        let snap = w.slide((12..22).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items.iter().map(|r| r.id).collect::<Vec<_>>(), vec![17, 18, 19, 20, 21]);
        assert!(snap.delta.removed.iter().any(|r| r.id == 7), "old window evicted");
    }

    #[test]
    fn count_window_single_stratum_degenerate() {
        // Degenerate stratification: all items in one stratum; the window
        // must still report exact deltas (the coordinator's single-shard
        // path builds on this).
        let mut w = CountWindow::new(6);
        w.slide((0..6).map(|i| Record::new(i, 0, i, 0, 1.0)).collect());
        let snap = w.slide((6..9).map(|i| Record::new(i, 0, i, 0, 1.0)).collect());
        assert!(snap.items.iter().all(|r| r.stratum == 0));
        assert_eq!(snap.delta.inserted.len(), 3);
        assert_eq!(snap.delta.removed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn time_window_empty_window_still_emits() {
        // Edge: a boundary with no data in range emits an empty snapshot
        // (the stream went quiet), not None.
        let mut w = TimeWindow::new(10, 5);
        let snap = w.try_emit(10).expect("boundary reached");
        assert_eq!(snap.window_id, 0);
        assert!(snap.items.is_empty());
        assert!(snap.delta.inserted.is_empty() && snap.delta.removed.is_empty());
        // Data arriving later lands in subsequent windows.
        w.ingest(vec![rec(1, 12)]);
        let snap = w.try_emit(15).expect("next boundary");
        assert_eq!(snap.items.len(), 1);
    }

    #[test]
    fn time_window_slide_equals_length_tumbles() {
        // slide == length is the largest legal slide: tumbling windows
        // with no overlap.
        let mut w = TimeWindow::new(4, 4);
        w.ingest((0..8).map(|i| rec(i, i)));
        let s0 = w.try_emit(4).unwrap();
        let s1 = w.try_emit(8).unwrap();
        assert_eq!(s0.items.len(), 4);
        assert_eq!(s1.items.len(), 4);
        let ids0: Vec<u64> = s0.items.iter().map(|r| r.id).collect();
        let ids1: Vec<u64> = s1.items.iter().map(|r| r.id).collect();
        assert!(ids0.iter().all(|id| !ids1.contains(id)), "tumbling windows overlap");
    }

    #[test]
    #[should_panic]
    fn time_window_slide_larger_than_length_rejected() {
        // slide > length would skip data; the constructor forbids it.
        TimeWindow::new(10, 11);
    }

    #[test]
    fn time_window_single_stratum_degenerate() {
        let mut w = TimeWindow::new(6, 3);
        w.ingest((0..12).map(|i| Record::new(i, 0, i, 0, 2.0)));
        let s0 = w.try_emit(6).unwrap();
        assert!(s0.items.iter().all(|r| r.stratum == 0));
        assert_eq!(s0.items.len(), 6);
        let s1 = w.try_emit(9).unwrap();
        assert_eq!(s1.delta.removed.len(), 3);
        assert_eq!(s1.delta.inserted.len(), 3);
        assert!(s1.items.iter().all(|r| r.stratum == 0));
    }

    #[test]
    fn time_window_emits_at_boundaries() {
        let mut w = TimeWindow::new(10, 5);
        w.ingest((0..20).map(|i| rec(i, i)));
        assert!(w.try_emit(9).is_none());
        let s0 = w.try_emit(10).unwrap();
        assert_eq!(s0.items.iter().map(|r| r.timestamp).max(), Some(9));
        assert_eq!(s0.items.len(), 10);
        assert_eq!(s0.delta.inserted.len(), 10); // first window: all new
        let s1 = w.try_emit(15).unwrap();
        // Window [5, 15): removed ts 0–4, inserted ts 10–14.
        assert_eq!(s1.delta.removed.len(), 5);
        assert_eq!(s1.delta.inserted.len(), 5);
        assert_eq!(s1.items.len(), 10);
        assert!(s1.items.iter().all(|r| (5..15).contains(&r.timestamp)));
    }

    #[test]
    fn time_window_variable_arrival_counts() {
        let mut w = TimeWindow::new(4, 2);
        // 2 records at tick 0, none at 1, 3 at tick 2, 1 at tick 3.
        w.ingest(vec![rec(0, 0), rec(1, 0), rec(2, 2), rec(3, 2), rec(4, 2), rec(5, 3)]);
        let s = w.try_emit(4).unwrap();
        assert_eq!(s.items.len(), 6);
        let s = w.try_emit(6).unwrap(); // window [2,6): drops ts<2
        assert_eq!(s.items.len(), 4);
        assert_eq!(s.delta.removed.len(), 2);
    }
}
