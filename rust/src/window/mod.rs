//! Sliding-window computation model (§2.3.2, Figure 2.3).
//!
//! The coordinator consumes the aggregated stream in slide-sized batches;
//! the window manager maintains the current computation window and reports
//! the **delta** (inserted / removed items) between adjacent windows — the
//! input-change set that drives change propagation in `sac/`.
//!
//! Two window kinds:
//! * [`CountWindow`] — fixed item count with item-count slide. This is what
//!   §5's figures parameterize ("window of 10 000 items, slide 4%"), and
//!   what the benches use.
//! * [`TimeWindow`] — time length + slide in ticks; item counts per window
//!   vary with arrival rate (the paper's stated general model, §2.3.3).

use std::collections::VecDeque;

use crate::workload::record::Record;

/// The change set between two adjacent windows.
#[derive(Debug, Clone, Default)]
pub struct WindowDelta {
    /// Items that entered the window this slide.
    pub inserted: Vec<Record>,
    /// Items that fell out of the window this slide.
    pub removed: Vec<Record>,
}

/// A full window snapshot handed to the sampling stage.
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    /// Monotonic window sequence number.
    pub window_id: u64,
    /// Items currently in the window, oldest first.
    pub items: Vec<Record>,
    /// Change set vs. the previous window.
    pub delta: WindowDelta,
}

/// Count-based sliding window.
#[derive(Debug)]
pub struct CountWindow {
    size: usize,
    buf: VecDeque<Record>,
    next_window_id: u64,
}

impl CountWindow {
    /// Window holding exactly `size` items once warm.
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        CountWindow { size, buf: VecDeque::with_capacity(size + 1), next_window_id: 0 }
    }

    /// Push one slide's worth of new items; returns the new window
    /// snapshot. Items beyond `size` fall out FIFO (oldest first).
    pub fn slide(&mut self, batch: Vec<Record>) -> WindowSnapshot {
        let mut removed = Vec::new();
        for r in &batch {
            self.buf.push_back(*r);
            if self.buf.len() > self.size {
                removed.push(self.buf.pop_front().expect("non-empty"));
            }
        }
        let id = self.next_window_id;
        self.next_window_id += 1;
        WindowSnapshot {
            window_id: id,
            items: self.buf.iter().copied().collect(),
            delta: WindowDelta { inserted: batch, removed },
        }
    }

    /// Change the target size (Fig 5.1(c) varies window size between
    /// adjacent windows). Shrinking evicts oldest items immediately;
    /// the evicted items are reported by the *next* `slide`'s delta via
    /// the returned vector here.
    pub fn resize(&mut self, new_size: usize) -> Vec<Record> {
        assert!(new_size > 0);
        self.size = new_size;
        let mut evicted = Vec::new();
        while self.buf.len() > self.size {
            evicted.push(self.buf.pop_front().expect("non-empty"));
        }
        evicted
    }

    /// Current item count.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no items buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Configured capacity.
    pub fn size(&self) -> usize {
        self.size
    }
}

/// Time-based sliding window (length and slide in logical ticks).
#[derive(Debug)]
pub struct TimeWindow {
    length: u64,
    slide: u64,
    /// Exclusive end of the last emitted window.
    next_end: u64,
    buf: VecDeque<Record>,
    next_window_id: u64,
}

impl TimeWindow {
    /// Window covering `[end-length, end)` sliding by `slide` ticks.
    pub fn new(length: u64, slide: u64) -> Self {
        assert!(length > 0 && slide > 0 && slide <= length);
        TimeWindow { length, slide, next_end: length, buf: VecDeque::new(), next_window_id: 0 }
    }

    /// Feed records (must arrive in non-decreasing timestamp order).
    pub fn ingest(&mut self, records: impl IntoIterator<Item = Record>) {
        for r in records {
            debug_assert!(self.buf.back().is_none_or(|b| b.timestamp <= r.timestamp));
            self.buf.push_back(r);
        }
    }

    /// Emit the next window if all its data (ticks < end) has been seen,
    /// i.e. `now >= end`. Removes items older than the new start.
    pub fn try_emit(&mut self, now: u64) -> Option<WindowSnapshot> {
        if now < self.next_end {
            return None;
        }
        let end = self.next_end;
        let start = end.saturating_sub(self.length);
        let prev_start = start.saturating_sub(self.slide);
        // Remove all old items from the window (Algorithm 1: timestamp < t).
        let mut removed = Vec::new();
        while let Some(front) = self.buf.front() {
            if front.timestamp < start {
                removed.push(self.buf.pop_front().expect("non-empty"));
            } else {
                break;
            }
        }
        // Inserted this slide: timestamps in [end - slide, end) — plus, for
        // the first window, everything.
        let ins_from = if self.next_window_id == 0 { 0 } else { end - self.slide };
        let items: Vec<Record> =
            self.buf.iter().filter(|r| r.timestamp < end).copied().collect();
        let inserted =
            items.iter().filter(|r| r.timestamp >= ins_from).copied().collect();
        // Items removed must have been in the previous window.
        removed.retain(|r| r.timestamp >= prev_start);
        let id = self.next_window_id;
        self.next_window_id += 1;
        self.next_end += self.slide;
        Some(WindowSnapshot { window_id: id, items, delta: WindowDelta { inserted, removed } })
    }

    /// Configured (length, slide).
    pub fn params(&self) -> (u64, u64) {
        (self.length, self.slide)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, ts: u64) -> Record {
        Record::new(id, 0, ts, 0, id as f64)
    }

    #[test]
    fn count_window_warms_then_slides() {
        let mut w = CountWindow::new(10);
        let snap = w.slide((0..10).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items.len(), 10);
        assert!(snap.delta.removed.is_empty());
        let snap = w.slide((10..14).map(|i| rec(i, i)).collect());
        assert_eq!(snap.items.len(), 10);
        assert_eq!(snap.delta.inserted.len(), 4);
        assert_eq!(
            snap.delta.removed.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(snap.items[0].id, 4);
    }

    #[test]
    fn count_window_overlap_invariant() {
        // |overlap| == size - slide for a warm window.
        let mut w = CountWindow::new(100);
        w.slide((0..100).map(|i| rec(i, 0)).collect());
        let s2 = w.slide((100..116).map(|i| rec(i, 1)).collect());
        let overlap = s2.items.iter().filter(|r| r.id < 100).count();
        assert_eq!(overlap, 84);
    }

    #[test]
    fn count_window_resize_evicts_oldest() {
        let mut w = CountWindow::new(10);
        w.slide((0..10).map(|i| rec(i, i)).collect());
        let evicted = w.resize(6);
        assert_eq!(evicted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(w.len(), 6);
        assert!(w.resize(20).is_empty());
    }

    #[test]
    fn window_ids_monotone() {
        let mut w = CountWindow::new(4);
        let a = w.slide(vec![rec(0, 0)]);
        let b = w.slide(vec![rec(1, 1)]);
        assert_eq!(a.window_id, 0);
        assert_eq!(b.window_id, 1);
    }

    #[test]
    fn time_window_emits_at_boundaries() {
        let mut w = TimeWindow::new(10, 5);
        w.ingest((0..20).map(|i| rec(i, i)));
        assert!(w.try_emit(9).is_none());
        let s0 = w.try_emit(10).unwrap();
        assert_eq!(s0.items.iter().map(|r| r.timestamp).max(), Some(9));
        assert_eq!(s0.items.len(), 10);
        assert_eq!(s0.delta.inserted.len(), 10); // first window: all new
        let s1 = w.try_emit(15).unwrap();
        // Window [5, 15): removed ts 0–4, inserted ts 10–14.
        assert_eq!(s1.delta.removed.len(), 5);
        assert_eq!(s1.delta.inserted.len(), 5);
        assert_eq!(s1.items.len(), 10);
        assert!(s1.items.iter().all(|r| (5..15).contains(&r.timestamp)));
    }

    #[test]
    fn time_window_variable_arrival_counts() {
        let mut w = TimeWindow::new(4, 2);
        // 2 records at tick 0, none at 1, 3 at tick 2, 1 at tick 3.
        w.ingest(vec![rec(0, 0), rec(1, 0), rec(2, 2), rec(3, 2), rec(4, 2), rec(5, 3)]);
        let s = w.try_emit(4).unwrap();
        assert_eq!(s.items.len(), 6);
        let s = w.try_emit(6).unwrap(); // window [2,6): drops ts<2
        assert_eq!(s.items.len(), 4);
        assert_eq!(s.delta.removed.len(), 2);
    }
}
