//! The virtual cost function (§2.3.3 assumption 2, §6.2).
//!
//! Maps the user's query budget to a per-window sample size. Three
//! implementations, matching the budget forms §2.1 lists:
//!
//! * [`FractionCost`] — direct sampling fraction (what §5's
//!   micro-benchmarks parameterize).
//! * [`TokenBucketCost`] — Pulsar-style resource budget: a token bucket
//!   refilled per window; every processed item costs tokens, the sample
//!   size is what the bucket can afford.
//! * [`LatencyCost`] — latency SLA: an EWMA predictor of per-item
//!   processing cost (the "resource prediction model" of §6.2) converts a
//!   window latency budget into an item count, adapting as observed
//!   latencies drift.

use crate::config::system::BudgetSpec;
use crate::error::{Error, Result};

/// Turns a window size into a sample size, within the query budget.
pub trait CostFunction: Send {
    /// Sample size for a window of `window_len` items.
    fn sample_size(&mut self, window_len: usize) -> usize;

    /// Feed back the observed processing cost of the last window
    /// (`items` processed in `elapsed_ms`). Only adaptive policies react.
    fn observe(&mut self, items: usize, elapsed_ms: f64);

    /// Name for reports.
    fn name(&self) -> &'static str;
}

/// Fixed sampling fraction.
#[derive(Debug, Clone, Copy)]
pub struct FractionCost {
    fraction: f64,
}

impl FractionCost {
    /// `fraction` ∈ (0, 1].
    pub fn new(fraction: f64) -> Self {
        assert!(fraction > 0.0 && fraction <= 1.0);
        FractionCost { fraction }
    }
}

impl CostFunction for FractionCost {
    fn sample_size(&mut self, window_len: usize) -> usize {
        ((window_len as f64 * self.fraction).round() as usize).clamp(1, window_len.max(1))
    }

    fn observe(&mut self, _items: usize, _elapsed_ms: f64) {}

    fn name(&self) -> &'static str {
        "fraction"
    }
}

/// Pulsar-style token bucket: `capacity` tokens refill each window;
/// processing one item costs `cost_per_item` tokens.
#[derive(Debug, Clone, Copy)]
pub struct TokenBucketCost {
    capacity: f64,
    cost_per_item: f64,
    tokens: f64,
}

impl TokenBucketCost {
    /// Bucket with `capacity` tokens per window.
    pub fn new(capacity: f64, cost_per_item: f64) -> Self {
        assert!(capacity > 0.0 && cost_per_item > 0.0);
        TokenBucketCost { capacity, cost_per_item, tokens: capacity }
    }

    /// Tokens currently available.
    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

impl CostFunction for TokenBucketCost {
    fn sample_size(&mut self, window_len: usize) -> usize {
        // Refill, then spend.
        self.tokens = self.capacity;
        let affordable = (self.tokens / self.cost_per_item).floor() as usize;
        let n = affordable.min(window_len).max(1);
        self.tokens -= n as f64 * self.cost_per_item;
        n
    }

    fn observe(&mut self, _items: usize, _elapsed_ms: f64) {}

    fn name(&self) -> &'static str {
        "token-bucket"
    }
}

/// Latency-SLA budget with an EWMA per-item cost model.
#[derive(Debug, Clone, Copy)]
pub struct LatencyCost {
    target_ms: f64,
    /// EWMA of per-item milliseconds.
    per_item_ms: f64,
    alpha: f64,
    /// Safety factor (< 1) so predictions undershoot the SLA.
    headroom: f64,
}

impl LatencyCost {
    /// Budget of `target_ms` per window; `initial_per_item_ms` seeds the
    /// model until observations arrive.
    pub fn new(target_ms: f64, initial_per_item_ms: f64) -> Self {
        assert!(target_ms > 0.0 && initial_per_item_ms > 0.0);
        LatencyCost { target_ms, per_item_ms: initial_per_item_ms, alpha: 0.3, headroom: 0.9 }
    }

    /// Current model estimate of per-item cost.
    pub fn per_item_ms(&self) -> f64 {
        self.per_item_ms
    }
}

impl CostFunction for LatencyCost {
    fn sample_size(&mut self, window_len: usize) -> usize {
        let n = (self.target_ms * self.headroom / self.per_item_ms).floor() as usize;
        n.clamp(1, window_len.max(1))
    }

    fn observe(&mut self, items: usize, elapsed_ms: f64) {
        if items == 0 || elapsed_ms <= 0.0 {
            return;
        }
        let observed = elapsed_ms / items as f64;
        self.per_item_ms = self.alpha * observed + (1.0 - self.alpha) * self.per_item_ms;
    }

    fn name(&self) -> &'static str {
        "latency-sla"
    }
}

/// Check a budget spec's parameters — shared by `SystemConfig::validate`
/// and the per-query validation in `Coordinator::submit_query`, so a bad
/// budget surfaces as a config error instead of a construction panic.
pub fn validate_spec(spec: &BudgetSpec) -> Result<()> {
    // Guards are written positively (`!(x > 0.0)`) so NaN fails them too
    // — `NaN <= 0.0` is false and would sneak past an inverted check
    // straight into the constructors' asserts.
    match *spec {
        BudgetSpec::Fraction(f) if !(0.0 < f && f <= 1.0) => Err(Error::Config(format!(
            "budget fraction must be in (0, 1], got {f}"
        ))),
        BudgetSpec::Tokens { per_window, cost_per_item }
            if !(per_window > 0.0 && cost_per_item > 0.0) =>
        {
            Err(Error::Config(format!(
                "token budget needs per_window > 0 and cost_per_item > 0, got {per_window} / {cost_per_item}"
            )))
        }
        BudgetSpec::LatencyMs(ms) if !(ms > 0.0) => Err(Error::Config(format!(
            "latency budget must be > 0 ms, got {ms}"
        ))),
        _ => Ok(()),
    }
}

/// Build the configured cost function.
pub fn from_spec(spec: &BudgetSpec) -> Box<dyn CostFunction> {
    match *spec {
        BudgetSpec::Fraction(f) => Box::new(FractionCost::new(f)),
        BudgetSpec::Tokens { per_window, cost_per_item } => {
            Box::new(TokenBucketCost::new(per_window, cost_per_item))
        }
        BudgetSpec::LatencyMs(ms) => Box::new(LatencyCost::new(ms, 0.001)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fraction_rounds_and_clamps() {
        let mut c = FractionCost::new(0.1);
        assert_eq!(c.sample_size(10_000), 1000);
        assert_eq!(c.sample_size(5), 1);
        let mut c = FractionCost::new(1.0);
        assert_eq!(c.sample_size(100), 100);
    }

    #[test]
    fn token_bucket_affords_budget() {
        let mut c = TokenBucketCost::new(500.0, 2.0);
        assert_eq!(c.sample_size(10_000), 250);
        // Refills every window.
        assert_eq!(c.sample_size(10_000), 250);
        // Small windows capped at window length.
        assert_eq!(c.sample_size(100), 100);
    }

    #[test]
    fn latency_model_adapts() {
        let mut c = LatencyCost::new(100.0, 0.01); // predicts 9000 items
        let n0 = c.sample_size(100_000);
        assert_eq!(n0, 9000);
        // Observed: items are 10× slower than the seed.
        for _ in 0..50 {
            c.observe(1000, 100.0); // 0.1 ms/item
        }
        let n1 = c.sample_size(100_000);
        assert!(n1 < n0 / 5, "model failed to adapt: {n0} -> {n1}");
        assert!((c.per_item_ms() - 0.1).abs() < 0.02);
    }

    #[test]
    fn latency_model_ignores_degenerate_observations() {
        let mut c = LatencyCost::new(100.0, 0.01);
        let before = c.per_item_ms();
        c.observe(0, 50.0);
        c.observe(100, 0.0);
        assert_eq!(c.per_item_ms(), before);
    }

    #[test]
    fn from_spec_builds_matching_policy() {
        assert_eq!(from_spec(&BudgetSpec::Fraction(0.5)).name(), "fraction");
        assert_eq!(
            from_spec(&BudgetSpec::Tokens { per_window: 10.0, cost_per_item: 1.0 }).name(),
            "token-bucket"
        );
        assert_eq!(from_spec(&BudgetSpec::LatencyMs(10.0)).name(), "latency-sla");
    }

    #[test]
    fn validate_spec_accepts_good_rejects_bad() {
        assert!(validate_spec(&BudgetSpec::Fraction(0.1)).is_ok());
        assert!(validate_spec(&BudgetSpec::Fraction(1.0)).is_ok());
        assert!(validate_spec(&BudgetSpec::Fraction(0.0)).is_err());
        assert!(validate_spec(&BudgetSpec::Fraction(1.5)).is_err());
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: 10.0, cost_per_item: 1.0 }).is_ok()
        );
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: 0.0, cost_per_item: 1.0 }).is_err()
        );
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: 10.0, cost_per_item: 0.0 }).is_err()
        );
        assert!(validate_spec(&BudgetSpec::LatencyMs(5.0)).is_ok());
        assert!(validate_spec(&BudgetSpec::LatencyMs(0.0)).is_err());
        // NaN must be rejected, not passed through to a constructor panic.
        assert!(validate_spec(&BudgetSpec::Fraction(f64::NAN)).is_err());
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: f64::NAN, cost_per_item: 1.0 })
                .is_err()
        );
        assert!(validate_spec(&BudgetSpec::LatencyMs(f64::NAN)).is_err());
    }

    #[test]
    fn sample_never_zero() {
        let mut c = FractionCost::new(0.001);
        assert!(c.sample_size(10) >= 1);
        let mut c = TokenBucketCost::new(0.5, 1.0);
        assert!(c.sample_size(10) >= 1);
        let mut c = LatencyCost::new(0.0001, 1.0);
        assert!(c.sample_size(10) >= 1);
    }
}
