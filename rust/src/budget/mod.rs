//! The virtual cost function (§2.3.3 assumption 2, §6.2).
//!
//! Maps the user's query budget to a per-window sample size. Four
//! implementations, matching the budget forms §2.1 lists plus the
//! OLA-style error contract:
//!
//! * [`FractionCost`] — direct sampling fraction (what §5's
//!   micro-benchmarks parameterize).
//! * [`TokenBucketCost`] — Pulsar-style resource budget: a token bucket
//!   refilled per window (unused tokens carry over up to a burst cap);
//!   every processed item costs tokens, the sample size is what the
//!   bucket can afford.
//! * [`LatencyCost`] — latency SLA: an EWMA predictor of per-item
//!   processing cost (the "resource prediction model" of §6.2) converts a
//!   window latency budget into an item count, adapting as observed
//!   latencies drift.
//! * [`TargetErrorCost`] — error-target contract ("≤ 2% relative error at
//!   95%"): a closed-loop controller that reads the achieved §3.5
//!   interval after every slide and solves Eq 3.2 backwards
//!   ([`required_sample_size`]) for the next slide's sample size.
//!
//! The first three run **open-loop** over the error bound (they size the
//! sample from resources and never look at the margin the system just
//! emitted); `TargetErrorCost` is the one that closes the loop.

use crate::config::system::BudgetSpec;
use crate::error::{Error, Result};
use crate::job::aggregate::AggregateKind;
use crate::stats::stratified::{estimate_sum, required_sample_size, StratumAgg};

/// Turns a window size into a sample size, within the query budget.
pub trait CostFunction: Send {
    /// Sample size for a window of `window_len` items.
    fn sample_size(&mut self, window_len: usize) -> usize;

    /// Feed back the observed processing cost of the last window
    /// (`items` processed in `elapsed_ms`). Only adaptive policies react.
    /// `elapsed_ms` is the cost *attributable to this budget's query*
    /// (its substrate share plus its own derivation — see
    /// [`attribute_query_cost`]), never the whole-slide latency.
    fn observe(&mut self, items: usize, elapsed_ms: f64);

    /// Feed back the achieved §3.5 per-stratum aggregates of the last
    /// slide, restricted to the strata the budget's query covers.
    /// `window_population` is the whole window's item count: the sampler
    /// allocates proportionally across *all* strata, so a budget whose
    /// query covers only part of the window must scale its demand by
    /// `window_population / covered_population` to actually land the
    /// samples it needs inside its own strata. Only error-target
    /// policies react; the default is a no-op.
    fn observe_bound(&mut self, _strata: &[StratumAgg], _window_population: f64) {}

    /// Does this policy consume [`CostFunction::observe_bound`] feedback?
    /// The coordinator skips building the per-stratum aggregates (and
    /// charges no `SlideWork::budget_adjust` work) when not.
    fn wants_bound_feedback(&self) -> bool {
        false
    }

    /// Durable adaptive state, if any — checkpointed as one base-segment
    /// entry plus journaled `BudgetAdjust` ops so a restored run
    /// continues with the same controller trajectory. `None` (the
    /// default) for stateless policies.
    fn export_state(&self) -> Option<f64> {
        None
    }

    /// Restore durable adaptive state exported by
    /// [`CostFunction::export_state`]. No-op by default.
    fn import_state(&mut self, _state: f64) {}

    /// Overload-degradation hook: multiply the policy's error target by
    /// `scale` (≥ 1; exactly 1 restores the configured baseline). Only
    /// closed-loop error-target policies react — open-loop budgets size
    /// the sample from resources, not from a bound, so there is nothing
    /// to widen and the default is a no-op. The
    /// [`DegradationController`] calls this every slide with its current
    /// ladder position.
    fn set_bound_scale(&mut self, _scale: f64) {}

    /// Name for reports.
    fn name(&self) -> &'static str;
}

/// Split one slide's realized cost into the share attributable to a
/// single query: its proportional share of the shared substrate cost
/// (`substrate_ms · alloc / union`) plus its own derivation time.
/// Returns the `(items, elapsed_ms)` pair to feed that query's
/// [`CostFunction::observe`].
///
/// This is the fix for the cross-contamination bug: feeding every query
/// the union sample size and the whole-slide latency let query A's load
/// inflate query B's per-item `LatencyCost` model. A query's observation
/// must scale with *its own* allocation — doubling A's budget leaves B's
/// `(items, elapsed)` untouched (see the unit tests).
pub fn attribute_query_cost(
    alloc: usize,
    union_realized: usize,
    substrate_ms: f64,
    derive_ms: f64,
) -> (usize, f64) {
    let share = if union_realized == 0 {
        0.0
    } else {
        substrate_ms * alloc as f64 / union_realized as f64
    };
    (alloc, share + derive_ms)
}

/// Fixed sampling fraction.
#[derive(Debug, Clone, Copy)]
pub struct FractionCost {
    fraction: f64,
}

impl FractionCost {
    /// `fraction` ∈ (0, 1].
    pub fn new(fraction: f64) -> Self {
        assert!(fraction > 0.0 && fraction <= 1.0);
        FractionCost { fraction }
    }
}

impl CostFunction for FractionCost {
    fn sample_size(&mut self, window_len: usize) -> usize {
        ((window_len as f64 * self.fraction).round() as usize).clamp(1, window_len.max(1))
    }

    fn observe(&mut self, _items: usize, _elapsed_ms: f64) {}

    fn name(&self) -> &'static str {
        "fraction"
    }
}

/// Pulsar-style token bucket: `capacity` tokens refill each window and
/// processing one item costs `cost_per_item` tokens. **Unused tokens
/// carry over** to later windows, capped at a burst ceiling (default
/// 2 × capacity), so a small window's leftover budget buys a larger
/// sample when the stream picks back up.
///
/// (Historical note: carry-over used to be dead code — `sample_size`
/// reset the bucket to `capacity` before spending, so the post-spend
/// subtraction never influenced anything and [`TokenBucketCost::tokens`]
/// reported a stale value between windows. The refill semantics are now
/// explicit: the bucket starts *empty*, gains `capacity` tokens at the
/// start of each window, is clamped to the burst cap, and keeps whatever
/// the window didn't spend.)
#[derive(Debug, Clone, Copy)]
pub struct TokenBucketCost {
    capacity: f64,
    cost_per_item: f64,
    /// Carry-over ceiling: refills never push the bucket past this.
    burst: f64,
    /// Tokens currently banked (post-spend; pre-refill of the next
    /// window). Starts at 0 — the first window affords exactly one
    /// refill, not refill + a phantom full bucket.
    tokens: f64,
}

impl TokenBucketCost {
    /// Bucket with `capacity` tokens per window and the default burst cap
    /// of `2 × capacity`.
    pub fn new(capacity: f64, cost_per_item: f64) -> Self {
        assert!(capacity > 0.0 && cost_per_item > 0.0);
        TokenBucketCost { capacity, cost_per_item, burst: 2.0 * capacity, tokens: 0.0 }
    }

    /// Override the burst cap (clamped to at least one refill).
    pub fn with_burst(mut self, burst: f64) -> Self {
        assert!(burst > 0.0);
        self.burst = burst.max(self.capacity);
        self
    }

    /// Tokens currently banked — live between windows: refills and spends
    /// update it, so this is the real carry-over balance.
    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

impl CostFunction for TokenBucketCost {
    fn sample_size(&mut self, window_len: usize) -> usize {
        // Refill (carry-over + one window's allowance, burst-capped),
        // then spend what the window actually uses.
        self.tokens = (self.tokens + self.capacity).min(self.burst);
        let affordable = (self.tokens / self.cost_per_item).floor() as usize;
        let n = affordable.min(window_len).max(1);
        // The forced minimum of 1 item may overdraw a sub-item budget;
        // saturate at 0 rather than carrying debt.
        self.tokens = (self.tokens - n as f64 * self.cost_per_item).max(0.0);
        n
    }

    fn observe(&mut self, _items: usize, _elapsed_ms: f64) {}

    fn export_state(&self) -> Option<f64> {
        Some(self.tokens)
    }

    fn import_state(&mut self, state: f64) {
        self.tokens = state.clamp(0.0, self.burst);
    }

    fn name(&self) -> &'static str {
        "token-bucket"
    }
}

/// Latency-SLA budget with an EWMA per-item cost model.
#[derive(Debug, Clone, Copy)]
pub struct LatencyCost {
    target_ms: f64,
    /// EWMA of per-item milliseconds.
    per_item_ms: f64,
    alpha: f64,
    /// Safety factor (< 1) so predictions undershoot the SLA.
    headroom: f64,
}

impl LatencyCost {
    /// Budget of `target_ms` per window; `initial_per_item_ms` seeds the
    /// model until observations arrive.
    pub fn new(target_ms: f64, initial_per_item_ms: f64) -> Self {
        assert!(target_ms > 0.0 && initial_per_item_ms > 0.0);
        LatencyCost { target_ms, per_item_ms: initial_per_item_ms, alpha: 0.3, headroom: 0.9 }
    }

    /// Current model estimate of per-item cost.
    pub fn per_item_ms(&self) -> f64 {
        self.per_item_ms
    }
}

impl CostFunction for LatencyCost {
    fn sample_size(&mut self, window_len: usize) -> usize {
        let n = (self.target_ms * self.headroom / self.per_item_ms).floor() as usize;
        n.clamp(1, window_len.max(1))
    }

    fn observe(&mut self, items: usize, elapsed_ms: f64) {
        if items == 0 || elapsed_ms <= 0.0 {
            return;
        }
        let observed = elapsed_ms / items as f64;
        self.per_item_ms = self.alpha * observed + (1.0 - self.alpha) * self.per_item_ms;
    }

    fn export_state(&self) -> Option<f64> {
        Some(self.per_item_ms)
    }

    fn import_state(&mut self, state: f64) {
        if state > 0.0 {
            self.per_item_ms = state;
        }
    }

    fn name(&self) -> &'static str {
        "latency-sla"
    }
}

/// Error-target budget (`BudgetSpec::TargetError`): the §6.2 cost
/// function run **closed-loop** over the §3.5 error bound, the way
/// OLA-style systems (BlinkDB's error-bounded queries, StreamApprox's
/// budget loop) let a user ask for "≤ 2% relative error at 95%".
///
/// After every slide its [`CostFunction::observe_bound`] hook receives
/// the per-stratum aggregates the query actually saw, re-estimates the
/// achieved interval at the controller's own confidence, and solves
/// Eq 3.2 backwards ([`required_sample_size`]: per stratum
/// `nᵢ ≈ (t·sᵢ/εᵢ)²`, aggregated under proportional allocation with
/// finite-population correction) for the sample size the target needs.
/// The demand is smoothed (EWMA) so one noisy variance estimate does not
/// whipsaw the sampler, floored at two samples per observed stratum (the
/// minimum that yields a variance estimate at all), and clamped to the
/// window at `sample_size` time.
///
/// Everything the controller reads — moments, populations, t-scores — is
/// byte-identical across the serial, sharded, and incremental execution
/// paths, so the controller trajectory (and therefore every sample size
/// it picks) is deterministic: no wall-clock input, unlike
/// [`LatencyCost`].
#[derive(Debug, Clone, Copy)]
pub struct TargetErrorCost {
    relative_bound: f64,
    confidence: f64,
    /// EWMA-smoothed sample-size demand; `None` until the first
    /// feedback arrives (the seed fraction sizes the warm-up windows).
    smoothed_n: Option<f64>,
    /// EWMA weight of the newest demand.
    alpha: f64,
    /// Sampling fraction used before any feedback exists.
    seed_fraction: f64,
    /// Overload-degradation multiplier on the relative bound (≥ 1;
    /// exactly 1 at baseline). Set per slide by the
    /// [`DegradationController`], never persisted: the controller's
    /// ladder position is the durable state and re-applies the scale
    /// after a restore.
    bound_scale: f64,
}

impl TargetErrorCost {
    /// Controller targeting `relative_bound` (ε/|value|, > 0) at
    /// `confidence` ∈ (0, 1).
    pub fn new(relative_bound: f64, confidence: f64) -> Self {
        assert!(relative_bound > 0.0);
        assert!(0.0 < confidence && confidence < 1.0);
        TargetErrorCost {
            relative_bound,
            confidence,
            smoothed_n: None,
            alpha: 0.3,
            seed_fraction: 0.1,
            bound_scale: 1.0,
        }
    }

    /// The target relative bound (the configured baseline, before any
    /// degradation widening).
    pub fn relative_bound(&self) -> f64 {
        self.relative_bound
    }

    /// The bound actually targeted right now: baseline × degradation
    /// scale.
    pub fn effective_bound(&self) -> f64 {
        self.relative_bound * self.bound_scale
    }

    /// The confidence the bound is promised at.
    pub fn confidence(&self) -> f64 {
        self.confidence
    }

    /// The controller's current smoothed sample-size demand, if feedback
    /// has arrived yet.
    pub fn demand(&self) -> Option<f64> {
        self.smoothed_n
    }
}

impl CostFunction for TargetErrorCost {
    fn sample_size(&mut self, window_len: usize) -> usize {
        let n = match self.smoothed_n {
            Some(n) => n.round() as usize,
            // No feedback yet: the paper's default 10% fraction seeds the
            // loop (a pilot sample the first windows refine).
            None => (window_len as f64 * self.seed_fraction).round() as usize,
        };
        n.clamp(1, window_len.max(1))
    }

    fn observe(&mut self, _items: usize, _elapsed_ms: f64) {}

    fn observe_bound(&mut self, strata: &[StratumAgg], window_population: f64) {
        // Achieved interval at the controller's own confidence (the
        // query's report may be at a different level).
        let Ok(est) = estimate_sum(strata, self.confidence) else {
            return;
        };
        if !(est.value.abs() > 0.0) {
            return; // no scale to target a *relative* bound against
        }
        let observed = strata.iter().filter(|s| s.b > 0.0).count();
        let covered_pop: f64 =
            strata.iter().filter(|s| s.b > 0.0).map(|s| s.population).sum();
        if !(covered_pop > 0.0) {
            return;
        }
        // b ≥ 2 per observed stratum: the least that estimates variance —
        // capped at the covered population itself (a 1-item stratum can
        // never yield 2 samples, and an inverted clamp range panics).
        let floor = ((2 * observed.max(1)) as f64).min(covered_pop).max(1.0);
        // The effective target: baseline bound widened by the current
        // degradation scale (×1 at baseline). Widening the margin shrinks
        // the backsolved demand — the load-shedding lever.
        let target_margin = self.relative_bound * self.bound_scale * est.value.abs();
        let required_covered = required_sample_size(strata, target_margin, est.t)
            // `None` = zero observed variance: any size meets the target.
            .unwrap_or(floor)
            .clamp(floor, covered_pop);
        // The backsolve is in covered-strata samples; the sampler spreads
        // a total budget across the WHOLE window proportionally, so scale
        // up by the uncovered remainder (×1 for whole-window queries).
        let scale = (window_population / covered_pop).max(1.0);
        let required =
            (required_covered * scale).clamp(floor, window_population.max(floor));
        self.smoothed_n = Some(match self.smoothed_n {
            Some(prev) => self.alpha * required + (1.0 - self.alpha) * prev,
            None => required,
        });
    }

    fn wants_bound_feedback(&self) -> bool {
        true
    }

    fn export_state(&self) -> Option<f64> {
        self.smoothed_n
    }

    fn import_state(&mut self, state: f64) {
        if state > 0.0 {
            self.smoothed_n = Some(state);
        }
    }

    fn set_bound_scale(&mut self, scale: f64) {
        self.bound_scale = scale.max(1.0);
    }

    fn name(&self) -> &'static str {
        "target-error"
    }
}

/// Configuration of the overload-degradation ladder (the `degradation.*`
/// TOML knobs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradationPolicy {
    /// Multiplicative widening per ladder step; > 1.
    pub step_factor: f64,
    /// Highest ladder level; 0 disables the controller entirely.
    pub max_steps: u32,
    /// Consecutive calm slides (lag at or below the watermark) required
    /// before stepping one level back down; ≥ 1.
    pub recover_slides: u32,
}

impl Default for DegradationPolicy {
    fn default() -> Self {
        DegradationPolicy { step_factor: 1.5, max_steps: 0, recover_slides: 2 }
    }
}

/// Overload-adaptive error widening, the StreamApprox-style degradation
/// lever: when consumer lag crosses the `pipeline.lag_watermark_slides`
/// watermark, step every `TargetError` bound up a configured ladder
/// (shedding sample demand through the Eq 3.2 backsolve — see
/// [`TargetErrorCost::observe_bound`]); as lag drains, walk back down to
/// the configured baseline.
///
/// The controller reads only byte-identical quantities — lag measured in
/// slides, never wall-clock — so its trajectory is deterministic across
/// the serial, sharded, and incremental execution paths and across
/// checkpoint/restore (its `(level, calm)` position rides the
/// checkpoint's `Misc` record).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradationController {
    policy: DegradationPolicy,
    /// Current ladder level in `0..=policy.max_steps`.
    level: u32,
    /// Consecutive calm slides observed at the current level.
    calm: u32,
}

impl DegradationController {
    /// Controller at the configured baseline (level 0).
    pub fn new(policy: DegradationPolicy) -> Self {
        DegradationController { policy, level: 0, calm: 0 }
    }

    /// Controller that never widens (`max_steps = 0`).
    pub fn disabled() -> Self {
        Self::new(DegradationPolicy::default())
    }

    /// Feed one slide's lag (in slides, i.e. `lag_items / slide_len`)
    /// against the watermark. Above the watermark: climb one level (up to
    /// `max_steps`) and reset the calm streak. At or below: extend the
    /// streak, and after `recover_slides` consecutive calm slides step
    /// one level back down.
    pub fn observe_lag_slides(&mut self, lag_slides: u64, watermark_slides: u64) {
        if self.policy.max_steps == 0 {
            return;
        }
        if lag_slides > watermark_slides {
            self.calm = 0;
            self.level = (self.level + 1).min(self.policy.max_steps);
        } else {
            self.calm += 1;
            if self.level > 0 && self.calm >= self.policy.recover_slides.max(1) {
                self.level -= 1;
                self.calm = 0;
            }
        }
    }

    /// Current ladder level.
    pub fn level(&self) -> u32 {
        self.level
    }

    /// The bound multiplier at the current level: `step_factor^level`,
    /// exactly 1.0 at baseline.
    pub fn scale(&self) -> f64 {
        if self.level == 0 {
            1.0
        } else {
            self.policy.step_factor.powi(self.level as i32)
        }
    }

    /// Checkpointable `(level, calm)` position.
    pub fn state(&self) -> (u32, u32) {
        (self.level, self.calm)
    }

    /// Restore a position captured by [`DegradationController::state`]
    /// (level clamped to the configured ladder).
    pub fn restore_state(&mut self, level: u32, calm: u32) {
        self.level = level.min(self.policy.max_steps);
        self.calm = calm;
    }
}

/// Check a budget spec's parameters — shared by `SystemConfig::validate`
/// and the per-query validation in `Coordinator::submit_query`, so a bad
/// budget surfaces as a config error instead of a construction panic.
pub fn validate_spec(spec: &BudgetSpec) -> Result<()> {
    // Guards are written positively (`!(x > 0.0)`) so NaN fails them too
    // — `NaN <= 0.0` is false and would sneak past an inverted check
    // straight into the constructors' asserts.
    match *spec {
        BudgetSpec::Fraction(f) if !(0.0 < f && f <= 1.0) => Err(Error::Config(format!(
            "budget fraction must be in (0, 1], got {f}"
        ))),
        BudgetSpec::Tokens { per_window, cost_per_item }
            if !(per_window > 0.0 && cost_per_item > 0.0) =>
        {
            Err(Error::Config(format!(
                "token budget needs per_window > 0 and cost_per_item > 0, got {per_window} / {cost_per_item}"
            )))
        }
        BudgetSpec::LatencyMs(ms) if !(ms > 0.0) => Err(Error::Config(format!(
            "latency budget must be > 0 ms, got {ms}"
        ))),
        BudgetSpec::TargetError { relative_bound, confidence }
            if !(relative_bound > 0.0 && 0.0 < confidence && confidence < 1.0) =>
        {
            Err(Error::Config(format!(
                "target-error budget needs relative_bound > 0 and confidence in (0, 1), \
                 got {relative_bound} @ {confidence}"
            )))
        }
        _ => Ok(()),
    }
}

/// Check a budget spec against the aggregate kind it would drive.
/// Sketch kinds (`Quantile` / `TopK` / `DistinctCount`) opt out of the
/// closed-loop `TargetError` budget: `TargetErrorCost` backsolves
/// Eq 3.2 — a moment-variance identity — for the sample size that hits
/// a *relative moment-interval* bound, and a sketch answer has no such
/// interval. Its honest uncertainty is a rank / count-bound /
/// standard-error surface whose width is set by the sketch caps, not by
/// the sample size the controller steers — the loop could never
/// converge on anything. Open-loop budgets (fraction, tokens, latency)
/// remain fully supported for sketch kinds.
pub fn validate_kind_budget(kind: AggregateKind, spec: &BudgetSpec) -> Result<()> {
    if kind.is_sketch() && matches!(spec, BudgetSpec::TargetError { .. }) {
        return Err(Error::Config(format!(
            "a target-error budget cannot drive a `{}` query: the §3.5 backsolve \
             controls a moment-interval width, and sketch kinds report rank / \
             count-bound / standard-error surfaces instead — use an open-loop \
             budget (fraction, tokens, latency)",
            kind.name()
        )));
    }
    Ok(())
}

/// Build the configured cost function.
pub fn from_spec(spec: &BudgetSpec) -> Box<dyn CostFunction> {
    match *spec {
        BudgetSpec::Fraction(f) => Box::new(FractionCost::new(f)),
        BudgetSpec::Tokens { per_window, cost_per_item } => {
            Box::new(TokenBucketCost::new(per_window, cost_per_item))
        }
        BudgetSpec::LatencyMs(ms) => Box::new(LatencyCost::new(ms, 0.001)),
        BudgetSpec::TargetError { relative_bound, confidence } => {
            Box::new(TargetErrorCost::new(relative_bound, confidence))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fraction_rounds_and_clamps() {
        let mut c = FractionCost::new(0.1);
        assert_eq!(c.sample_size(10_000), 1000);
        assert_eq!(c.sample_size(5), 1);
        let mut c = FractionCost::new(1.0);
        assert_eq!(c.sample_size(100), 100);
    }

    #[test]
    fn token_bucket_affords_budget() {
        let mut c = TokenBucketCost::new(500.0, 2.0);
        // The bucket starts empty: the first window affords exactly one
        // refill, not refill + a phantom full bucket.
        assert_eq!(c.sample_size(10_000), 250);
        // A fully spent bucket refills to the same allowance.
        assert_eq!(c.sample_size(10_000), 250);
        // Small windows capped at window length.
        assert_eq!(c.sample_size(100), 100);
    }

    #[test]
    fn token_bucket_carries_over_with_burst_cap() {
        let mut c = TokenBucketCost::new(500.0, 2.0);
        assert_eq!(c.tokens(), 0.0, "bucket starts empty");
        // A 100-item window spends 200 of the 500-token refill…
        assert_eq!(c.sample_size(100), 100);
        assert_eq!(c.tokens(), 300.0, "accessor reports the live balance");
        // …and the leftover carries into the next window's budget:
        // refill min(300 + 500, burst 1000) = 800 → 400 items.
        assert_eq!(c.sample_size(10_000), 400);
        assert_eq!(c.tokens(), 0.0);
        // Two idle (1-item) windows bank tokens only up to the burst cap.
        assert_eq!(c.sample_size(1), 1);
        assert_eq!(c.sample_size(1), 1);
        assert_eq!(c.tokens(), 996.0); // 500−2, then min(498+500, 1000)−2
        assert_eq!(c.sample_size(10_000), 500, "burst cap bounds the binge");
        // A custom burst cap of one refill disables carry-over entirely.
        let mut c = TokenBucketCost::new(500.0, 2.0).with_burst(500.0);
        assert_eq!(c.sample_size(100), 100);
        assert_eq!(c.sample_size(10_000), 250, "burst = capacity → no carry-over");
        // Carry-over state round-trips through the checkpoint hooks.
        let mut c = TokenBucketCost::new(500.0, 2.0);
        c.sample_size(100);
        let state = c.export_state().unwrap();
        assert_eq!(state, 300.0);
        let mut restored = TokenBucketCost::new(500.0, 2.0);
        restored.import_state(state);
        assert_eq!(restored.tokens(), 300.0);
        assert_eq!(restored.sample_size(10_000), 400);
    }

    #[test]
    fn latency_model_adapts() {
        let mut c = LatencyCost::new(100.0, 0.01); // predicts 9000 items
        let n0 = c.sample_size(100_000);
        assert_eq!(n0, 9000);
        // Observed: items are 10× slower than the seed.
        for _ in 0..50 {
            c.observe(1000, 100.0); // 0.1 ms/item
        }
        let n1 = c.sample_size(100_000);
        assert!(n1 < n0 / 5, "model failed to adapt: {n0} -> {n1}");
        assert!((c.per_item_ms() - 0.1).abs() < 0.02);
    }

    #[test]
    fn latency_model_ignores_degenerate_observations() {
        let mut c = LatencyCost::new(100.0, 0.01);
        let before = c.per_item_ms();
        c.observe(0, 50.0);
        c.observe(100, 0.0);
        assert_eq!(c.per_item_ms(), before);
    }

    #[test]
    fn from_spec_builds_matching_policy() {
        assert_eq!(from_spec(&BudgetSpec::Fraction(0.5)).name(), "fraction");
        assert_eq!(
            from_spec(&BudgetSpec::Tokens { per_window: 10.0, cost_per_item: 1.0 }).name(),
            "token-bucket"
        );
        assert_eq!(from_spec(&BudgetSpec::LatencyMs(10.0)).name(), "latency-sla");
        let target =
            from_spec(&BudgetSpec::TargetError { relative_bound: 0.02, confidence: 0.95 });
        assert_eq!(target.name(), "target-error");
        assert!(target.wants_bound_feedback(), "the loop-closing policy");
        assert!(!from_spec(&BudgetSpec::Fraction(0.5)).wants_bound_feedback());
    }

    /// One stratum's aggregates with the given sample/population shape.
    fn agg(b: f64, sum: f64, sumsq: f64, population: f64) -> StratumAgg {
        StratumAgg { b, sum, sumsq, population }
    }

    #[test]
    fn target_error_seeds_then_tracks_demand() {
        let mut c = TargetErrorCost::new(0.01, 0.95);
        // Before feedback: the 10% pilot fraction, window-clamped.
        assert_eq!(c.sample_size(10_000), 1000);
        assert_eq!(c.sample_size(5), 1);
        assert!(c.demand().is_none());
        // Feedback: one stratum, b = 100 of B = 10 000, mean 50, s² ≈ 64.
        // A 1% relative target on τ̂ ≈ 500 000 is ε = 5000.
        let strata = [agg(100.0, 5000.0, 256_400.0, 10_000.0)];
        c.observe_bound(&strata, 10_000.0);
        let first = c.demand().expect("feedback must set a demand");
        assert!(first > 2.0, "non-degenerate demand, got {first}");
        // The controller's next ask is its smoothed demand, clamped.
        assert_eq!(c.sample_size(10_000), first.round() as usize);
        assert!(c.sample_size(10) <= 10, "never exceeds the window");
        // Stationary feedback converges: repeated identical observations
        // move the EWMA monotonically toward the same fixed point.
        let mut prev = first;
        for _ in 0..20 {
            c.observe_bound(&strata, 10_000.0);
            let cur = c.demand().unwrap();
            assert!(
                (cur - prev).abs() <= (first - prev).abs().max(1e-9) + 1e-9,
                "demand must not diverge: {prev} -> {cur}"
            );
            prev = cur;
        }
        // Tighter target, larger demand.
        let mut tight = TargetErrorCost::new(0.001, 0.95);
        tight.observe_bound(&strata, 10_000.0);
        assert!(tight.demand().unwrap() > prev, "0.1% must cost more than 1%");
        assert!(
            tight.demand().unwrap() <= 10_000.0,
            "demand is population-clamped (FPC)"
        );
        // A stratum-restricted query covering 1/4 of the window must
        // scale its demand by 4×: proportional allocation only lands a
        // quarter of the total budget inside its stratum.
        let mut whole = TargetErrorCost::new(0.01, 0.95);
        let mut filtered = TargetErrorCost::new(0.01, 0.95);
        whole.observe_bound(&strata, 10_000.0);
        filtered.observe_bound(&strata, 40_000.0);
        let (dw, df) = (whole.demand().unwrap(), filtered.demand().unwrap());
        assert!(
            (df - 4.0 * dw).abs() < 1e-6 * dw,
            "filtered demand must scale with the uncovered window: {dw} vs {df}"
        );
    }

    #[test]
    fn target_error_handles_degenerate_feedback() {
        let mut c = TargetErrorCost::new(0.02, 0.95);
        // Empty / zero-value / zero-variance feedback must not poison the
        // controller with NaN or zero demands.
        c.observe_bound(&[], 100.0);
        assert!(c.demand().is_none());
        c.observe_bound(&[agg(10.0, 0.0, 0.0, 100.0)], 100.0); // τ̂ = 0
        assert!(c.demand().is_none());
        c.observe_bound(&[agg(10.0, 50.0, 250.0, 100.0)], 100.0); // s² = 0
        let d = c.demand().expect("zero variance still sets the floor demand");
        assert_eq!(d, 2.0, "floor = 2 per observed stratum");
        // A single-item stratum: the 2-per-stratum floor exceeds the
        // covered population — must cap at the population, not panic on
        // an inverted clamp range. (Scale-up then asks for the whole
        // window: landing 1 sample in a 1-item stratum under
        // proportional allocation takes a census.)
        let mut tiny = TargetErrorCost::new(0.02, 0.95);
        tiny.observe_bound(&[agg(1.0, 5.0, 25.0, 1.0)], 100.0);
        assert_eq!(tiny.demand(), Some(100.0));
        assert!(c.sample_size(1000) >= 1);
        // State round-trips through the checkpoint hooks.
        let state = c.export_state().unwrap();
        let mut restored = TargetErrorCost::new(0.02, 0.95);
        restored.import_state(state);
        assert_eq!(restored.demand(), c.demand());
    }

    #[test]
    fn attribution_scales_with_own_allocation_not_the_union() {
        // The cross-contamination regression, pinned at the unit level:
        // two queries on wildly different budgets share one slide.
        let (big_alloc, small_alloc, union) = (10_000usize, 100usize, 10_000usize);
        let substrate_ms = 80.0;
        let (items_b, ms_b) = attribute_query_cost(big_alloc, union, substrate_ms, 0.5);
        let (items_s, ms_s) = attribute_query_cost(small_alloc, union, substrate_ms, 0.5);
        // Each query observes *its own* allocation, never the union.
        assert_eq!(items_b, big_alloc);
        assert_eq!(items_s, small_alloc);
        // The small query pays its 1% substrate share plus its derive.
        assert!((ms_s - (0.8 + 0.5)).abs() < 1e-12, "got {ms_s}");
        assert!((ms_b - (80.0 + 0.5)).abs() < 1e-12, "got {ms_b}");
        // Query A's load must NOT inflate query B's observation: double
        // A's allocation (union and substrate cost grow with it) and B's
        // per-item estimate stays put.
        let (_, ms_s2) =
            attribute_query_cost(small_alloc, 2 * union, 2.0 * substrate_ms, 0.5);
        assert!(
            (ms_s2 - ms_s).abs() < 1e-12,
            "B's share changed with A's load: {ms_s} -> {ms_s2}"
        );
        // Degenerate union: only the derive cost is attributable.
        let (items_0, ms_0) = attribute_query_cost(0, 0, substrate_ms, 0.25);
        assert_eq!(items_0, 0);
        assert_eq!(ms_0, 0.25);
    }

    #[test]
    fn validate_spec_accepts_good_rejects_bad() {
        assert!(validate_spec(&BudgetSpec::Fraction(0.1)).is_ok());
        assert!(validate_spec(&BudgetSpec::Fraction(1.0)).is_ok());
        assert!(validate_spec(&BudgetSpec::Fraction(0.0)).is_err());
        assert!(validate_spec(&BudgetSpec::Fraction(1.5)).is_err());
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: 10.0, cost_per_item: 1.0 }).is_ok()
        );
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: 0.0, cost_per_item: 1.0 }).is_err()
        );
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: 10.0, cost_per_item: 0.0 }).is_err()
        );
        assert!(validate_spec(&BudgetSpec::LatencyMs(5.0)).is_ok());
        assert!(validate_spec(&BudgetSpec::LatencyMs(0.0)).is_err());
        let te = |relative_bound, confidence| {
            validate_spec(&BudgetSpec::TargetError { relative_bound, confidence })
        };
        assert!(te(0.02, 0.95).is_ok());
        assert!(te(0.0, 0.95).is_err());
        assert!(te(-0.1, 0.95).is_err());
        assert!(te(0.02, 0.0).is_err());
        assert!(te(0.02, 1.0).is_err());
        // NaN must be rejected, not passed through to a constructor panic.
        assert!(validate_spec(&BudgetSpec::Fraction(f64::NAN)).is_err());
        assert!(
            validate_spec(&BudgetSpec::Tokens { per_window: f64::NAN, cost_per_item: 1.0 })
                .is_err()
        );
        assert!(validate_spec(&BudgetSpec::LatencyMs(f64::NAN)).is_err());
        assert!(te(f64::NAN, 0.95).is_err());
        assert!(te(0.02, f64::NAN).is_err());
    }

    #[test]
    fn sketch_kinds_opt_out_of_target_error_budgets() {
        let closed = BudgetSpec::TargetError { relative_bound: 0.02, confidence: 0.95 };
        // Moment kinds may close the loop; sketch kinds must not — the
        // Eq 3.2 backsolve steers a moment-interval width that sketch
        // surfaces do not have.
        for kind in AggregateKind::ALL {
            let verdict = validate_kind_budget(kind, &closed);
            if kind.is_sketch() {
                let err = verdict.expect_err("sketch kind must reject TargetError");
                assert!(
                    matches!(err, Error::Config(ref msg) if msg.contains(kind.name())),
                    "rejection must name the kind"
                );
            } else {
                assert!(verdict.is_ok(), "{} under TargetError", kind.name());
            }
            // Open-loop budgets are kind-agnostic.
            assert!(validate_kind_budget(kind, &BudgetSpec::Fraction(0.1)).is_ok());
            assert!(validate_kind_budget(kind, &BudgetSpec::LatencyMs(2.0)).is_ok());
        }
    }

    fn ladder(step_factor: f64, max_steps: u32, recover_slides: u32) -> DegradationController {
        DegradationController::new(DegradationPolicy { step_factor, max_steps, recover_slides })
    }

    /// Satellite property: the ladder is monotone under rising lag — the
    /// scale never decreases while lag stays above the watermark — and is
    /// capped at the configured ceiling.
    #[test]
    fn degradation_monotone_under_rising_lag() {
        let mut c = ladder(1.5, 4, 2);
        assert_eq!(c.scale(), 1.0);
        let mut prev = c.scale();
        for lag in 5..40u64 {
            c.observe_lag_slides(lag, 4);
            let cur = c.scale();
            assert!(cur >= prev, "scale regressed under rising lag: {prev} -> {cur}");
            assert!(c.level() <= 4, "ladder must cap at max_steps");
            prev = cur;
        }
        assert_eq!(c.level(), 4);
        assert!((c.scale() - 1.5f64.powi(4)).abs() < 1e-12);
    }

    /// Satellite property: once lag clears, the controller returns
    /// EXACTLY to the configured baseline — scale() == 1.0 bit-for-bit,
    /// not merely approximately.
    #[test]
    fn degradation_returns_exactly_to_baseline() {
        for &(factor, steps, recover) in
            &[(1.5, 3u32, 2u32), (2.0, 5, 1), (1.1, 8, 4), (3.0, 1, 3)]
        {
            let mut c = ladder(factor, steps, recover);
            // Drive to the top of the ladder…
            for _ in 0..(steps + 5) {
                c.observe_lag_slides(100, 4);
            }
            assert_eq!(c.level(), steps);
            // …then drain: each level takes `recover` calm slides.
            for _ in 0..(steps * recover + recover) {
                c.observe_lag_slides(0, 4);
            }
            assert_eq!(c.level(), 0, "factor={factor} steps={steps}");
            assert_eq!(c.scale().to_bits(), 1.0f64.to_bits(), "baseline must be exact");
        }
    }

    /// Recovery requires `recover_slides` CONSECUTIVE calm slides: a lag
    /// spike mid-streak resets it.
    #[test]
    fn degradation_recovery_streak_resets_on_spike() {
        let mut c = ladder(1.5, 4, 3);
        for _ in 0..2 {
            c.observe_lag_slides(10, 4);
        }
        assert_eq!(c.level(), 2);
        c.observe_lag_slides(0, 4);
        c.observe_lag_slides(0, 4);
        assert_eq!(c.level(), 2, "streak of 2 < recover_slides 3");
        c.observe_lag_slides(10, 4); // spike resets the streak (and climbs)
        assert_eq!(c.level(), 3);
        for _ in 0..3 {
            c.observe_lag_slides(0, 4);
        }
        assert_eq!(c.level(), 2, "a full fresh streak steps down once");
    }

    /// A disabled ladder (max_steps = 0) never moves, whatever the lag.
    #[test]
    fn degradation_disabled_never_widens() {
        let mut c = DegradationController::disabled();
        for lag in [0u64, 5, 500, u64::MAX] {
            c.observe_lag_slides(lag, 4);
            assert_eq!(c.level(), 0);
            assert_eq!(c.scale().to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn degradation_state_roundtrip_and_clamp() {
        let mut c = ladder(1.5, 4, 2);
        for _ in 0..3 {
            c.observe_lag_slides(10, 4);
        }
        c.observe_lag_slides(0, 4);
        let (level, calm) = c.state();
        assert_eq!((level, calm), (3, 1));
        let mut restored = ladder(1.5, 4, 2);
        restored.restore_state(level, calm);
        assert_eq!(restored.state(), c.state());
        assert_eq!(restored.scale().to_bits(), c.scale().to_bits());
        // A shrunk ladder clamps a restored out-of-range level.
        let mut narrow = ladder(1.5, 2, 2);
        narrow.restore_state(7, 0);
        assert_eq!(narrow.level(), 2);
    }

    /// Satellite property: degradation never widens an open-loop budget.
    /// `set_bound_scale` is a no-op for fraction/token/latency policies —
    /// their sample sizing is resource-driven and must be untouched by
    /// the ladder — and `validate_kind_budget` guarantees sketch kinds
    /// only ever carry open-loop budgets, so no sketch query can widen.
    #[test]
    fn degradation_never_widens_open_loop_budgets() {
        let window = 10_000usize;
        let specs = [
            BudgetSpec::Fraction(0.1),
            BudgetSpec::Tokens { per_window: 500.0, cost_per_item: 2.0 },
            BudgetSpec::LatencyMs(100.0),
        ];
        for spec in &specs {
            let mut plain = from_spec(spec);
            let mut scaled = from_spec(spec);
            scaled.set_bound_scale(8.0);
            for _ in 0..5 {
                assert_eq!(
                    plain.sample_size(window),
                    scaled.sample_size(window),
                    "{} must ignore the degradation scale",
                    plain.name()
                );
            }
        }
        // The closed-loop policy DOES react: a widened bound sheds
        // sample demand through the backsolve.
        let strata = [agg(100.0, 5000.0, 256_400.0, 10_000.0)];
        let mut base = TargetErrorCost::new(0.01, 0.95);
        let mut wide = TargetErrorCost::new(0.01, 0.95);
        wide.set_bound_scale(4.0);
        base.observe_bound(&strata, 10_000.0);
        wide.observe_bound(&strata, 10_000.0);
        assert!(
            wide.demand().unwrap() < base.demand().unwrap(),
            "widened bound must shed demand: {:?} vs {:?}",
            base.demand(),
            wide.demand()
        );
        assert!((wide.effective_bound() - 0.04).abs() < 1e-12);
        // Returning the scale to baseline restores the exact configured
        // target.
        wide.set_bound_scale(1.0);
        assert_eq!(wide.effective_bound().to_bits(), 0.01f64.to_bits());
        // Sketch kinds cannot even carry a TargetError budget, so the
        // ladder can never reach a sketch query's surface.
        let closed = BudgetSpec::TargetError { relative_bound: 0.02, confidence: 0.95 };
        for kind in AggregateKind::ALL {
            if kind.is_sketch() {
                assert!(validate_kind_budget(kind, &closed).is_err());
            }
        }
    }

    #[test]
    fn sample_never_zero() {
        let mut c = FractionCost::new(0.001);
        assert!(c.sample_size(10) >= 1);
        let mut c = TokenBucketCost::new(0.5, 1.0);
        assert!(c.sample_size(10) >= 1);
        let mut c = LatencyCost::new(0.0001, 1.0);
        assert!(c.sample_size(10) >= 1);
        let mut c = TargetErrorCost::new(0.5, 0.95);
        assert!(c.sample_size(10) >= 1);
        assert!(c.sample_size(0) >= 1);
    }
}
