//! IncApprox launcher.
//!
//! ```text
//! incapprox [--config cfg.toml] [--mode incapprox|native|incremental|approx]
//!           [--windows N] [--workload section5|fluctuating|flows|tweets]
//!           [--window SIZE] [--slide N] [--fraction F] [--seed S]
//!           [--pjrt] [--artifacts DIR] [--verbose]
//! ```
//!
//! Runs the full pipeline (generators → kafka substrate → coordinator)
//! for N windows and prints one report line per window plus a summary.

use incapprox::cli::Args;
use incapprox::config::system::{BudgetSpec, ExecModeSpec, SystemConfig};
use incapprox::coordinator::{Coordinator, Pipeline};
use incapprox::error::{Error, Result};
#[cfg(feature = "pjrt")]
use incapprox::runtime::{PjrtBackend, PjrtRuntime};
use incapprox::workload::flows::FlowLogGen;
use incapprox::workload::gen::MultiStream;
use incapprox::workload::tweets::TweetGen;

fn build_workload(name: &str, seed: u64) -> Result<MultiStream> {
    match name {
        "section5" => Ok(MultiStream::paper_section5(seed)),
        "fluctuating" => Ok(MultiStream::paper_fluctuating(seed, 500)),
        "flows" => Ok(FlowLogGen::case_study(4, seed)),
        "tweets" => Ok(TweetGen::case_study(seed)),
        other => Err(Error::Config(format!("unknown workload `{other}`"))),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env(&["pjrt", "verbose", "help"])?;
    if args.flag("help") {
        println!("{}", include_str!("main.rs").lines().take(12).collect::<Vec<_>>().join("\n"));
        return Ok(());
    }
    incapprox::logging::init_with_level(if args.flag("verbose") {
        log::LevelFilter::Debug
    } else {
        log::LevelFilter::Info
    });

    let mut cfg = match args.get("config") {
        Some(path) => SystemConfig::from_file(path)?,
        None => SystemConfig::default(),
    };
    if let Some(mode) = args.get("mode") {
        cfg.mode = ExecModeSpec::parse(mode)?;
    }
    cfg.window_size = args.get_parse("window", cfg.window_size)?;
    cfg.slide = args.get_parse("slide", cfg.slide)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.map_rounds = args.get_parse("map-rounds", cfg.map_rounds)?;
    if let Some(f) = args.get("fraction") {
        cfg.budget = BudgetSpec::Fraction(
            f.parse().map_err(|_| Error::Config(format!("bad --fraction `{f}`")))?,
        );
    }
    if args.flag("pjrt") {
        cfg.use_pjrt = true;
    }
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    cfg.validate()?;

    let windows: usize = args.get_parse("windows", 20)?;
    let workload = args.get("workload").unwrap_or("section5");

    log::info!(
        "mode={} window={} slide={} workload={} backend={}",
        cfg.mode.name(),
        cfg.window_size,
        cfg.slide,
        workload,
        if cfg.use_pjrt { "pjrt" } else { "native" }
    );

    let source = build_workload(workload, cfg.seed)?;
    // With `num_workers > 1` the coordinator builds its own sharded
    // worker-pool backend; only the PJRT override is wired here.
    #[allow(unused_mut)]
    let mut coordinator = Coordinator::new(cfg.clone());
    if cfg.use_pjrt {
        #[cfg(feature = "pjrt")]
        {
            let rt = std::sync::Arc::new(PjrtRuntime::load(&cfg.artifacts_dir)?);
            log::info!("pjrt platform: {}", rt.platform());
            coordinator = coordinator
                .with_backend(Box::new(PjrtBackend::with_rounds(rt, cfg.map_rounds)));
        }
        #[cfg(not(feature = "pjrt"))]
        return Err(Error::Config(
            "this binary was built without the `pjrt` feature; rebuild with \
             `cargo build --features pjrt`"
                .into(),
        ));
    }

    let mut pipeline = Pipeline::new(coordinator, source)?;
    let reports = pipeline.run(windows)?;
    for r in &reports {
        println!("{}", r.summary());
    }

    let stats = pipeline.coordinator().memo_stats();
    let mean_latency: f64 =
        reports.iter().map(|r| r.latency_ms).sum::<f64>() / reports.len() as f64;
    let mean_reuse: f64 =
        reports.iter().skip(1).map(|r| r.item_reuse_fraction()).sum::<f64>()
            / reports.len().saturating_sub(1).max(1) as f64;
    println!(
        "\nsummary: {} windows, mean latency {:.3} ms, item reuse {:.1}%, memo hit-rate {:.1}%",
        reports.len(),
        mean_latency,
        mean_reuse * 100.0,
        stats.hit_rate() * 100.0
    );
    Ok(())
}
