//! Struct-of-arrays record batches — the in-memory hot-path layout.
//!
//! Every per-slide kernel (chunk hashing, moment folds, rank scoring,
//! sketch feeds) used to walk `&[Record]` row slices: 40-byte strided
//! loads to reach one 8-byte field. [`ColumnarBatch`] transposes a
//! record run into five dense `Arc` column buffers so each kernel
//! iterates exactly the columns it needs — `values` for the moments
//! fold, `ids`/`values` for the chunk hash, `ids` for sampler ranks,
//! `ids`/`values`/`keys` for the sketch feed.
//!
//! Columnar is a *representation*, not a semantic: `from_records` /
//! `to_records` round-trip losslessly and order-preservingly, and every
//! kernel rewritten against columns is pinned bit-equal to its retained
//! row-path reference (`tests/columnar_kernels.rs`, invariant
//! "columnar ≡ row bytes" in `docs/ARCHITECTURE.md`). Nothing columnar
//! is durable state — the checkpoint wire format is unchanged and rows
//! are rebuilt on demand via the lazy [`ColumnarBatch::rows`] view for
//! legacy callers.

use std::sync::Arc;
use std::sync::OnceLock;

use crate::workload::record::{Record, StratumId};

/// An immutable struct-of-arrays batch of records.
///
/// All five columns share one length; element `i` across the columns is
/// the `i`-th record of the originating run, in run order. Cloning bumps
/// `Arc` refcounts — column buffers are never copied on clone. The row
/// view is materialized at most once per batch (shared across clones
/// made *after* materialization) and only when a legacy `&[Record]`
/// caller asks for it.
#[derive(Debug, Clone)]
pub struct ColumnarBatch {
    ids: Arc<[u64]>,
    strata: Arc<[StratumId]>,
    timestamps: Arc<[u64]>,
    keys: Arc<[u64]>,
    values: Arc<[f64]>,
    /// Lazily transposed row view for legacy `&[Record]` callers.
    rows: OnceLock<Arc<[Record]>>,
}

impl Default for ColumnarBatch {
    fn default() -> Self {
        ColumnarBuilder::new().finish()
    }
}

/// Bitwise equality: `values` compare by `f64::to_bits`, so NaNs are
/// equal to themselves and `-0.0 != 0.0`. This is the same relation the
/// chunk-reuse gate uses — two batches are equal exactly when every
/// byte-identity consumer (hashes, sketches, reports) cannot tell them
/// apart.
impl PartialEq for ColumnarBatch {
    fn eq(&self, other: &Self) -> bool {
        self.ids == other.ids
            && self.strata == other.strata
            && self.timestamps == other.timestamps
            && self.keys == other.keys
            && self.values.len() == other.values.len()
            && self
                .values
                .iter()
                .zip(other.values.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

impl ColumnarBatch {
    /// Transpose a row slice into columns.
    pub fn from_records(records: &[Record]) -> Self {
        let mut b = ColumnarBuilder::with_capacity(records.len());
        b.extend_records(records);
        b.finish()
    }

    /// Transpose an owned row vector into columns.
    pub fn from_vec(records: Vec<Record>) -> Self {
        Self::from_records(&records)
    }

    /// Transpose a shared row slice into columns **and** adopt it as the
    /// batch's cached row view — [`Self::rows`] is then free. The window
    /// snapshot path uses this: it owns the row copy anyway, so exact-
    /// mode consumers keep their `&[Record]` view at zero extra cost
    /// while kernels get dense columns.
    pub fn from_rows_cached(records: Arc<[Record]>) -> Self {
        let batch = Self::from_records(&records);
        let _ = batch.rows.set(records);
        batch
    }

    /// Rows, freshly transposed (see [`Self::rows`] for the cached view).
    pub fn to_records(&self) -> Vec<Record> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    /// Lazy row view: transposed on first call, cached for the batch's
    /// lifetime. Legacy `&[Record]` call sites go through here.
    pub fn rows(&self) -> &[Record] {
        self.rows.get_or_init(|| self.to_records().into())
    }

    /// The cached row view as a shareable `Arc` slice.
    pub fn rows_arc(&self) -> Arc<[Record]> {
        self.rows();
        // The cell was just initialized above; read it back without
        // re-transposing.
        match self.rows.get() {
            Some(r) => Arc::clone(r),
            None => Arc::from(self.to_records()),
        }
    }

    /// Reassemble record `i` from the columns.
    ///
    /// Panics if `i >= len()`, like slice indexing.
    #[inline]
    pub fn get(&self, i: usize) -> Record {
        Record {
            id: self.ids[i],
            stratum: self.strata[i],
            timestamp: self.timestamps[i],
            key: self.keys[i],
            value: self.values[i],
        }
    }

    /// Number of records in the batch.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    /// Whether the batch holds no records.
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// The `id` column.
    #[inline]
    pub fn ids(&self) -> &[u64] {
        &self.ids
    }

    /// The `stratum` column.
    #[inline]
    pub fn strata(&self) -> &[StratumId] {
        &self.strata
    }

    /// The `timestamp` column.
    #[inline]
    pub fn timestamps(&self) -> &[u64] {
        &self.timestamps
    }

    /// The `key` column.
    #[inline]
    pub fn keys(&self) -> &[u64] {
        &self.keys
    }

    /// The `value` column.
    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Copy the half-open row range `[a, b)` into a new batch. Dense
    /// column memcpy — no row transpose.
    ///
    /// Panics if `a > b` or `b > len()`, like slice indexing.
    pub fn slice(&self, a: usize, b: usize) -> Self {
        ColumnarBatch {
            ids: self.ids[a..b].into(),
            strata: self.strata[a..b].into(),
            timestamps: self.timestamps[a..b].into(),
            keys: self.keys[a..b].into(),
            values: self.values[a..b].into(),
            rows: OnceLock::new(),
        }
    }

    /// Whether this batch is bit-identical to a row slice (values by
    /// `to_bits`). The columnar twin of `chunk::records_bit_equal`.
    pub fn bit_eq_records(&self, rows: &[Record]) -> bool {
        if self.len() != rows.len() {
            return false;
        }
        rows.iter().enumerate().all(|(i, r)| {
            self.ids[i] == r.id
                && self.strata[i] == r.stratum
                && self.timestamps[i] == r.timestamp
                && self.keys[i] == r.key
                && self.values[i].to_bits() == r.value.to_bits()
        })
    }

    /// Whether `other` is bit-identical to this batch's row range
    /// `[a, b)` — the chunk-reuse gate, run as five dense column
    /// compares instead of a row walk.
    ///
    /// Panics if `a > b` or `b > len()`, like slice indexing.
    pub fn range_bit_eq(&self, a: usize, b: usize, other: &Self) -> bool {
        if other.len() != b - a {
            return false;
        }
        self.ids[a..b] == other.ids[..]
            && self.strata[a..b] == other.strata[..]
            && self.timestamps[a..b] == other.timestamps[..]
            && self.keys[a..b] == other.keys[..]
            && self.values[a..b]
                .iter()
                .zip(other.values.iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    /// Whether two batches share the same column buffers (the columnar
    /// twin of `Arc::ptr_eq` on a row slice) — used by the zero-copy
    /// chunk-reuse assertions.
    pub fn ptr_eq(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.ids, &other.ids)
            && Arc::ptr_eq(&self.strata, &other.strata)
            && Arc::ptr_eq(&self.timestamps, &other.timestamps)
            && Arc::ptr_eq(&self.keys, &other.keys)
            && Arc::ptr_eq(&self.values, &other.values)
    }
}

/// Incrementally assembles a [`ColumnarBatch`] column by column — the
/// native emission path for workload generators and window delta/
/// snapshot construction (no intermediate `Vec<Record>`).
#[derive(Debug, Default)]
pub struct ColumnarBuilder {
    ids: Vec<u64>,
    strata: Vec<StratumId>,
    timestamps: Vec<u64>,
    keys: Vec<u64>,
    values: Vec<f64>,
}

impl ColumnarBuilder {
    /// Empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty builder with per-column capacity for `n` records.
    pub fn with_capacity(n: usize) -> Self {
        ColumnarBuilder {
            ids: Vec::with_capacity(n),
            strata: Vec::with_capacity(n),
            timestamps: Vec::with_capacity(n),
            keys: Vec::with_capacity(n),
            values: Vec::with_capacity(n),
        }
    }

    /// Append one record.
    #[inline]
    pub fn push(&mut self, r: &Record) {
        self.push_parts(r.id, r.stratum, r.timestamp, r.key, r.value);
    }

    /// Append one record given as loose fields (generators emit here
    /// without ever forming a `Record`).
    #[inline]
    pub fn push_parts(
        &mut self,
        id: u64,
        stratum: StratumId,
        timestamp: u64,
        key: u64,
        value: f64,
    ) {
        self.ids.push(id);
        self.strata.push(stratum);
        self.timestamps.push(timestamp);
        self.keys.push(key);
        self.values.push(value);
    }

    /// Append a row slice.
    pub fn extend_records(&mut self, records: &[Record]) {
        for r in records {
            self.push(r);
        }
    }

    /// Records appended so far.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    /// Whether nothing has been appended.
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Freeze into an immutable batch.
    pub fn finish(self) -> ColumnarBatch {
        ColumnarBatch {
            ids: self.ids.into(),
            strata: self.strata.into(),
            timestamps: self.timestamps.into(),
            keys: self.keys.into(),
            values: self.values.into(),
            rows: OnceLock::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, stratum: StratumId, ts: u64, key: u64, value: f64) -> Record {
        Record { id, stratum, timestamp: ts, key, value }
    }

    fn sample() -> Vec<Record> {
        vec![
            rec(3, 0, 10, 7, 1.5),
            rec(1, 2, 11, 8, -0.25),
            rec(9, 1, 12, 7, f64::NAN),
            rec(4, 0, 13, 9, 0.0),
        ]
    }

    #[test]
    fn round_trip_preserves_rows_and_order() {
        let rows = sample();
        let b = ColumnarBatch::from_records(&rows);
        assert_eq!(b.len(), rows.len());
        let back = b.to_records();
        for (a, r) in back.iter().zip(rows.iter()) {
            assert_eq!(a.id, r.id);
            assert_eq!(a.stratum, r.stratum);
            assert_eq!(a.timestamp, r.timestamp);
            assert_eq!(a.key, r.key);
            assert_eq!(a.value.to_bits(), r.value.to_bits());
        }
        assert!(b.bit_eq_records(&rows));
    }

    #[test]
    fn lazy_row_view_is_cached() {
        let b = ColumnarBatch::from_records(&sample());
        let p1 = b.rows().as_ptr();
        let p2 = b.rows().as_ptr();
        assert_eq!(p1, p2, "row view must transpose once");
        assert_eq!(b.rows_arc().as_ptr(), p1);
    }

    #[test]
    fn empty_batch() {
        let b = ColumnarBatch::default();
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        assert!(b.to_records().is_empty());
        assert!(b.rows().is_empty());
        assert!(b.bit_eq_records(&[]));
        assert_eq!(b, ColumnarBatch::from_records(&[]));
    }

    #[test]
    fn slice_is_dense_and_fresh() {
        let b = ColumnarBatch::from_records(&sample());
        let s = b.slice(1, 3);
        assert_eq!(s.len(), 2);
        assert_eq!(s.ids(), &[1, 9]);
        assert!(b.range_bit_eq(1, 3, &s));
        assert!(!b.range_bit_eq(0, 2, &s));
        assert!(!s.ptr_eq(&b));
        assert!(s.ptr_eq(&s.clone()));
    }

    #[test]
    fn builder_parts_match_record_push() {
        let rows = sample();
        let mut a = ColumnarBuilder::with_capacity(rows.len());
        let mut b = ColumnarBuilder::new();
        for r in &rows {
            a.push(r);
            b.push_parts(r.id, r.stratum, r.timestamp, r.key, r.value);
        }
        assert_eq!(a.len(), rows.len());
        assert!(!a.is_empty());
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn bit_equality_distinguishes_nan_payloads_not_identity() {
        let rows = sample();
        let b = ColumnarBatch::from_records(&rows);
        // NaN == NaN under bit equality (same payload).
        assert_eq!(b, b.clone());
        let mut flipped = rows.clone();
        flipped[3].value = -0.0;
        assert!(!b.bit_eq_records(&flipped), "-0.0 must differ from 0.0");
    }
}
