//! Lightweight metrics: counters, gauges, histograms, and a registry.
//!
//! The coordinator and benches use these for throughput/latency reporting;
//! everything is process-local and lock-cheap (atomics for counters, a
//! mutex-guarded buffer for histograms).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Metrics are observational: a thread that panicked while holding a
/// guard can only have left a partially updated sample buffer, which is
/// still safe to read — so recover from poisoning instead of
/// propagating the panic.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Value distribution with quantile queries.
#[derive(Debug, Default)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        lock(&self.samples).push(v);
    }

    /// Number of observations.
    pub fn count(&self) -> usize {
        lock(&self.samples).len()
    }

    /// Mean of observations (0 if empty).
    pub fn mean(&self) -> f64 {
        let s = lock(&self.samples);
        if s.is_empty() {
            0.0
        } else {
            s.iter().sum::<f64>() / s.len() as f64
        }
    }

    /// Quantile in [0, 1] by nearest-rank on the sorted samples.
    pub fn quantile(&self, q: f64) -> f64 {
        let mut s = lock(&self.samples).clone();
        if s.is_empty() {
            return 0.0;
        }
        s.sort_by(f64::total_cmp);
        let idx = ((q.clamp(0.0, 1.0)) * (s.len() - 1) as f64).round() as usize;
        s[idx]
    }

    /// Reset.
    pub fn clear(&self) {
        lock(&self.samples).clear();
    }
}

/// Named metrics registry.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        lock(&self.counters)
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Counter::new()))
            .clone()
    }

    /// Get or create a histogram.
    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        lock(&self.histograms)
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    /// Render a sorted text report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in lock(&self.counters).iter() {
            out.push_str(&format!("counter {name} = {}\n", c.get()));
        }
        for (name, h) in lock(&self.histograms).iter() {
            out.push_str(&format!(
                "histogram {name}: n={} mean={:.4} p50={:.4} p99={:.4}\n",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99)
            ));
        }
        out
    }
}

/// Cumulative wall-clock breakdown of the coordinator's sharded window
/// pipeline — one observation per window. Besides the three coarse
/// phases (plan / compute / finalize) it tracks the two columnar kernel
/// passes that run inside them: sampler maintenance (batched delta
/// ranks) and the sketch feed. Benches read it to attribute end-to-end
/// speedups to the phase that earned them.
#[derive(Debug, Default)]
pub struct PhaseProfile {
    plan: Histogram,
    compute: Histogram,
    finalize: Histogram,
    sampler: Histogram,
    sketch: Histogram,
}

impl PhaseProfile {
    /// Empty profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one window's phase timings (milliseconds). `sampler_ms`
    /// and `sketch_ms` are kernel sub-phases, not additive with the
    /// coarse three (the sampler runs during prepare, the sketch feed
    /// during finalize).
    pub fn observe(
        &self,
        plan_ms: f64,
        compute_ms: f64,
        finalize_ms: f64,
        sampler_ms: f64,
        sketch_ms: f64,
    ) {
        self.plan.observe(plan_ms);
        self.compute.observe(compute_ms);
        self.finalize.observe(finalize_ms);
        self.sampler.observe(sampler_ms);
        self.sketch.observe(sketch_ms);
    }

    /// Windows observed.
    pub fn windows(&self) -> usize {
        self.plan.count()
    }

    /// Mean planning-phase milliseconds per window.
    pub fn plan_mean_ms(&self) -> f64 {
        self.plan.mean()
    }

    /// Mean compute-phase (batched backend call) milliseconds per window.
    pub fn compute_mean_ms(&self) -> f64 {
        self.compute.mean()
    }

    /// Mean finalize-phase milliseconds per window.
    pub fn finalize_mean_ms(&self) -> f64 {
        self.finalize.mean()
    }

    /// Mean sampler-maintenance kernel milliseconds per window.
    pub fn sampler_mean_ms(&self) -> f64 {
        self.sampler.mean()
    }

    /// Mean sketch feed-pass milliseconds per window.
    pub fn sketch_mean_ms(&self) -> f64 {
        self.sketch.mean()
    }

    /// One-line summary, e.g. for bench output.
    pub fn summary(&self) -> String {
        format!(
            "phases over {} windows: plan {:.3} ms, compute {:.3} ms, finalize {:.3} ms \
             (sampler {:.3} ms, sketch {:.3} ms) (means)",
            self.windows(),
            self.plan_mean_ms(),
            self.compute_mean_ms(),
            self.finalize_mean_ms(),
            self.sampler_mean_ms(),
            self.sketch_mean_ms()
        )
    }
}

/// Items touched by each pipeline stage during one window slide — the
/// accounting behind the O(delta) invariant: on the incremental slide
/// path every field scales with the input change (plus the sample for
/// the biasing stages), never with the window. The from-scratch baseline
/// pays `window_items`/`sampler_items` proportional to the whole window;
/// `benches/incremental_scaling.rs` prints both side by side.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SlideWork {
    /// Records materialized or scanned by the window layer (full-view
    /// copies on the from-scratch path; |delta| on the incremental path).
    pub window_items: u64,
    /// Items offered to / removed from the sampler this slide.
    pub sampler_items: u64,
    /// Records hashed into fresh chunks during planning (delta chunks
    /// plus cache-missed full-path runs).
    pub plan_items: u64,
    /// Items whose moments the backend computed fresh.
    pub compute_items: u64,
    /// Per-stratum moment reads performed to derive the registered
    /// queries' answers — with `budget_adjust`, the only counters allowed
    /// to scale with query count (O(strata) per query; derivation never
    /// touches items).
    pub derive_items: u64,
    /// Per-stratum aggregate reads fed back to **adaptive error-target
    /// budgets** (`BudgetSpec::TargetError`) to re-solve Eq 3.2 for the
    /// next slide's sample size. O(strata) per adaptive budget; 0 when
    /// every budget is open-loop. Like `derive_items`, allowed to scale
    /// with query count — never with the window.
    pub budget_adjust: u64,
    /// Items hashed or folded into per-chunk **sketch bundles** this
    /// slide (rehashed records on cache-missed runs plus items of chunks
    /// whose bundle was not memoized). 0 unless a sketch-backed query
    /// (`Quantile`/`TopK`/`DistinctCount`) is registered, so it lives
    /// outside `substrate_total` — the moment substrate's flatness gate
    /// must not move when a sketch query joins the mix. On the
    /// incremental path this tracks the delta, never the window, and it
    /// is independent of *how many* sketch queries are registered (one
    /// side map serves them all).
    pub sketch_items: u64,
    /// Bytes appended to the in-memory checkpoint chain this slide (0
    /// when checkpointing is off). The durability analog of the O(delta)
    /// invariant: once the base segment exists, periodic checkpoints
    /// append delta segments whose size tracks the state change since the
    /// last checkpoint, never the window —
    /// `benches/checkpoint_overhead.rs --smoke` asserts it.
    pub checkpoint_bytes: u64,
    /// Items replayed to rebuild state from a checkpoint (window buffer,
    /// memoized runs, chunk entries, journaled batches). Recorded once on
    /// the restored coordinator's profile; 0 on every later slide.
    pub restore_items: u64,
    /// Injected memo-loss faults observed this slide (0 or 1) — surfaces
    /// `FaultInjector::maybe_inject` through the work profile so benches
    /// and tests can report fault counts alongside the work they caused.
    pub fault_injections: u64,
    /// Compute-call retries spent this slide by the driver's
    /// `RetryPolicy` (0 on a clean slide). Like `fault_injections`, an
    /// event count — excluded from the items-touched totals so the
    /// O(delta) work comparisons are untouched by fault handling.
    pub retries: u64,
    /// Per-stratum state reads performed by the partition **merge tier**
    /// to fold K partition states into one global report: O(strata · K)
    /// per slide, independent of record count — the scale-out analog of
    /// `derive_items`. Always 0 on single-coordinator runs;
    /// `benches/partition_scaleout.rs --smoke` asserts the flatness.
    pub merge_items: u64,
}

impl SlideWork {
    /// Sum over all item-touching stages — the headline per-slide
    /// items-touched number. Excludes `checkpoint_bytes` (bytes, not
    /// items), `restore_items` (one-time recovery cost, not steady-state
    /// slide work), and the event counts `fault_injections` / `retries`,
    /// so enabling durability or fault handling never perturbs the
    /// O(delta) work comparisons.
    pub fn total(&self) -> u64 {
        self.substrate_total()
            + self.derive_items
            + self.budget_adjust
            + self.sketch_items
            + self.merge_items
    }

    /// Items touched by the shared substrate stages (window, sampler,
    /// plan, compute) — everything except per-query derivation. The
    /// multi-query invariant: this must be independent of query count.
    pub fn substrate_total(&self) -> u64 {
        self.window_items + self.sampler_items + self.plan_items + self.compute_items
    }
}

/// Cumulative [`SlideWork`] across windows, plus the most recent slide —
/// the coordinator records one observation per window and benches read
/// it to show per-slide cost tracking |delta| instead of |window|.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkProfile {
    total: SlideWork,
    last: SlideWork,
    windows: u64,
}

impl WorkProfile {
    /// Empty profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one window's work accounting.
    pub fn observe(&mut self, w: SlideWork) {
        self.total.window_items += w.window_items;
        self.total.sampler_items += w.sampler_items;
        self.total.plan_items += w.plan_items;
        self.total.compute_items += w.compute_items;
        self.total.derive_items += w.derive_items;
        self.total.budget_adjust += w.budget_adjust;
        self.total.sketch_items += w.sketch_items;
        self.total.checkpoint_bytes += w.checkpoint_bytes;
        self.total.restore_items += w.restore_items;
        self.total.fault_injections += w.fault_injections;
        self.total.retries += w.retries;
        self.total.merge_items += w.merge_items;
        self.last = w;
        self.windows += 1;
    }

    /// Attribute checkpoint bytes written after the slide's observation
    /// (the coordinator takes periodic checkpoints once the slide's
    /// report is out, so the cost lands on the slide that paid it).
    pub fn note_checkpoint_bytes(&mut self, bytes: u64) {
        self.total.checkpoint_bytes += bytes;
        self.last.checkpoint_bytes += bytes;
    }

    /// Record the one-time item-replay cost of a restore on the restored
    /// coordinator's profile.
    pub fn note_restore_items(&mut self, items: u64) {
        self.total.restore_items += items;
        self.last.restore_items += items;
    }

    /// The most recent window's work (steady-state per-slide cost).
    pub fn last(&self) -> SlideWork {
        self.last
    }

    /// Summed work across all observed windows.
    pub fn total(&self) -> SlideWork {
        self.total
    }

    /// Windows observed.
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Mean items touched per slide across all observed windows.
    pub fn mean_total_per_slide(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.total.total() as f64 / self.windows as f64
        }
    }

    /// One-line summary, e.g. for bench output.
    pub fn summary(&self) -> String {
        format!(
            "items/slide over {} windows: mean {:.0} (last: window {} + sampler {} + plan {} + compute {} + derive {})",
            self.windows,
            self.mean_total_per_slide(),
            self.last.window_items,
            self.last.sampler_items,
            self.last.plan_items,
            self.last.compute_items,
            self.last.derive_items
        )
    }
}

/// Wall-clock stopwatch in milliseconds.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

impl Stopwatch {
    /// Start timing.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn counter_thread_safe() {
        let c = std::sync::Arc::new(Counter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-12);
        assert!((h.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((h.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!((h.quantile(0.5) - 50.0).abs() <= 1.0);
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn registry_reuses_instances() {
        let r = Registry::new();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").get(), 2);
        r.histogram("lat").observe(1.0);
        let report = r.report();
        assert!(report.contains("counter x = 2"));
        assert!(report.contains("histogram lat"));
    }

    #[test]
    fn phase_profile_accumulates() {
        let p = PhaseProfile::new();
        assert_eq!(p.windows(), 0);
        p.observe(1.0, 4.0, 0.5, 0.2, 0.1);
        p.observe(3.0, 2.0, 1.5, 0.4, 0.3);
        assert_eq!(p.windows(), 2);
        assert!((p.plan_mean_ms() - 2.0).abs() < 1e-12);
        assert!((p.compute_mean_ms() - 3.0).abs() < 1e-12);
        assert!((p.finalize_mean_ms() - 1.0).abs() < 1e-12);
        assert!((p.sampler_mean_ms() - 0.3).abs() < 1e-12);
        assert!((p.sketch_mean_ms() - 0.2).abs() < 1e-12);
        assert!(p.summary().contains("2 windows"));
        assert!(p.summary().contains("sampler"));
    }

    #[test]
    fn slide_work_totals_and_profile() {
        let w1 = SlideWork {
            window_items: 10,
            sampler_items: 20,
            plan_items: 5,
            compute_items: 1,
            derive_items: 6,
            budget_adjust: 4,
            sketch_items: 2,
            ..SlideWork::default()
        };
        let w2 = SlideWork {
            window_items: 2,
            sampler_items: 4,
            plan_items: 3,
            compute_items: 7,
            derive_items: 0,
            budget_adjust: 0,
            sketch_items: 0,
            checkpoint_bytes: 100,
            restore_items: 9,
            fault_injections: 1,
            retries: 2,
            merge_items: 0,
        };
        assert_eq!(w1.substrate_total(), 36);
        // Per-query derivation, budget feedback, and sketch folds count
        // toward the headline total but never the substrate.
        assert_eq!(w1.total(), 48);
        // Durability counters stay out of the items-touched totals.
        assert_eq!(w2.total(), 16);
        assert_eq!(w2.substrate_total(), 16);
        let mut p = WorkProfile::new();
        assert_eq!(p.windows(), 0);
        assert_eq!(p.mean_total_per_slide(), 0.0);
        p.observe(w1);
        p.observe(w2);
        assert_eq!(p.windows(), 2);
        assert_eq!(p.last(), w2);
        assert_eq!(p.total().window_items, 12);
        assert_eq!(p.total().derive_items, 6);
        assert_eq!(p.total().budget_adjust, 4);
        assert_eq!(p.total().sketch_items, 2);
        assert_eq!(p.total().checkpoint_bytes, 100);
        assert_eq!(p.total().restore_items, 9);
        assert_eq!(p.total().fault_injections, 1);
        assert_eq!(p.total().retries, 2, "retries accumulate like the other event counts");
        assert_eq!(p.total().total(), 64, "event counts stay out of the totals");
        assert!((p.mean_total_per_slide() - 32.0).abs() < 1e-12);
        assert!(p.summary().contains("2 windows"));
    }

    #[test]
    fn merge_items_count_toward_total_but_not_substrate() {
        let w = SlideWork {
            window_items: 4,
            sampler_items: 2,
            merge_items: 12,
            ..SlideWork::default()
        };
        assert_eq!(w.substrate_total(), 6, "merge work never lands on the substrate");
        assert_eq!(w.total(), 18);
        let mut p = WorkProfile::new();
        p.observe(w);
        p.observe(SlideWork { merge_items: 3, ..SlideWork::default() });
        assert_eq!(p.total().merge_items, 15);
        assert_eq!(p.last().merge_items, 3);
    }

    #[test]
    fn checkpoint_and_restore_notes_accumulate_without_new_windows() {
        let mut p = WorkProfile::new();
        p.observe(SlideWork { window_items: 3, ..SlideWork::default() });
        p.note_checkpoint_bytes(512);
        p.note_checkpoint_bytes(64);
        p.note_restore_items(40);
        assert_eq!(p.windows(), 1, "notes must not count as windows");
        assert_eq!(p.last().checkpoint_bytes, 576);
        assert_eq!(p.total().checkpoint_bytes, 576);
        assert_eq!(p.total().restore_items, 40);
        // Items-touched totals are untouched by durability notes.
        assert_eq!(p.total().total(), 3);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
    }
}
