//! One-line import of the session-era public API.
//!
//! ```
//! use incapprox::prelude::*;
//!
//! let cfg = SystemConfig { window_size: 1000, slide: 100, seed: 7, ..SystemConfig::default() };
//! let source = MultiStream::paper_section5(cfg.seed);
//! let mut session = Session::new(Coordinator::new(cfg), source)?;
//! let q = session.submit(QuerySpec::new(AggregateKind::Mean))?;
//! let out = session.warmup()?;
//! assert!(out.query(q).is_some());
//! # Ok::<(), incapprox::Error>(())
//! ```

pub use crate::config::system::{BudgetSpec, ExecModeSpec, ShardStrategy, SystemConfig};
pub use crate::coordinator::{
    Coordinator, Pipeline, QueryId, QueryReport, QuerySpec, Session, SlideOutput,
    StratumReport, WindowReport,
};
pub use crate::error::{Error, Result};
pub use crate::job::aggregate::{AggregateKind, ErrorSurface};
pub use crate::partition::{MergeTier, PartitionCoordinator, PartitionState};
pub use crate::stats::stratified::Estimate;
pub use crate::workload::gen::MultiStream;
pub use crate::workload::record::{Record, StratumId};
