//! Crash-recoverable substrate — checkpoint/restore of the full
//! incremental state.
//!
//! The thesis assumes memoized state is stored fault-tolerantly (§2.3.3
//! assumption 3, §6.3): without that, a crash throws away the entire
//! memoized substrate and the next run pays full from-scratch cost —
//! exactly what incremental computation exists to avoid. This module
//! makes the substrate durable: a checkpoint captures the sharded
//! [`MemoStore`](crate::sac::memo::MemoStore) (chunk results keyed by
//! content hash, per-chunk sketch bundles under the same hashes,
//! per-stratum sample runs, combined moments), the window
//! buffer (count- or time-based), the
//! [`Session`](crate::coordinator::Session) query registry, and the
//! fault-injector RNG — everything a restored coordinator needs to
//! continue **byte-identically** from the next slide onward. The
//! persistent sampler is deliberately *not* serialized: its sample is a
//! pure function of (window contents, seed), so restore rebuilds it from
//! the restored window and counts that work in
//! [`SlideWork::restore_items`](crate::metrics::SlideWork).
//!
//! ## Artifact format
//!
//! A hand-rolled, versioned, checksummed binary stream (the workspace is
//! offline — no `serde`; see [`wire`] for the primitives):
//!
//! ```text
//! magic "IACK" | version | compat (seed, mode, chunk_size, map_rounds)
//! segment count | segments… | session section? | checksum
//! ```
//!
//! Segments form an incremental chain:
//!
//! * a **Base** segment is a full snapshot — O(state);
//! * a **Delta** segment holds only the *journal* of substrate
//!   mutations since the previous segment (slide batches, eviction
//!   horizons, freshly memoized chunks, resizes) plus a Copy/Insert
//!   diff of the memoized sample runs — O(state delta).
//!
//! The coordinator maintains the chain in memory at the
//! `pipeline.checkpoint_every_slides` cadence, so steady-state
//! checkpoint cost tracks the slide delta, never the window
//! (`SlideWork::checkpoint_bytes` measures it;
//! `benches/checkpoint_overhead.rs --smoke` asserts it). The chain
//! re-bases when deltas outgrow the base or after an injected fault.
//! Restore decodes the base, replays each delta through the real window
//! and memo implementations, rebuilds the sampler, and verifies the
//! trailing checksum — corruption or truncation yields
//! [`Error::Checkpoint`], never a panic or a silently wrong state.

pub(crate) mod wire;

use std::collections::BTreeMap;
use std::io::{Read, Write};

use crate::config::system::{BudgetSpec, ExecModeSpec, SystemConfig};
use crate::coordinator::query::QuerySpec;
use crate::error::{Error, Result};
use crate::fault::{FaultPlanState, RecoveryPolicy};
use crate::job::aggregate::AggregateKind;
use crate::job::moments::Moments;
use crate::job::sketch::SketchBundle;
use crate::sampling::SampleRun;
use crate::util::hash::FastMap;
use crate::workload::gen::{MultiStreamSpec, SubstreamSpec, ValueDist};
use crate::workload::record::{Record, StratumId};

use wire::{CkptReader, CkptWriter};

/// Artifact magic ("IACK" little-endian).
const MAGIC: u32 = 0x4B43_4149;
/// Format version. Bump on any wire change; readers reject other
/// versions instead of misparsing them. History: v1 = PR 4's initial
/// format; v2 adds adaptive-budget controller state (the
/// `budget_states` base-segment field, the `BudgetAdjust` journal op,
/// and budget wire tag 3 for `BudgetSpec::TargetError`); v3 adds
/// per-chunk sketch state (the `sketches` base-segment field, the
/// `PutChunkSketch` journal op) and replaces the aggregate-kind wire
/// byte — previously an index into `AggregateKind::ALL`, which cannot
/// represent parameterized kinds like `Quantile(750)` — with an
/// explicit tag plus a `u32` parameter for `Quantile`/`TopK`; v4
/// replaces the single memo-channel injector RNG in `Misc` with the
/// full multi-channel fault-plan state (four RNGs, four counters, the
/// latched broker / checkpoint-write verdicts) and adds the
/// degradation-controller ladder position, so restored runs replay the
/// exact fault schedule on every channel *and* continue the same
/// bound-widening trajectory; v5 adds the partition layer's state: the
/// `PartitionSlide` journal op (a router-driven count-window slide with
/// an explicit eviction count) and the optional `owned_strata` list in
/// `Misc`, so a partition's artifact records which stratum range it
/// owned (`None` = the whole stream, i.e. a single-coordinator run).
const VERSION: u32 = 5;

/// The `budget_states` slot of the coordinator's *session-level* cost
/// function (`SystemConfig::budget`). Per-query controllers use their
/// raw `QueryId`, which is a sequence number and can never collide with
/// this sentinel.
pub(crate) const SESSION_BUDGET_SLOT: u64 = u64::MAX;

/// Configuration facts baked into an artifact. Restore demands they
/// match the target config: a different seed, mode, chunk size, map
/// weight, or slide would change sampling ranks, chunk boundaries,
/// memoized values, or the batch pacing itself, silently breaking
/// byte-identical continuation — better a loud error. (Worker count,
/// shard strategy, and budgets may differ freely: sharding is
/// output-neutral and the memo re-places entries by stratum;
/// `window_size` is carried by the window state itself.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Compat {
    pub seed: u64,
    pub mode: ExecModeSpec,
    pub chunk_size: u64,
    pub map_rounds: u32,
    pub slide: u64,
}

fn mode_tag(mode: ExecModeSpec) -> u8 {
    match mode {
        ExecModeSpec::Native => 0,
        ExecModeSpec::IncrementalOnly => 1,
        ExecModeSpec::ApproxOnly => 2,
        ExecModeSpec::IncApprox => 3,
    }
}

fn mode_from_tag(tag: u8) -> Result<ExecModeSpec> {
    Ok(match tag {
        0 => ExecModeSpec::Native,
        1 => ExecModeSpec::IncrementalOnly,
        2 => ExecModeSpec::ApproxOnly,
        3 => ExecModeSpec::IncApprox,
        other => return Err(Error::Checkpoint(format!("unknown mode tag {other}"))),
    })
}

impl Compat {
    /// Extract the compat facts from a config.
    pub fn of(cfg: &SystemConfig) -> Compat {
        Compat {
            seed: cfg.seed,
            mode: cfg.mode,
            chunk_size: cfg.chunk_size as u64,
            map_rounds: cfg.map_rounds,
            slide: cfg.slide as u64,
        }
    }

    /// Reject a restore target whose config would diverge from the
    /// checkpointed run.
    pub fn check(&self, cfg: &SystemConfig) -> Result<()> {
        let target = Compat::of(cfg);
        if *self != target {
            return Err(Error::Checkpoint(format!(
                "config mismatch: checkpoint was taken under seed={} mode={} \
                 chunk_size={} map_rounds={} slide={}, restore target has seed={} \
                 mode={} chunk_size={} map_rounds={} slide={}",
                self.seed,
                self.mode.name(),
                self.chunk_size,
                self.map_rounds,
                self.slide,
                target.seed,
                target.mode.name(),
                target.chunk_size,
                target.map_rounds,
                target.slide,
            )));
        }
        Ok(())
    }
}

/// Durable window state (both kinds; the min-timestamp deque and the
/// delta anchors are rebuilt by the window's own `restore_parts`).
#[derive(Debug, Clone)]
pub(crate) enum WindowCkpt {
    /// A [`CountWindow`](crate::window::CountWindow).
    Count { size: u64, next_window_id: u64, buf: Vec<Record>, pending: Vec<Record> },
    /// A [`TimeWindow`](crate::window::TimeWindow).
    Time {
        length: u64,
        slide: u64,
        next_end: u64,
        in_window: u64,
        next_window_id: u64,
        buf: Vec<Record>,
    },
}

/// One memoized chunk result, with the stratum that owns it (so restore
/// can re-place it under any shard count).
#[derive(Debug, Clone, Copy)]
pub(crate) struct ChunkEntry {
    pub stratum: StratumId,
    pub hash: u64,
    pub moments: Moments,
    pub min_ts: u64,
    pub window_id: u64,
}

/// One memoized per-chunk sketch bundle (the synopsis side map behind
/// the `Quantile`/`TopK`/`DistinctCount` kinds), keyed by the same
/// content hash as the chunk's [`ChunkEntry`]. The folded per-stratum
/// sketches are never serialized — they are a pure function of
/// (window, seed) and the restored run refolds them from these.
#[derive(Debug, Clone)]
pub(crate) struct SketchChunkEntry {
    pub stratum: StratumId,
    pub hash: u64,
    pub bundle: SketchBundle,
    pub min_ts: u64,
    pub window_id: u64,
}

/// One registered query with its stable id.
#[derive(Debug, Clone)]
pub(crate) struct QueryEntry {
    pub raw_id: u64,
    pub spec: QuerySpec,
}

/// Small always-current state written into every segment: counters that
/// drive recompute epochs, the query registry, the recovery policy, the
/// multi-channel fault-plan state (so a restored run replays the same
/// fault schedule *and* handles it the same way — including any broker
/// or checkpoint-write verdict drawn but not yet consumed), and the
/// degradation controller's ladder position.
#[derive(Debug, Clone)]
pub(crate) struct Misc {
    pub windows_processed: u64,
    pub next_query_id: u64,
    pub queries: Vec<QueryEntry>,
    pub recovery: RecoveryPolicy,
    pub fault: FaultPlanState,
    pub degrade_level: u32,
    pub degrade_calm: u32,
    /// The stratum range this coordinator owns when it runs as one
    /// partition of a merge tier; `None` on single-coordinator runs
    /// (the whole stream). Restore hands the list back to the
    /// partition layer so a rebalanced assignment survives a restart.
    pub owned_strata: Option<Vec<StratumId>>,
}

fn policy_tag(p: RecoveryPolicy) -> u8 {
    match p {
        RecoveryPolicy::ContinueWithout => 0,
        RecoveryPolicy::LineageRecompute => 1,
        RecoveryPolicy::Replicated => 2,
        RecoveryPolicy::Checkpoint => 3,
    }
}

fn policy_from_tag(tag: u8) -> Result<RecoveryPolicy> {
    Ok(match tag {
        0 => RecoveryPolicy::ContinueWithout,
        1 => RecoveryPolicy::LineageRecompute,
        2 => RecoveryPolicy::Replicated,
        3 => RecoveryPolicy::Checkpoint,
        other => return Err(Error::Checkpoint(format!("unknown recovery tag {other}"))),
    })
}

/// A full snapshot of the substrate.
#[derive(Debug, Clone)]
pub(crate) struct BaseState {
    pub window: WindowCkpt,
    pub chunks: Vec<ChunkEntry>,
    pub items: BTreeMap<StratumId, Vec<Record>>,
    pub moments: BTreeMap<StratumId, Moments>,
    pub misc: Misc,
    /// Adaptive-budget controller state at the snapshot:
    /// `(slot, policy, state)` per cost function that carries durable
    /// state (`CostFunction::export_state`), where `slot` is the raw
    /// query id or [`SESSION_BUDGET_SLOT`] and `policy` is the cost
    /// function's name. Later `BudgetAdjust` journal ops update these
    /// slots; restore applies the final value — but only onto a cost
    /// function of the *same policy* (budgets may differ freely between
    /// checkpoint and restore configs, and e.g. a banked-token count
    /// must never be imported as a latency EWMA) — so the controller
    /// trajectory continues exactly where the live run was.
    pub budget_states: Vec<(u64, String, f64)>,
    /// Memoized per-chunk sketch bundles, sorted by hash (stable
    /// artifact bytes). Empty on runs without sketch queries — such
    /// artifacts pay zero bytes for the field beyond its count.
    pub sketches: Vec<SketchChunkEntry>,
}

/// One journaled substrate mutation. Deltas replay these through the
/// *real* window and memo implementations at restore, so the rebuilt
/// internal state (min-ts deque, pending resize evictions, shard
/// contents) is exactly what the live run held.
#[derive(Debug, Clone)]
pub(crate) enum JournalOp {
    /// One count-window slide's input batch.
    Slide { inserted: Vec<Record> },
    /// One time-window ingest + emit attempt.
    Tick { records: Vec<Record>, now: u64 },
    /// A mid-stream window resize.
    Resize { new_size: u64 },
    /// Algorithm 1's memo eviction horizon for one window.
    Evict { horizon: u64 },
    /// A freshly memoized chunk result.
    PutChunk {
        stratum: StratumId,
        hash: u64,
        moments: Moments,
        min_ts: u64,
        window_id: u64,
    },
    /// An adaptive budget's post-slide controller state (absolute, not a
    /// delta — replay is last-wins). `slot` is the raw query id or
    /// [`SESSION_BUDGET_SLOT`]; `policy` is the cost function's name,
    /// checked at import so a state never lands on a different policy.
    BudgetAdjust { slot: u64, policy: String, state: f64 },
    /// A freshly memoized per-chunk sketch bundle (the sketch analog of
    /// `PutChunk`, keyed by the same content hash).
    PutChunkSketch {
        stratum: StratumId,
        hash: u64,
        bundle: SketchBundle,
        min_ts: u64,
        window_id: u64,
    },
    /// One router-driven partition slide: the records routed to this
    /// partition plus the exact FIFO eviction count the merge tier's
    /// global window simulation prescribed (partitioned count windows
    /// are capacity-free; see `CountWindow::slide_external`).
    PartitionSlide { inserted: Vec<Record>, evict: u64 },
}

impl JournalOp {
    /// Record-count cost of the op (journal-size cap accounting).
    pub fn record_cost(&self) -> usize {
        match self {
            JournalOp::Slide { inserted } => inserted.len(),
            JournalOp::Tick { records, .. } => records.len(),
            JournalOp::PartitionSlide { inserted, .. } => inserted.len(),
            _ => 1,
        }
    }
}

/// One edit op of a sample-run diff: either a contiguous copy out of the
/// previous run or literally inserted records. Adjacent windows share
/// most of their runs (the bias keeps a memoized prefix, the sampler
/// keeps rank order), so steady-state diffs are a few ops + the delta's
/// records.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum RunOp {
    /// Copy `prev[start .. start + len]`.
    Copy { start: u64, len: u64 },
    /// Append these records.
    Insert(Vec<Record>),
}

/// Changes since the previous segment.
#[derive(Debug, Clone)]
pub(crate) struct DeltaState {
    pub ops: Vec<JournalOp>,
    /// Per current stratum: `(final_len, edit ops vs the previous
    /// segment's run)`. Strata absent here are dropped.
    pub items: Vec<(StratumId, u64, Vec<RunOp>)>,
    pub moments: BTreeMap<StratumId, Moments>,
    pub misc: Misc,
}

/// One link of the checkpoint chain.
#[derive(Debug, Clone)]
pub(crate) enum Segment {
    Base(BaseState),
    Delta(DeltaState),
}

/// Extra state a [`Session`](crate::coordinator::Session) checkpoint
/// carries beyond the coordinator: the generator spec (so the restored
/// stream emits the exact same records), the periodic-checkpoint cadence
/// position (so post-restore fallback images refresh on the same
/// schedule), and the broker backlog (produced but not yet consumed
/// records, replayed into the fresh broker).
#[derive(Debug, Clone)]
pub(crate) struct SessionSection {
    pub source: MultiStreamSpec,
    pub slides_since_ckpt: u64,
    pub backlog: Vec<Record>,
}

/// A decoded artifact: compat facts, the segment chain (still encoded —
/// decoded lazily segment by segment during restore), and the optional
/// session section.
#[derive(Debug, Clone)]
pub(crate) struct Artifact {
    pub compat: Compat,
    pub segments: Vec<Vec<u8>>,
    pub session: Option<SessionSection>,
}

// ---------------------------------------------------------------------
// Run diffing
// ---------------------------------------------------------------------

#[inline]
fn records_bit_equal(a: &Record, b: &Record) -> bool {
    a.id == b.id
        && a.stratum == b.stratum
        && a.timestamp == b.timestamp
        && a.key == b.key
        && a.value.to_bits() == b.value.to_bits()
}

/// Diff `cur` against `prev` into Copy/Insert ops. Retained items keep
/// their relative order across adjacent runs (bias preserves the
/// memoized prefix; the sampler preserves rank order), so the monotone
/// single-pass walk below finds long copy ranges; any out-of-order
/// retained item simply degrades to a literal insert — correctness never
/// depends on the order assumption.
pub(crate) fn diff_run(prev: &SampleRun, cur: &SampleRun) -> Vec<RunOp> {
    let prev_recs = prev.records();
    if prev_recs.is_empty() {
        if cur.is_empty() {
            return Vec::new();
        }
        return vec![RunOp::Insert(cur.records().to_vec())];
    }
    let pos: FastMap<u64, usize> =
        prev_recs.iter().enumerate().map(|(i, r)| (r.id, i)).collect();
    let mut ops: Vec<RunOp> = Vec::new();
    let mut copy: Option<(usize, usize)> = None; // (start, len)
    let mut pending: Vec<Record> = Vec::new();
    let mut floor = 0usize; // next prev index eligible for a copy
    for r in cur.records() {
        let hit = pos
            .get(&r.id)
            .copied()
            .filter(|&p| p >= floor && records_bit_equal(&prev_recs[p], r));
        match hit {
            Some(p) => {
                if !pending.is_empty() {
                    ops.push(RunOp::Insert(std::mem::take(&mut pending)));
                }
                copy = match copy {
                    Some((s, l)) if s + l == p => Some((s, l + 1)),
                    Some((s, l)) => {
                        ops.push(RunOp::Copy { start: s as u64, len: l as u64 });
                        Some((p, 1))
                    }
                    None => Some((p, 1)),
                };
                floor = p + 1;
            }
            None => {
                if let Some((s, l)) = copy.take() {
                    ops.push(RunOp::Copy { start: s as u64, len: l as u64 });
                }
                pending.push(*r);
            }
        }
    }
    if let Some((s, l)) = copy {
        ops.push(RunOp::Copy { start: s as u64, len: l as u64 });
    }
    if !pending.is_empty() {
        ops.push(RunOp::Insert(pending));
    }
    ops
}

/// Rebuild a run from `prev` and its diff ops. Bounds and the expected
/// final length are verified — a corrupted delta errors out instead of
/// producing a silently wrong sample.
pub(crate) fn apply_run_ops(
    prev: &SampleRun,
    ops: &[RunOp],
    expect_len: usize,
) -> Result<Vec<Record>> {
    let prev_recs = prev.records();
    let mut out: Vec<Record> = Vec::with_capacity(expect_len);
    for op in ops {
        match op {
            RunOp::Copy { start, len } => {
                let s = *start as usize;
                let e = s
                    .checked_add(*len as usize)
                    .filter(|&e| e <= prev_recs.len())
                    .ok_or_else(|| {
                        Error::Checkpoint(format!(
                            "run diff copy out of bounds ({start}+{len} > {})",
                            prev_recs.len()
                        ))
                    })?;
                out.extend_from_slice(&prev_recs[s..e]);
            }
            RunOp::Insert(rs) => out.extend_from_slice(rs),
        }
    }
    if out.len() != expect_len {
        return Err(Error::Checkpoint(format!(
            "run diff rebuilt {} records, expected {expect_len}",
            out.len()
        )));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Segment encoding
// ---------------------------------------------------------------------

fn put_moments<W: Write>(w: &mut CkptWriter<W>, m: &Moments) -> Result<()> {
    w.f64(m.count)?;
    w.f64(m.sum)?;
    w.f64(m.sumsq)?;
    w.f64(m.min)?;
    w.f64(m.max)
}

fn get_moments<R: Read>(r: &mut CkptReader<R>) -> Result<Moments> {
    Ok(Moments {
        count: r.f64()?,
        sum: r.f64()?,
        sumsq: r.f64()?,
        min: r.f64()?,
        max: r.f64()?,
    })
}

fn put_budget<W: Write>(w: &mut CkptWriter<W>, b: &BudgetSpec) -> Result<()> {
    match b {
        BudgetSpec::Fraction(f) => {
            w.u8(0)?;
            w.f64(*f)?;
            w.f64(0.0)
        }
        BudgetSpec::Tokens { per_window, cost_per_item } => {
            w.u8(1)?;
            w.f64(*per_window)?;
            w.f64(*cost_per_item)
        }
        BudgetSpec::LatencyMs(ms) => {
            w.u8(2)?;
            w.f64(*ms)?;
            w.f64(0.0)
        }
        BudgetSpec::TargetError { relative_bound, confidence } => {
            w.u8(3)?;
            w.f64(*relative_bound)?;
            w.f64(*confidence)
        }
    }
}

fn get_budget<R: Read>(r: &mut CkptReader<R>) -> Result<BudgetSpec> {
    let tag = r.u8()?;
    let a = r.f64()?;
    let b = r.f64()?;
    Ok(match tag {
        0 => BudgetSpec::Fraction(a),
        1 => BudgetSpec::Tokens { per_window: a, cost_per_item: b },
        2 => BudgetSpec::LatencyMs(a),
        3 => BudgetSpec::TargetError { relative_bound: a, confidence: b },
        other => return Err(Error::Checkpoint(format!("unknown budget tag {other}"))),
    })
}

/// Aggregate-kind wire encoding: an explicit tag byte, plus a `u32`
/// parameter for the parameterized kinds. (v2 wrote an index into
/// `AggregateKind::ALL`, which cannot name a kind like `Quantile(750)`
/// that is not literally in `ALL` — the `position(..).expect(..)` there
/// was a latent panic the moment parameterized kinds arrived.)
fn put_kind<W: Write>(w: &mut CkptWriter<W>, k: AggregateKind) -> Result<()> {
    match k {
        AggregateKind::Sum => w.u8(0),
        AggregateKind::Mean => w.u8(1),
        AggregateKind::Count => w.u8(2),
        AggregateKind::Variance => w.u8(3),
        AggregateKind::StdDev => w.u8(4),
        AggregateKind::Extrema => w.u8(5),
        AggregateKind::Quantile(permille) => {
            w.u8(6)?;
            w.u32(permille as u32)
        }
        AggregateKind::TopK(k) => {
            w.u8(7)?;
            w.u32(k as u32)
        }
        AggregateKind::DistinctCount => w.u8(8),
    }
}

fn get_kind<R: Read>(r: &mut CkptReader<R>) -> Result<AggregateKind> {
    Ok(match r.u8()? {
        0 => AggregateKind::Sum,
        1 => AggregateKind::Mean,
        2 => AggregateKind::Count,
        3 => AggregateKind::Variance,
        4 => AggregateKind::StdDev,
        5 => AggregateKind::Extrema,
        6 => {
            let p = r.u32()?;
            AggregateKind::Quantile(u16::try_from(p).map_err(|_| {
                Error::Checkpoint(format!("quantile parameter {p} out of range"))
            })?)
        }
        7 => {
            let k = r.u32()?;
            AggregateKind::TopK(u16::try_from(k).map_err(|_| {
                Error::Checkpoint(format!("top-k parameter {k} out of range"))
            })?)
        }
        8 => AggregateKind::DistinctCount,
        other => {
            return Err(Error::Checkpoint(format!("unknown aggregate kind tag {other}")))
        }
    })
}

fn put_misc<W: Write>(w: &mut CkptWriter<W>, m: &Misc) -> Result<()> {
    w.u64(m.windows_processed)?;
    w.u64(m.next_query_id)?;
    w.u64(m.queries.len() as u64)?;
    for q in &m.queries {
        w.u64(q.raw_id)?;
        put_kind(w, q.spec.kind)?;
        match q.spec.stratum {
            Some(s) => {
                w.u8(1)?;
                w.u32(s)?;
            }
            None => {
                w.u8(0)?;
                w.u32(0)?;
            }
        }
        w.f64(q.spec.confidence)?;
        put_budget(w, &q.spec.budget)?;
        match q.spec.map_rounds {
            Some(rounds) => {
                w.u8(1)?;
                w.u32(rounds)?;
            }
            None => {
                w.u8(0)?;
                w.u32(0)?;
            }
        }
    }
    w.u8(policy_tag(m.recovery))?;
    // Fault-plan state in fixed channel order (memo, compute, broker,
    // checkpoint-write): RNG words, injected counters, latched verdicts.
    for rng in m.fault.rngs {
        for word in rng {
            w.u64(word)?;
        }
    }
    for count in m.fault.injected {
        w.u64(count)?;
    }
    w.u8(u8::from(m.fault.pending_broker))?;
    w.u8(u8::from(m.fault.pending_checkpoint_write))?;
    w.u32(m.degrade_level)?;
    w.u32(m.degrade_calm)?;
    match &m.owned_strata {
        Some(strata) => {
            w.u8(1)?;
            w.u32(strata.len() as u32)?;
            for &s in strata {
                w.u32(s)?;
            }
            Ok(())
        }
        None => {
            w.u8(0)?;
            w.u32(0)
        }
    }
}

fn get_misc<R: Read>(r: &mut CkptReader<R>) -> Result<Misc> {
    let windows_processed = r.u64()?;
    let next_query_id = r.u64()?;
    let n = r.len()?;
    let mut queries = Vec::with_capacity(n.min(1 << 12));
    for _ in 0..n {
        let raw_id = r.u64()?;
        let kind = get_kind(r)?;
        let has_stratum = r.u8()? != 0;
        let stratum_raw = r.u32()?;
        let confidence = r.f64()?;
        let budget = get_budget(r)?;
        let has_rounds = r.u8()? != 0;
        let rounds_raw = r.u32()?;
        queries.push(QueryEntry {
            raw_id,
            spec: QuerySpec {
                kind,
                stratum: has_stratum.then_some(stratum_raw),
                confidence,
                budget,
                map_rounds: has_rounds.then_some(rounds_raw),
            },
        });
    }
    let recovery = policy_from_tag(r.u8()?)?;
    let mut fault = FaultPlanState::default();
    for rng in &mut fault.rngs {
        for word in rng.iter_mut() {
            *word = r.u64()?;
        }
    }
    for count in &mut fault.injected {
        *count = r.u64()?;
    }
    fault.pending_broker = r.u8()? != 0;
    fault.pending_checkpoint_write = r.u8()? != 0;
    let degrade_level = r.u32()?;
    let degrade_calm = r.u32()?;
    let has_owned = r.u8()? != 0;
    let n_owned = r.u32()? as usize;
    let owned_strata = if has_owned {
        if n_owned > 1 << 20 {
            return Err(Error::Checkpoint(format!(
                "implausible owned-strata count {n_owned} (corrupted?)"
            )));
        }
        let mut strata = Vec::with_capacity(n_owned.min(1 << 12));
        for _ in 0..n_owned {
            strata.push(r.u32()?);
        }
        Some(strata)
    } else {
        None
    };
    Ok(Misc {
        windows_processed,
        next_query_id,
        queries,
        recovery,
        fault,
        degrade_level,
        degrade_calm,
        owned_strata,
    })
}

fn put_window<W: Write>(w: &mut CkptWriter<W>, win: &WindowCkpt) -> Result<()> {
    match win {
        WindowCkpt::Count { size, next_window_id, buf, pending } => {
            w.u8(0)?;
            w.u64(*size)?;
            w.u64(*next_window_id)?;
            w.records(buf)?;
            w.records(pending)
        }
        WindowCkpt::Time { length, slide, next_end, in_window, next_window_id, buf } => {
            w.u8(1)?;
            w.u64(*length)?;
            w.u64(*slide)?;
            w.u64(*next_end)?;
            w.u64(*in_window)?;
            w.u64(*next_window_id)?;
            w.records(buf)
        }
    }
}

fn get_window<R: Read>(r: &mut CkptReader<R>) -> Result<WindowCkpt> {
    match r.u8()? {
        0 => Ok(WindowCkpt::Count {
            size: r.u64()?,
            next_window_id: r.u64()?,
            buf: r.records()?,
            pending: r.records()?,
        }),
        1 => Ok(WindowCkpt::Time {
            length: r.u64()?,
            slide: r.u64()?,
            next_end: r.u64()?,
            in_window: r.u64()?,
            next_window_id: r.u64()?,
            buf: r.records()?,
        }),
        other => Err(Error::Checkpoint(format!("unknown window tag {other}"))),
    }
}

fn put_chunk_entry<W: Write>(w: &mut CkptWriter<W>, c: &ChunkEntry) -> Result<()> {
    w.u32(c.stratum)?;
    w.u64(c.hash)?;
    put_moments(w, &c.moments)?;
    w.u64(c.min_ts)?;
    w.u64(c.window_id)
}

fn get_chunk_entry<R: Read>(r: &mut CkptReader<R>) -> Result<ChunkEntry> {
    Ok(ChunkEntry {
        stratum: r.u32()?,
        hash: r.u64()?,
        moments: get_moments(r)?,
        min_ts: r.u64()?,
        window_id: r.u64()?,
    })
}

fn put_sketch_entry<W: Write>(w: &mut CkptWriter<W>, s: &SketchChunkEntry) -> Result<()> {
    w.u32(s.stratum)?;
    w.u64(s.hash)?;
    w.bytes(&s.bundle.to_bytes())?;
    w.u64(s.min_ts)?;
    w.u64(s.window_id)
}

fn get_sketch_entry<R: Read>(r: &mut CkptReader<R>) -> Result<SketchChunkEntry> {
    let stratum = r.u32()?;
    let hash = r.u64()?;
    // `from_bytes` revalidates the bundle (caps, key order, level/rho
    // ranges), so a bit flip inside a sketch segment that survives the
    // outer checksum check still cannot smuggle in malformed state.
    let bundle = SketchBundle::from_bytes(&r.bytes()?)?;
    Ok(SketchChunkEntry { stratum, hash, bundle, min_ts: r.u64()?, window_id: r.u64()? })
}

fn put_stratum_moments<W: Write>(
    w: &mut CkptWriter<W>,
    m: &BTreeMap<StratumId, Moments>,
) -> Result<()> {
    w.u64(m.len() as u64)?;
    for (&s, mo) in m {
        w.u32(s)?;
        put_moments(w, mo)?;
    }
    Ok(())
}

fn get_stratum_moments<R: Read>(
    r: &mut CkptReader<R>,
) -> Result<BTreeMap<StratumId, Moments>> {
    let n = r.len()?;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let s = r.u32()?;
        out.insert(s, get_moments(r)?);
    }
    Ok(out)
}

fn put_journal_op<W: Write>(w: &mut CkptWriter<W>, op: &JournalOp) -> Result<()> {
    match op {
        JournalOp::Slide { inserted } => {
            w.u8(0)?;
            w.records(inserted)
        }
        JournalOp::Tick { records, now } => {
            w.u8(1)?;
            w.u64(*now)?;
            w.records(records)
        }
        JournalOp::Resize { new_size } => {
            w.u8(2)?;
            w.u64(*new_size)
        }
        JournalOp::Evict { horizon } => {
            w.u8(3)?;
            w.u64(*horizon)
        }
        JournalOp::PutChunk { stratum, hash, moments, min_ts, window_id } => {
            w.u8(4)?;
            put_chunk_entry(
                w,
                &ChunkEntry {
                    stratum: *stratum,
                    hash: *hash,
                    moments: *moments,
                    min_ts: *min_ts,
                    window_id: *window_id,
                },
            )
        }
        JournalOp::BudgetAdjust { slot, policy, state } => {
            w.u8(5)?;
            w.u64(*slot)?;
            w.bytes(policy.as_bytes())?;
            w.f64(*state)
        }
        JournalOp::PutChunkSketch { stratum, hash, bundle, min_ts, window_id } => {
            w.u8(6)?;
            put_sketch_entry(
                w,
                &SketchChunkEntry {
                    stratum: *stratum,
                    hash: *hash,
                    bundle: bundle.clone(),
                    min_ts: *min_ts,
                    window_id: *window_id,
                },
            )
        }
        JournalOp::PartitionSlide { inserted, evict } => {
            w.u8(7)?;
            w.u64(*evict)?;
            w.records(inserted)
        }
    }
}

fn get_journal_op<R: Read>(r: &mut CkptReader<R>) -> Result<JournalOp> {
    Ok(match r.u8()? {
        0 => JournalOp::Slide { inserted: r.records()? },
        1 => {
            let now = r.u64()?;
            JournalOp::Tick { records: r.records()?, now }
        }
        2 => JournalOp::Resize { new_size: r.u64()? },
        3 => JournalOp::Evict { horizon: r.u64()? },
        4 => {
            let c = get_chunk_entry(r)?;
            JournalOp::PutChunk {
                stratum: c.stratum,
                hash: c.hash,
                moments: c.moments,
                min_ts: c.min_ts,
                window_id: c.window_id,
            }
        }
        5 => {
            let slot = r.u64()?;
            let policy = policy_name(r.bytes()?)?;
            JournalOp::BudgetAdjust { slot, policy, state: r.f64()? }
        }
        6 => {
            let s = get_sketch_entry(r)?;
            JournalOp::PutChunkSketch {
                stratum: s.stratum,
                hash: s.hash,
                bundle: s.bundle,
                min_ts: s.min_ts,
                window_id: s.window_id,
            }
        }
        7 => {
            let evict = r.u64()?;
            JournalOp::PartitionSlide { inserted: r.records()?, evict }
        }
        other => return Err(Error::Checkpoint(format!("unknown journal op tag {other}"))),
    })
}

/// Decode a budget-policy name (always ASCII in practice; anything
/// non-UTF-8 is corruption).
fn policy_name(bytes: Vec<u8>) -> Result<String> {
    String::from_utf8(bytes)
        .map_err(|_| Error::Checkpoint("budget policy name is not UTF-8".into()))
}

/// Encode one segment into a standalone blob (the outer artifact
/// checksum covers it; segments carry no checksum of their own).
pub(crate) fn encode_segment(seg: &Segment) -> Vec<u8> {
    let mut buf = Vec::new();
    {
        let mut w = CkptWriter::new(&mut buf);
        let encode = |w: &mut CkptWriter<&mut Vec<u8>>| -> Result<()> {
            match seg {
                Segment::Base(b) => {
                    w.u8(0)?;
                    put_window(w, &b.window)?;
                    w.u64(b.chunks.len() as u64)?;
                    for c in &b.chunks {
                        put_chunk_entry(w, c)?;
                    }
                    w.u64(b.items.len() as u64)?;
                    for (&s, recs) in &b.items {
                        w.u32(s)?;
                        w.records(recs)?;
                    }
                    put_stratum_moments(w, &b.moments)?;
                    put_misc(w, &b.misc)?;
                    w.u64(b.budget_states.len() as u64)?;
                    for (slot, policy, state) in &b.budget_states {
                        w.u64(*slot)?;
                        w.bytes(policy.as_bytes())?;
                        w.f64(*state)?;
                    }
                    w.u64(b.sketches.len() as u64)?;
                    for s in &b.sketches {
                        put_sketch_entry(w, s)?;
                    }
                    Ok(())
                }
                Segment::Delta(d) => {
                    w.u8(1)?;
                    w.u64(d.ops.len() as u64)?;
                    for op in &d.ops {
                        put_journal_op(w, op)?;
                    }
                    w.u64(d.items.len() as u64)?;
                    for (s, final_len, ops) in &d.items {
                        w.u32(*s)?;
                        w.u64(*final_len)?;
                        w.u64(ops.len() as u64)?;
                        for op in ops {
                            match op {
                                RunOp::Copy { start, len } => {
                                    w.u8(0)?;
                                    w.u64(*start)?;
                                    w.u64(*len)?;
                                }
                                RunOp::Insert(rs) => {
                                    w.u8(1)?;
                                    w.records(rs)?;
                                }
                            }
                        }
                    }
                    put_stratum_moments(w, &d.moments)?;
                    put_misc(w, &d.misc)
                }
            }
        };
        // lint:allow(panic-freedom) -- writes into a Vec<u8> sink, which is infallible
        encode(&mut w).expect("Vec sink cannot fail");
    }
    buf
}

/// Decode one segment blob.
pub(crate) fn decode_segment(bytes: &[u8]) -> Result<Segment> {
    let mut r = CkptReader::new(bytes);
    match r.u8()? {
        0 => {
            let window = get_window(&mut r)?;
            let n_chunks = r.len()?;
            let mut chunks = Vec::with_capacity(n_chunks.min(1 << 16));
            for _ in 0..n_chunks {
                chunks.push(get_chunk_entry(&mut r)?);
            }
            let n_items = r.len()?;
            let mut items = BTreeMap::new();
            for _ in 0..n_items {
                let s = r.u32()?;
                items.insert(s, r.records()?);
            }
            let moments = get_stratum_moments(&mut r)?;
            let misc = get_misc(&mut r)?;
            let n_states = r.len()?;
            let mut budget_states = Vec::with_capacity(n_states.min(1 << 12));
            for _ in 0..n_states {
                let slot = r.u64()?;
                let policy = policy_name(r.bytes()?)?;
                budget_states.push((slot, policy, r.f64()?));
            }
            let n_sketches = r.len()?;
            let mut sketches = Vec::with_capacity(n_sketches.min(1 << 16));
            for _ in 0..n_sketches {
                sketches.push(get_sketch_entry(&mut r)?);
            }
            Ok(Segment::Base(BaseState {
                window,
                chunks,
                items,
                moments,
                misc,
                budget_states,
                sketches,
            }))
        }
        1 => {
            let n_ops = r.len()?;
            let mut ops = Vec::with_capacity(n_ops.min(1 << 16));
            for _ in 0..n_ops {
                ops.push(get_journal_op(&mut r)?);
            }
            let n_items = r.len()?;
            let mut items = Vec::with_capacity(n_items.min(1 << 12));
            for _ in 0..n_items {
                let s = r.u32()?;
                let final_len = r.u64()?;
                let n_run_ops = r.len()?;
                let mut run_ops = Vec::with_capacity(n_run_ops.min(1 << 12));
                for _ in 0..n_run_ops {
                    run_ops.push(match r.u8()? {
                        0 => RunOp::Copy { start: r.u64()?, len: r.u64()? },
                        1 => RunOp::Insert(r.records()?),
                        other => {
                            return Err(Error::Checkpoint(format!(
                                "unknown run op tag {other}"
                            )))
                        }
                    });
                }
                items.push((s, final_len, run_ops));
            }
            let moments = get_stratum_moments(&mut r)?;
            let misc = get_misc(&mut r)?;
            Ok(Segment::Delta(DeltaState { ops, items, moments, misc }))
        }
        other => Err(Error::Checkpoint(format!("unknown segment tag {other}"))),
    }
}

// ---------------------------------------------------------------------
// Artifact framing
// ---------------------------------------------------------------------

fn put_dist<W: Write>(w: &mut CkptWriter<W>, d: &ValueDist) -> Result<()> {
    match d {
        ValueDist::Constant(v) => {
            w.u8(0)?;
            w.f64(*v)?;
            w.f64(0.0)
        }
        ValueDist::Uniform(lo, hi) => {
            w.u8(1)?;
            w.f64(*lo)?;
            w.f64(*hi)
        }
        ValueDist::Normal(m, s) => {
            w.u8(2)?;
            w.f64(*m)?;
            w.f64(*s)
        }
        ValueDist::LogNormal(mu, sigma) => {
            w.u8(3)?;
            w.f64(*mu)?;
            w.f64(*sigma)
        }
    }
}

fn get_dist<R: Read>(r: &mut CkptReader<R>) -> Result<ValueDist> {
    let tag = r.u8()?;
    let a = r.f64()?;
    let b = r.f64()?;
    Ok(match tag {
        0 => ValueDist::Constant(a),
        1 => ValueDist::Uniform(a, b),
        2 => ValueDist::Normal(a, b),
        3 => ValueDist::LogNormal(a, b),
        other => return Err(Error::Checkpoint(format!("unknown dist tag {other}"))),
    })
}

fn put_session<W: Write>(w: &mut CkptWriter<W>, s: &SessionSection) -> Result<()> {
    w.u64(s.source.subs.len() as u64)?;
    for sub in &s.source.subs {
        match sub {
            SubstreamSpec::Poisson { stratum, rate, dist, rng } => {
                w.u8(0)?;
                w.u32(*stratum)?;
                put_dist(w, dist)?;
                for v in rng {
                    w.u64(*v)?;
                }
                w.f64(*rate)?;
            }
            SubstreamSpec::Fluctuating { stratum, schedule, dist, rng } => {
                w.u8(1)?;
                w.u32(*stratum)?;
                put_dist(w, dist)?;
                for v in rng {
                    w.u64(*v)?;
                }
                w.u64(schedule.len() as u64)?;
                for (start, rate) in schedule {
                    w.u64(*start)?;
                    w.f64(*rate)?;
                }
            }
        }
    }
    w.u64(s.source.next_id)?;
    w.u64(s.source.now)?;
    w.u64(s.slides_since_ckpt)?;
    w.records(&s.backlog)
}

fn get_session<R: Read>(r: &mut CkptReader<R>) -> Result<SessionSection> {
    let n = r.len()?;
    let mut subs = Vec::with_capacity(n.min(1 << 10));
    for _ in 0..n {
        let tag = r.u8()?;
        let stratum = r.u32()?;
        let dist = get_dist(r)?;
        let mut rng = [0u64; 4];
        for v in &mut rng {
            *v = r.u64()?;
        }
        subs.push(match tag {
            0 => SubstreamSpec::Poisson { stratum, rate: r.f64()?, dist, rng },
            1 => {
                let n_sched = r.len()?;
                let mut schedule = Vec::with_capacity(n_sched.min(1 << 10));
                for _ in 0..n_sched {
                    let start = r.u64()?;
                    schedule.push((start, r.f64()?));
                }
                SubstreamSpec::Fluctuating { stratum, schedule, dist, rng }
            }
            other => {
                return Err(Error::Checkpoint(format!("unknown sub-stream tag {other}")))
            }
        });
    }
    let next_id = r.u64()?;
    let now = r.u64()?;
    let slides_since_ckpt = r.u64()?;
    let backlog = r.records()?;
    Ok(SessionSection {
        source: MultiStreamSpec { subs, next_id, now },
        slides_since_ckpt,
        backlog,
    })
}

impl Artifact {
    /// Write the full artifact (header, segments, optional session
    /// section, trailing checksum). Returns bytes written.
    pub fn write<W: Write>(&self, sink: &mut W) -> Result<u64> {
        let mut w = CkptWriter::new(sink);
        w.u32(MAGIC)?;
        w.u32(VERSION)?;
        w.u64(self.compat.seed)?;
        w.u8(mode_tag(self.compat.mode))?;
        w.u64(self.compat.chunk_size)?;
        w.u32(self.compat.map_rounds)?;
        w.u64(self.compat.slide)?;
        w.u32(self.segments.len() as u32)?;
        for seg in &self.segments {
            w.bytes(seg)?;
        }
        match &self.session {
            Some(s) => {
                w.u8(1)?;
                put_session(&mut w, s)?;
            }
            None => w.u8(0)?,
        }
        w.finish()
    }

    /// Read and checksum-verify an artifact. Every malformation —
    /// truncation, bit flips, a future version — is an
    /// [`Error::Checkpoint`].
    pub fn read<R: Read>(source: R) -> Result<Artifact> {
        let mut r = CkptReader::new(source);
        let magic = r.u32()?;
        if magic != MAGIC {
            return Err(Error::Checkpoint(format!(
                "bad magic {magic:#010x} — not an IncApprox checkpoint"
            )));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(Error::Checkpoint(format!(
                "unsupported checkpoint version {version} (this build reads {VERSION})"
            )));
        }
        let seed = r.u64()?;
        let mode = mode_from_tag(r.u8()?)?;
        let chunk_size = r.u64()?;
        let map_rounds = r.u32()?;
        let slide = r.u64()?;
        let n_segments = r.u32()? as usize;
        if n_segments > 1 << 20 {
            return Err(Error::Checkpoint(format!(
                "implausible segment count {n_segments} (corrupted?)"
            )));
        }
        let mut segments = Vec::with_capacity(n_segments.min(1 << 10));
        for _ in 0..n_segments {
            segments.push(r.bytes()?);
        }
        let session = match r.u8()? {
            0 => None,
            1 => Some(get_session(&mut r)?),
            other => {
                return Err(Error::Checkpoint(format!("unknown session flag {other}")))
            }
        };
        r.verify_checksum()?;
        if segments.is_empty() {
            return Err(Error::Checkpoint("artifact holds no segments".into()));
        }
        Ok(Artifact {
            compat: Compat { seed, mode, chunk_size, map_rounds, slide },
            segments,
            session,
        })
    }
}

// ---------------------------------------------------------------------
// The coordinator-side chain tracker
// ---------------------------------------------------------------------

/// Cap on journaled records between checkpoints. A coordinator that was
/// armed but never checkpointed again would otherwise grow its journal
/// without bound; past the cap the tracker drops the journal and forces
/// the next checkpoint to re-base.
const JOURNAL_RECORD_CAP: usize = 1 << 20;

/// In-memory incremental checkpoint chain, owned by the coordinator once
/// checkpointing is armed (first checkpoint call or the periodic knob).
#[derive(Debug, Default)]
pub(crate) struct CkptTracker {
    /// Encoded segments: one base, then deltas.
    pub segments: Vec<Vec<u8>>,
    /// Size of the base segment.
    pub base_bytes: u64,
    /// Total size of the delta segments.
    pub delta_bytes: u64,
    /// Substrate mutations since the last segment.
    pub journal: Vec<JournalOp>,
    /// Record-count cost of the journal (cap accounting).
    pub journal_cost: usize,
    /// Memoized sample runs as of the last segment (diff anchors).
    pub prev_items: BTreeMap<StratumId, SampleRun>,
    /// Force a re-base at the next checkpoint (set after faults or a
    /// journal overflow — any history the journal can no longer
    /// represent faithfully).
    pub force_base: bool,
    /// Memo image as of the last segment — what
    /// [`RecoveryPolicy::Checkpoint`](crate::fault::RecoveryPolicy)
    /// falls back to on injected memo loss.
    pub memo_image: Option<crate::sac::memo::MemoSnapshot>,
}

impl CkptTracker {
    /// Append a journal op, enforcing the record cap.
    pub fn push(&mut self, op: JournalOp) {
        if self.force_base {
            return; // journal is already invalid; the next segment re-bases
        }
        self.journal_cost += op.record_cost();
        if self.journal_cost > JOURNAL_RECORD_CAP {
            self.invalidate();
            return;
        }
        self.journal.push(op);
    }

    /// Drop the journal and force the next checkpoint to re-base.
    pub fn invalidate(&mut self) {
        self.force_base = true;
        self.journal.clear();
        self.journal_cost = 0;
    }

    /// Should the next segment be a base? (First segment, invalidated
    /// history, or deltas have outgrown the base — the classic
    /// incremental-checkpoint compaction rule.)
    pub fn wants_base(&self) -> bool {
        self.segments.is_empty() || self.force_base || self.delta_bytes > self.base_bytes
    }

    /// Install a freshly encoded base segment, dropping older history.
    pub fn install_base(&mut self, seg: Vec<u8>) -> u64 {
        let n = seg.len() as u64;
        self.base_bytes = n;
        self.delta_bytes = 0;
        self.segments.clear();
        self.segments.push(seg);
        self.after_segment();
        n
    }

    /// Append a freshly encoded delta segment.
    pub fn install_delta(&mut self, seg: Vec<u8>) -> u64 {
        let n = seg.len() as u64;
        self.delta_bytes += n;
        self.segments.push(seg);
        self.after_segment();
        n
    }

    fn after_segment(&mut self) {
        self.journal.clear();
        self.journal_cost = 0;
        self.force_base = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, ts: u64) -> Record {
        Record::new(id, (id % 3) as u32, ts, id % 7, id as f64 * 0.5)
    }

    fn run_of(ids: &[u64]) -> SampleRun {
        SampleRun::from_vec(ids.iter().map(|&i| rec(i, i)).collect())
    }

    fn rebuilt(prev: &SampleRun, cur: &SampleRun) -> Vec<Record> {
        let ops = diff_run(prev, cur);
        apply_run_ops(prev, &ops, cur.len()).unwrap()
    }

    #[test]
    fn diff_roundtrips_and_compresses_shared_runs() {
        let prev = run_of(&(0..500).collect::<Vec<_>>());
        // Slide-like edit: drop a prefix, keep the middle, append fresh.
        let cur = run_of(&(40..560).collect::<Vec<_>>());
        let ops = diff_run(&prev, &cur);
        assert_eq!(apply_run_ops(&prev, &ops, cur.len()).unwrap(), cur.records());
        // One long copy + one insert — not hundreds of literals.
        assert!(ops.len() <= 3, "diff should compress: {} ops", ops.len());
        let inserted: usize = ops
            .iter()
            .map(|o| match o {
                RunOp::Insert(rs) => rs.len(),
                RunOp::Copy { .. } => 0,
            })
            .sum();
        assert_eq!(inserted, 60, "only the fresh suffix is literal");
    }

    #[test]
    fn diff_handles_disorder_empties_and_identity() {
        let prev = run_of(&[1, 2, 3, 4, 5]);
        // Reordered retained items degrade to inserts but stay correct.
        let cur = run_of(&[5, 1, 9, 2]);
        assert_eq!(rebuilt(&prev, &cur), cur.records());
        // Identity: a single whole-run copy.
        let ops = diff_run(&prev, &prev.clone());
        assert_eq!(ops, vec![RunOp::Copy { start: 0, len: 5 }]);
        // Empty prev / empty cur.
        assert_eq!(rebuilt(&SampleRun::default(), &cur), cur.records());
        assert!(diff_run(&prev, &SampleRun::default()).is_empty());
        assert!(diff_run(&SampleRun::default(), &SampleRun::default()).is_empty());
    }

    #[test]
    fn diff_detects_value_mutation() {
        // Same id, different value bits: must not be copied as shared.
        let prev = run_of(&[1, 2, 3]);
        let mut records = prev.records().to_vec();
        records[1].value += 1.0;
        let cur = SampleRun::from_vec(records);
        assert_eq!(rebuilt(&prev, &cur), cur.records());
        let ops = diff_run(&prev, &cur);
        assert!(
            ops.iter().any(|o| matches!(o, RunOp::Insert(_))),
            "mutated record must be inserted literally"
        );
    }

    #[test]
    fn apply_rejects_corrupted_ops() {
        let prev = run_of(&[1, 2, 3]);
        let oob = [RunOp::Copy { start: 2, len: 5 }];
        assert!(apply_run_ops(&prev, &oob, 5).is_err());
        let overflow = [RunOp::Copy { start: u64::MAX, len: 2 }];
        assert!(apply_run_ops(&prev, &overflow, 2).is_err());
        let short = [RunOp::Copy { start: 0, len: 2 }];
        assert!(apply_run_ops(&prev, &short, 3).is_err(), "length mismatch must error");
    }

    #[test]
    fn segment_roundtrip_base_and_delta() {
        let misc = Misc {
            windows_processed: 7,
            next_query_id: 3,
            queries: vec![
                QueryEntry {
                    raw_id: 2,
                    spec: QuerySpec {
                        kind: AggregateKind::Mean,
                        stratum: Some(1),
                        confidence: 0.99,
                        budget: BudgetSpec::TargetError {
                            relative_bound: 0.02,
                            confidence: 0.95,
                        },
                        map_rounds: Some(0),
                    },
                },
                QueryEntry {
                    // A parameterized kind NOT in `AggregateKind::ALL` —
                    // under the v2 ALL-index encoding this would panic.
                    raw_id: 3,
                    spec: QuerySpec {
                        kind: AggregateKind::Quantile(750),
                        stratum: None,
                        confidence: 0.9,
                        budget: BudgetSpec::Fraction(0.2),
                        map_rounds: None,
                    },
                },
            ],
            recovery: RecoveryPolicy::Checkpoint,
            fault: FaultPlanState {
                rngs: [[1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12], [13, 14, 15, 16]],
                injected: [5, 2, 1, 0],
                pending_broker: true,
                pending_checkpoint_write: false,
            },
            degrade_level: 2,
            degrade_calm: 1,
            owned_strata: Some(vec![0, 2, 5]),
        };
        let sketch = SketchBundle::from_records(7, &[rec(1, 1), rec(2, 2)]);
        let base = Segment::Base(BaseState {
            window: WindowCkpt::Count {
                size: 10,
                next_window_id: 4,
                buf: vec![rec(1, 1), rec(2, 2)],
                pending: vec![rec(9, 0)],
            },
            chunks: vec![ChunkEntry {
                stratum: 2,
                hash: 0xABCD,
                moments: Moments::from_values(&[1.0, 2.0]),
                min_ts: 1,
                window_id: 3,
            }],
            items: BTreeMap::from([(0u32, vec![rec(1, 1)])]),
            moments: BTreeMap::from([(0u32, Moments::from_values(&[3.0]))]),
            misc: misc.clone(),
            budget_states: vec![
                (SESSION_BUDGET_SLOT, "target-error".to_string(), 123.5),
                (2, "token-bucket".to_string(), 77.25),
            ],
            sketches: vec![SketchChunkEntry {
                stratum: 2,
                hash: 0xABCD,
                bundle: sketch.clone(),
                min_ts: 1,
                window_id: 3,
            }],
        });
        let bytes = encode_segment(&base);
        let decoded = decode_segment(&bytes).unwrap();
        assert!(matches!(decoded, Segment::Base(_)), "expected base segment");
        match decoded {
            Segment::Base(b) => {
                assert!(matches!(b.window, WindowCkpt::Count { size: 10, .. }));
                assert_eq!(b.chunks.len(), 1);
                assert_eq!(b.chunks[0].hash, 0xABCD);
                assert_eq!(b.chunks[0].stratum, 2);
                assert_eq!(b.items[&0].len(), 1);
                assert_eq!(b.misc.windows_processed, 7);
                assert_eq!(b.misc.queries[0].spec.confidence, 0.99);
                assert_eq!(
                    b.misc.queries[0].spec.budget,
                    BudgetSpec::TargetError { relative_bound: 0.02, confidence: 0.95 },
                    "budget wire tag 3 must round-trip"
                );
                assert_eq!(
                    b.misc.queries[1].spec.kind,
                    AggregateKind::Quantile(750),
                    "parameterized kinds must round-trip through the tag encoding"
                );
                assert_eq!(b.misc.recovery, RecoveryPolicy::Checkpoint);
                assert_eq!(
                    b.misc.fault, misc.fault,
                    "the full multi-channel fault plan must round-trip"
                );
                assert_eq!((b.misc.degrade_level, b.misc.degrade_calm), (2, 1));
                assert_eq!(
                    b.misc.owned_strata,
                    Some(vec![0, 2, 5]),
                    "a partition's stratum range must round-trip"
                );
                assert_eq!(
                    b.budget_states,
                    vec![
                        (SESSION_BUDGET_SLOT, "target-error".to_string(), 123.5),
                        (2, "token-bucket".to_string(), 77.25),
                    ],
                    "controller state must round-trip with its policy tag"
                );
                assert_eq!(b.sketches.len(), 1);
                assert_eq!(b.sketches[0].stratum, 2);
                assert_eq!(b.sketches[0].hash, 0xABCD);
                assert_eq!(
                    b.sketches[0].bundle, sketch,
                    "sketch bundles must round-trip bit-exactly"
                );
            }
            Segment::Delta(_) => {}
        }

        let delta = Segment::Delta(DeltaState {
            ops: vec![
                JournalOp::Slide { inserted: vec![rec(5, 5)] },
                JournalOp::Tick { records: vec![rec(6, 6)], now: 9 },
                JournalOp::Resize { new_size: 20 },
                JournalOp::Evict { horizon: 4 },
                JournalOp::PutChunk {
                    stratum: 1,
                    hash: 0xFEED,
                    moments: Moments::EMPTY,
                    min_ts: 5,
                    window_id: 8,
                },
                JournalOp::BudgetAdjust {
                    slot: SESSION_BUDGET_SLOT,
                    policy: "target-error".to_string(),
                    state: 321.75,
                },
                JournalOp::PutChunkSketch {
                    stratum: 1,
                    hash: 0xFEED,
                    bundle: sketch.clone(),
                    min_ts: 5,
                    window_id: 8,
                },
                JournalOp::PartitionSlide { inserted: vec![rec(8, 8)], evict: 3 },
            ],
            items: vec![(
                1u32,
                3,
                vec![RunOp::Copy { start: 0, len: 2 }, RunOp::Insert(vec![rec(7, 7)])],
            )],
            moments: BTreeMap::new(),
            misc,
        });
        let bytes = encode_segment(&delta);
        let decoded = decode_segment(&bytes).unwrap();
        assert!(matches!(decoded, Segment::Delta(_)), "expected delta segment");
        match decoded {
            Segment::Delta(d) => {
                assert_eq!(d.ops.len(), 8);
                assert!(matches!(
                    &d.ops[7],
                    JournalOp::PartitionSlide { inserted, evict: 3 } if inserted.len() == 1
                ));
                assert_eq!(d.ops[7].record_cost(), 1, "cost is the routed batch size");
                assert!(matches!(d.ops[2], JournalOp::Resize { new_size: 20 }));
                assert!(matches!(
                    &d.ops[5],
                    JournalOp::BudgetAdjust { slot: SESSION_BUDGET_SLOT, policy, state }
                        if policy == "target-error" && *state == 321.75
                ));
                assert!(matches!(
                    &d.ops[6],
                    JournalOp::PutChunkSketch { hash: 0xFEED, bundle, .. }
                        if *bundle == sketch
                ));
                assert_eq!(d.items.len(), 1);
                assert_eq!(d.items[0].1, 3);
                assert_eq!(d.items[0].2.len(), 2);
            }
            Segment::Base(_) => {}
        }
        // Garbage does not decode.
        assert!(decode_segment(&[0xFF, 0x00]).is_err());
        assert!(decode_segment(&[]).is_err());
    }

    #[test]
    fn artifact_roundtrip_with_session_section() {
        let seg = encode_segment(&Segment::Delta(DeltaState {
            ops: vec![],
            items: vec![],
            moments: BTreeMap::new(),
            misc: Misc {
                windows_processed: 0,
                next_query_id: 0,
                queries: vec![],
                recovery: RecoveryPolicy::LineageRecompute,
                fault: FaultPlanState::default(),
                degrade_level: 0,
                degrade_calm: 0,
                owned_strata: None,
            },
        }));
        let art = Artifact {
            compat: Compat {
                seed: 42,
                mode: ExecModeSpec::IncApprox,
                chunk_size: 64,
                map_rounds: 0,
                slide: 400,
            },
            segments: vec![seg.clone(), seg],
            session: Some(SessionSection {
                source: MultiStreamSpec {
                    subs: vec![
                        SubstreamSpec::Poisson {
                            stratum: 0,
                            rate: 3.0,
                            dist: ValueDist::Normal(10.0, 2.0),
                            rng: [9, 8, 7, 6],
                        },
                        SubstreamSpec::Fluctuating {
                            stratum: 1,
                            schedule: vec![(0, 1.0), (100, 2.5)],
                            dist: ValueDist::LogNormal(1.0, 0.5),
                            rng: [5, 4, 3, 2],
                        },
                    ],
                    next_id: 1234,
                    now: 99,
                },
                slides_since_ckpt: 1,
                backlog: vec![rec(10, 10), rec(11, 11)],
            }),
        };
        let mut buf = Vec::new();
        let written = art.write(&mut buf).unwrap();
        assert_eq!(written as usize, buf.len());

        let back = Artifact::read(&buf[..]).unwrap();
        assert_eq!(back.compat, art.compat);
        assert_eq!(back.segments.len(), 2);
        let sect = back.session.expect("session section");
        assert_eq!(sect.source.subs.len(), 2);
        assert_eq!(sect.source.next_id, 1234);
        assert_eq!(sect.source.now, 99);
        assert_eq!(sect.slides_since_ckpt, 1);
        assert_eq!(sect.backlog.len(), 2);
        assert!(
            matches!(&sect.source.subs[1], SubstreamSpec::Fluctuating { .. }),
            "wrong sub spec: {:?}",
            sect.source.subs[1]
        );
        if let SubstreamSpec::Fluctuating { schedule, rng, .. } = &sect.source.subs[1] {
            assert_eq!(schedule, &vec![(0, 1.0), (100, 2.5)]);
            assert_eq!(rng, &[5, 4, 3, 2]);
        }

        // Corruption in a segment blob is caught by the outer checksum.
        let mut bad = buf.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(matches!(Artifact::read(&bad[..]), Err(Error::Checkpoint(_))));
        // Truncation too.
        assert!(matches!(Artifact::read(&buf[..buf.len() - 3]), Err(Error::Checkpoint(_))));
        // Wrong magic.
        let mut wrong = buf.clone();
        wrong[0] ^= 0xFF;
        assert!(matches!(Artifact::read(&wrong[..]), Err(Error::Checkpoint(_))));
    }

    #[test]
    fn tracker_rebases_on_invalidation_and_growth() {
        let mut t = CkptTracker::default();
        assert!(t.wants_base(), "empty chain must start with a base");
        t.install_base(vec![0; 100]);
        assert!(!t.wants_base());
        t.push(JournalOp::Evict { horizon: 1 });
        assert_eq!(t.journal.len(), 1);
        t.install_delta(vec![0; 60]);
        assert!(t.journal.is_empty(), "segment install drains the journal");
        assert!(!t.wants_base());
        t.install_delta(vec![0; 60]);
        assert!(t.wants_base(), "deltas outgrew the base: compact");
        // Fault-style invalidation drops the journal and forces a base.
        let mut t = CkptTracker::default();
        t.install_base(vec![0; 100]);
        t.push(JournalOp::Evict { horizon: 1 });
        t.invalidate();
        assert!(t.journal.is_empty());
        assert!(t.wants_base());
        t.push(JournalOp::Evict { horizon: 2 });
        assert!(t.journal.is_empty(), "invalidated tracker ignores ops until re-based");
    }
}
