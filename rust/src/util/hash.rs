//! Stable content hashing for memoization keys.
//!
//! Memo keys must be stable across runs and processes (the paper's memoized
//! results survive across windows; our fault-tolerance tests persist them),
//! so we avoid `std::collections::hash_map::DefaultHasher` (randomized per
//! process) and use FNV-1a with a 64-bit avalanche finisher.

/// FNV-1a over a byte slice.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Strong 64-bit finalizer (SplitMix64 avalanche) — use after combining
/// several field hashes so that low-entropy inputs still spread.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A fast `std::hash::Hasher` for keys that are already well-mixed 64-bit
/// values (chunk content hashes, record ids run through the coordinator's
/// diff sets). SipHash's DoS resistance is wasted on internal keys and
/// showed up at ~5% of the pipeline profile (EXPERIMENTS.md §Perf L3.3);
/// this one is a single SplitMix64 avalanche.
#[derive(Debug, Clone, Copy, Default)]
pub struct FastHasher {
    state: u64,
}

impl std::hash::Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        self.state = mix64(self.state ^ fnv1a(bytes));
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = mix64(self.state ^ v);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }
}

/// `HashSet` with [`FastHasher`].
pub type FastSet<T> = std::collections::HashSet<T, std::hash::BuildHasherDefault<FastHasher>>;

/// `HashMap` with [`FastHasher`].
pub type FastMap<K, V> =
    std::collections::HashMap<K, V, std::hash::BuildHasherDefault<FastHasher>>;

/// Incremental stable hasher for composite keys (chunk contents, query
/// specs). Order-sensitive.
#[derive(Debug, Clone)]
pub struct StableHasher {
    state: u64,
}

impl Default for StableHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl StableHasher {
    /// Fresh hasher with the FNV offset basis.
    pub fn new() -> Self {
        StableHasher { state: 0xcbf2_9ce4_8422_2325 }
    }

    /// Absorb a u64.
    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.state = mix64(self.state ^ v).wrapping_mul(0x0000_0100_0000_01B3);
    }

    /// Absorb raw bytes.
    #[inline]
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        self.write_u64(fnv1a(bytes));
    }

    /// Absorb an f64 by bit pattern (NaN-stable: all NaNs collapse).
    #[inline]
    pub fn write_f64(&mut self, v: f64) {
        let bits = if v.is_nan() { u64::MAX } else { v.to_bits() };
        self.write_u64(bits);
    }

    /// Final digest.
    #[inline]
    pub fn finish(&self) -> u64 {
        mix64(self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Reference values for FNV-1a 64-bit.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn stable_across_instances() {
        let mut a = StableHasher::new();
        let mut b = StableHasher::new();
        for h in [&mut a, &mut b] {
            h.write_u64(1);
            h.write_bytes(b"stratum-3");
            h.write_f64(1.5);
        }
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn order_sensitive() {
        let mut a = StableHasher::new();
        a.write_u64(1);
        a.write_u64(2);
        let mut b = StableHasher::new();
        b.write_u64(2);
        b.write_u64(1);
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn nan_collapses() {
        let mut a = StableHasher::new();
        a.write_f64(f64::NAN);
        let mut b = StableHasher::new();
        b.write_f64(-f64::NAN);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn stable_hasher_golden_vectors() {
        // Pinned digests (independently computed from the algorithm
        // spec). The incremental chunking path reuses stored hashes
        // instead of re-hashing unchanged runs, so any drift in
        // `StableHasher` — especially `write_f64`'s bit-pattern rule —
        // across toolchains or refactors would silently split the memo
        // keyspace. These constants make that a loud failure instead.
        assert_eq!(mix64(0), 0x0);
        assert_eq!(mix64(1), 0x5692_161d_100b_05e5);
        assert_eq!(mix64(0xDEAD_BEEF), 0x4e06_2702_ec92_9eea);

        assert_eq!(StableHasher::new().finish(), 0xf52a_15e9_a9b5_e89b);

        let mut h = StableHasher::new();
        h.write_u64(42);
        assert_eq!(h.finish(), 0x69de_48d0_775c_4d32);

        let mut h = StableHasher::new();
        h.write_u64(1);
        h.write_u64(2);
        h.write_u64(3);
        assert_eq!(h.finish(), 0x0cf1_ccbd_e514_5998);

        // write_f64 coverage: normal value, both zeros (distinct bit
        // patterns, distinct digests), NaN collapse, negative value.
        let f64_digest = |v: f64| {
            let mut h = StableHasher::new();
            h.write_f64(v);
            h.finish()
        };
        assert_eq!(f64_digest(1.5), 0xf0d4_2273_9efe_9821);
        assert_eq!(f64_digest(0.0), 0x51de_1b0e_99b4_c033);
        assert_eq!(f64_digest(-0.0), 0xe9e7_6c7e_b7a2_c17f);
        assert_eq!(f64_digest(f64::NAN), 0xda32_fe1e_8eb9_e7a5);
        assert_eq!(f64_digest(-1.25), 0x2902_7a1c_ed6b_277e);

        let mut h = StableHasher::new();
        h.write_bytes(b"stratum-3");
        assert_eq!(h.finish(), 0x4ff1_6c48_618a_c398);

        // The exact absorb sequence `Chunk::from_run` uses: stratum id,
        // then (id, value-bits) per record — stratum 3, ids 0..4 with
        // values i * 0.5.
        let mut h = StableHasher::new();
        h.write_u64(3);
        for i in 0..4u64 {
            h.write_u64(i);
            h.write_f64(i as f64 * 0.5);
        }
        assert_eq!(h.finish(), 0x9f4f_15df_2302_e94c);
    }

    #[test]
    fn mix64_spreads_low_entropy() {
        // Consecutive integers should not produce consecutive hashes.
        let h: Vec<u64> = (0u64..16).map(mix64).collect();
        for w in h.windows(2) {
            assert!(w[1].wrapping_sub(w[0]) != 1);
        }
    }
}
