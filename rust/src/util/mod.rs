//! Small self-contained utilities shared by every subsystem.
//!
//! No third-party `rand`, `serde`, or hashing crates are reachable in this
//! offline build, so the deterministic PRNG, content hashing, and
//! compensated summation live here (see DESIGN.md §3, substitution table).

pub mod hash;
pub mod ksum;
pub mod rng;

pub use hash::{fnv1a, mix64, StableHasher};
pub use ksum::NeumaierSum;
pub use rng::Rng;
