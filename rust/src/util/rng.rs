//! Deterministic pseudo-random number generation.
//!
//! Everything stochastic in IncApprox (reservoir acceptance, eviction
//! choice, Poisson arrivals, fault injection, the property-test harness)
//! draws from this generator, so every experiment in EXPERIMENTS.md is
//! reproducible from its seed. The core is SplitMix64 feeding a
//! xoshiro256**-style state — small, fast, and statistically solid far
//! beyond this workload's needs.

/// Deterministic PRNG (xoshiro256** seeded via SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Snapshot of the internal xoshiro256** state, for checkpointing.
    /// Restoring via [`Rng::from_state`] continues the exact stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a snapshot taken by [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free bound is overkill here;
        // 128-bit multiply keeps bias < 2^-64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive), matching the paper's
    /// `random(a, b)` subroutine in Algorithm 3.
    #[inline]
    pub fn range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p` (clamped to [0,1]).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential inter-arrival time with rate `lambda` (> 0).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u == 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's product method for small λ, normal approximation above 64
    /// (the generators use λ ≤ ~20, so the approximation branch only
    /// guards pathological configs).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = self.normal_with(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), via partial
    /// Fisher–Yates over an index vector.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(5);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let x = r.range_inclusive(3, 7);
            assert!((3..=7).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 7;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(9);
        for &lambda in &[0.5, 3.0, 10.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda + 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(19);
        for _ in 0..100 {
            let k = r.below(20);
            let idx = r.sample_indices(20, k);
            assert_eq!(idx.len(), k);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k);
            assert!(idx.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(29);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
