//! Compensated (Neumaier) summation.
//!
//! The native (exact) baseline aggregates whole 10k-item windows in f64;
//! plain left-to-right summation drifts enough to trip the tight
//! native-vs-PJRT comparison tests, so all scalar reductions in the job
//! executor and the stats module run through this accumulator.

/// Neumaier variant of Kahan summation: exact for well-conditioned inputs,
/// and tolerant of addends larger than the running sum.
#[derive(Debug, Clone, Copy, Default)]
pub struct NeumaierSum {
    sum: f64,
    compensation: f64,
}

impl NeumaierSum {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one value.
    #[inline]
    pub fn add(&mut self, v: f64) {
        let t = self.sum + v;
        if self.sum.abs() >= v.abs() {
            self.compensation += (self.sum - t) + v;
        } else {
            self.compensation += (v - t) + self.sum;
        }
        self.sum = t;
    }

    /// Current compensated total.
    #[inline]
    pub fn total(&self) -> f64 {
        self.sum + self.compensation
    }
}

/// Compensated sum of a slice.
pub fn ksum(xs: &[f64]) -> f64 {
    let mut acc = NeumaierSum::new();
    for &x in xs {
        acc.add(x);
    }
    acc.total()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_sum() {
        assert_eq!(ksum(&[1.0, 2.0, 3.0]), 6.0);
    }

    #[test]
    fn recovers_cancellation() {
        // 1.0 + 1e100 - 1e100 == 1.0 with compensation, 0.0 without.
        assert_eq!(ksum(&[1.0, 1e100, -1e100]), 1.0);
    }

    #[test]
    fn many_smalls_onto_large() {
        let mut xs = vec![1e16];
        xs.extend(std::iter::repeat(1.0).take(10_000));
        // Naive summation loses every 1.0 (1e16 + 1 == 1e16 in f64).
        let naive: f64 = xs.iter().sum();
        assert_eq!(naive, 1e16);
        assert_eq!(ksum(&xs), 1e16 + 10_000.0);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(ksum(&[]), 0.0);
    }
}
