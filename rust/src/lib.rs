//! # IncApprox — the marriage of incremental and approximate computing
//!
//! A rust + JAX + Pallas reproduction of *"The Marriage of Incremental and
//! Approximate Computing"* (Krishnan, TU Dresden 2016; the IncApprox
//! system, WWW'16). The crate is the Layer-3 coordinator of a three-layer
//! stack:
//!
//! * **L3 (this crate)** — streaming orchestrator: stream aggregation,
//!   sliding windows, stratified/biased reservoir sampling, self-adjusting
//!   computation (memoization + change propagation), query-budget cost
//!   functions, and stratified error bounds.
//! * **L2 (`python/compile/model.py`)** — the window estimator compute
//!   graph, AOT-lowered once to HLO text.
//! * **L1 (`python/compile/kernels/`)** — the Pallas chunk-moments kernel
//!   the L2 graph calls; executed at runtime through the PJRT CPU client
//!   (`runtime` module, behind the `pjrt` feature). Python is never on
//!   the request path.
//!
//! Entry points: a [`coordinator::Session`] serves N concurrent
//! [`coordinator::QuerySpec`]s over one shared stream, window, sample,
//! and memo store ([`prelude`] re-exports the session-era API);
//! [`coordinator::Coordinator`] drives the paper's Algorithm 1 over any
//! [`workload`] source; `examples/` show end-to-end usage;
//! `rust/benches/` regenerate the paper's figures.

#![warn(missing_docs)]

pub mod bench_harness;
pub mod budget;
pub mod checkpoint;
pub mod classify;
pub mod cli;
pub mod columnar;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod fault;
pub mod job;
pub mod kafka;
pub mod lint;
pub mod logging;
pub mod metrics;
pub mod partition;
pub mod prelude;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sac;
pub mod sampling;
pub mod stats;
pub mod util;
pub mod window;
pub mod workload;

pub use error::{Error, Result};
