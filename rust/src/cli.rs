//! Tiny command-line argument parser for the launcher and examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. (No `clap` in the offline crate set.)

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); skip the binary name
    /// before calling if you pass `std::env::args()`.
    ///
    /// `known_flags` lists boolean options that consume no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: the rest is positional.
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it.peek().is_some_and(|next| next.starts_with("--")) {
                    return Err(Error::Config(format!("option --{body} needs a value")));
                } else if let Some(v) = it.next() {
                    out.opts.insert(body.to_string(), v);
                } else {
                    return Err(Error::Config(format!("option --{body} needs a value")));
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process command line.
    pub fn from_env(known_flags: &[&str]) -> Result<Self> {
        Self::parse(std::env::args().skip(1), known_flags)
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.opts.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse()
                .map_err(|_| Error::Config(format!("cannot parse --{key} value `{raw}`"))),
        }
    }

    /// Was a boolean flag given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--mode", "native", "--seed=7"], &[]).unwrap();
        assert_eq!(a.get("mode"), Some("native"));
        assert_eq!(a.get_parse::<u64>("seed", 0).unwrap(), 7);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["run", "--verbose", "--n", "3", "trace.txt"], &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional(), &["run".to_string(), "trace.txt".to_string()]);
        assert_eq!(a.get_parse::<usize>("n", 0).unwrap(), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &[]).unwrap();
        assert_eq!(a.get_parse::<usize>("missing", 9).unwrap(), 9);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--x", "1", "--", "--not-an-opt"], &[]).unwrap();
        assert_eq!(a.positional(), &["--not-an-opt".to_string()]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&["--mode"], &[]).is_err());
        assert!(parse(&["--mode", "--other", "x"], &[]).is_err());
    }

    #[test]
    fn bad_typed_parse_is_error() {
        let a = parse(&["--n", "abc"], &[]).unwrap();
        assert!(a.get_parse::<usize>("n", 0).is_err());
    }
}
