//! Crate-wide error type.
//!
//! Hand-implemented `Display`/`Error` (no `thiserror` in the offline
//! crate set — see DESIGN.md substitution table).

use std::fmt;

/// Unified error type for every IncApprox subsystem.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / CLI problems.
    Config(String),

    /// Stream-aggregator (kafka substrate) problems.
    Kafka(String),

    /// Sampling invariant violations.
    Sampling(String),

    /// Self-adjusting-computation / memoization problems.
    Sac(String),

    /// Statistics / error-estimation domain errors.
    Stats(String),

    /// PJRT runtime problems (artifact loading, compilation, execution).
    Runtime(String),

    /// Budget / cost-function problems.
    Budget(String),

    /// Job execution problems.
    Job(String),

    /// Injected or real fault surfaced to the coordinator.
    Fault(String),

    /// Checkpoint encode/decode problems (version or seed mismatch,
    /// truncation, corruption). Restoring from a damaged artifact returns
    /// this instead of panicking.
    Checkpoint(String),

    /// Underlying XLA/PJRT error.
    Xla(String),

    /// I/O error (trace files, artifacts).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Kafka(m) => write!(f, "kafka error: {m}"),
            Error::Sampling(m) => write!(f, "sampling error: {m}"),
            Error::Sac(m) => write!(f, "sac error: {m}"),
            Error::Stats(m) => write!(f, "stats error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Budget(m) => write!(f, "budget error: {m}"),
            Error::Job(m) => write!(f, "job error: {m}"),
            Error::Fault(m) => write!(f, "fault: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            // Transparent: the io::Error message stands alone.
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert_eq!(Error::Job("y".into()).to_string(), "job error: y");
        assert_eq!(Error::Stats("z".into()).to_string(), "stats error: z");
        assert_eq!(Error::Checkpoint("w".into()).to_string(), "checkpoint error: w");
    }

    #[test]
    fn io_is_transparent_with_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err: Error = io.into();
        assert_eq!(err.to_string(), "gone");
        assert!(std::error::Error::source(&err).is_some());
    }
}
