//! Crate-wide error type.

/// Unified error type for every IncApprox subsystem.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Configuration file / CLI problems.
    #[error("config error: {0}")]
    Config(String),

    /// Stream-aggregator (kafka substrate) problems.
    #[error("kafka error: {0}")]
    Kafka(String),

    /// Sampling invariant violations.
    #[error("sampling error: {0}")]
    Sampling(String),

    /// Self-adjusting-computation / memoization problems.
    #[error("sac error: {0}")]
    Sac(String),

    /// Statistics / error-estimation domain errors.
    #[error("stats error: {0}")]
    Stats(String),

    /// PJRT runtime problems (artifact loading, compilation, execution).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Budget / cost-function problems.
    #[error("budget error: {0}")]
    Budget(String),

    /// Job execution problems.
    #[error("job error: {0}")]
    Job(String),

    /// Injected or real fault surfaced to the coordinator.
    #[error("fault: {0}")]
    Fault(String),

    /// Underlying XLA/PJRT error.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O error (trace files, artifacts).
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
