//! Measurement harness for `cargo bench` targets.
//!
//! Criterion is not reachable offline, so the bench binaries (declared
//! with `harness = false`) use this module: warmup, repeated timed
//! iterations, mean / std / p50 / p99 reporting with aligned rows,
//! throughput derivation ([`Measurement::throughput`]), and machine-
//! readable result emission ([`JsonReporter`], hand-rolled JSON — no
//! `serde` offline) — enough to regenerate every figure/table in
//! EXPERIMENTS.md and to diff runs across commits.

use std::hint::black_box as std_black_box;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Re-exported black box to keep benched work alive.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Summary statistics of one measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Mean per-iteration milliseconds.
    pub mean_ms: f64,
    /// Standard deviation (ms).
    pub std_ms: f64,
    /// Median (ms).
    pub p50_ms: f64,
    /// 99th percentile (ms).
    pub p99_ms: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl Measurement {
    /// Throughput implied by the mean iteration time when each iteration
    /// processes `items_per_iter` items (items/second; 0 for degenerate
    /// timings).
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        if self.mean_ms <= 0.0 {
            0.0
        } else {
            items_per_iter as f64 / (self.mean_ms / 1e3)
        }
    }

    fn from_samples(mut samples: Vec<f64>) -> Measurement {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| samples[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Measurement { mean_ms: mean, std_ms: var.sqrt(), p50_ms: q(0.5), p99_ms: q(0.99), iters: n }
    }
}

/// A configurable micro/macro benchmark.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
}

impl Bench {
    /// Named bench with defaults (3 warmup, 10 measured iterations).
    pub fn new(name: impl Into<String>) -> Self {
        Bench { name: name.into(), warmup: 3, iters: 10 }
    }

    /// Set warmup iterations.
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Set measured iterations.
    pub fn iters(mut self, n: usize) -> Self {
        assert!(n > 0);
        self.iters = n;
        self
    }

    /// Run and summarize. `f` receives the iteration index; use
    /// [`black_box`] on results inside.
    pub fn run<F: FnMut(usize)>(&self, mut f: F) -> Measurement {
        for i in 0..self.warmup {
            f(i);
        }
        let samples: Vec<f64> = (0..self.iters)
            .map(|i| {
                let start = Instant::now();
                f(i);
                start.elapsed().as_secs_f64() * 1e3
            })
            .collect();
        Measurement::from_samples(samples)
    }

    /// Run and print one aligned row.
    pub fn run_and_report<F: FnMut(usize)>(&self, f: F) -> Measurement {
        let m = self.run(f);
        println!(
            "{:<44} mean {:>9.3} ms  ±{:>8.3}  p50 {:>9.3}  p99 {:>9.3}  (n={})",
            self.name, m.mean_ms, m.std_ms, m.p50_ms, m.p99_ms, m.iters
        );
        m
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        // JSON has no Infinity/NaN; emit null.
        "null".to_string()
    }
}

/// Collects bench results as JSON rows and writes one `.json` file per
/// bench under `target/bench-results/`, so figure data survives the run
/// and can be diffed across commits.
#[derive(Debug)]
pub struct JsonReporter {
    path: PathBuf,
    rows: Vec<String>,
}

impl JsonReporter {
    /// Reporter writing to `target/bench-results/<bench>.json` (relative
    /// to the working directory `cargo bench` runs benches in — the
    /// package root).
    pub fn for_bench(bench: &str) -> Self {
        Self::to_path(Path::new("target/bench-results").join(format!("{bench}.json")))
    }

    /// Reporter writing to an explicit path (tests).
    pub fn to_path(path: impl Into<PathBuf>) -> Self {
        JsonReporter { path: path.into(), rows: Vec::new() }
    }

    /// Record one data point of a named series — the numeric fields of
    /// one printed table row.
    pub fn record_point(&mut self, series: &str, fields: &[(&str, f64)]) {
        let mut row = format!("{{\"series\": \"{}\"", json_escape(series));
        for (k, v) in fields {
            row.push_str(&format!(", \"{}\": {}", json_escape(k), json_num(*v)));
        }
        row.push('}');
        self.rows.push(row);
    }

    /// Record a timing [`Measurement`] under a name.
    pub fn record_measurement(&mut self, name: &str, m: &Measurement) {
        self.record_point(
            name,
            &[
                ("mean_ms", m.mean_ms),
                ("std_ms", m.std_ms),
                ("p50_ms", m.p50_ms),
                ("p99_ms", m.p99_ms),
                ("iters", m.iters as f64),
            ],
        );
    }

    /// Write the collected rows as a JSON array and return the path.
    pub fn finish(self) -> std::io::Result<PathBuf> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(&self.path)?;
        writeln!(f, "[")?;
        for (i, row) in self.rows.iter().enumerate() {
            let sep = if i + 1 == self.rows.len() { "" } else { "," };
            writeln!(f, "  {row}{sep}")?;
        }
        writeln!(f, "]")?;
        println!("(bench results written to {})", self.path.display());
        Ok(self.path)
    }
}

/// Print a section header for a figure/table reproduction.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one row of a result table (free-form columns).
pub fn row(cols: &[String]) {
    println!("{}", cols.join("\t"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_statistics() {
        let m = Measurement::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((m.mean_ms - 3.0).abs() < 1e-12);
        assert!((m.p50_ms - 3.0).abs() < 1e-12);
        assert_eq!(m.iters, 5);
        assert!(m.std_ms > 0.0);
    }

    #[test]
    fn bench_runs_warmup_plus_iters() {
        let mut calls = 0usize;
        let b = Bench::new("t").warmup(2).iters(5);
        let m = b.run(|_| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn throughput_from_mean() {
        let m = Measurement { mean_ms: 100.0, std_ms: 0.0, p50_ms: 100.0, p99_ms: 100.0, iters: 1 };
        assert!((m.throughput(1000) - 10_000.0).abs() < 1e-9);
        let zero = Measurement { mean_ms: 0.0, ..m };
        assert_eq!(zero.throughput(1000), 0.0);
    }

    #[test]
    fn json_reporter_writes_valid_rows() {
        let dir = std::env::temp_dir().join("incapprox_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let mut rep = JsonReporter::to_path(&path);
        rep.record_point("fig5a", &[("sample_pct", 10.0), ("memoized", 123.0)]);
        rep.record_measurement("mode=native", &Measurement {
            mean_ms: 1.5,
            std_ms: 0.1,
            p50_ms: 1.4,
            p99_ms: 2.0,
            iters: 5,
        });
        rep.record_point("weird \"name\"", &[("nan", f64::NAN)]);
        let out = rep.finish().unwrap();
        let text = std::fs::read_to_string(out).unwrap();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"series\": \"fig5a\""));
        assert!(text.contains("\"sample_pct\": 10"));
        assert!(text.contains("\"mean_ms\": 1.5"));
        assert!(text.contains("\\\"name\\\""));
        assert!(text.contains("\"nan\": null"));
        // Rows are comma-separated except the last.
        assert_eq!(text.matches("},").count(), 2);
    }

    #[test]
    fn bench_timings_positive() {
        let b = Bench::new("spin").warmup(0).iters(3);
        let m = b.run(|_| {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.mean_ms >= 0.0);
        assert!(m.p99_ms >= m.p50_ms);
    }
}
