//! Measurement harness for `cargo bench` targets.
//!
//! Criterion is not reachable offline, so the bench binaries (declared
//! with `harness = false`) use this module: warmup, repeated timed
//! iterations, and mean / std / p50 / p99 reporting with aligned rows —
//! enough to regenerate every figure/table in EXPERIMENTS.md.

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Re-exported black box to keep benched work alive.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Summary statistics of one measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Mean per-iteration milliseconds.
    pub mean_ms: f64,
    /// Standard deviation (ms).
    pub std_ms: f64,
    /// Median (ms).
    pub p50_ms: f64,
    /// 99th percentile (ms).
    pub p99_ms: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl Measurement {
    fn from_samples(mut samples: Vec<f64>) -> Measurement {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| samples[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Measurement { mean_ms: mean, std_ms: var.sqrt(), p50_ms: q(0.5), p99_ms: q(0.99), iters: n }
    }
}

/// A configurable micro/macro benchmark.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
}

impl Bench {
    /// Named bench with defaults (3 warmup, 10 measured iterations).
    pub fn new(name: impl Into<String>) -> Self {
        Bench { name: name.into(), warmup: 3, iters: 10 }
    }

    /// Set warmup iterations.
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Set measured iterations.
    pub fn iters(mut self, n: usize) -> Self {
        assert!(n > 0);
        self.iters = n;
        self
    }

    /// Run and summarize. `f` receives the iteration index; use
    /// [`black_box`] on results inside.
    pub fn run<F: FnMut(usize)>(&self, mut f: F) -> Measurement {
        for i in 0..self.warmup {
            f(i);
        }
        let samples: Vec<f64> = (0..self.iters)
            .map(|i| {
                let start = Instant::now();
                f(i);
                start.elapsed().as_secs_f64() * 1e3
            })
            .collect();
        Measurement::from_samples(samples)
    }

    /// Run and print one aligned row.
    pub fn run_and_report<F: FnMut(usize)>(&self, f: F) -> Measurement {
        let m = self.run(f);
        println!(
            "{:<44} mean {:>9.3} ms  ±{:>8.3}  p50 {:>9.3}  p99 {:>9.3}  (n={})",
            self.name, m.mean_ms, m.std_ms, m.p50_ms, m.p99_ms, m.iters
        );
        m
    }
}

/// Print a section header for a figure/table reproduction.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one row of a result table (free-form columns).
pub fn row(cols: &[String]) {
    println!("{}", cols.join("\t"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_statistics() {
        let m = Measurement::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((m.mean_ms - 3.0).abs() < 1e-12);
        assert!((m.p50_ms - 3.0).abs() < 1e-12);
        assert_eq!(m.iters, 5);
        assert!(m.std_ms > 0.0);
    }

    #[test]
    fn bench_runs_warmup_plus_iters() {
        let mut calls = 0usize;
        let b = Bench::new("t").warmup(2).iters(5);
        let m = b.run(|_| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn bench_timings_positive() {
        let b = Bench::new("spin").warmup(0).iters(3);
        let m = b.run(|_| {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.mean_ms >= 0.0);
        assert!(m.p99_ms >= m.p50_ms);
    }
}
