//! Minimal leveled logger backing the `log` facade.
//!
//! No `env_logger` in the offline crate set, so this module provides the
//! subset the launcher and examples need: level filtering from the
//! `INCAPPROX_LOG` environment variable (`error|warn|info|debug|trace`),
//! monotonic-millis timestamps, and target prefixes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INSTALLED: AtomicBool = AtomicBool::new(false);

struct SimpleLogger {
    start: Instant,
}

impl log::Log for SimpleLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let ms = self.start.elapsed().as_millis();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{ms:>8}ms {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Parse a level name; unknown strings fall back to `Info`.
pub fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the logger once; further calls are no-ops. Level comes from
/// `INCAPPROX_LOG` (default `info`).
pub fn init() {
    init_with_level(
        std::env::var("INCAPPROX_LOG")
            .map(|v| parse_level(&v))
            .unwrap_or(LevelFilter::Info),
    );
}

/// Install with an explicit level (used by tests and benches).
pub fn init_with_level(level: LevelFilter) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        log::set_max_level(level);
        return;
    }
    let logger = Box::new(SimpleLogger { start: Instant::now() });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), LevelFilter::Error);
        assert_eq!(parse_level("WARN"), LevelFilter::Warn);
        assert_eq!(parse_level("debug"), LevelFilter::Debug);
        assert_eq!(parse_level("trace"), LevelFilter::Trace);
        assert_eq!(parse_level("off"), LevelFilter::Off);
        assert_eq!(parse_level("bogus"), LevelFilter::Info);
    }

    #[test]
    fn init_idempotent() {
        init_with_level(LevelFilter::Warn);
        init_with_level(LevelFilter::Info);
        assert_eq!(log::max_level(), LevelFilter::Info);
        log::info!("logger smoke");
    }
}
