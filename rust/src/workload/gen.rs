//! Synthetic stream generators matching the paper's §5 setup.
//!
//! Each sub-stream is an independent Poisson process: at logical tick `t`
//! a sub-stream with mean rate λ emits `Poisson(λ)` records. §5.1 uses
//! three sub-streams with rates 3:4:5; §5.1.4 uses two fluctuating
//! sub-streams plus one constant.

use crate::columnar::{ColumnarBatch, ColumnarBuilder};
use crate::util::rng::Rng;
use crate::workload::record::{Record, StratumId};

/// Distribution of record values within a sub-stream. §2.3.3 assumes
/// items within a stratum are i.i.d.; different strata may differ.
#[derive(Debug, Clone, Copy)]
pub enum ValueDist {
    /// Constant value.
    Constant(f64),
    /// Uniform over `[lo, hi)`.
    Uniform(f64, f64),
    /// Normal with (mean, std).
    Normal(f64, f64),
    /// Log-normal via `exp(Normal(mu, sigma))` — heavy-tailed sizes.
    LogNormal(f64, f64),
}

impl ValueDist {
    /// Draw one value.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            ValueDist::Constant(v) => v,
            ValueDist::Uniform(lo, hi) => lo + (hi - lo) * rng.f64(),
            ValueDist::Normal(m, s) => rng.normal_with(m, s),
            ValueDist::LogNormal(mu, sigma) => rng.normal_with(mu, sigma).exp(),
        }
    }

    /// Exact mean of the distribution (for test assertions).
    pub fn mean(&self) -> f64 {
        match *self {
            ValueDist::Constant(v) => v,
            ValueDist::Uniform(lo, hi) => 0.5 * (lo + hi),
            ValueDist::Normal(m, _) => m,
            ValueDist::LogNormal(mu, sigma) => (mu + 0.5 * sigma * sigma).exp(),
        }
    }
}

/// A source of records per logical tick.
pub trait Generator {
    /// Emit all records for tick `t`. Ids are assigned by the caller
    /// ([`MultiStream`]) so they are unique across sub-streams.
    fn tick(&mut self, t: u64, next_id: &mut u64) -> Vec<Record>;

    /// Emit tick `t` directly into a columnar builder — no intermediate
    /// row vector. Implementations MUST draw from their RNG in exactly
    /// the same order as [`Generator::tick`] so both paths produce
    /// identical streams; the default delegates to `tick`, which makes
    /// that true by construction. Returns the number of records emitted.
    fn tick_into(&mut self, t: u64, next_id: &mut u64, out: &mut ColumnarBuilder) -> usize {
        let batch = self.tick(t, next_id);
        out.extend_records(&batch);
        batch.len()
    }

    /// Stratum this generator feeds (for single-stratum generators).
    fn stratum(&self) -> StratumId;

    /// Current mean arrival rate (records/tick) — used by tests and the
    /// aggregator's rate counters that pick the re-allocation interval T.
    fn rate(&self, t: u64) -> f64;

    /// Durable description of this generator's full state (structure +
    /// RNG), for session checkpoints. `None` (the default) marks the
    /// generator as non-checkpointable; a session over it refuses to
    /// checkpoint instead of silently diverging on restore.
    fn spec(&self) -> Option<SubstreamSpec> {
        None
    }
}

/// Durable description of one checkpointable sub-stream: everything
/// needed to rebuild the generator mid-stream, including its RNG state
/// (see [`crate::util::rng::Rng::state`]).
#[derive(Debug, Clone)]
pub enum SubstreamSpec {
    /// A [`PoissonSubstream`].
    Poisson {
        /// Stratum the sub-stream feeds.
        stratum: StratumId,
        /// Mean arrival rate (records/tick).
        rate: f64,
        /// Value distribution.
        dist: ValueDist,
        /// RNG state at checkpoint time.
        rng: [u64; 4],
    },
    /// A [`FluctuatingSubstream`].
    Fluctuating {
        /// Stratum the sub-stream feeds.
        stratum: StratumId,
        /// `(start_tick, rate)` schedule, sorted by start.
        schedule: Vec<(u64, f64)>,
        /// Value distribution.
        dist: ValueDist,
        /// RNG state at checkpoint time.
        rng: [u64; 4],
    },
}

/// Durable description of a whole [`MultiStream`] (see
/// [`MultiStream::checkpoint_spec`]).
#[derive(Debug, Clone)]
pub struct MultiStreamSpec {
    /// Per-sub-stream specs, in merge order.
    pub subs: Vec<SubstreamSpec>,
    /// Next record id to assign.
    pub next_id: u64,
    /// Current logical time.
    pub now: u64,
}

/// Constant-rate Poisson sub-stream.
pub struct PoissonSubstream {
    stratum: StratumId,
    rate: f64,
    dist: ValueDist,
    rng: Rng,
}

impl PoissonSubstream {
    /// New sub-stream with mean `rate` items/tick.
    pub fn new(stratum: StratumId, rate: f64, dist: ValueDist, seed: u64) -> Self {
        PoissonSubstream { stratum, rate, dist, rng: Rng::new(seed) }
    }
}

impl Generator for PoissonSubstream {
    fn tick(&mut self, t: u64, next_id: &mut u64) -> Vec<Record> {
        let n = self.rng.poisson(self.rate);
        (0..n)
            .map(|_| {
                let id = *next_id;
                *next_id += 1;
                let key = self.rng.next_u64() % 97; // small key space for group-bys
                Record::new(id, self.stratum, t, key, self.dist.sample(&mut self.rng))
            })
            .collect()
    }

    fn stratum(&self) -> StratumId {
        self.stratum
    }

    fn rate(&self, _t: u64) -> f64 {
        self.rate
    }

    fn spec(&self) -> Option<SubstreamSpec> {
        Some(SubstreamSpec::Poisson {
            stratum: self.stratum,
            rate: self.rate,
            dist: self.dist,
            rng: self.rng.state(),
        })
    }

    fn tick_into(&mut self, t: u64, next_id: &mut u64, out: &mut ColumnarBuilder) -> usize {
        // Same draw order as `tick`: poisson, then (key, value) per record.
        let n = self.rng.poisson(self.rate);
        for _ in 0..n {
            let id = *next_id;
            *next_id += 1;
            let key = self.rng.next_u64() % 97;
            out.push_parts(id, self.stratum, t, key, self.dist.sample(&mut self.rng));
        }
        n as usize
    }
}

/// Sub-stream whose rate follows a piecewise schedule — §5.1.4's
/// "fluctuating arrival rate". The schedule maps tick thresholds to
/// rates: the rate at tick `t` is the entry with the largest `start ≤ t`.
pub struct FluctuatingSubstream {
    stratum: StratumId,
    /// (start_tick, rate) pairs, sorted by start.
    schedule: Vec<(u64, f64)>,
    dist: ValueDist,
    rng: Rng,
}

impl FluctuatingSubstream {
    /// Build from a schedule; panics if empty or unsorted.
    pub fn new(
        stratum: StratumId,
        schedule: Vec<(u64, f64)>,
        dist: ValueDist,
        seed: u64,
    ) -> Self {
        assert!(!schedule.is_empty(), "schedule must be non-empty");
        assert!(
            schedule.windows(2).all(|w| w[0].0 <= w[1].0),
            "schedule must be sorted by start tick"
        );
        FluctuatingSubstream { stratum, schedule, dist, rng: Rng::new(seed) }
    }
}

impl Generator for FluctuatingSubstream {
    fn tick(&mut self, t: u64, next_id: &mut u64) -> Vec<Record> {
        let rate = self.rate(t);
        let n = self.rng.poisson(rate);
        (0..n)
            .map(|_| {
                let id = *next_id;
                *next_id += 1;
                let key = self.rng.next_u64() % 97;
                Record::new(id, self.stratum, t, key, self.dist.sample(&mut self.rng))
            })
            .collect()
    }

    fn stratum(&self) -> StratumId {
        self.stratum
    }

    fn rate(&self, t: u64) -> f64 {
        let mut rate = self.schedule[0].1;
        for &(start, r) in &self.schedule {
            if start <= t {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }

    fn spec(&self) -> Option<SubstreamSpec> {
        Some(SubstreamSpec::Fluctuating {
            stratum: self.stratum,
            schedule: self.schedule.clone(),
            dist: self.dist,
            rng: self.rng.state(),
        })
    }

    fn tick_into(&mut self, t: u64, next_id: &mut u64, out: &mut ColumnarBuilder) -> usize {
        // Same draw order as `tick`: poisson, then (key, value) per record.
        let rate = self.rate(t);
        let n = self.rng.poisson(rate);
        for _ in 0..n {
            let id = *next_id;
            *next_id += 1;
            let key = self.rng.next_u64() % 97;
            out.push_parts(id, self.stratum, t, key, self.dist.sample(&mut self.rng));
        }
        n as usize
    }
}

/// Merges several sub-streams into one id-spaced stream — the "stream
/// aggregator input" side of Figure 2.1.
pub struct MultiStream {
    subs: Vec<Box<dyn Generator + Send>>,
    next_id: u64,
    now: u64,
}

impl MultiStream {
    /// Combine sub-streams.
    pub fn new(subs: Vec<Box<dyn Generator + Send>>) -> Self {
        MultiStream { subs, next_id: 0, now: 0 }
    }

    /// The paper's §5.1 three-sub-stream setup (rates 3:4:5), with
    /// per-stratum Normal value distributions.
    pub fn paper_section5(seed: u64) -> Self {
        let rates = [3.0, 4.0, 5.0];
        let subs = rates
            .iter()
            .enumerate()
            .map(|(i, &r)| {
                Box::new(PoissonSubstream::new(
                    i as StratumId,
                    r,
                    ValueDist::Normal(10.0 * (i + 1) as f64, 2.0),
                    seed.wrapping_add(i as u64 + 1),
                )) as Box<dyn Generator + Send>
            })
            .collect();
        MultiStream::new(subs)
    }

    /// §5.1.4: two fluctuating sub-streams plus one constant.
    /// The fluctuation schedules follow the figure's x-axis: S1 rate
    /// 1→3→2, S2 rate 2→1→3, S3 constant 2.
    pub fn paper_fluctuating(seed: u64, phase_ticks: u64) -> Self {
        let s1 = FluctuatingSubstream::new(
            0,
            vec![(0, 1.0), (phase_ticks, 3.0), (2 * phase_ticks, 2.0)],
            ValueDist::Normal(10.0, 2.0),
            seed.wrapping_add(1),
        );
        let s2 = FluctuatingSubstream::new(
            1,
            vec![(0, 2.0), (phase_ticks, 1.0), (2 * phase_ticks, 3.0)],
            ValueDist::Normal(20.0, 2.0),
            seed.wrapping_add(2),
        );
        let s3 = PoissonSubstream::new(2, 2.0, ValueDist::Normal(30.0, 2.0), seed.wrapping_add(3));
        MultiStream::new(vec![Box::new(s1), Box::new(s2), Box::new(s3)])
    }

    /// Advance one tick; returns all records across sub-streams.
    pub fn tick(&mut self) -> Vec<Record> {
        let t = self.now;
        self.now += 1;
        let mut out = Vec::new();
        for sub in &mut self.subs {
            out.extend(sub.tick(t, &mut self.next_id));
        }
        out
    }

    /// Generate **at least** `n` records — the batch is rounded *up* to
    /// whole generator ticks, so `take_records(n).len() ≥ n` and usually
    /// strictly greater (with the §5 rates 3+4+5 the overshoot is up to
    /// ~a dozen records per call). Ticks are never split because records
    /// within one tick share a timestamp: splitting would let a later
    /// call emit records "before" ones already handed out. Callers
    /// sizing slides/windows off `n` must therefore treat `n` as a floor
    /// — e.g. the driver tests accept `2×slide..4×slide` deltas instead
    /// of exactly `2×slide` (pinned by `take_records_rounds_up_to_ticks`).
    pub fn take_records(&mut self, n: usize) -> Vec<Record> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            out.extend(self.tick());
        }
        out
    }

    /// Advance one tick, writing straight into `out` (no row vector).
    /// Stream-identical to [`MultiStream::tick`]: the sub-streams'
    /// `tick_into` impls draw their RNGs in the same order. Returns the
    /// number of records emitted.
    pub fn tick_into(&mut self, out: &mut ColumnarBuilder) -> usize {
        let t = self.now;
        self.now += 1;
        let mut emitted = 0;
        for sub in &mut self.subs {
            emitted += sub.tick_into(t, &mut self.next_id, out);
        }
        emitted
    }

    /// [`MultiStream::take_records`] emitting a [`ColumnarBatch`]
    /// natively: at least `n` records, rounded up to whole ticks, built
    /// column-wise without an intermediate row vector. Consuming the
    /// same stream through `take_columns` or `take_records` yields
    /// bit-identical records (pinned by `take_columns_matches_rows`).
    pub fn take_columns(&mut self, n: usize) -> ColumnarBatch {
        let mut out = ColumnarBuilder::with_capacity(n);
        while out.len() < n {
            self.tick_into(&mut out);
        }
        out.finish()
    }

    /// Number of sub-streams.
    pub fn substream_count(&self) -> usize {
        self.subs.len()
    }

    /// Current logical time.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Export the stream's full durable state (per-sub-stream structure +
    /// RNG, id cursor, clock) for a session checkpoint. Errors if any
    /// sub-stream does not support checkpointing (its
    /// [`Generator::spec`] returns `None`).
    pub fn checkpoint_spec(&self) -> crate::error::Result<MultiStreamSpec> {
        let mut subs = Vec::with_capacity(self.subs.len());
        for (i, sub) in self.subs.iter().enumerate() {
            match sub.spec() {
                Some(s) => subs.push(s),
                None => {
                    return Err(crate::error::Error::Checkpoint(format!(
                        "sub-stream {i} (stratum {}) is not checkpointable",
                        sub.stratum()
                    )))
                }
            }
        }
        Ok(MultiStreamSpec { subs, next_id: self.next_id, now: self.now })
    }

    /// Rebuild a stream mid-flight from a [`MultiStreamSpec`]: the
    /// restored stream emits exactly the records the checkpointed one
    /// would have emitted next.
    pub fn from_spec(spec: MultiStreamSpec) -> Self {
        let subs = spec
            .subs
            .into_iter()
            .map(|s| match s {
                SubstreamSpec::Poisson { stratum, rate, dist, rng } => {
                    let mut sub = PoissonSubstream::new(stratum, rate, dist, 0);
                    sub.rng = Rng::from_state(rng);
                    Box::new(sub) as Box<dyn Generator + Send>
                }
                SubstreamSpec::Fluctuating { stratum, schedule, dist, rng } => {
                    let mut sub = FluctuatingSubstream::new(stratum, schedule, dist, 0);
                    sub.rng = Rng::from_state(rng);
                    Box::new(sub) as Box<dyn Generator + Send>
                }
            })
            .collect();
        MultiStream { subs, next_id: spec.next_id, now: spec.now }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_substream_rate() {
        let mut s = PoissonSubstream::new(0, 4.0, ValueDist::Constant(1.0), 1);
        let mut next_id = 0;
        let n: usize = (0..20_000).map(|t| s.tick(t, &mut next_id).len()).sum();
        let mean = n as f64 / 20_000.0;
        assert!((mean - 4.0).abs() < 0.1, "mean {mean}");
        assert_eq!(next_id as usize, n);
    }

    #[test]
    fn take_columns_matches_rows() {
        // Row and columnar emission must draw RNGs identically: the same
        // seeded stream consumed either way yields bit-identical records.
        for seed in [3u64, 11, 29] {
            let mut rows = MultiStream::paper_section5(seed);
            let mut cols = MultiStream::paper_section5(seed);
            for n in [1usize, 64, 257] {
                let r = rows.take_records(n);
                let c = cols.take_columns(n);
                assert!(c.bit_eq_records(&r), "seed {seed} n {n} diverged");
                assert_eq!(rows.now(), cols.now());
            }
            let mut rows = MultiStream::paper_fluctuating(seed, 50);
            let mut cols = MultiStream::paper_fluctuating(seed, 50);
            let r = rows.take_records(300);
            let c = cols.take_columns(300);
            assert!(c.bit_eq_records(&r), "fluctuating seed {seed} diverged");
        }
    }

    #[test]
    fn take_records_rounds_up_to_ticks() {
        // The ≥ n gotcha, pinned: batches are whole ticks, so a request
        // for n records overshoots by up to one tick's worth — and never
        // undershoots or splits a tick across calls.
        let mut ms = MultiStream::paper_section5(11);
        for &n in &[1usize, 200, 2000] {
            let batch = ms.take_records(n);
            assert!(batch.len() >= n, "take_records({n}) returned {}", batch.len());
            // §5 rates 3+4+5 = 12/tick on average: the overshoot is
            // bounded by one tick, not proportional to n.
            assert!(
                batch.len() < n + 64,
                "overshoot must stay within ~one tick: {} for n={n}",
                batch.len()
            );
            // Whole ticks only: the last timestamp never continues into
            // the next call's first record (no tick is split).
            let last_ts = batch.last().unwrap().timestamp;
            let next = ms.take_records(1);
            assert!(
                next.first().unwrap().timestamp > last_ts,
                "tick split across calls: {} then {}",
                last_ts,
                next.first().unwrap().timestamp
            );
        }
    }

    #[test]
    fn ids_unique_and_monotone_across_substreams() {
        let mut ms = MultiStream::paper_section5(3);
        let recs = ms.take_records(5000);
        let mut ids: Vec<u64> = recs.iter().map(|r| r.id).collect();
        let orig = ids.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), orig.len());
    }

    #[test]
    fn section5_rates_are_3_4_5() {
        let mut ms = MultiStream::paper_section5(7);
        let recs = ms.take_records(60_000);
        let mut counts = [0usize; 3];
        for r in &recs {
            counts[r.stratum as usize] += 1;
        }
        let total: usize = counts.iter().sum();
        let props: Vec<f64> = counts.iter().map(|&c| c as f64 / total as f64).collect();
        for (got, want) in props.iter().zip([3.0 / 12.0, 4.0 / 12.0, 5.0 / 12.0]) {
            assert!((got - want).abs() < 0.02, "props {props:?}");
        }
    }

    #[test]
    fn fluctuating_schedule_changes_rate() {
        let s = FluctuatingSubstream::new(
            0,
            vec![(0, 1.0), (100, 3.0), (200, 2.0)],
            ValueDist::Constant(1.0),
            5,
        );
        assert_eq!(s.rate(0), 1.0);
        assert_eq!(s.rate(99), 1.0);
        assert_eq!(s.rate(100), 3.0);
        assert_eq!(s.rate(250), 2.0);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_schedule_panics() {
        FluctuatingSubstream::new(0, vec![(10, 1.0), (0, 2.0)], ValueDist::Constant(1.0), 1);
    }

    #[test]
    fn value_dist_means() {
        let mut rng = Rng::new(11);
        for dist in [
            ValueDist::Constant(5.0),
            ValueDist::Uniform(0.0, 10.0),
            ValueDist::Normal(7.0, 2.0),
            ValueDist::LogNormal(1.0, 0.5),
        ] {
            let n = 60_000;
            let mean: f64 = (0..n).map(|_| dist.sample(&mut rng)).sum::<f64>() / n as f64;
            assert!(
                (mean - dist.mean()).abs() < 0.05 * dist.mean().abs().max(1.0),
                "{dist:?}: mean {mean} want {}",
                dist.mean()
            );
        }
    }

    #[test]
    fn multistream_spec_roundtrip_continues_identically() {
        // Checkpoint both generator shapes mid-stream; the restored
        // stream must emit the exact same records as the original.
        for mut live in
            [MultiStream::paper_section5(7), MultiStream::paper_fluctuating(7, 50)]
        {
            live.take_records(1234);
            let spec = live.checkpoint_spec().unwrap();
            let mut restored = MultiStream::from_spec(spec);
            assert_eq!(restored.now(), live.now());
            for _ in 0..40 {
                let (a, b) = (live.tick(), restored.tick());
                assert_eq!(a.len(), b.len());
                for (ra, rb) in a.iter().zip(&b) {
                    assert_eq!(ra.id, rb.id);
                    assert_eq!(ra.stratum, rb.stratum);
                    assert_eq!(ra.timestamp, rb.timestamp);
                    assert_eq!(ra.key, rb.key);
                    assert_eq!(ra.value.to_bits(), rb.value.to_bits());
                }
            }
        }
    }

    #[test]
    fn fluctuating_multistream_has_three_strata() {
        let mut ms = MultiStream::paper_fluctuating(9, 100);
        let recs = ms.take_records(2000);
        let mut strata: Vec<u32> = recs.iter().map(|r| r.stratum).collect();
        strata.sort_unstable();
        strata.dedup();
        assert_eq!(strata, vec![0, 1, 2]);
    }
}
