//! Workloads: the record model, synthetic stream generators, and traces.
//!
//! §5 of the paper evaluates on *simulated* streams (Poisson sub-streams
//! with rates 3:4:5, fluctuating-rate variants); its case studies are
//! network monitoring and Twitter analytics. This module provides all of
//! them: [`PoissonSubstream`] / [`FluctuatingSubstream`] generators
//! matching §5, plus flow-log and tweet-like synthetic case-study streams,
//! and record/replay of traces for reproducible benchmarking.

pub mod flows;
pub mod gen;
pub mod record;
pub mod trace;
pub mod tweets;

pub use flows::FlowLogGen;
pub use gen::{
    FluctuatingSubstream, Generator, MultiStream, MultiStreamSpec, PoissonSubstream,
    SubstreamSpec, ValueDist,
};
pub use record::{Record, StratumId};
pub use trace::{read_trace, write_trace, TraceReplay};
pub use tweets::TweetGen;
