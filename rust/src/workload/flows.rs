//! Synthetic network-monitoring stream (the paper's first case study).
//!
//! Models a flow-log feed: each record is one flow observation; the
//! stratum is the monitored subnet (sub-stream source), the key is a
//! hashed 5-tuple, and the value is the flow's byte count — heavy-tailed
//! log-normal, the classic elephant/mice mix. A windowed SUM over values
//! is "bytes per window per subnet", the real-time traffic aggregate the
//! case study monitors.

use crate::util::rng::Rng;
use crate::workload::gen::{Generator, MultiStream, ValueDist};
use crate::workload::record::{Record, StratumId};

/// One subnet's flow generator.
pub struct FlowLogGen {
    stratum: StratumId,
    rate: f64,
    bytes: ValueDist,
    rng: Rng,
    /// Number of distinct active flows (keys) in this subnet.
    flow_population: u64,
}

impl FlowLogGen {
    /// A subnet emitting `rate` flow records per tick.
    pub fn new(stratum: StratumId, rate: f64, seed: u64) -> Self {
        FlowLogGen {
            stratum,
            rate,
            // exp(N(6.2, 1.3)) bytes ≈ median 500 B, long tail to MBs.
            bytes: ValueDist::LogNormal(6.2, 1.3),
            rng: Rng::new(seed),
            flow_population: 4096,
        }
    }

    /// Build the full case-study stream: `subnets` sub-streams with
    /// heterogeneous rates (1, 2, …).
    pub fn case_study(subnets: usize, seed: u64) -> MultiStream {
        let subs = (0..subnets)
            .map(|i| {
                Box::new(FlowLogGen::new(
                    i as StratumId,
                    (i + 1) as f64,
                    seed.wrapping_add(100 + i as u64),
                )) as Box<dyn Generator + Send>
            })
            .collect();
        MultiStream::new(subs)
    }
}

impl Generator for FlowLogGen {
    fn tick(&mut self, t: u64, next_id: &mut u64) -> Vec<Record> {
        let n = self.rng.poisson(self.rate);
        (0..n)
            .map(|_| {
                let id = *next_id;
                *next_id += 1;
                let key = self.rng.next_u64() % self.flow_population;
                Record::new(id, self.stratum, t, key, self.bytes.sample(&mut self.rng))
            })
            .collect()
    }

    fn stratum(&self) -> StratumId {
        self.stratum
    }

    fn rate(&self, _t: u64) -> f64 {
        self.rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_positive_and_heavy_tailed() {
        let mut g = FlowLogGen::new(0, 5.0, 1);
        let mut next_id = 0;
        let mut values = Vec::new();
        for t in 0..5000 {
            values.extend(g.tick(t, &mut next_id).into_iter().map(|r| r.value));
        }
        assert!(values.iter().all(|&v| v > 0.0));
        let mean = values.iter().sum::<f64>() / values.len() as f64;
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        // Log-normal: mean well above median.
        assert!(mean > 1.5 * median, "mean {mean} median {median}");
    }

    #[test]
    fn case_study_strata_and_rates() {
        let mut ms = FlowLogGen::case_study(4, 2);
        let recs = ms.take_records(40_000);
        let mut counts = [0usize; 4];
        for r in &recs {
            counts[r.stratum as usize] += 1;
        }
        // Rates 1:2:3:4.
        for i in 1..4 {
            assert!(
                counts[i] > counts[i - 1],
                "counts not increasing: {counts:?}"
            );
        }
    }
}
