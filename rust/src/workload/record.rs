//! The pipeline's data model.

/// Identifier of a stratum (one sub-stream, §2.3.3 assumption 1).
pub type StratumId = u32;

/// One streaming data item.
///
/// `id` is globally unique and stable — it is what memoization keys and
/// chunk content hashes are built from, so re-observing the same item in
/// the next window's overlap region produces the same hashes (the whole
/// point of the marriage).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record {
    /// Globally unique, monotonically assigned item id.
    pub id: u64,
    /// Sub-stream / stratum label (source of event).
    pub stratum: StratumId,
    /// Event time in logical ticks.
    pub timestamp: u64,
    /// Grouping key for keyed aggregations (e.g. hashtag, flow 5-tuple).
    pub key: u64,
    /// The measure being aggregated (bytes, engagement, latency, …).
    pub value: f64,
}

impl Record {
    /// Convenience constructor.
    pub fn new(id: u64, stratum: StratumId, timestamp: u64, key: u64, value: f64) -> Self {
        Record { id, stratum, timestamp, key, value }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction() {
        let r = Record::new(1, 2, 3, 4, 5.0);
        assert_eq!((r.id, r.stratum, r.timestamp, r.key), (1, 2, 3, 4));
        assert_eq!(r.value, 5.0);
    }
}
