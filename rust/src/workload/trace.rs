//! Trace recording and replay.
//!
//! Benchmarks must compare execution modes on *identical* inputs, so a
//! generated stream can be flushed to a TSV trace and replayed. The format
//! is one record per line: `id \t stratum \t timestamp \t key \t value`.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::workload::record::Record;

/// Write records to a TSV trace file.
pub fn write_trace(path: impl AsRef<Path>, records: &[Record]) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for r in records {
        writeln!(w, "{}\t{}\t{}\t{}\t{}", r.id, r.stratum, r.timestamp, r.key, r.value)?;
    }
    w.flush()?;
    Ok(())
}

/// Read a TSV trace file back.
pub fn read_trace(path: impl AsRef<Path>) -> Result<Vec<Record>> {
    let file = std::fs::File::open(path)?;
    let reader = BufReader::new(file);
    let mut out = Vec::new();
    for (idx, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split('\t');
        let parse_err =
            |what: &str| Error::Config(format!("trace line {}: bad {what}", idx + 1));
        let id = parts.next().and_then(|s| s.parse().ok()).ok_or_else(|| parse_err("id"))?;
        let stratum =
            parts.next().and_then(|s| s.parse().ok()).ok_or_else(|| parse_err("stratum"))?;
        let timestamp =
            parts.next().and_then(|s| s.parse().ok()).ok_or_else(|| parse_err("timestamp"))?;
        let key = parts.next().and_then(|s| s.parse().ok()).ok_or_else(|| parse_err("key"))?;
        let value =
            parts.next().and_then(|s| s.parse().ok()).ok_or_else(|| parse_err("value"))?;
        out.push(Record { id, stratum, timestamp, key, value });
    }
    Ok(out)
}

/// Replay a recorded trace tick by tick (records grouped by timestamp).
pub struct TraceReplay {
    records: Vec<Record>,
    pos: usize,
    now: u64,
}

impl TraceReplay {
    /// Wrap an in-memory trace (must be sorted by timestamp).
    pub fn new(mut records: Vec<Record>) -> Self {
        records.sort_by_key(|r| (r.timestamp, r.id));
        TraceReplay { records, pos: 0, now: 0 }
    }

    /// Load from file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        Ok(Self::new(read_trace(path)?))
    }

    /// All records with `timestamp == now`, advancing the clock.
    pub fn tick(&mut self) -> Vec<Record> {
        let t = self.now;
        self.now += 1;
        let start = self.pos;
        while self.pos < self.records.len() && self.records[self.pos].timestamp == t {
            self.pos += 1;
        }
        self.records[start..self.pos].to_vec()
    }

    /// True when fully replayed.
    pub fn exhausted(&self) -> bool {
        self.pos >= self.records.len()
    }

    /// Total record count.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True if the trace is empty.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::MultiStream;

    #[test]
    fn roundtrip_preserves_records() {
        let mut ms = MultiStream::paper_section5(4);
        let recs = ms.take_records(1000);
        let dir = std::env::temp_dir().join("incapprox_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tsv");
        write_trace(&path, &recs).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(recs.len(), back.len());
        for (a, b) in recs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.stratum, b.stratum);
            assert_eq!(a.timestamp, b.timestamp);
            assert_eq!(a.key, b.key);
            assert!((a.value - b.value).abs() < 1e-9 * a.value.abs().max(1.0));
        }
    }

    #[test]
    fn replay_groups_by_tick() {
        let recs = vec![
            Record::new(0, 0, 0, 0, 1.0),
            Record::new(1, 0, 0, 0, 2.0),
            Record::new(2, 0, 2, 0, 3.0),
        ];
        let mut replay = TraceReplay::new(recs);
        assert_eq!(replay.tick().len(), 2);
        assert_eq!(replay.tick().len(), 0); // tick 1 empty
        assert_eq!(replay.tick().len(), 1);
        assert!(replay.exhausted());
    }

    #[test]
    fn read_rejects_garbage() {
        let dir = std::env::temp_dir().join("incapprox_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tsv");
        std::fs::write(&path, "1\t2\tnot_a_number\t4\t5.0\n").unwrap();
        assert!(read_trace(&path).is_err());
    }

    #[test]
    fn read_skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("incapprox_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.tsv");
        std::fs::write(&path, "# header\n\n1\t0\t0\t0\t1.5\n").unwrap();
        let recs = read_trace(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].value, 1.5);
    }
}
