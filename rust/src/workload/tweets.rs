//! Synthetic Twitter-like stream (the paper's second case study).
//!
//! Models tweet events from user classes with very different volumes and
//! engagement distributions — the strata: 0 = celebrity accounts (rare,
//! huge engagement), 1 = active users, 2 = long tail. The key is a
//! hashtag id (Zipf-ish via squared uniform); the value is an engagement
//! score. A windowed SUM per window ≈ "trending volume", the case study's
//! real-time analytics query.

use crate::util::rng::Rng;
use crate::workload::gen::{Generator, MultiStream, ValueDist};
use crate::workload::record::{Record, StratumId};

/// One user-class tweet generator.
pub struct TweetGen {
    stratum: StratumId,
    rate: f64,
    engagement: ValueDist,
    hashtags: u64,
    rng: Rng,
}

impl TweetGen {
    /// A user class emitting `rate` tweets per tick.
    pub fn new(stratum: StratumId, rate: f64, engagement: ValueDist, seed: u64) -> Self {
        TweetGen { stratum, rate, engagement, hashtags: 512, rng: Rng::new(seed) }
    }

    /// Full case-study stream: celebrity / active / long-tail classes.
    pub fn case_study(seed: u64) -> MultiStream {
        let subs: Vec<Box<dyn Generator + Send>> = vec![
            Box::new(TweetGen::new(
                0,
                0.5,
                ValueDist::LogNormal(5.0, 1.0),
                seed.wrapping_add(201),
            )),
            Box::new(TweetGen::new(
                1,
                4.0,
                ValueDist::LogNormal(2.0, 0.8),
                seed.wrapping_add(202),
            )),
            Box::new(TweetGen::new(
                2,
                8.0,
                ValueDist::LogNormal(0.5, 0.6),
                seed.wrapping_add(203),
            )),
        ];
        MultiStream::new(subs)
    }
}

impl Generator for TweetGen {
    fn tick(&mut self, t: u64, next_id: &mut u64) -> Vec<Record> {
        let n = self.rng.poisson(self.rate);
        (0..n)
            .map(|_| {
                let id = *next_id;
                *next_id += 1;
                // Squared uniform skews toward low hashtag ids (popular tags).
                let u = self.rng.f64();
                let key = ((u * u) * self.hashtags as f64) as u64;
                Record::new(id, self.stratum, t, key, self.engagement.sample(&mut self.rng))
            })
            .collect()
    }

    fn stratum(&self) -> StratumId {
        self.stratum
    }

    fn rate(&self, _t: u64) -> f64 {
        self.rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_study_minority_stratum_present() {
        let mut ms = TweetGen::case_study(5);
        let recs = ms.take_records(20_000);
        let mut counts = [0usize; 3];
        for r in &recs {
            counts[r.stratum as usize] += 1;
        }
        // Celebrities are a true minority but never zero — this is the
        // stratification guarantee the paper's sampling must preserve.
        assert!(counts[0] > 0);
        assert!(counts[0] < counts[1] && counts[1] < counts[2], "{counts:?}");
    }

    #[test]
    fn hashtags_skewed_to_popular() {
        let mut g = TweetGen::new(0, 8.0, ValueDist::Constant(1.0), 9);
        let mut next_id = 0;
        let mut low = 0usize;
        let mut total = 0usize;
        for t in 0..2000 {
            for r in g.tick(t, &mut next_id) {
                total += 1;
                if r.key < 128 {
                    low += 1;
                }
            }
        }
        // 128/512 = 25% of the key space should receive ~50% of tweets.
        assert!(low as f64 / total as f64 > 0.4, "{low}/{total}");
    }
}
